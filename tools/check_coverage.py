"""Coverage floor gate: fail CI when line coverage of a source subtree
drops below a floor.

Reads a Cobertura ``coverage.xml`` (what ``pytest --cov --cov-report=
xml`` emits), aggregates line hits over every file whose path starts
with ``--path``, and exits non-zero below ``--floor``.  Used by CI to
hold ``src/repro/serve/`` at its pre-prefix-cache coverage so the new
allocator / trie / COW paths cannot land untested.

Usage::

    pytest --cov=repro --cov-report=xml
    python tools/check_coverage.py --xml coverage.xml \
        --path src/repro/serve --floor 0.85

The floor can also come from the ``COVERAGE_FLOOR`` environment
variable.  Exit codes: 0 ok, 1 below floor, 2 operational error
(missing file / no matching sources).
"""
from __future__ import annotations

import argparse
import os
import sys
import xml.etree.ElementTree as ET
from pathlib import Path
from typing import Tuple


def subtree_coverage(xml_path: Path, prefix: str) -> Tuple[int, int]:
    """(covered, valid) line counts over files under ``prefix``.

    Cobertura <class filename=...> entries are relative to one of the
    report's <source> roots; match on the filename joined with each
    source root as well as bare, so both absolute-ish and package-
    relative layouts work.
    """
    root = ET.parse(xml_path).getroot()
    sources = [s.text or "" for s in root.iter("source")]
    prefix = prefix.rstrip("/")
    covered = valid = 0

    def under(c: str) -> bool:
        # segment-anchored: the prefix must be a whole path-segment run
        # ("src/repro/serve" never matches "mysrc/repro/serve2/x.py")
        c = c.replace("\\", "/")
        return (c == prefix or c.startswith(prefix + "/")
                or f"/{prefix}/" in c)

    for cls in root.iter("class"):
        fname = cls.get("filename", "")
        candidates = [fname] + [str(Path(s) / fname) for s in sources]
        if not any(under(c) for c in candidates):
            continue
        for line in cls.iter("line"):
            valid += 1
            if int(line.get("hits", "0")) > 0:
                covered += 1
    return covered, valid


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--xml", type=Path, default=Path("coverage.xml"))
    ap.add_argument("--path", default="src/repro/serve",
                    help="source subtree the floor applies to")
    ap.add_argument("--floor", type=float, default=float(
        os.environ.get("COVERAGE_FLOOR", "0.85")),
        help="minimum line-coverage fraction (default 0.85)")
    args = ap.parse_args(argv)

    if not args.xml.exists():
        print(f"coverage gate: {args.xml} not found (run pytest with "
              "--cov=repro --cov-report=xml first)")
        return 2
    covered, valid = subtree_coverage(args.xml, args.path)
    if valid == 0:
        print(f"coverage gate: no lines under '{args.path}' in "
              f"{args.xml} — path filter or report layout drifted")
        return 2
    rate = covered / valid
    status = "OK" if rate >= args.floor else "BELOW FLOOR"
    print(f"coverage gate [{args.path}]: {covered}/{valid} lines = "
          f"{rate:.1%} (floor {args.floor:.0%}) — {status}")
    return 0 if rate >= args.floor else 1


if __name__ == "__main__":
    sys.exit(main())
