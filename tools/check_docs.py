"""Docs gate: keep DESIGN.md and the source docstrings honest.

Three checks, all cheap enough for the CI lint job:

1. **Citations resolve.**  Every ``DESIGN.md §N`` (or bare ``§N``)
   reference in a source docstring under the audited trees must name a
   section that actually exists as a ``## §N ...`` header in DESIGN.md
   — a renumbered or deleted section fails the build instead of
   leaving dangling citations.
2. **Modules cite.**  Every module under ``src/repro/serve/`` and
   ``src/repro/kernels/`` must open with a module docstring containing
   at least one ``§N`` citation, so new code cannot land without
   saying which design section it implements.
3. **The table of contents matches.**  DESIGN.md's ``## Contents``
   list must enumerate exactly the ``## §N ...`` headers present, in
   order — the index at the top cannot silently drift from the body.

Usage::

    python tools/check_docs.py [--design DESIGN.md] [--root src/repro]

Exit codes: 0 ok, 1 violations found, 2 operational error (missing
DESIGN.md / unparseable source).
"""
from __future__ import annotations

import argparse
import ast
import re
import sys
from pathlib import Path
from typing import Dict, List

# trees whose modules must carry a citing docstring
AUDITED = ("serve", "kernels")

_SECTION = re.compile(r"^##\s+§(\d+)\s+(.*)$", re.MULTILINE)
_TOC_ENTRY = re.compile(r"^-\s+§(\d+)\s+(.*)$", re.MULTILINE)
_CITATION = re.compile(r"§(\d+)")


def design_sections(design: Path) -> Dict[int, str]:
    """{section number: title} for every ``## §N ...`` header."""
    return {int(n): t.strip()
            for n, t in _SECTION.findall(design.read_text())}


def toc_entries(design: Path) -> List[tuple]:
    """[(number, title)] from the ``## Contents`` block, in order."""
    text = design.read_text()
    m = re.search(r"^## Contents\n(.*?)(?=^## )", text,
                  re.MULTILINE | re.DOTALL)
    if m is None:
        return []
    return [(int(n), t.strip()) for n, t in _TOC_ENTRY.findall(m.group(1))]


def module_docstring(path: Path) -> str:
    return ast.get_docstring(ast.parse(path.read_text())) or ""


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--design", type=Path, default=Path("DESIGN.md"))
    ap.add_argument("--root", type=Path, default=Path("src/repro"),
                    help="package root holding the audited trees")
    args = ap.parse_args(argv)

    if not args.design.exists():
        print(f"docs gate: {args.design} not found")
        return 2
    sections = design_sections(args.design)
    if not sections:
        print(f"docs gate: no '## §N' section headers in {args.design}")
        return 2

    errors: List[str] = []

    # -- check 3: TOC vs actual headers -------------------------------
    toc = toc_entries(args.design)
    want = sorted(sections.items())
    if not toc:
        errors.append(f"{args.design}: no '## Contents' list found")
    elif toc != want:
        errors.append(
            f"{args.design}: Contents list does not match the section "
            f"headers — listed {toc}, headers {want}")

    # -- checks 1 + 2: source docstrings ------------------------------
    audited_files = []
    for tree in AUDITED:
        root = args.root / tree
        if not root.is_dir():
            print(f"docs gate: audited tree {root} missing")
            return 2
        audited_files += sorted(root.rglob("*.py"))
    for path in audited_files:
        try:
            doc = module_docstring(path)
        except SyntaxError as e:
            print(f"docs gate: cannot parse {path}: {e}")
            return 2
        cites = sorted({int(n) for n in _CITATION.findall(doc)})
        if not doc.strip():
            errors.append(f"{path}: missing module docstring")
        elif not cites:
            errors.append(f"{path}: module docstring cites no "
                          "DESIGN.md section (add e.g. 'DESIGN.md §6')")
        for n in cites:
            if n not in sections:
                errors.append(
                    f"{path}: docstring cites DESIGN.md §{n}, which "
                    "has no matching '## §{0}' header".format(n))

    if errors:
        print(f"docs gate: {len(errors)} violation(s)")
        for e in errors:
            print(f"  - {e}")
        return 1
    n_cites = len(audited_files)
    print(f"docs gate: OK — {len(sections)} DESIGN.md sections, "
          f"{n_cites} audited modules, all citations resolve, "
          "Contents in sync")
    return 0


if __name__ == "__main__":
    sys.exit(main())
