"""Serving engine: continuous batching correctness."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import clover_decompose, clover_prune
from repro.models import init_lm_params
from repro.serve import Engine, EngineConfig, Request, greedy_reference

_greedy_reference = greedy_reference


def test_engine_matches_reference_greedy():
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    prompt = np.arange(4, dtype=np.int32) + 7
    eng = Engine(params, cfg, EngineConfig(slots=2, max_len=32))
    out = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=5)])
    assert out[0].generated == _greedy_reference(params, cfg, prompt, 5)


def test_engine_mixed_lengths_interleaved():
    """Requests with different prompt lengths and arrival order must each
    match their isolated reference — per-slot positions really work."""
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(1))
    prompts = [np.arange(3, dtype=np.int32) + 2,
               np.arange(7, dtype=np.int32) + 11,
               np.arange(5, dtype=np.int32) + 23,
               np.arange(2, dtype=np.int32) + 31]
    reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    eng = Engine(params, cfg, EngineConfig(slots=2, max_len=32))
    eng.run(reqs)
    for r, p in zip(reqs, prompts):
        assert r.done
        assert r.generated == _greedy_reference(params, cfg, p, 4), r.uid


def test_engine_rwkv_state_isolation():
    """Recurrent-state archs: a slot reused for a second request must not
    leak the first request's state."""
    cfg = get_config("rwkv6-1.6b").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(2))
    p1 = np.arange(6, dtype=np.int32) + 3
    p2 = np.arange(4, dtype=np.int32) + 40
    eng = Engine(params, cfg, EngineConfig(slots=1, max_len=32))
    reqs = [Request(uid=0, prompt=p1, max_new_tokens=3),
            Request(uid=1, prompt=p2, max_new_tokens=3)]
    eng.run(reqs)
    assert reqs[1].generated == _greedy_reference(params, cfg, p2, 3)


def test_engine_on_clover_pruned_model():
    """The paper's serving story: engine over a pruned (smaller-KV) model."""
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(3))
    dp, dcfg, _ = clover_decompose(params, cfg, peft=False)
    pp, pcfg = clover_prune(dp, dcfg, qk_ratio=0.5, vo_ratio=0.5)
    eng = Engine(pp, pcfg, EngineConfig(slots=2, max_len=32))
    # KV cache really is at the pruned rank
    k = eng.state["blocks"][0]["kv"]["k"]
    assert k.shape[-1] == pcfg.clover.qk_rank < cfg.head_dim_
    prompt = np.arange(4, dtype=np.int32) + 5
    out = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=4)])
    assert out[0].generated == _greedy_reference(pp, pcfg, prompt, 4)


def test_engine_capacity_guard():
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(params, cfg, EngineConfig(slots=1, max_len=8))
    with pytest.raises(ValueError, match="max_len"):
        eng.run([Request(uid=0, prompt=np.arange(6, dtype=np.int32),
                         max_new_tokens=6)])


# ---------------------------------------------------------------------------
# chunked-prefill scheduler
# ---------------------------------------------------------------------------

def test_chunked_prefill_matches_whole_prompt():
    """Multi-chunk prefill (prompt >> chunk) emits the same greedy tokens
    as the whole-prompt reference — chunking is exact, not approximate."""
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(4))
    prompt = (np.arange(11, dtype=np.int32) * 3 + 1) % cfg.vocab_size
    eng = Engine(params, cfg, EngineConfig(slots=2, max_len=32,
                                           prefill_chunk=4))
    out = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=5)])
    assert out[0].generated == _greedy_reference(params, cfg, prompt, 5)


def test_exactly_two_compiled_shapes():
    """Mixed prompt lengths + multi-chunk prompts compile exactly two
    step shapes (chunk, decode) — no per-length jit cache."""
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(5))
    prompts = [np.arange(n, dtype=np.int32) + 2 for n in (2, 5, 9, 13, 3)]
    eng = Engine(params, cfg, EngineConfig(slots=2, max_len=32,
                                           prefill_chunk=4))
    reqs = [Request(uid=i, prompt=p, max_new_tokens=3)
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    assert eng.compiled_shapes() in (2, None)   # None: no jit introspection
    for r, p in zip(reqs, prompts):
        assert r.generated == _greedy_reference(params, cfg, p, 3), r.uid


def test_decode_interleaves_with_prefill():
    """While one slot chunks a long prompt, an already-decoding slot
    keeps emitting (rides the chunk step with length 1) — admission
    never stalls generation on attention-only archs."""
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(6))
    short = np.arange(3, dtype=np.int32) + 2
    long = np.arange(16, dtype=np.int32) + 5
    eng = Engine(params, cfg, EngineConfig(slots=2, max_len=40,
                                           prefill_chunk=4))
    r_short = Request(uid=0, prompt=short, max_new_tokens=8)
    eng.submit(r_short)
    eng.step()                      # short prompt prefilled, 1st token out
    n_before = len(r_short.generated)
    r_long = Request(uid=1, prompt=long, max_new_tokens=4)
    eng.submit(r_long)
    eng.step()                      # long prompt chunk 1 of 4 ...
    eng.step()                      # ... chunk 2: short slot must advance
    assert len(r_short.generated) == n_before + 2
    eng.run([])                     # drain
    assert r_short.generated == _greedy_reference(params, cfg, short, 8)
    assert r_long.generated == _greedy_reference(params, cfg, long, 4)


def test_eos_mid_chunk_retires_and_frees_slot():
    """A stream hitting eos while another slot is mid-prefill retires
    immediately; its slot is reused by the queued request."""
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(7))
    p1 = np.arange(4, dtype=np.int32) + 3
    ref1 = _greedy_reference(params, cfg, p1, 8)
    eos = ref1[2]                   # retire after 3 generated tokens
    p_long = np.arange(14, dtype=np.int32) + 8
    p3 = np.arange(5, dtype=np.int32) + 17
    ecfg = EngineConfig(slots=2, max_len=40, eos_id=eos, prefill_chunk=4)
    eng = Engine(params, cfg, ecfg)
    reqs = [Request(uid=0, prompt=p1, max_new_tokens=8),
            Request(uid=1, prompt=p_long, max_new_tokens=4),
            Request(uid=2, prompt=p3, max_new_tokens=4)]
    eng.run(reqs)
    assert reqs[0].done
    assert len(reqs[0].generated) <= 3      # retired early, slot freed
    for r, p in ((reqs[0], p1), (reqs[1], p_long), (reqs[2], p3)):
        assert r.done
        want = _greedy_reference(params, cfg, p, r.max_new_tokens)
        stop = want.index(eos) + 1 if eos in want else len(want)
        assert r.generated == want[:stop], r.uid


def test_queue_pressure_more_requests_than_slots():
    """8 requests through 2 slots: FIFO admission, every stream exact."""
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(8))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size,
                            int(rng.integers(2, 12))).astype(np.int32)
               for _ in range(8)]
    eng = Engine(params, cfg, EngineConfig(slots=2, max_len=32,
                                           prefill_chunk=4))
    reqs = [Request(uid=i, prompt=p, max_new_tokens=3)
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    assert eng.compiled_shapes() in (2, None)   # None: no jit introspection
    for r, p in zip(reqs, prompts):
        assert r.done
        assert r.generated == _greedy_reference(params, cfg, p, 3), r.uid


def test_recurrent_arch_multi_chunk_prompt():
    """rwkv: a prompt longer than one chunk exercises full-window chunks
    plus the TAIL (token-by-token) remainder — recurrent state must
    survive the handoff exactly."""
    cfg = get_config("rwkv6-1.6b").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(9))
    p1 = np.arange(11, dtype=np.int32) + 3   # 2 full chunks + 3 tail
    p2 = np.arange(5, dtype=np.int32) + 40   # tail-only prompt
    eng = Engine(params, cfg, EngineConfig(slots=2, max_len=32,
                                           prefill_chunk=4))
    reqs = [Request(uid=0, prompt=p1, max_new_tokens=3),
            Request(uid=1, prompt=p2, max_new_tokens=3)]
    eng.run(reqs)
    assert reqs[0].generated == _greedy_reference(params, cfg, p1, 3)
    assert reqs[1].generated == _greedy_reference(params, cfg, p2, 3)


# ---------------------------------------------------------------------------
# multi-tenant SV-adapter serving (DESIGN.md §13)
# ---------------------------------------------------------------------------

def _adapter_setup():
    import jax.numpy as jnp
    from repro.core import AdapterRegistry
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(4))
    dp, dcfg, _ = clover_decompose(params, cfg, peft=True)
    reg = AdapterRegistry(dp)
    rng = np.random.default_rng(7)
    reg.register(tuple(
        {k: jnp.asarray(rng.uniform(0.7, 1.3, np.shape(v)), jnp.float32)
         for k, v in entry.items()} for entry in reg.get(0)))
    return dp, dcfg, reg


def test_adapter_identity_is_bitwise_base_model():
    """An engine with a registry, serving only adapter 0, must emit
    token-identical streams to an engine with no registry at all
    (x * 1.0 == x), and report per-adapter counters."""
    dp, dcfg, reg = _adapter_setup()
    prompts = [np.arange(4, dtype=np.int32) + 3 + 5 * i for i in range(3)]
    ecfg = EngineConfig(slots=2, max_len=32, prefill_chunk=4)
    plain = Engine(dp, dcfg, ecfg)
    base = [r.generated for r in plain.run(
        [Request(uid=i, prompt=p, max_new_tokens=4)
         for i, p in enumerate(prompts)])]
    eng = Engine(dp, dcfg, ecfg, adapters=reg)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=4, adapter_id=0)
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    assert [r.generated for r in reqs] == base
    st = eng.stats()
    assert st["adapter_done"] == {0: 3}
    assert st["adapter_tokens"] == {0: 12}
    assert "adapter_done" not in plain.stats()


def test_adapter_stream_matches_folded_model():
    """A tenant's stream equals the single-tenant replay on the model
    with its adapter folded into the s_qk/s_vo diagonals — even when
    tenants share slots in one batch."""
    dp, dcfg, reg = _adapter_setup()
    folded = reg.folded(dp, 1)
    prompts = [np.arange(5, dtype=np.int32) + 11 * (1 + i) for i in range(2)]
    want = {0: _greedy_reference(dp, dcfg, prompts[0], 5),
            1: _greedy_reference(folded, dcfg, prompts[1], 5)}
    eng = Engine(dp, dcfg, EngineConfig(slots=2, max_len=32,
                                        prefill_chunk=4), adapters=reg)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=5, adapter_id=i)
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    for r in reqs:
        assert r.generated == want[r.adapter_id], r.uid
    assert want[0] != want[1]      # the adapter really changed the stream


def test_adapter_submit_validation():
    dp, dcfg, reg = _adapter_setup()
    with pytest.raises(ValueError):
        Request(uid=0, prompt=np.arange(3, dtype=np.int32), max_new_tokens=2,
                adapter_id=-1)
    eng = Engine(dp, dcfg, EngineConfig(slots=1, max_len=16), adapters=reg)
    with pytest.raises(ValueError, match="adapter"):
        eng.submit(Request(uid=0, prompt=np.arange(3, dtype=np.int32),
                           max_new_tokens=2, adapter_id=5))
    # without a registry only the identity id is accepted
    plain = Engine(dp, dcfg, EngineConfig(slots=1, max_len=16))
    with pytest.raises(ValueError, match="adapter"):
        plain.submit(Request(uid=1, prompt=np.arange(3, dtype=np.int32),
                             max_new_tokens=2, adapter_id=1))
    # an executor cannot be combined with a registry after the fact
    with pytest.raises(ValueError, match="executor"):
        Engine(dp, dcfg, EngineConfig(slots=1, max_len=16), adapters=reg,
               executor=plain.exe)
