"""Serving engine: continuous batching correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import clover_decompose, clover_prune
from repro.models import init_lm_params, forward
from repro.serve import Engine, EngineConfig, Request


def _greedy_reference(params, cfg, prompt, n):
    seq = list(prompt)
    gen = []
    for _ in range(n):
        logits, _ = forward(params, cfg, jnp.asarray(seq)[None, :])
        t = int(jnp.argmax(logits[0, -1]))
        gen.append(t)
        seq.append(t)
    return gen


def test_engine_matches_reference_greedy():
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    prompt = np.arange(4, dtype=np.int32) + 7
    eng = Engine(params, cfg, EngineConfig(slots=2, max_len=32))
    out = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=5)])
    assert out[0].generated == _greedy_reference(params, cfg, prompt, 5)


def test_engine_mixed_lengths_interleaved():
    """Requests with different prompt lengths and arrival order must each
    match their isolated reference — per-slot positions really work."""
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(1))
    prompts = [np.arange(3, dtype=np.int32) + 2,
               np.arange(7, dtype=np.int32) + 11,
               np.arange(5, dtype=np.int32) + 23,
               np.arange(2, dtype=np.int32) + 31]
    reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    eng = Engine(params, cfg, EngineConfig(slots=2, max_len=32))
    eng.run(reqs)
    for r, p in zip(reqs, prompts):
        assert r.done
        assert r.generated == _greedy_reference(params, cfg, p, 4), r.uid


def test_engine_rwkv_state_isolation():
    """Recurrent-state archs: a slot reused for a second request must not
    leak the first request's state."""
    cfg = get_config("rwkv6-1.6b").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(2))
    p1 = np.arange(6, dtype=np.int32) + 3
    p2 = np.arange(4, dtype=np.int32) + 40
    eng = Engine(params, cfg, EngineConfig(slots=1, max_len=32))
    reqs = [Request(uid=0, prompt=p1, max_new_tokens=3),
            Request(uid=1, prompt=p2, max_new_tokens=3)]
    eng.run(reqs)
    assert reqs[1].generated == _greedy_reference(params, cfg, p2, 3)


def test_engine_on_clover_pruned_model():
    """The paper's serving story: engine over a pruned (smaller-KV) model."""
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(3))
    dp, dcfg, _ = clover_decompose(params, cfg, peft=False)
    pp, pcfg = clover_prune(dp, dcfg, qk_ratio=0.5, vo_ratio=0.5)
    eng = Engine(pp, pcfg, EngineConfig(slots=2, max_len=32))
    # KV cache really is at the pruned rank
    k = eng.state["blocks"][0]["kv"]["k"]
    assert k.shape[-1] == pcfg.clover.qk_rank < cfg.head_dim_
    prompt = np.arange(4, dtype=np.int32) + 5
    out = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=4)])
    assert out[0].generated == _greedy_reference(pp, pcfg, prompt, 4)


def test_engine_capacity_guard():
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(params, cfg, EngineConfig(slots=1, max_len=8))
    with pytest.raises(AssertionError):
        eng.run([Request(uid=0, prompt=np.arange(6, dtype=np.int32),
                         max_new_tokens=6)])
