"""Shared host-side lifecycle model for the paged pool + prefix trie.

Drives the REAL ``PageAllocator`` + ``PrefixCache`` through the same
sequence lifecycle serve.engine runs (admission maps trie hits
read-only and resumes past them; writes COW shared pages; prefill
completion, preemption and retirement publish full-page runs), and
checks the allocator invariants after every operation.

Two drivers share it so the invariants are exercised both with and
without hypothesis installed:
  * tests/test_property.py — a hypothesis ``RuleBasedStateMachine``
    (shrinking, CI ``ci`` profile with >= 200 examples);
  * tests/test_prefix_cache.py — a seeded numpy random walk that runs
    on minimal installs too.
"""
import numpy as np

from repro.serve.memory import HostTier, PageAllocator, PrefixCache


class PoolLifecycle:
    """One pool + trie + per-slot sequence models, with invariants.

    With ``host_pages > 0`` the model also attaches a ``HostTier``
    under the trie (DESIGN.md §12): eviction spills each dropped page's
    content host-side before freeing it, and admission restores
    host-tier hits onto the slot's fresh pages exactly the way
    ``Engine._restore_pages`` does — so the state machines exercise the
    spill/restore transitions against the same allocator invariants.
    Page "content" here is the token slice the page committed (tracked
    in ``page_content``), which lets restore assert the hash-keyed slab
    is byte-for-byte the content that prefix produced earlier."""

    def __init__(self, n_pages=12, page_tokens=4, slots=3, table_pages=10,
                 host_pages=0):
        self.n_pages, self.pt = n_pages, page_tokens
        self.slots, self.table = slots, table_pages
        self.alloc = PageAllocator(n_pages, page_tokens, slots, table_pages)
        self.prefix = PrefixCache(self.alloc, salt=("model",))
        self.host = None
        self.page_content = {}
        if host_pages > 0:
            self.host = HostTier(host_pages)
            self.prefix.host = self.host
            # spill reads the page's committed token slice — the model
            # stand-in for the engine's device->host row copy
            self.prefix.page_reader = (
                lambda page: self.page_content[page])
        # per-slot: {"stream": committed tokens, "L": prompt length,
        # "written": committed cache length} or None
        self.seq = [None] * slots

    def free_slots(self):
        return [s for s in range(self.slots) if self.seq[s] is None]

    def active_slots(self):
        return [s for s in range(self.slots) if self.seq[s] is not None]

    def _publish(self, s):
        q = self.seq[s]
        n_full = q["written"] // self.pt
        if n_full > 0:
            for idx in range(n_full):
                self.page_content[self.alloc.tables[s][idx]] = tuple(
                    int(t) for t in q["stream"][idx * self.pt:
                                                (idx + 1) * self.pt])
            self.prefix.insert(q["stream"][:n_full * self.pt],
                               self.alloc.tables[s][:n_full])

    # -- lifecycle operations (mirror serve.engine) --------------------
    def admit(self, s, tokens) -> bool:
        """Admission: match the trie, map hits read-only, resume past
        them, cover the remaining prompt (evicting idle trie pages when
        short).  False -> head-of-line wait, nothing retained."""
        assert self.seq[s] is None
        tokens = np.asarray(tokens, np.int32)
        L = len(tokens)
        pages = self.prefix.match(tokens)
        resume, hit = 0, 0
        if pages and self.alloc.map_shared(s, pages):
            hit = len(pages)
            resume = min(hit * self.pt, L - 1)
        ok = self.alloc.ensure(s, L)
        if not ok:
            short = (self.alloc.pages_for(L) - len(self.alloc.tables[s])
                     - self.alloc.free_pages)
            if short > 0 and self.prefix.evict(short) > 0:
                ok = self.alloc.ensure(s, L)
        if not ok:
            self.alloc.release(s)
            return False
        extra = self._restore(s, tokens, hit)
        if extra > 0:
            resume = min((hit + extra) * self.pt, L - 1)
        self.seq[s] = {"stream": tokens, "L": L, "written": resume}
        return True

    def _restore(self, s, tokens, hit) -> int:
        """Host-tier restore at admission (mirrors
        ``Engine._restore_pages``): probe consecutive full-page chain
        hashes past the trie hit, land each host slab on the slot's own
        fresh page, then publish the extended run.  Asserts the slab is
        exactly the token slice the hash commits to."""
        if self.host is None:
            return 0
        n_full = len(tokens) // self.pt
        if n_full <= hit:
            return 0
        hashes = self.prefix.chain_hashes(tokens, n_full)
        extra = 0
        for i in range(hit, n_full):
            rows = self.host.get(hashes[i])
            if rows is None:
                break               # restores must stay consecutive
            want = tuple(int(t)
                         for t in tokens[i * self.pt:(i + 1) * self.pt])
            assert rows == want, (rows, want)   # hash-keyed content
            self.page_content[self.alloc.tables[s][i]] = rows
            extra += 1
        if extra > 0:
            self.host.restores += extra
            self.prefix.insert(tokens[:(hit + extra) * self.pt],
                               self.alloc.tables[s][:hit + extra])
        return extra

    def write(self, s, take, new_tokens) -> bool:
        """One step's scatter-write window [written, written + take):
        cover with pages and COW anything shared — the engine's
        ``_cover_writes`` contract.  ``new_tokens`` extends the stream
        when the window grows past it (decode).  Publishes the prompt's
        full-page run when the window completes the prefill."""
        q = self.seq[s]
        end = min(q["written"] + int(take), self.table * self.pt)
        if end <= q["written"]:
            return False
        if not self.alloc.ensure(s, end):
            if not self.prefix.evict(self.alloc.pages_for(end)):
                return False
            if not self.alloc.ensure(s, end):
                return False
        for idx in range(q["written"] // self.pt, (end - 1) // self.pt + 1):
            if self.alloc.refcount[self.alloc.tables[s][idx]] > 1:
                if not self.alloc.free_pages:
                    return False    # engine would evict/preempt here
                pair = self.alloc.cow(s, idx)
                assert pair is not None and pair[0] != pair[1]
                if pair[0] in self.page_content:
                    self.page_content[pair[1]] = self.page_content[pair[0]]
        grown = end - len(q["stream"])
        if grown > 0:
            q["stream"] = np.concatenate(
                [q["stream"], np.asarray(new_tokens[:grown], np.int32)])
        crossed = q["written"] < q["L"] <= end
        q["written"] = end
        if crossed:
            self._publish(s)
        return True

    def close(self, s):
        """Preemption and retirement are the same pool transaction:
        publish the committed full-page run, then decref everything."""
        self._publish(s)
        self.alloc.release(s)
        self.seq[s] = None

    def drop(self, s):
        """Cancel / shed / timeout / fault-requeue: release WITHOUT
        publishing — the allocator and trie must end exactly as if the
        sequence had never run (DESIGN.md §11).  Same decref path as
        ``close``, no trie insert."""
        self.alloc.release(s)
        self.seq[s] = None

    def evict(self, n) -> int:
        return self.prefix.evict(n)

    # -- invariants ----------------------------------------------------
    def check(self):
        # the production checker first (the one chaos tests and
        # serve_bench call), then the model's independent re-derivation
        self.alloc.assert_consistent(self.prefix, context="model")
        a, pfx = self.alloc, self.prefix
        expect = {}
        for t in a.tables:
            for p in t:
                expect[p] = expect.get(p, 0) + 1
        for node in pfx.nodes.values():
            expect[node["page"]] = expect.get(node["page"], 0) + 1
        for p in range(a.n_pages):
            # refcount == the page's actual reference multiset, >= 0
            assert a.refcount[p] == expect.get(p, 0), p
            # free iff unreferenced; never both free and mapped
            assert (p in a.free_list) == (expect.get(p, 0) == 0), p
        assert len(set(a.free_list)) == len(a.free_list)    # no double-free
        assert set(expect).isdisjoint(a.free_list)
        # pool conservation: free + unique mapped-or-indexed == n_pages
        assert len(a.free_list) + len(expect) == a.n_pages
        assert a.sentinel not in expect
        for t in a.tables:
            assert len(t) <= a.table_pages
        for key, node in pfx.nodes.items():
            assert a.refcount[node["page"]] >= 1    # trie pages refcounted
            kids = sum(1 for n in pfx.nodes.values()
                       if n["parent_key"] == key)
            assert node["children"] == kids
        if self.host is not None:
            h = self.host
            # host budget holds; counters account exactly for the
            # slots present (spills in minus LRU drops, restores are
            # copies and never remove a slot — DESIGN.md §12)
            assert len(h) <= h.capacity
            assert h.dropped <= h.spills
            assert len(h._slots) <= h.spills - h.dropped
