"""Shared test fixtures.  NOTE: do NOT set XLA_FLAGS here — smoke tests and
benches must see the single real CPU device; only launch/dryrun.py forces
512 placeholder devices (and multi-device tests spawn subprocesses)."""
import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def assert_no_nans(tree, where=""):
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        assert not bool(jnp.any(jnp.isnan(leaf))), f"NaN at {where}{path}"
