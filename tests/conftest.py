"""Shared test fixtures.  NOTE: do NOT set XLA_FLAGS here — smoke tests and
benches must see the single real CPU device; only launch/dryrun.py forces
512 placeholder devices (and multi-device tests spawn subprocesses)."""
import os

import jax
import jax.numpy as jnp
import pytest

# Named hypothesis profiles (one knob instead of per-test @settings):
#   * dev (default): fast local iteration / the CI fast leg;
#   * ci: the CI slow leg selects it via HYPOTHESIS_PROFILE=ci — more
#     examples, no deadline (shared runners stall unpredictably).
# Tests that put a MODEL in the loop still pin their own small
# max_examples explicitly; everything else inherits the profile.
try:
    from hypothesis import settings

    settings.register_profile("ci", max_examples=200, deadline=None)
    settings.register_profile("dev", max_examples=20, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:          # minimal installs run without hypothesis
    pass


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def assert_no_nans(tree, where=""):
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        assert not bool(jnp.any(jnp.isnan(leaf))), f"NaN at {where}{path}"
