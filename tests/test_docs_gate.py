"""tools/check_docs.py: citation resolution, docstring coverage and
table-of-contents sync over synthetic DESIGN.md + source trees."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "tools"))
import check_docs  # noqa: E402

REPO = Path(__file__).resolve().parents[1]

DESIGN = """\
# Design

Intro paragraph.

## Contents

- §1 Allocator
- §2 Host tier

## Section index

**§1** blurb.  **§2** blurb.

## §1 Allocator

Body.

## §2 Host tier

Body.
"""


def _tree(tmp_path, design=DESIGN, serve_doc='"""Pool (DESIGN.md §1)."""\n',
          kernels_doc='"""Movers (§1, §2)."""\n'):
    """Build a minimal repo layout check_docs can audit."""
    d = tmp_path / "DESIGN.md"
    d.write_text(design)
    root = tmp_path / "src" / "repro"
    for tree, doc in (("serve", serve_doc), ("kernels", kernels_doc)):
        pkg = root / tree
        pkg.mkdir(parents=True)
        (pkg / "mod.py").write_text(doc + "X = 1\n")
    return ["--design", str(d), "--root", str(root)]


def test_clean_tree_passes(tmp_path, capsys):
    assert check_docs.main(_tree(tmp_path)) == 0
    assert "OK" in capsys.readouterr().out


def test_dangling_citation_fails(tmp_path, capsys):
    argv = _tree(tmp_path, serve_doc='"""Cites DESIGN.md §99."""\n')
    assert check_docs.main(argv) == 1
    assert "§99" in capsys.readouterr().out


def test_missing_docstring_fails(tmp_path, capsys):
    argv = _tree(tmp_path, serve_doc="")
    assert check_docs.main(argv) == 1
    assert "missing module docstring" in capsys.readouterr().out


def test_citation_free_docstring_fails(tmp_path, capsys):
    argv = _tree(tmp_path, serve_doc='"""Docstring, no citation."""\n')
    assert check_docs.main(argv) == 1
    assert "cites no" in capsys.readouterr().out


def test_toc_drift_fails(tmp_path, capsys):
    stale = DESIGN.replace("- §2 Host tier\n", "")
    assert check_docs.main(_tree(tmp_path, design=stale)) == 1
    assert "Contents" in capsys.readouterr().out


def test_toc_title_mismatch_fails(tmp_path):
    renamed = DESIGN.replace("- §2 Host tier", "- §2 Host tier (old name)")
    assert check_docs.main(_tree(tmp_path, design=renamed)) == 1


def test_operational_errors(tmp_path):
    argv = _tree(tmp_path)
    missing = ["--design", str(tmp_path / "nope.md"), argv[2], argv[3]]
    assert check_docs.main(missing) == 2
    # DESIGN.md with no §N headers at all is operational, not a violation
    (tmp_path / "DESIGN.md").write_text("# Design\n\nno sections\n")
    assert check_docs.main(argv) == 2
    # unparseable source
    (tmp_path / "src" / "repro" / "serve" / "mod.py").write_text("def (:\n")
    (tmp_path / "DESIGN.md").write_text(DESIGN)
    assert check_docs.main(argv) == 2


def test_repo_state_passes():
    """The gate the CI lint job runs must hold for the actual tree."""
    assert check_docs.main(["--design", str(REPO / "DESIGN.md"),
                            "--root", str(REPO / "src" / "repro")]) == 0
