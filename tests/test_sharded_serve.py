"""Rank-balanced tensor-parallel serving (DESIGN.md §10).

Three layers of coverage:
  * the rank-balanced head partitioner in core/prune.py — pure host
    logic (balance bound, determinism, degenerate one-head-per-shard,
    non-divisible rejection) plus the ragged-rank zero-padding and the
    head-permutation exactness it relies on;
  * the ShardedExecutor at tp=1 — the full sharded code path (mesh,
    placement, plan, salt) runs on a single device, so the fast CI leg
    exercises it without forced host devices;
  * real tp >= 2 engine runs (preemption, copy-on-write prefix reuse,
    stream identity) — these need ``jax.device_count() >= tp`` and run
    in the CI sharded leg (XLA_FLAGS=--xla_force_host_platform_device_
    count=4); single-device runs skip them, and one subprocess test
    (slow) keeps tp=2 exactness covered on any host.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (clover_decompose, clover_prune, head_rank_loads,
                        mask_head_ranks, permute_attention_heads,
                        rank_balanced_partition)
from repro.models import init_lm_params
from repro.models import transformer as T
from repro.serve import (Engine, EngineConfig, LocalExecutor, Request,
                         ShardedExecutor)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _model(prune=0.0):
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    if prune > 0:
        dp, dcfg, _ = clover_decompose(params, cfg, peft=False)
        params, cfg = clover_prune(dp, dcfg, qk_ratio=prune,
                                   vo_ratio=prune)
    return params, cfg


def _streams(params, cfg, ecfg, prompts, max_new=4, executor=None):
    eng = Engine(params, cfg, ecfg, executor=executor)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    return eng, [tuple(r.generated) for r in reqs]


def _prompts(cfg, sizes=(3, 9, 5)):
    rng = np.random.default_rng(7)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in sizes]


# ---------------------------------------------------------------------------
# the partitioner (pure host logic — no devices)
# ---------------------------------------------------------------------------

def test_partition_balance_bound_heterogeneous():
    """A prune-0.5-style heterogeneous rank profile must land within
    the 1.15 max/min rank-load bound the serving acceptance demands —
    and always beat (or tie) the naive contiguous split."""
    rng = np.random.default_rng(0)
    for n_shards in (2, 4):
        for _ in range(20):
            # per-head kept ranks around half of head_dim 64, snapped
            # to multiples of 8 like the TPU plan produces
            loads = (rng.integers(2, 9, 16) * 8).astype(float)
            plan = rank_balanced_partition(loads, n_shards)
            assert plan.balance <= 1.15, (loads, plan)
            per = len(loads) // n_shards
            naive = [sum(loads[s * per:(s + 1) * per])
                     for s in range(n_shards)]
            naive_bal = max(naive) / min(naive)
            assert plan.balance <= naive_bal + 1e-9
            # equal cardinality + full coverage
            assert sorted(h for b in plan.kv_assign for h in b) == \
                list(range(len(loads)))
            assert all(len(b) == per for b in plan.kv_assign)


def test_partition_deterministic():
    loads = [9.0, 5.0, 7.0, 3.0, 9.0, 1.0, 2.0, 2.0]
    a = rank_balanced_partition(loads, 4, group=2)
    b = rank_balanced_partition(list(loads), 4, group=2)
    assert a == b
    assert a.salt() == b.salt()
    # the q perm follows the kv perm at GQA granularity
    assert a.q_perm == tuple(kv * 2 + g for kv in a.kv_perm
                             for g in range(2))


def test_partition_uniform_is_identity():
    """Uniform ranks (the engine's default plan) keep the exact head
    order — sharded summation order matches the unsharded model."""
    plan = rank_balanced_partition(head_rank_loads(_model()[1]), 2)
    assert plan.identity
    assert plan.balance == 1.0


def test_partition_degenerate_one_head_per_shard():
    loads = [4.0, 1.0, 3.0, 2.0]
    plan = rank_balanced_partition(loads, 4)
    assert all(len(b) == 1 for b in plan.kv_assign)
    assert sorted(h for b in plan.kv_assign for h in b) == [0, 1, 2, 3]
    assert plan.balance == 4.0           # unavoidable at 1 head/shard


def test_partition_rejects_nondivisible():
    with pytest.raises(ValueError, match="do not split"):
        rank_balanced_partition([1.0, 2.0, 3.0], 2)


# ---------------------------------------------------------------------------
# ragged ranks + head permutation: the exactness the executor relies on
# ---------------------------------------------------------------------------

def test_mask_head_ranks_matches_uniform_prune():
    """Zero-padding every head to a uniform rank must reproduce the
    SLICED pruned model: padded rank dims contribute exactly zero."""
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    dp, dcfg, _ = clover_decompose(params, cfg, peft=False)
    pruned, pcfg = clover_prune(dp, dcfg, qk_ratio=0.5, vo_ratio=0.5)
    r_qk, r_vo = pcfg.qk_dim, pcfg.vo_dim
    kv = cfg.n_kv_heads
    masked = mask_head_ranks(dp, dcfg, [r_qk] * kv, [r_vo] * kv)
    toks = np.arange(12, dtype=np.int32)[None, :] % cfg.vocab_size
    lp, _ = T.forward(pruned, pcfg, toks)
    lm, _ = T.forward(masked, dcfg, toks)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lm),
                               atol=2e-4, rtol=2e-4)
    assert (np.argmax(np.asarray(lp), -1)
            == np.argmax(np.asarray(lm), -1)).all()


def test_mask_head_ranks_tail_is_inert():
    """The garbage-row convention, rank edition: with the Q/O side
    masked, garbage in the K/V-side tail dims can NEVER influence the
    output — q_tail (zero) * k_tail (garbage) contributes exactly 0.0,
    and v_tail garbage reaches only the zeroed wo tail rows.  This is
    what makes ragged-rank cache rows safe: stale/padded rank dims
    exist physically but are unreadable.  Bitwise check."""
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    dp, dcfg, _ = clover_decompose(params, cfg, peft=False)
    kv = cfg.n_kv_heads
    rng = np.random.default_rng(3)
    qk = rng.integers(8, cfg.head_dim_, kv)         # RAGGED per head
    vo = rng.integers(8, cfg.head_dim_, kv)
    masked = mask_head_ranks(dp, dcfg, qk, vo)

    # build K/V-side garbage with support EXACTLY on the masked-out
    # tail dims: shift wk/wv by +100 everywhere, re-mask, and keep the
    # difference (the shift that survived only in the tail)
    def shift_kv(tree):
        out = dict(tree)
        out["blocks"] = tuple(
            {**blk, "attn": {k: (v + 100.0 if k in ("wk", "wv") else v)
                             for k, v in blk["attn"].items()}}
            if "attn" in blk else blk
            for blk in tree["blocks"])
        return out

    shifted = shift_kv(dp)
    masked_shifted = mask_head_ranks(shifted, dcfg, qk, vo)
    poisoned = dict(masked)
    poisoned["blocks"] = tuple(
        {**mb, "attn": {k: (mb["attn"][k]                 # tail-only
                            + (sb["attn"][k] - msb["attn"][k])
                            if k in ("wk", "wv") else v)
                        for k, v in mb["attn"].items()}}
        if "attn" in mb else mb
        for mb, sb, msb in zip(masked["blocks"], shifted["blocks"],
                               masked_shifted["blocks"]))

    toks = np.arange(10, dtype=np.int32)[None, :] % cfg.vocab_size
    l0, _ = T.forward(masked, dcfg, toks)
    l1, _ = T.forward(poisoned, dcfg, toks)
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))


def test_permute_heads_preserves_function():
    """Attention sums over heads, so a consistent head permutation is
    (numerically near-)exact and greedy streams never move."""
    params, cfg = _model(0.5)
    plan = rank_balanced_partition(
        np.arange(cfg.n_kv_heads, dtype=float) + 1.0, 2,
        group=cfg.q_per_kv)
    assert not plan.identity
    permuted = permute_attention_heads(params, cfg, plan)
    toks = np.arange(11, dtype=np.int32)[None, :] % cfg.vocab_size
    l0, _ = T.forward(params, cfg, toks)
    l1, _ = T.forward(permuted, cfg, toks)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               atol=1e-4, rtol=1e-4)
    assert (np.argmax(np.asarray(l0), -1)
            == np.argmax(np.asarray(l1), -1)).all()


# ---------------------------------------------------------------------------
# ShardedExecutor at tp=1: the full sharded path on a single device
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ("dense", "prefix"))
def test_sharded_executor_tp1_matches_local(layout):
    """tp=1 runs the ENTIRE sharded code path (mesh, plan, placement,
    output pinning, sharded draft/verify and the rollback's index
    commit) on one device — fast-leg coverage without forced devices."""
    params, cfg = _model(0.5)
    prompts = _prompts(cfg)
    ecfg = EngineConfig(slots=2, max_len=32, prefill_chunk=4,
                        paged=(layout != "dense"), page_tokens=4,
                        prefix_cache=(layout == "prefix"), spec_k=2)
    _, want = _streams(params, cfg, ecfg, prompts,
                       executor=LocalExecutor(params, cfg, ecfg))
    exe = ShardedExecutor(params, cfg, ecfg, tp=1)
    eng, got = _streams(params, cfg, ecfg, prompts, executor=exe)
    assert got == want
    assert exe.plan is not None and exe.plan.identity
    assert exe.shard_load_fractions() == [1.0]
    # the plan is in the prefix-cache salt (layout reuse stays correct)
    if layout == "prefix":
        assert "tp" in eng.prefix._root[1]
    shapes = eng.compiled_shapes()
    assert shapes is None or shapes <= 5


def test_engine_tp_config_builds_sharded_executor():
    params, cfg = _model()
    eng = Engine(params, cfg,
                 EngineConfig(slots=2, max_len=16, prefill_chunk=4, tp=1))
    assert isinstance(eng.exe, LocalExecutor)
    assert not isinstance(eng.exe, ShardedExecutor)
    if jax.device_count() >= 2 and jax.device_count() % 2 == 0:
        eng = Engine(params, cfg,
                     EngineConfig(slots=2, max_len=16, prefill_chunk=4,
                                  tp=2))
        assert isinstance(eng.exe, ShardedExecutor)
        assert eng.exe.tp == 2


# ---------------------------------------------------------------------------
# real tensor parallelism (CI sharded leg: 4 forced host devices)
# ---------------------------------------------------------------------------

def _need(tp):
    if jax.device_count() < tp or jax.device_count() % tp:
        pytest.skip(f"needs a device count divisible by {tp} (have "
                    f"{jax.device_count()}; run under XLA_FLAGS="
                    "--xla_force_host_platform_device_count=4)")


def test_tp2_preemption_streams_identical():
    """An undersized page pool forces preemption+requeue mid-trace;
    the sharded engine must preempt identically and keep byte-identical
    streams (scheduling is layout-blind)."""
    _need(2)
    params, cfg = _model(0.5)
    prompts = _prompts(cfg, sizes=(9, 8, 7, 6))
    ecfg = EngineConfig(slots=4, max_len=24, prefill_chunk=4, paged=True,
                        page_tokens=4, n_pages=10)
    e1, s1 = _streams(params, cfg, ecfg, prompts, max_new=6)
    e2, s2 = _streams(params, cfg, dataclasses.replace(ecfg, tp=2),
                      prompts, max_new=6)
    assert e1.sched.preemptions > 0
    assert e2.sched.preemptions == e1.sched.preemptions
    assert s1 == s2


def test_tp2_prefix_cow_warm_replay():
    """Copy-on-write prefix sharing under tp=2: the warm replay hits
    the trie (read-only shared pages + COW on the resume write) and
    still matches the cold streams; page copies run on the SHARDED
    pools."""
    _need(2)
    params, cfg = _model(0.5)
    rng = np.random.default_rng(5)
    sys_p = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    prompts = [np.concatenate([sys_p,
                               rng.integers(0, cfg.vocab_size, 1 + i)
                               .astype(np.int32)]) for i in range(3)]
    ecfg = EngineConfig(slots=2, max_len=32, prefill_chunk=4, paged=True,
                        page_tokens=4, prefix_cache=True, spec_k=2, tp=2)
    eng = Engine(params, cfg, ecfg)
    cold = [Request(uid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    eng.run(cold)
    warm = [Request(uid=10 + i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    eng.run(warm)
    assert all(w.generated == c.generated for w, c in zip(warm, cold))
    assert all(w.cached_tokens > 0 for w in warm)
    shapes = eng.compiled_shapes()
    assert shapes is None or shapes <= 5


def test_tp4_one_kv_head_per_shard():
    """Degenerate partition: tp == n_kv_heads, one head per shard."""
    _need(4)
    params, cfg = _model(0.5)
    assert cfg.n_kv_heads == 4
    prompts = _prompts(cfg, sizes=(4, 7))
    ecfg = EngineConfig(slots=2, max_len=24, prefill_chunk=4)
    _, want = _streams(params, cfg, ecfg, prompts)
    eng, got = _streams(params, cfg, dataclasses.replace(ecfg, tp=4),
                        prompts)
    assert got == want
    assert all(len(b) == 1 for b in eng.exe.plan.kv_assign)


def test_tp2_nondivisible_heads_replicate():
    """KV-head counts that do not divide tp degrade to replication
    (plan=None, sharding rules drop the axis) — correct, not parallel."""
    _need(2)
    cfg = get_config("phi3-medium-14b").reduced()
    assert cfg.n_kv_heads % 2 == 1
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    prompts = _prompts(cfg, sizes=(3, 6))
    ecfg = EngineConfig(slots=2, max_len=16, prefill_chunk=4)
    _, want = _streams(params, cfg, ecfg, prompts, max_new=3)
    eng, got = _streams(params, cfg, dataclasses.replace(ecfg, tp=2),
                        prompts, max_new=3)
    assert got == want
    assert eng.exe.plan is None


@pytest.mark.slow
def test_tp2_exactness_subprocess():
    """tp=2 stream identity on ANY host: a fresh process forces 4 host
    devices, so the slow leg keeps real-parallelism coverage even when
    the main process sees one device."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=4"
        import dataclasses
        import jax, numpy as np
        from repro.configs import get_config
        from repro.core import clover_decompose, clover_prune
        from repro.models import init_lm_params
        from repro.serve import Engine, EngineConfig, Request
        cfg = get_config("musicgen-large").reduced()
        params = init_lm_params(cfg, jax.random.PRNGKey(0))
        dp, dcfg, _ = clover_decompose(params, cfg, peft=False)
        params, cfg = clover_prune(dp, dcfg, qk_ratio=0.5, vo_ratio=0.5)
        rng = np.random.default_rng(7)
        prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
                   for n in (3, 9, 5)]
        base = EngineConfig(slots=2, max_len=32, prefill_chunk=4,
                            paged=True, page_tokens=4)
        out = []
        for ecfg in (base, dataclasses.replace(base, tp=2)):
            eng = Engine(params, cfg, ecfg)
            reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
                    for i, p in enumerate(prompts)]
            eng.run(reqs)
            out.append([r.generated for r in reqs])
        assert out[0] == out[1], out
        print("TP_MATCH")
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    res = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "TP_MATCH" in res.stdout
