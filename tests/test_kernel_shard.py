"""shard_map'd kernel parity: per-shard Pallas execution must be
BITWISE identical to the single-device kernels.

The serving hot-path kernels (flash-decode, paged flash-decode,
page-copy, full-sequence attention) iterate a grid whose (batch,
kv-head) cells are independent, so splitting slots over "data" and KV
heads over "model" (``kernels.ops.resolve(impl, mesh)``) must not
change a single bit — these tests assert ``np.array_equal`` on raw
outputs, with RAGGED per-head ranks (zero tails at different widths
per head, the shape CLOVER's per-head spectra produce) so head
splitting is exercised over genuinely non-uniform loads.

Also covers the dispatch API itself (``resolve`` aliases, idempotence,
caching) and the loud ``ValueError`` contracts that replaced the
sharded executor's silent ``kernel_impl="xla"`` demotion.

The mesh cases need >= 2 host devices — the CI sharded leg forces 4
via ``XLA_FLAGS=--xla_force_host_platform_device_count=4``; plain
single-device runs skip them.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.launch.mesh import make_host_mesh


def _need(tp: int):
    if jax.device_count() < tp or jax.device_count() % tp:
        pytest.skip(f"needs a device count divisible by {tp} (have "
                    f"{jax.device_count()}; run under XLA_FLAGS="
                    "--xla_force_host_platform_device_count=4)")


def _ragged_kv(rng, shape, head_axis, rank_axis):
    """Random tensor with a DIFFERENT zero-padded rank tail per head —
    how CLOVER's per-head rank pruning lands in a shared-width cache."""
    x = rng.standard_normal(shape).astype(np.float32)
    n_heads, width = shape[head_axis], shape[rank_axis]
    for h in range(n_heads):
        r = 1 + (h * 7) % width          # ragged: 1..width, varies per head
        idx = [slice(None)] * len(shape)
        idx[head_axis] = h
        idx[rank_axis] = slice(r, None)
        x[tuple(idx)] = 0.0
    return jnp.asarray(x)


# ---------------------------------------------------------------------------
# dispatch API
# ---------------------------------------------------------------------------

def test_resolve_aliases_and_idempotence():
    d = kops.resolve("interpret")
    assert d.impl == "interpret" and d.kernel_path and d.mesh is None
    assert kops.resolve("interpret") is d           # cached
    assert kops.resolve(d) is d                     # idempotent
    assert not kops.resolve("xla").kernel_path
    assert not kops.resolve("ref").kernel_path
    # "pallas" canonicalizes per platform (CPU has no native lowering)
    p = kops.resolve("pallas")
    assert p.requested == "pallas"
    if jax.local_devices()[0].platform not in ("tpu", "gpu"):
        assert p.impl == "interpret"
    with pytest.raises(ValueError, match="unknown kernel impl"):
        kops.resolve("cuda")


def test_resolve_attaches_mesh_once():
    _need(2)
    mesh = make_host_mesh(model=2)
    d = kops.resolve("interpret", mesh=mesh)
    assert d.mesh is mesh
    assert kops.resolve("interpret", mesh=mesh) is d      # cached per mesh
    assert kops.resolve(d).mesh is mesh                   # pass-through
    assert "shard_map" in d.describe()
    # a meshless dispatch gains the mesh, a meshed one keeps its own
    assert kops.resolve(kops.resolve("interpret"), mesh=mesh).mesh is mesh


def test_engine_config_rejects_unknown_alias():
    from repro.serve import EngineConfig
    with pytest.raises(ValueError, match="kernel_impl"):
        EngineConfig(kernel_impl="cuda")


def test_recurrent_tp_kernel_path_raises():
    """tp > 1 + kernel path + recurrent arch is the one genuinely
    impossible combo left — it must raise, naming the reason, instead
    of silently demoting to XLA.  (Fires before any mesh/device work,
    so this runs on a single device too.)"""
    from repro.configs import get_config
    from repro.serve import EngineConfig
    from repro.serve.executor import validate_kernel_parallelism
    rcfg = dataclasses.replace(get_config("rwkv6-1.6b").reduced(),
                               kernel_impl="interpret")
    with pytest.raises(ValueError, match="not shard_map-partitioned"):
        validate_kernel_parallelism(rcfg, 2)
    validate_kernel_parallelism(rcfg, 1)                  # tp=1: fine
    validate_kernel_parallelism(
        dataclasses.replace(rcfg, kernel_impl="xla"), 2)  # xla: fine
    from repro.models import init_lm_params
    params = init_lm_params(rcfg, jax.random.PRNGKey(0))
    from repro.serve import Engine
    with pytest.raises(ValueError, match="recurrent"):
        Engine(params, rcfg, EngineConfig(slots=2, max_len=16, tp=2))
        # ^ inherits kernel_impl="interpret" from the arch config


# ---------------------------------------------------------------------------
# per-kernel bitwise parity, single device vs shard_map
# ---------------------------------------------------------------------------

def test_decode_attention_shard_parity():
    _need(2)
    mesh = make_host_mesh(model=2)
    rng = np.random.default_rng(0)
    B, H, KV, dq, dv, T = 4, 8, 4, 16, 12, 40
    q = jnp.asarray(rng.standard_normal((B, H, dq)).astype(np.float32))
    k = _ragged_kv(rng, (B, T, KV, dq), head_axis=2, rank_axis=3)
    v = _ragged_kv(rng, (B, T, KV, dv), head_axis=2, rank_axis=3)
    lens = jnp.asarray([1, 17, 40, 5], jnp.int32)
    single = kops.resolve("interpret")
    sharded = kops.resolve("interpret", mesh=mesh)
    a = jax.jit(lambda *x: single.decode_attention(*x, scale=0.25))(
        q, k, v, lens)
    b = jax.jit(lambda *x: sharded.decode_attention(*x, scale=0.25))(
        q, k, v, lens)
    assert a.dtype == b.dtype and np.array_equal(np.asarray(a),
                                                 np.asarray(b))


def test_paged_decode_attention_shard_parity():
    _need(2)
    mesh = make_host_mesh(model=2)
    rng = np.random.default_rng(1)
    B, H, KV, dq, dv = 4, 8, 4, 16, 12
    N, PT, nP = 11, 8, 5                 # row N-1 = the garbage row
    q = jnp.asarray(rng.standard_normal((B, H, dq)).astype(np.float32))
    kp = _ragged_kv(rng, (N, PT, KV, dq), head_axis=2, rank_axis=3)
    vp = _ragged_kv(rng, (N, PT, KV, dv), head_axis=2, rank_axis=3)
    # host-global page ids, including sentinel entries past each slot's
    # coverage — identical tables must dereference identically per shard
    table = jnp.asarray(rng.integers(0, N, (B, nP)), jnp.int32)
    lens = jnp.asarray([3, 24, 40, 9], jnp.int32)
    single = kops.resolve("interpret")
    sharded = kops.resolve("interpret", mesh=mesh)
    a = jax.jit(lambda *x: single.paged_decode_attention(*x, scale=0.3))(
        q, kp, vp, table, lens)
    b = jax.jit(lambda *x: sharded.paged_decode_attention(*x, scale=0.3))(
        q, kp, vp, table, lens)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_page_copy_shard_parity():
    _need(2)
    mesh = make_host_mesh(model=2)
    rng = np.random.default_rng(2)
    nb, N, PT, KV, r = 2, 10, 8, 4, 12
    pool = _ragged_kv(rng, (nb, N, PT, KV, r), head_axis=3, rank_axis=4)
    src = jnp.asarray([1, 3, 6], jnp.int32)
    dst = jnp.asarray([5, 7, 0], jnp.int32)
    single = kops.resolve("interpret")
    sharded = kops.resolve("interpret", mesh=mesh)
    a = jax.jit(single.page_copy)(pool, src, dst)
    b = jax.jit(sharded.page_copy)(pool, src, dst)
    assert np.array_equal(np.asarray(a), np.asarray(b))
    # and both actually cloned the rows
    assert np.array_equal(np.asarray(a)[:, 5], np.asarray(pool)[:, 1])


def test_clover_attention_shard_parity():
    _need(2)
    mesh = make_host_mesh(model=2)
    rng = np.random.default_rng(3)
    B, S, H, KV, dq, dv = 2, 24, 8, 4, 16, 12
    q = jnp.asarray(rng.standard_normal((B, S, H, dq)).astype(np.float32))
    k = _ragged_kv(rng, (B, S, KV, dq), head_axis=2, rank_axis=3)
    v = _ragged_kv(rng, (B, S, KV, dv), head_axis=2, rank_axis=3)
    single = kops.resolve("interpret")
    sharded = kops.resolve("interpret", mesh=mesh)
    a = jax.jit(lambda *x: single.clover_attention(*x, scale=0.25))(q, k, v)
    b = jax.jit(lambda *x: sharded.clover_attention(*x, scale=0.25))(q, k, v)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_nondivisible_heads_degrade_to_replication():
    """KV-head counts that do not divide the model axis must still run
    (replicated per kernel — correct, just not parallel) and match the
    single-device kernel bitwise."""
    _need(2)
    mesh = make_host_mesh(model=2)
    rng = np.random.default_rng(4)
    B, H, KV, dq, dv, T = 4, 6, 3, 8, 8, 16        # 3 kv heads, tp=2
    q = jnp.asarray(rng.standard_normal((B, H, dq)).astype(np.float32))
    k = _ragged_kv(rng, (B, T, KV, dq), head_axis=2, rank_axis=3)
    v = _ragged_kv(rng, (B, T, KV, dv), head_axis=2, rank_axis=3)
    lens = jnp.asarray([4, 16, 8, 1], jnp.int32)
    from repro.parallel.sharding import kernel_axes
    b_ax, m_ax = kernel_axes(mesh, batch=B, kv_heads=KV)
    assert m_ax is None and b_ax == "data"
    a = jax.jit(lambda *x: kops.resolve("interpret")
                .decode_attention(*x, scale=0.5))(q, k, v, lens)
    b = jax.jit(lambda *x: kops.resolve("interpret", mesh=mesh)
                .decode_attention(*x, scale=0.5))(q, k, v, lens)
    assert np.array_equal(np.asarray(a), np.asarray(b))
