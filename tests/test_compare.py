"""benchmarks/compare.py perf gate: regression math and — the part a
rename silently defeated once — key drift in BOTH directions."""
import json

import pytest

from benchmarks import compare


def _write(path, metrics):
    path.write_text(json.dumps({"metrics": metrics}))


@pytest.fixture
def dirs(tmp_path):
    base = tmp_path / "baselines"
    cur = tmp_path / "current"
    base.mkdir()
    cur.mkdir()
    return base, cur


def _argv(base, cur, **kw):
    argv = ["--baseline-dir", str(base), "--current-dir", str(cur)]
    for k, v in kw.items():
        argv += [f"--{k}", str(v)]
    return argv


def test_gate_ok_and_regression(dirs):
    base, cur = dirs
    _write(base / "BENCH_serve.json", {"s1": {"tokens_per_s": 100.0,
                                              "itl_p95_ms": 10.0}})
    _write(cur / "BENCH_serve.json", {"s1": {"tokens_per_s": 98.0,
                                             "itl_p95_ms": 11.0}})
    assert compare.main(_argv(base, cur, threshold=0.25)) == 0
    _write(cur / "BENCH_serve.json", {"s1": {"tokens_per_s": 50.0,
                                             "itl_p95_ms": 10.0}})
    assert compare.main(_argv(base, cur, threshold=0.25)) == 1


def test_baseline_key_missing_from_current_fails(dirs):
    """Forward drift: a renamed/crashed scenario vanishes from the
    current run — its baselined metric must fail the gate."""
    base, cur = dirs
    _write(base / "BENCH_serve.json",
           {"s1": {"tokens_per_s": 100.0}, "s2": {"tokens_per_s": 50.0}})
    _write(cur / "BENCH_serve.json", {"s1": {"tokens_per_s": 100.0}})
    assert compare.main(_argv(base, cur)) == 1


def test_current_key_missing_from_baseline_fails(dirs):
    """Reverse drift: a NEW gated metric with no baseline would run
    ungated forever — it must fail until adopted with --update."""
    base, cur = dirs
    _write(base / "BENCH_serve.json", {"s1": {"tokens_per_s": 100.0}})
    _write(cur / "BENCH_serve.json",
           {"s1": {"tokens_per_s": 100.0}, "s2": {"tokens_per_s": 77.0}})
    assert compare.main(_argv(base, cur)) == 1
    # --update adopts it, after which the gate passes
    assert compare.main(_argv(base, cur) + ["--update"]) == 0
    assert compare.main(_argv(base, cur)) == 0


def test_file_level_drift_both_directions(dirs):
    base, cur = dirs
    _write(base / "BENCH_serve.json", {"s1": {"tokens_per_s": 1.0}})
    _write(cur / "BENCH_serve.json", {"s1": {"tokens_per_s": 1.0}})
    # current produced an extra bench file nobody baselined
    _write(cur / "BENCH_new.json", {"x": {"tokens_per_s": 9.0}})
    assert compare.main(_argv(base, cur)) == 1
    (cur / "BENCH_new.json").unlink()
    # baseline file with no current counterpart (module crashed/skipped)
    _write(base / "BENCH_kernel.json", {"k": {"tokens_per_s": 2.0}})
    assert compare.main(_argv(base, cur)) == 1


def test_ungated_metrics_do_not_gate(dirs):
    base, cur = dirs
    _write(base / "BENCH_serve.json", {"s1": {"ttft_warm_ms": 1.0}})
    _write(cur / "BENCH_serve.json", {"s1": {"ttft_warm_ms": 99.0}})
    assert compare.main(_argv(base, cur)) == 0
