"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dt):
    return dict(atol=5e-2, rtol=5e-2) if dt == jnp.bfloat16 \
        else dict(atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("B,S,H,KV,dq,dv", [
    (2, 64, 4, 2, 32, 24),       # GQA, asymmetric (CLOVER-pruned shape)
    (1, 96, 8, 8, 16, 16),       # MHA, square, non-pow2 seq
    (2, 40, 4, 1, 64, 48),       # MQA, padding path (40 % 32 != 0)
    (1, 128, 25, 25, 8, 8),      # odd head count (gpt2-xl family)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, KV, dq, dv, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, dq), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, dq), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, dv), dtype)
    o_ref = ref.attention_ref(q, k, v, causal=True)
    o_pal = ops.clover_attention(q, k, v, causal=True, impl="interpret",
                                 block_q=32, block_k=32)
    np.testing.assert_allclose(
        np.asarray(o_pal, np.float32), np.asarray(o_ref, np.float32),
        **_tol(dtype))


@pytest.mark.parametrize("B,H,KV,T,dq,dv", [
    (2, 4, 2, 100, 32, 24),
    (3, 8, 1, 256, 16, 16),
    (1, 16, 16, 33, 64, 64),
])
def test_flash_decode_sweep(B, H, KV, T, dq, dv):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, H, dq))
    k = jax.random.normal(ks[1], (B, T, KV, dq))
    v = jax.random.normal(ks[2], (B, T, KV, dv))
    lengths = jax.random.randint(ks[3], (B,), 1, T + 1)
    o_ref = ref.decode_attention_ref(q, k, v, lengths)
    o_pal = ops.decode_attention(q, k, v, lengths, impl="interpret",
                                 block_t=32)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               atol=5e-5, rtol=5e-5)


def test_flash_decode_respects_lengths():
    """Tokens beyond each row's length must not influence the output."""
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    B, H, KV, T, d = 2, 4, 2, 64, 16
    q = jax.random.normal(ks[0], (B, H, d))
    k = jax.random.normal(ks[1], (B, T, KV, d))
    v = jax.random.normal(ks[2], (B, T, KV, d))
    lengths = jnp.array([10, 30])
    o1 = ops.decode_attention(q, k, v, lengths, impl="interpret", block_t=16)
    k2 = k.at[:, 35:].set(999.0)
    v2 = v.at[:, 35:].set(-999.0)
    o2 = ops.decode_attention(q, k2, v2, lengths, impl="interpret",
                              block_t=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_flash_decode_chunk_advanced_slots():
    """Chunked prefill advances a slot's cache index by chunk-size, not
    1, and leaves garbage KV beyond each slot's valid region (padded
    window writes).  Decoding against such a cache must equal decoding
    against one with the garbage zeroed — for slots parked exactly at
    chunk boundaries AND mid-chunk."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    B, H, KV, T, d, chunk = 3, 4, 2, 96, 16, 32
    q = jax.random.normal(ks[0], (B, H, d))
    k = jax.random.normal(ks[1], (B, T, KV, d))
    v = jax.random.normal(ks[2], (B, T, KV, d))
    lengths = jnp.array([chunk, 2 * chunk, chunk + 5], jnp.int32)
    poison_k, poison_v = k, v
    clean_k, clean_v = k, v
    for b in range(B):
        L = int(lengths[b])
        poison_k = poison_k.at[b, L:].set(1e4)
        poison_v = poison_v.at[b, L:].set(-1e4)
        clean_k = clean_k.at[b, L:].set(0.0)
        clean_v = clean_v.at[b, L:].set(0.0)
    o_poison = ops.decode_attention(q, poison_k, poison_v, lengths,
                                    impl="interpret", block_t=32)
    o_clean = ops.decode_attention(q, clean_k, clean_v, lengths,
                                   impl="interpret", block_t=32)
    o_ref = ref.decode_attention_ref(q, clean_k, clean_v, lengths)
    np.testing.assert_allclose(np.asarray(o_poison), np.asarray(o_clean),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(o_poison), np.asarray(o_ref),
                               atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("B,H,T,d", [
    (2, 2, 50, 16),              # padding path (50 % 16 != 0)
    (1, 4, 128, 32),
    (2, 1, 17, 8),
])
def test_wkv6_sweep(B, H, T, d):
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    r = jax.random.normal(ks[0], (B, H, T, d))
    k = jax.random.normal(ks[1], (B, H, T, d)) * 0.5
    v = jax.random.normal(ks[2], (B, H, T, d))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, H, T, d)) * 0.5)
    u = jax.random.normal(ks[4], (H, d)) * 0.1
    s0 = jax.random.normal(ks[5], (B, H, d, d)) * 0.1
    o_ref, s_ref = ref.wkv6_ref(r, k, v, logw, u, s0)
    o_pal, s_pal = ops.wkv6(r, k, v, logw, u, s0, impl="interpret", chunk=16)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_pal), np.asarray(s_ref),
                               atol=1e-4, rtol=1e-4)


def test_wkv6_chunk_invariance():
    """Chunk size is an implementation detail: results must not change."""
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    B, H, T, d = 1, 2, 64, 16
    r = jax.random.normal(ks[0], (B, H, T, d))
    k = jax.random.normal(ks[1], (B, H, T, d)) * 0.5
    v = jax.random.normal(ks[2], (B, H, T, d))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, H, T, d)) * 0.5)
    u = jax.random.normal(ks[4], (H, d)) * 0.1
    outs = [np.asarray(ops.wkv6(r, k, v, logw, u, impl="interpret",
                                chunk=c)[0]) for c in (8, 16, 64)]
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-4)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-4)


def test_model_chunked_wkv_matches_ref():
    """The model's XLA chunked path is itself oracle-consistent."""
    from repro.models.rwkv import wkv6_chunked
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    B, H, T, d = 2, 2, 64, 16
    r = jax.random.normal(ks[0], (B, H, T, d))
    k = jax.random.normal(ks[1], (B, H, T, d)) * 0.5
    v = jax.random.normal(ks[2], (B, H, T, d))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, H, T, d)) * 0.5)
    u = jax.random.normal(ks[4], (H, d)) * 0.1
    s0 = jnp.zeros((B, H, d, d))
    o_ref, s_ref = ref.wkv6_ref(r, k, v, logw, u, s0)
    o_c, s_c = wkv6_chunked(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_ref),
                               atol=1e-4, rtol=1e-4)


def test_prefill_window_alignment():
    """S < T: queries align to the END of the key range (cached prefill)."""
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    B, S, T, H, d = 1, 32, 64, 2, 16
    q = jax.random.normal(ks[0], (B, S, H, d))
    k = jax.random.normal(ks[1], (B, T, H, d))
    v = jax.random.normal(ks[2], (B, T, H, d))
    o_ref = ref.attention_ref(q, k, v, causal=True)
    o_pal = ops.clover_attention(q, k, v, causal=True, impl="interpret",
                                 block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("B,S,dI,dS", [
    (2, 64, 32, 8),
    (1, 50, 48, 4),       # padding path (50 % 16 != 0)
    (2, 128, 64, 16),
])
def test_mamba_scan_sweep(B, S, dI, dS):
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, dI)) * 0.5) * 0.1
    A = jnp.abs(jax.random.normal(ks[1], (dI, dS))) + 0.5
    Bm = jax.random.normal(ks[2], (B, S, dS))
    C = jax.random.normal(ks[3], (B, S, dS))
    x = jax.random.normal(ks[4], (B, S, dI))
    h0 = jax.random.normal(jax.random.PRNGKey(8), (B, dI, dS)) * 0.1
    y_ref, h_ref = ref.mamba_scan_ref(dt, A, Bm, C, x, h0)
    y_pal, h_pal = ops.mamba_scan(dt, A, Bm, C, x, h0, chunk=16, tile=16,
                                  impl="interpret")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h_pal), np.asarray(h_ref),
                               atol=1e-5, rtol=1e-5)


def test_mamba_model_pallas_equivalence():
    import dataclasses
    from repro.configs import get_config
    from repro.models import init_lm_params, forward
    cfg = get_config("jamba-v0.1-52b").reduced()
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=0.0))
    key = jax.random.PRNGKey(0)
    params = init_lm_params(cfg, key)
    toks = jax.random.randint(key, (1, 8), 0, cfg.vocab_size)
    base, _ = forward(params, cfg, toks)
    cfg_p = dataclasses.replace(cfg, kernel_impl="interpret")
    out, _ = forward(params, cfg_p, toks)
    scale = float(jnp.max(jnp.abs(base))) + 1e-6
    assert float(jnp.max(jnp.abs(out - base))) / scale < 1e-3
