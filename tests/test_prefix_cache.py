"""Copy-on-write prefix caching (DESIGN.md §9): trie semantics,
refcounted allocator, COW engine exactness, the page_copy kernel, and
the write-floor defense — plus a seeded random-walk over the shared
lifecycle model (the no-hypothesis counterpart of the state machine in
tests/test_property.py)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.models import init_lm_params
from repro.models import transformer as T
from repro.serve import Engine, EngineConfig, Request, greedy_reference
from repro.serve.memory import PageAllocator, PrefixCache

from pool_model import PoolLifecycle


@functools.lru_cache(maxsize=1)
def _model(seed=0):
    cfg = get_config("musicgen-large").reduced()
    return init_lm_params(cfg, jax.random.PRNGKey(seed)), cfg


def _prefix_cfg(**kw):
    base = dict(slots=2, max_len=40, prefill_chunk=4, paged=True,
                page_tokens=4, prefix_cache=True)
    base.update(kw)
    return EngineConfig(**base)


# ---------------------------------------------------------------------------
# allocator: refcounts, sharing, COW
# ---------------------------------------------------------------------------

def test_allocator_refcounts_share_and_cow():
    a = PageAllocator(n_pages=6, page_tokens=4, slots=2, table_pages=8)
    assert a.ensure(0, 8)                      # 2 private pages
    p0, p1 = a.tables[0]
    assert a.refcount[p0] == a.refcount[p1] == 1
    assert a.map_shared(1, [p0, p1])           # slot 1 maps them read-only
    assert a.refcount[p0] == 2 and a.used_pages() == 2   # unique count
    # COW on the shared entry: fresh page, old loses one ref
    pair = a.cow(1, 0)
    assert pair is not None and pair[0] == p0
    assert a.refcount[p0] == 1 and a.refcount[pair[1]] == 1
    assert a.tables[1][0] == pair[1]
    assert a.cow(1, 0) is None                 # now exclusive: no copy
    # release decrefs; shared page survives via the other table
    a.release(1)
    assert a.refcount[p0] == 1 and a.refcount[pair[1]] == 0
    assert pair[1] in a.free_list
    a.release(0)
    assert a.free_pages == 6


def test_allocator_map_shared_respects_table_width():
    a = PageAllocator(n_pages=8, page_tokens=4, slots=2, table_pages=3)
    assert a.ensure(0, 12)
    assert not a.map_shared(1, a.tables[0] + a.tables[0])   # 6 > 3
    assert a.tables[1] == [] and all(a.refcount[p] == 1 for p in a.tables[0])


# ---------------------------------------------------------------------------
# trie: match / insert / evict
# ---------------------------------------------------------------------------

def _trie(n_pages=8):
    a = PageAllocator(n_pages, page_tokens=4, slots=2, table_pages=8)
    return a, PrefixCache(a, salt=("t",))


def test_trie_match_insert_longest_prefix():
    a, t = _trie()
    toks = np.arange(12, dtype=np.int32)
    assert a.ensure(0, 12)
    t.insert(toks, a.tables[0])                # 3 full pages
    assert len(t) == 3
    assert t.match(toks) == a.tables[0][:3]
    assert t.match(toks[:9]) == a.tables[0][:2]      # page-aligned only
    other = np.concatenate([toks[:8], np.array([99, 98, 97, 96], np.int32)])
    assert t.match(other) == a.tables[0][:2]         # diverges at page 2
    assert t.match(np.array([5, 6, 7, 8], np.int32)) == []
    # first writer wins: re-inserting the same run under different pages
    # keeps the existing nodes
    assert a.ensure(1, 12)
    t.insert(toks, a.tables[1])
    assert t.match(toks) == a.tables[0][:3]
    assert all(a.refcount[p] == 1 for p in a.tables[1])


def test_trie_salt_isolates_rank_plans():
    a = PageAllocator(8, page_tokens=4, slots=2, table_pages=8)
    t_a = PrefixCache(a, salt=("rank64",))
    t_b = PrefixCache(a, salt=("rank32",))
    toks = np.arange(8, dtype=np.int32)
    assert a.ensure(0, 8)
    t_a.insert(toks, a.tables[0])
    assert t_b.match(toks) == []               # never aliases across salts
    assert t_a.match(toks) == a.tables[0][:2]


def test_trie_extra_key_isolates_tenants():
    """Per-adapter trie partition (DESIGN.md §13): runs inserted under an
    ``extra`` key never match other keys, and ``extra=()`` is the same
    namespace as the legacy positional calls."""
    a, t = _trie()
    toks = np.arange(8, dtype=np.int32)
    assert a.ensure(0, 8) and a.ensure(1, 8)
    t.insert(toks, a.tables[0])                  # legacy call, no extra
    t.insert(toks, a.tables[1], extra=(1,))
    assert t.match(toks, extra=()) == a.tables[0][:2]    # () == legacy
    assert t.match(toks) == a.tables[0][:2]
    assert t.match(toks, extra=(1,)) == a.tables[1][:2]
    assert t.match(toks, extra=(2,)) == []               # unknown tenant
    # hash chains are stable per (salt, extra) and disjoint across keys
    assert t.chain_hashes(toks, 2) == t.chain_hashes(toks, 2, extra=())
    assert t.chain_hashes(toks, 2) != t.chain_hashes(toks, 2, extra=(1,))


def test_trie_evict_lru_leaf_first_and_skips_mapped():
    a, t = _trie(n_pages=8)
    old = np.arange(8, dtype=np.int32)
    new = np.arange(8, dtype=np.int32) + 50
    assert a.ensure(0, 8) and a.ensure(1, 8)
    t.insert(old, a.tables[0])
    pages_old = list(a.tables[0])
    t.insert(new, a.tables[1])
    a.release(0)
    a.release(1)                               # all 4 pages trie-only now
    t.match(new)                               # refresh "new"'s clock
    assert t.evict(1) == 1                     # evicts the LRU leaf first
    assert t.match(old) == pages_old[:1]       # old's LEAF went, root kept
    assert pages_old[1] in a.free_list
    # a mapped page is never evictable: map "new"'s pages into a slot
    assert a.map_shared(0, t.match(new))
    assert len(t.match(old)) == 1
    t.evict(8)
    assert t.match(old) == []                  # unmapped: evicted
    assert len(t.match(new)) == 2              # mapped (refcount 2): kept


# ---------------------------------------------------------------------------
# page_copy kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nb,N,pt,KV,r", [(3, 9, 4, 2, 16), (1, 5, 8, 1, 8)])
def test_page_copy_kernel_matches_ref(nb, N, pt, KV, r):
    pool = jax.random.normal(jax.random.PRNGKey(0), (nb, N, pt, KV, r))
    # distinct pairs + sentinel self-copy padding (row N-1)
    src = jnp.array([0, 3, N - 1], jnp.int32)
    dst = jnp.array([2, 1, N - 1], jnp.int32)
    o_ref = ref.page_copy_ref(pool, src, dst)
    o_pal = ops.page_copy(pool, src, dst, impl="interpret")
    np.testing.assert_array_equal(np.asarray(o_pal), np.asarray(o_ref))
    # copied rows hold the src content; untouched rows keep their bytes
    np.testing.assert_array_equal(np.asarray(o_pal)[:, 2],
                                  np.asarray(pool)[:, 0])
    untouched = [i for i in range(N) if i not in (1, 2)]
    np.testing.assert_array_equal(np.asarray(o_pal)[:, untouched],
                                  np.asarray(pool)[:, untouched])


# ---------------------------------------------------------------------------
# engine: exactness of warm replays, COW full hits, sharing
# ---------------------------------------------------------------------------

def test_warm_replay_exact_and_skips_prefill():
    """Replaying prompts that share a system prefix hits the trie: the
    streams stay reference-exact and the warm requests' first token
    arrives in strictly fewer engine steps than the cold ones."""
    params, cfg = _model()
    sys_p = (np.arange(16, dtype=np.int32) * 3 + 1) % cfg.vocab_size
    prompts = [np.concatenate([sys_p, np.arange(3, dtype=np.int32) + 7 * i])
               .astype(np.int32) for i in range(3)]
    refs = [greedy_reference(params, cfg, p, 5) for p in prompts]

    def first_token_steps(eng, req):
        eng.submit(req)
        steps = 0
        while not req.generated:
            eng.step()
            steps += 1
        while not req.done:
            eng.step()
        return steps

    eng = Engine(params, cfg, _prefix_cfg())
    cold_steps = first_token_steps(
        eng, Request(uid=0, prompt=prompts[0], max_new_tokens=5))
    for i, (p, want) in enumerate(zip(prompts, refs)):
        req = Request(uid=1 + i, prompt=p, max_new_tokens=5)
        warm_steps = first_token_steps(eng, req)
        assert req.cached_tokens == 16, req.uid    # 4 shared pages
        assert req.generated == want, req.uid
        assert warm_steps < cold_steps
    assert eng.sched.prefix_hits == 3
    assert refs[0]  # seed stream exact too (checked via i == 0 above)


def test_full_hit_cow_keeps_shared_pages_intact():
    """A page-aligned full hit resumes at L-1 INSIDE a shared page: the
    rewrite must COW it, so replaying the same prompt repeatedly stays
    exact every time (a mutated shared page would corrupt replay 3)."""
    params, cfg = _model()
    prompt = (np.arange(20, dtype=np.int32) * 5 + 2) % cfg.vocab_size
    want = greedy_reference(params, cfg, prompt, 4)
    eng = Engine(params, cfg, _prefix_cfg())
    for i in range(3):
        req = Request(uid=i, prompt=prompt, max_new_tokens=4)
        eng.run([req])
        assert req.generated == want, i
        if i > 0:
            assert req.cached_tokens == 19     # full hit resumes at L-1
    assert eng.compiled_shapes() in (3, None)  # +1 page-copy shape only


def test_concurrent_requests_share_pages():
    """Two in-flight requests with the same prompt: prefill-end
    publication lets the second map the first's pages while BOTH are
    still decoding — and the pool's unique-page footprint shrinks."""
    params, cfg = _model()
    prompt = (np.arange(12, dtype=np.int32) * 3 + 4) % cfg.vocab_size
    want = greedy_reference(params, cfg, prompt, 6)
    eng = Engine(params, cfg, _prefix_cfg())
    r1 = Request(uid=0, prompt=prompt, max_new_tokens=6)
    eng.submit(r1)
    for _ in range(3):                         # 12 tokens / chunk 4
        eng.step()
    assert len(eng.prefix) == 3                # prompt pages published
    r2 = Request(uid=1, prompt=prompt, max_new_tokens=6)
    eng.submit(r2)
    eng.run([])
    assert r1.generated == want and r2.generated == want
    assert r2.cached_tokens == 11              # full hit (12 aligned: L-1)
    shared = [p for p in range(eng.alloc.n_pages)
              if eng.alloc.refcount[p] > 1]
    assert shared or eng.prefix.evicted == 0   # pages really were shared


def test_preempted_sequence_resumes_from_trie():
    """Preemption publishes the committed run; re-admission matches it,
    so the re-prefill is mostly skipped and the stream stays exact."""
    params, cfg = _model(seed=1)
    p1 = np.arange(8, dtype=np.int32) + 3
    p2 = np.arange(8, dtype=np.int32) + 17
    ecfg = _prefix_cfg(max_len=32, n_pages=6)  # forces preemption
    eng = Engine(params, cfg, ecfg)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=8)
            for i, p in enumerate((p1, p2))]
    eng.run(reqs)
    assert eng.sched.preemptions >= 1
    for r, p in zip(reqs, (p1, p2)):
        assert r.done
        assert r.generated == greedy_reference(params, cfg, p, 8), r.uid


def test_spec_decoding_composes_with_prefix_cache():
    params, cfg = _model(seed=1)
    sys_p = (np.arange(12, dtype=np.int32) * 3 + 1) % cfg.vocab_size
    prompts = [np.concatenate(
        [sys_p, np.arange(3, dtype=np.int32) + 9 * i]).astype(np.int32)
        for i in range(3)]
    refs = [greedy_reference(params, cfg, p, 6) for p in prompts]
    ecfg = _prefix_cfg(spec_k=3, draft_rank_ratio=0.5)
    eng = Engine(params, cfg, ecfg)
    eng.run([Request(uid=0, prompt=prompts[0], max_new_tokens=6)])
    reqs = [Request(uid=1 + i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    for r, want in zip(reqs, refs):
        assert r.cached_tokens == 12 and r.generated == want, r.uid
    assert eng.compiled_shapes() in (3, 4, 5, None)


def test_prefix_cache_config_guards():
    params, cfg = _model()
    with pytest.raises(ValueError, match="paged"):
        Engine(params, cfg, EngineConfig(slots=1, max_len=16,
                                         prefix_cache=True))
    rcfg = get_config("rwkv6-1.6b").reduced()
    rparams = init_lm_params(rcfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="attention-only"):
        Engine(rparams, rcfg, EngineConfig(slots=1, max_len=16, paged=True,
                                           prefix_cache=True))


# ---------------------------------------------------------------------------
# write-floor defense: sub-floor scatter-writes land in the garbage row
# ---------------------------------------------------------------------------

def test_write_floor_protects_read_only_prefix():
    """Even if the host COW logic failed, a window scattered below
    ``write_floor`` must land in the pool's garbage row: every real
    page keeps its bytes bit-for-bit."""
    params, cfg = _model()
    state = T.init_decode_state_paged(cfg, 1, n_pages=4, page_tokens=4)
    pages = jnp.array([[0, 1, 2, 3]], jnp.int32)
    toks = jnp.arange(8, dtype=jnp.int32)[None]
    _, state = T.prefill_chunk(params, cfg, toks, state,
                               jnp.array([8], jnp.int32), pages=pages)
    before = jax.tree.map(lambda a: np.asarray(a), state["blocks"])
    # rewind and replay the SAME window with the floor at 8: all its
    # writes are sub-floor and must be rerouted to the garbage row
    state["index"] = jnp.zeros((1,), jnp.int32)
    _, poisoned = T.prefill_chunk(params, cfg, toks + 1, state,
                                  jnp.array([8], jnp.int32), pages=pages,
                                  write_floor=jnp.array([8], jnp.int32))

    def real_rows(tree):
        out = []
        jax.tree_util.tree_map_with_path(
            lambda p, leaf: out.append(np.asarray(leaf)[:, :4])
            if any(getattr(q, "key", None) == "kv" for q in p) else None,
            tree)
        return out

    for a, b in zip(real_rows(before), real_rows(poisoned["blocks"])):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# lifecycle random walk (no-hypothesis counterpart of the state machine)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(8))
def test_pool_lifecycle_random_walk(seed):
    """Seeded random admit/COW-write/close/evict walk over the shared
    PoolLifecycle model; invariants checked after every operation."""
    rng = np.random.default_rng(seed)
    pool = PoolLifecycle(n_pages=12, page_tokens=4, slots=3,
                         table_pages=10)
    for _ in range(300):
        op = rng.integers(0, 6)
        if op == 0 and pool.free_slots():
            L = int(rng.integers(1, pool.table * pool.pt - 8))
            pool.admit(pool.free_slots()[0],
                       rng.integers(0, 3, L).astype(np.int32))
        elif op in (1, 2) and pool.active_slots():
            s = int(rng.choice(pool.active_slots()))
            take = int(rng.integers(1, 7))
            pool.write(s, take, rng.integers(0, 3, take).astype(np.int32))
        elif op == 3 and pool.active_slots():
            pool.close(int(rng.choice(pool.active_slots())))
        elif op == 4 and pool.active_slots():
            # cancel/shed/timeout: release with NO publish
            pool.drop(int(rng.choice(pool.active_slots())))
        else:
            pool.evict(int(rng.integers(1, 5)))
        pool.check()
    while pool.active_slots():
        pool.close(pool.active_slots()[0])
        pool.check()
    pool.evict(pool.alloc.n_pages)
    pool.check()
    assert pool.alloc.free_pages == pool.alloc.n_pages
