"""CLOVER decomposition invariants: the paper's core claims as tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, ASSIGNED_ARCHS
from repro.core import (clover_decompose, merge_clover, svd_lowrank_product,
                        svd_tall, qk_mode)
from repro.models import init_lm_params, forward

ALL_ARCHS = ASSIGNED_ARCHS + ["gpt2-xl"]


def _dropless(cfg):
    if cfg.moe:
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.0))
    return cfg


def _setup(name, seed=0, B=2, S=8):
    cfg = _dropless(get_config(name).reduced())
    key = jax.random.PRNGKey(seed)
    params = init_lm_params(cfg, key)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    fe = None
    if cfg.frontend != "none":
        fe = jax.random.normal(key, (B, cfg.frontend_len, cfg.d_model)) * 0.02
    return cfg, params, toks, fe


# ---------------------------------------------------------------------------
# QR-trick SVD correctness
# ---------------------------------------------------------------------------

def test_svd_lowrank_product_reconstructs():
    key = jax.random.PRNGKey(1)
    A = jax.random.normal(key, (96, 16))
    B = jax.random.normal(jax.random.PRNGKey(2), (64, 16))
    U, S, Vt = svd_lowrank_product(A, B)
    np.testing.assert_allclose(np.asarray((U * S) @ Vt),
                               np.asarray(A @ B.T), atol=1e-4)
    # orthonormal factors, descending spectrum
    np.testing.assert_allclose(np.asarray(U.T @ U), np.eye(16), atol=1e-5)
    np.testing.assert_allclose(np.asarray(Vt @ Vt.T), np.eye(16), atol=1e-5)
    assert bool(jnp.all(S[:-1] >= S[1:] - 1e-6))


def test_svd_tall_reconstructs():
    W = jax.random.normal(jax.random.PRNGKey(3), (80, 24))
    U, S, Vt = svd_tall(W)
    np.testing.assert_allclose(np.asarray((U * S) @ Vt), np.asarray(W),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# function preservation (the paper's central invariance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALL_ARCHS)
def test_decompose_preserves_function(name):
    cfg, params, toks, fe = _setup(name)
    base, _ = forward(params, cfg, toks, frontend_embeds=fe)
    scale = float(jnp.max(jnp.abs(base))) + 1e-6
    for peft in (True, False):
        p2, cfg2, _ = clover_decompose(params, cfg, peft=peft)
        out, _ = forward(p2, cfg2, toks, frontend_embeds=fe)
        err = float(jnp.max(jnp.abs(out - base))) / scale
        assert err < 1e-4, f"{name} peft={peft}: rel err {err}"


@pytest.mark.parametrize("name", ["musicgen-large", "jamba-v0.1-52b",
                                  "stablelm-3b"])
def test_merge_back_roundtrip(name):
    cfg, params, toks, fe = _setup(name)
    base, _ = forward(params, cfg, toks, frontend_embeds=fe)
    p2, cfg2, _ = clover_decompose(params, cfg, peft=True)
    # perturb the trainable transitions (simulating fine-tuning)...
    def bump(path, leaf):
        names = [getattr(p, "key", "") for p in path]
        if any(n in ("s_qk", "s_vo", "k_t", "up_t") for n in names):
            return leaf + 0.01 * jax.random.normal(
                jax.random.PRNGKey(hash(tuple(names)) % 2**31), leaf.shape)
        return leaf
    p2b = jax.tree_util.tree_map_with_path(bump, p2)
    tuned, _ = forward(p2b, cfg2, toks, frontend_embeds=fe)
    # ...then merging must preserve the TUNED function exactly
    p3, cfg3 = merge_clover(p2b, cfg2)
    merged, _ = forward(p3, cfg3, toks, frontend_embeds=fe)
    scale = float(jnp.max(jnp.abs(tuned))) + 1e-6
    assert float(jnp.max(jnp.abs(merged - tuned))) / scale < 1e-4
    # and the merged tree has no leftover adapter keys
    leaves = [getattr(p[-1], "key", "")
              for p, _ in jax.tree_util.tree_flatten_with_path(p3)[0]]
    assert not any(k in ("s_qk", "s_vo", "k_t", "up_t") for k in leaves)


def test_qk_mode_per_arch():
    assert qk_mode(get_config("musicgen-large")) == "cross"
    assert qk_mode(get_config("stablelm-3b")) == "partial"
    assert qk_mode(get_config("phi3-medium-14b")) == "intra"
    assert qk_mode(get_config("gpt2-xl")) == "cross"


def test_spectra_shapes_and_order():
    cfg, params, _, _ = _setup("musicgen-large")
    _, _, extras = clover_decompose(params, cfg, peft=False)
    sp = extras[0]["spectra"]
    assert "qk" in sp and "vo" in sp
    s = np.asarray(sp["qk"])           # (n_blocks, KV, d)
    assert s.shape[-1] == cfg.head_dim_
    assert (np.diff(s, axis=-1) <= 1e-5).all(), "spectra must be sorted"
