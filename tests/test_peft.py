"""PEFT: CLOVER-S training mechanics + LoRA/DoRA/PiSSA baselines."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (clover_decompose, merge_clover, PeftConfig,
                        partition, combine, count_params, init_adapters,
                        materialize, pissa_residual)
from repro.models import init_lm_params, forward
from repro.optim import AdamWConfig
from repro.train.step import TrainConfig, make_train_step, make_opt_state
from repro.launch.mesh import make_host_mesh


def _setup(name="gpt2-xl", seed=0):
    cfg = get_config(name).reduced()
    key = jax.random.PRNGKey(seed)
    params = init_lm_params(cfg, key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    return cfg, params, toks


@pytest.mark.parametrize("method", ["lora", "dora", "pissa"])
def test_adapter_init_is_identity(method):
    cfg, params, toks = _setup()
    base, _ = forward(params, cfg, toks)
    pc = PeftConfig(method=method, rank=4)
    ad = init_adapters(params, pc, jax.random.PRNGKey(1))
    p0 = pissa_residual(params, ad, pc) if method == "pissa" else params
    eff = materialize(p0, ad, pc)
    out, _ = forward(eff, cfg, toks)
    scale = float(jnp.max(jnp.abs(base))) + 1e-6
    assert float(jnp.max(jnp.abs(out - base))) / scale < 1e-4


def test_partition_combine_roundtrip():
    cfg, params, _ = _setup()
    p2, cfg2, _ = clover_decompose(params, cfg, peft=True)
    tr, fr = partition(p2)
    back = combine(tr, fr)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(p2)[0],
            jax.tree_util.tree_flatten_with_path(back)[0]):
        assert jax.tree_util.keystr(pa) == jax.tree_util.keystr(pb)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert count_params(tr) > 0
    assert count_params(tr) + count_params(fr) == count_params(p2)


def test_clover_s_grads_only_touch_transitions():
    """peft_mode training updates ONLY the S matrices (+ nothing else)."""
    cfg, params, toks = _setup("musicgen-large")
    p2, cfg2, _ = clover_decompose(params, cfg, peft=True)
    mesh = make_host_mesh()
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-2, weight_decay=0.0),
                       warmup_steps=1, total_steps=10, remat=False,
                       peft_mode=True)
    step, _ = make_train_step(cfg2, tcfg, mesh)
    opt = make_opt_state(p2, peft_mode=True)
    batch = {"tokens": toks, "labels": toks}
    jstep = jax.jit(step)
    p3, opt, metrics = jstep(p2, opt, batch)
    p3, opt, metrics = jstep(p3, opt, batch)  # step 0 is inside warmup
    changed, unchanged = [], []
    for (path, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(p2)[0],
            jax.tree_util.tree_flatten_with_path(p3)[0]):
        names = [getattr(q, "key", "") for q in path]
        diff = float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                     - b.astype(jnp.float32))))
        if any(n in ("s_qk", "s_vo", "k_t", "up_t") for n in names):
            changed.append(diff)
        else:
            unchanged.append(diff)
    assert max(unchanged) == 0.0, "frozen leaves moved"
    assert max(changed) > 0.0, "trainable transitions did not move"


def test_clover_param_budget_vs_lora():
    """Appendix A.2: CLOVER per-head S params ~ LoRA rank-d/2... the
    reduced config just checks the formula H*dq^2 + H*dv^2 + blocks."""
    cfg, params, _ = _setup("musicgen-large")
    p2, _, _ = clover_decompose(params, cfg, peft=True)
    tr, _ = partition(p2)
    n = count_params(tr)
    d = cfg.head_dim_
    H, KV = cfg.n_heads, cfg.n_kv_heads
    per_layer = H * d * d + H * d * d   # s_qk + s_vo (cross mode)
    up_blocks = cfg.d_ff // min(cfg.clover.up_block, cfg.d_ff)
    per_layer += up_blocks * min(cfg.clover.up_block, cfg.d_ff) ** 2
    assert n == cfg.n_layers * per_layer


def test_full_finetune_then_merge_preserves():
    """Train S a few steps, merge, verify function equality."""
    cfg, params, toks = _setup("musicgen-large")
    p2, cfg2, _ = clover_decompose(params, cfg, peft=True)
    mesh = make_host_mesh()
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=5e-3, weight_decay=0.0),
                       warmup_steps=1, total_steps=5, remat=False,
                       peft_mode=True)
    step, _ = make_train_step(cfg2, tcfg, mesh)
    opt = make_opt_state(p2, peft_mode=True)
    batch = {"tokens": toks, "labels": toks}
    jstep = jax.jit(step)
    losses = []
    for _ in range(5):
        p2, opt, m = jstep(p2, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], "PEFT training did not reduce loss"
    tuned, _ = forward(p2, cfg2, toks)
    p3, cfg3 = merge_clover(p2, cfg2)
    merged, _ = forward(p3, cfg3, toks)
    scale = float(jnp.max(jnp.abs(tuned))) + 1e-6
    assert float(jnp.max(jnp.abs(merged - tuned))) / scale < 1e-4
