"""PEFT: CLOVER-S training mechanics + LoRA/DoRA/PiSSA baselines."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (clover_decompose, merge_clover, PeftConfig,
                        partition, combine, count_params, init_adapters,
                        materialize, pissa_residual, merge_adapters,
                        sv_extract, sv_fold, AdapterRegistry)
from repro.models import init_lm_params, forward
from repro.optim import AdamWConfig
from repro.train.step import TrainConfig, make_train_step, make_opt_state
from repro.launch.mesh import make_host_mesh


def _setup(name="gpt2-xl", seed=0):
    cfg = get_config(name).reduced()
    key = jax.random.PRNGKey(seed)
    params = init_lm_params(cfg, key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    return cfg, params, toks


@pytest.mark.parametrize("method", ["lora", "dora", "pissa"])
def test_adapter_init_is_identity(method):
    cfg, params, toks = _setup()
    base, _ = forward(params, cfg, toks)
    pc = PeftConfig(method=method, rank=4)
    ad = init_adapters(params, pc, jax.random.PRNGKey(1))
    p0 = pissa_residual(params, ad, pc) if method == "pissa" else params
    eff = materialize(p0, ad, pc)
    out, _ = forward(eff, cfg, toks)
    scale = float(jnp.max(jnp.abs(base))) + 1e-6
    assert float(jnp.max(jnp.abs(out - base))) / scale < 1e-4


def test_partition_combine_roundtrip():
    cfg, params, _ = _setup()
    p2, cfg2, _ = clover_decompose(params, cfg, peft=True)
    tr, fr = partition(p2)
    back = combine(tr, fr)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(p2)[0],
            jax.tree_util.tree_flatten_with_path(back)[0]):
        assert jax.tree_util.keystr(pa) == jax.tree_util.keystr(pb)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert count_params(tr) > 0
    assert count_params(tr) + count_params(fr) == count_params(p2)


def test_clover_s_grads_only_touch_transitions():
    """peft_mode training updates ONLY the S matrices (+ nothing else)."""
    cfg, params, toks = _setup("musicgen-large")
    p2, cfg2, _ = clover_decompose(params, cfg, peft=True)
    mesh = make_host_mesh()
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-2, weight_decay=0.0),
                       warmup_steps=1, total_steps=10, remat=False,
                       peft_mode=True)
    step, _ = make_train_step(cfg2, tcfg, mesh)
    opt = make_opt_state(p2, peft_mode=True)
    batch = {"tokens": toks, "labels": toks}
    jstep = jax.jit(step)
    p3, opt, metrics = jstep(p2, opt, batch)
    p3, opt, metrics = jstep(p3, opt, batch)  # step 0 is inside warmup
    changed, unchanged = [], []
    for (path, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(p2)[0],
            jax.tree_util.tree_flatten_with_path(p3)[0]):
        names = [getattr(q, "key", "") for q in path]
        diff = float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                     - b.astype(jnp.float32))))
        if any(n in ("s_qk", "s_vo", "k_t", "up_t") for n in names):
            changed.append(diff)
        else:
            unchanged.append(diff)
    assert max(unchanged) == 0.0, "frozen leaves moved"
    assert max(changed) > 0.0, "trainable transitions did not move"


def test_clover_param_budget_vs_lora():
    """Appendix A.2: CLOVER per-head S params ~ LoRA rank-d/2... the
    reduced config just checks the formula H*dq^2 + H*dv^2 + blocks."""
    cfg, params, _ = _setup("musicgen-large")
    p2, _, _ = clover_decompose(params, cfg, peft=True)
    tr, _ = partition(p2)
    n = count_params(tr)
    d = cfg.head_dim_
    H, KV = cfg.n_heads, cfg.n_kv_heads
    per_layer = H * d * d + H * d * d   # s_qk + s_vo (cross mode)
    up_blocks = cfg.d_ff // min(cfg.clover.up_block, cfg.d_ff)
    per_layer += up_blocks * min(cfg.clover.up_block, cfg.d_ff) ** 2
    assert n == cfg.n_layers * per_layer


def test_full_finetune_then_merge_preserves():
    """Train S a few steps, merge, verify function equality."""
    cfg, params, toks = _setup("musicgen-large")
    p2, cfg2, _ = clover_decompose(params, cfg, peft=True)
    mesh = make_host_mesh()
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=5e-3, weight_decay=0.0),
                       warmup_steps=1, total_steps=5, remat=False,
                       peft_mode=True)
    step, _ = make_train_step(cfg2, tcfg, mesh)
    opt = make_opt_state(p2, peft_mode=True)
    batch = {"tokens": toks, "labels": toks}
    jstep = jax.jit(step)
    losses = []
    for _ in range(5):
        p2, opt, m = jstep(p2, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], "PEFT training did not reduce loss"
    tuned, _ = forward(p2, cfg2, toks)
    p3, cfg3 = merge_clover(p2, cfg2)
    merged, _ = forward(p3, cfg3, toks)
    scale = float(jnp.max(jnp.abs(tuned))) + 1e-6
    assert float(jnp.max(jnp.abs(merged - tuned))) / scale < 1e-4


def test_narrow_target_scales_by_effective_rank():
    """A target narrower than the configured rank must be scaled by
    alpha / r_eff (the clamped rank), not alpha / rank — regression for
    the silent 8x under-scaling on narrow targets."""
    pc = PeftConfig(method="lora", rank=32, alpha=32.0, targets=("wq",))
    params = {"wq": jnp.zeros((1, 8, 4, 1), jnp.float32)}  # flat (1, 8, 4)
    ad = init_adapters(params, pc, jax.random.PRNGKey(0))
    (name, entry), = ad.items()
    assert float(entry["r_eff"]) == 4.0          # min(n_in=8, n_out=4)
    entry["b"] = jnp.ones_like(entry["b"])       # make the delta nonzero
    eff = materialize(params, ad, pc)
    delta = jnp.einsum("nor,nri->nio", entry["b"], entry["a"])
    want = ((pc.alpha / 4.0) * delta).reshape(params["wq"].shape)
    np.testing.assert_allclose(np.asarray(eff["wq"]), np.asarray(want),
                               rtol=1e-6)
    # the nominal scale would have been 8x too small here
    assert pc.scale == 1.0


def test_pissa_residual_roundtrip_is_original():
    """materialize(pissa_residual(params, ad), ad) == params at init, to
    float32 rounding (the subtract/re-add of the principal component)."""
    cfg, params, _ = _setup()
    pc = PeftConfig(method="pissa", rank=4)
    ad = init_adapters(params, pc, jax.random.PRNGKey(1))
    back = materialize(pissa_residual(params, ad, pc), ad, pc)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_flatten_with_path(back)[0]):
        assert jax.tree_util.keystr(pa) == jax.tree_util.keystr(pb)
        d = float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32))))
        s = float(jnp.max(jnp.abs(a))) + 1e-9
        assert d / s < 1e-6, jax.tree_util.keystr(pa)


def test_merge_adapters_init_is_bitwise_identity():
    """LoRA's zero-init b makes the init-time merge exactly W + 0, so
    merging (or re-merging) a fresh adapter must change no bits."""
    cfg, params, _ = _setup()
    pc = PeftConfig(method="lora", rank=4)
    ad = init_adapters(params, pc, jax.random.PRNGKey(1))
    merged = merge_adapters(params, ad, pc)
    twice = merge_adapters(merged, ad, pc)       # idempotent at init
    for a, b, c in zip(jax.tree.leaves(params), jax.tree.leaves(merged),
                       jax.tree.leaves(twice)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(b), np.asarray(c))


def test_sv_extract_fold_bitwise_inverse():
    """sv_fold(params, sv_extract(params)) must reproduce every leaf
    bitwise — diagonals re-written with their own values, off-diagonal
    transition content and every other train key untouched."""
    cfg, params, _ = _setup("musicgen-large")
    p2, _, _ = clover_decompose(params, cfg, peft=True)
    diags = sv_extract(p2)
    assert any(diags), "no SV transitions extracted"
    for entry in diags:
        if entry:
            assert set(entry) <= {"s_qk_diag", "s_vo_diag"}
    back = sv_fold(p2, diags)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(p2)[0],
            jax.tree_util.tree_flatten_with_path(back)[0]):
        assert jax.tree_util.keystr(pa) == jax.tree_util.keystr(pb)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.fixture(scope="module")
def decomposed():
    cfg, params, _ = _setup("musicgen-large")
    p2, cfg2, _ = clover_decompose(params, cfg, peft=True)
    return p2, cfg2


def test_adapter_registry_identity_and_validation(decomposed):
    p2, _ = decomposed
    reg = AdapterRegistry(p2)
    assert len(reg) == 1 and reg.n_adapters == 1
    # id 0 folds back to the base model bitwise (x * 1.0 == x)
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(reg.folded(p2, 0))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # malformed registrations fail loudly
    with pytest.raises(ValueError):
        reg.register(tuple({} for _ in reg.get(0)))          # missing keys
    with pytest.raises(ValueError):
        reg.register(reg.get(0) + reg.get(0))                # wrong length
    with pytest.raises(ValueError):
        reg.register(tuple(
            {k: v[..., :1] for k, v in e.items()} for e in reg.get(0)))
    with pytest.raises(ValueError):
        reg.update(0, reg.get(0))          # identity slot is reserved
    # the registry refuses non-decomposed params outright
    cfg, params, _ = _setup("musicgen-large")
    with pytest.raises(ValueError):
        AdapterRegistry(params)


def test_adapter_registry_bank_and_versions(decomposed):
    p2, _ = decomposed
    reg = AdapterRegistry(p2)
    two = tuple({k: 2.0 * v for k, v in e.items()} for e in reg.get(0))
    aid = reg.register(two)
    assert aid == 1 and len(reg) == 2
    assert reg.version(aid) == 0
    g0 = reg.generation
    assert reg.update(aid, two) == 1 and reg.generation == g0 + 1
    bank = reg.bank()
    assert len(bank) == len(reg.get(0))
    seen = 0
    for pos, entry in zip(bank, reg.get(0)):
        if pos is None:
            assert not entry
            continue
        for bk, sk in (("a_qk", "s_qk_diag"), ("a_vo", "s_vo_diag")):
            if sk in entry:
                seen += 1
                nb, A, H, d = pos[bk].shape
                assert A == len(reg)
                assert (nb, H, d) == tuple(entry[sk].shape)
                np.testing.assert_array_equal(
                    np.asarray(pos[bk][:, 0]), 1.0)   # id 0 = identity
                np.testing.assert_array_equal(
                    np.asarray(pos[bk][:, 1]), 2.0)
    assert seen > 0
    # scales_from_finetuned of the base diagonals is the identity adapter
    ident = reg.scales_from_finetuned(sv_extract(p2))
    for e in ident:
        for v in e.values():
            np.testing.assert_array_equal(np.asarray(v), 1.0)
