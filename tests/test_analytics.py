"""Analytics functions backing Figs 2/4/5/6."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.analytics import (coverage, delta_spectrum, effective_rank,
                                  energy_topk, intruder_dims,
                                  projection_mass, qk_curves, vo_curves)
from repro.models import init_lm_params


def _attn0(cfg, params):
    j = next(i for i, (m, _) in enumerate(cfg.pattern) if m == "attn")
    return jax.tree.map(lambda a: a[0], params["blocks"][j]["attn"])


def test_curves_shapes():
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    attn = _attn0(cfg, params)
    S, van = qk_curves(attn, cfg.q_per_kv)
    assert S.shape == van.shape == (cfg.n_kv_heads, cfg.head_dim_)
    Sv, vanv = vo_curves(attn, cfg.q_per_kv)
    assert Sv.shape == (cfg.n_kv_heads, cfg.head_dim_)
    # spectra sorted descending
    assert bool(jnp.all(S[:, :-1] >= S[:, 1:] - 1e-5))


def test_energy_topk_bounds():
    s = jnp.array([[4.0, 2.0, 1.0, 0.0]])
    e = energy_topk(s, 2)
    np.testing.assert_allclose(float(e[0]), 20.0 / 21.0, atol=1e-6)
    assert float(energy_topk(s, 4)[0]) == 1.0


def test_projection_mass_normalized():
    X = jax.random.normal(jax.random.PRNGKey(0), (32, 16))
    dirs = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(1),
                                           (16, 16)))[0]
    p = projection_mass(X, dirs)
    np.testing.assert_allclose(float(jnp.sum(p)), 1.0, atol=1e-5)


def test_coverage_full_basis_is_one():
    X = jax.random.normal(jax.random.PRNGKey(0), (32, 8))
    Q = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(1), (8, 8)))[0]
    assert abs(coverage(X, Q) - 1.0) < 1e-5
    assert coverage(X, Q[:, :2]) < 1.0


def test_delta_rank_and_intruders():
    key = jax.random.PRNGKey(0)
    W0 = jax.random.normal(key, (48, 48))
    lowrank = (jax.random.normal(jax.random.PRNGKey(1), (48, 3))
               @ jax.random.normal(jax.random.PRNGKey(2), (3, 48)))
    s = delta_spectrum(W0, W0 + 2.0 * lowrank)
    assert effective_rank(s, tol=1e-2) == 3
    # a big low-rank perturbation injects intruder dims; identity doesn't
    assert intruder_dims(W0, W0 + 5.0 * lowrank, k=8) >= 1
    assert intruder_dims(W0, W0, k=8) == 0
