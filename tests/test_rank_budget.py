"""Spectrum-driven rank budgets (DESIGN.md §14): planner invariants,
§5 applicability, plan-salt isolation, rank-clamped kernel parity, and
tp token identity under a non-uniform plan."""
import dataclasses
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (RankBudget, apply_rank_budget, budget_kept_energy,
                        clover_decompose, plan_rank_budget)
from repro.core.prune import snap_rank, threshold_ratios
from repro.kernels import ref
from repro.kernels.decode_attention import flash_decode_ranked
from repro.kernels.paged_decode_attention import paged_flash_decode_ranked
from repro.serve.memory import PageAllocator, PrefixCache

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _cfg(rotary_pct=None):
    cfg = get_config("musicgen-large").reduced()     # cross: no RoPE
    if rotary_pct is not None:
        cfg = dataclasses.replace(cfg, rope=True, rotary_pct=rotary_pct)
    return cfg


def _spectra(nb, kv, d, seed=0, head_scale=None):
    """Descending per-head spectra, optionally scaled per (block, head)."""
    rng = np.random.default_rng(seed)
    s = np.sort(rng.uniform(0.1, 1.0, (nb, kv, d)), -1)[..., ::-1]
    if head_scale is not None:
        s = s * np.asarray(head_scale, np.float64)[..., None]
    return np.ascontiguousarray(s)


def _extras(cfg, seed=0, head_scale=None):
    """One attention position + one spectra-free position."""
    d = cfg.head_dim_
    d_eff = d - (cfg.rope_dims if 0 < cfg.rope_dims < d else 0)
    return [{"spectra": {
        "qk": _spectra(2, 2, d_eff, seed=seed, head_scale=head_scale),
        "vo": _spectra(2, 2, d, seed=seed + 1, head_scale=head_scale),
    }}, {}]


def _flat(plan):
    return (tuple(r for j in plan.qk_ranks for b in j for r in b),
            tuple(r for j in plan.vo_ranks for b in j for r in b))


def test_planner_monotone_in_budget():
    """A larger budget never shrinks any head's kept rank."""
    cfg = _cfg()
    extras = _extras(cfg, head_scale=[[1.0, 0.3], [0.7, 0.1]])
    m = cfg.clover.rank_multiple
    prev = None
    for total in range(4 * m, 2 * 2 * 2 * cfg.head_dim_ + 1, m):
        qk, vo = _flat(plan_rank_budget(extras, cfg, total_rank=total))
        if prev is not None:
            assert all(a <= b for a, b in zip(prev[0], qk))
            assert all(a <= b for a, b in zip(prev[1], vo))
        prev = (qk, vo)


def test_planner_budget_conservation():
    """Kept total lands within one snapped block above the target and
    clamps exactly at the mandatory floor and at capacity."""
    cfg = _cfg()
    extras = _extras(cfg, head_scale=[[1.0, 0.3], [0.7, 0.1]])
    d, m = cfg.head_dim_, cfg.clover.rank_multiple
    nb = kv = 2
    floor = nb * kv * 2 * m                  # one qk + one vo block each
    capacity = nb * kv * 2 * d
    for target in range(floor, capacity + 1, m):
        plan = plan_rank_budget(extras, cfg, total_rank=target)
        assert target <= plan.total_rank < target + m
        assert plan.total_rank == sum(sum(_flat(plan), ()))
    assert plan_rank_budget(extras, cfg, total_rank=1).total_rank == floor
    assert plan_rank_budget(
        extras, cfg, total_rank=10 ** 6).total_rank == capacity
    # the fractional form agrees with the absolute form
    full = plan_rank_budget(extras, cfg, budget=1.0)
    assert full.total_rank == capacity


def test_planner_beats_uniform_at_matched_total():
    """Greedy kept energy >= the uniform plan's at the same total."""
    cfg = _cfg()
    extras = _extras(cfg, head_scale=[[1.0, 0.25], [0.6, 0.1]])
    d = cfg.head_dim_
    keep = d // 2
    uniform = RankBudget(
        head_dim=d, rank_multiple=cfg.clover.rank_multiple,
        total_rank=2 * 2 * 2 * keep, budget=2 * 2 * 2 * keep,
        qk_ranks=(((keep, keep), (keep, keep)), ()),
        vo_ranks=(((keep, keep), (keep, keep)), ()))
    planned = plan_rank_budget(extras, cfg, total_rank=uniform.total_rank)
    assert planned.total_rank == uniform.total_rank
    assert (budget_kept_energy(extras, planned)
            >= budget_kept_energy(extras, uniform) - 1e-9)
    assert planned.qk_ranks != uniform.qk_ranks   # spread ⇒ non-uniform


def test_partial_rope_rotated_block_always_kept():
    """§5: in partial-RoPE mode every planned qk rank includes the
    rotated block — even at the minimum budget."""
    cfg = _cfg(rotary_pct=0.5)
    rot = cfg.rope_dims
    assert 0 < rot < cfg.head_dim_
    extras = _extras(cfg)
    m = cfg.clover.rank_multiple
    for total in (1, 100, 10 ** 6):
        plan = plan_rank_budget(extras, cfg, total_rank=total)
        qk, _ = _flat(plan)
        assert all(rot + m <= r <= cfg.head_dim_ for r in qk)
    tiny_qk, _ = _flat(plan_rank_budget(extras, cfg, total_rank=1))
    assert set(tiny_qk) == {rot + m}              # floor: rot + one block


def test_intra_mode_qk_untouchable():
    """§5: full RoPE pins every qk rank at head_dim; only V-O prunes."""
    cfg = _cfg(rotary_pct=1.0)
    d = cfg.head_dim_
    extras = [{"spectra": {"vo": _spectra(2, 2, d)}}, {}]
    for total in (1, 150, 10 ** 6):
        qk, vo = _flat(plan_rank_budget(extras, cfg, total_rank=total))
        assert set(qk) == {d}
        assert all(r <= d for r in vo)


def test_plan_salt_isolates_prefix_trie():
    """Pages published under one rank plan must never hit under
    another: the plan salt roots a disjoint key space."""
    cfg = _cfg()
    extras = _extras(cfg, head_scale=[[1.0, 0.3], [0.7, 0.1]])
    plan_a = plan_rank_budget(extras, cfg, total_rank=256)
    plan_b = plan_rank_budget(extras, cfg, total_rank=200)
    assert plan_a.salt() != plan_b.salt()
    # determinism: replanning the same budget reproduces the same salt
    assert plan_a.salt() == plan_rank_budget(
        extras, cfg, total_rank=256).salt()

    alloc = PageAllocator(n_pages=8, page_tokens=4, slots=1, table_pages=8)
    assert alloc.ensure(0, 8)                     # two pages for slot 0
    cache_a = PrefixCache(alloc, salt=plan_a.salt())
    cache_b = PrefixCache(alloc, salt=plan_b.salt())
    tokens = np.arange(8, dtype=np.int32)
    cache_a.insert(tokens, list(alloc.tables[0]))
    assert cache_a.match(tokens) == list(alloc.tables[0])
    assert cache_b.match(tokens) == []


def test_threshold_ratios_contract():
    """Regression pin: the uniform summary AND the per-layer/per-head
    implied keeps the docstring promises, on hand-built spectra."""
    cfg = _cfg()
    d, m = cfg.head_dim_, cfg.clover.rank_multiple
    # head (b, h) has exactly counts[b][h] singular values >= 0.5
    counts = np.array([[4, 12], [20, 30]])
    sp = np.full((2, 2, d), 0.1)
    for b in range(2):
        for h in range(2):
            sp[b, h, :counts[b, h]] = np.linspace(
                1.0, 0.5, counts[b, h])
    extras = [{"spectra": {"qk": sp, "vo": sp}}, {}]
    out = threshold_ratios(extras, cfg, qk_thresh=0.5, vo_thresh=0.5)
    snapped = tuple(tuple(snap_rank(int(c), m, d) for c in row)
                    for row in counts)            # ((8,16),(24,32))
    assert out["qk_keep"] == out["vo_keep"] == snap_rank(30, m, d) == 32
    assert out["qk_ratio"] == out["vo_ratio"] == 0.0
    assert out["qk_head_keeps"] == (snapped, ())
    assert out["vo_head_keeps"] == (snapped, ())


def _zero_pad(q, k, v, rq, rv, G):
    qz, kz, vz = q.copy(), k.copy(), v.copy()
    for h in range(len(rq)):
        qz[..., h * G:(h + 1) * G, rq[h]:] = 0.0
        kz[..., h, rq[h]:] = 0.0
        vz[..., h, rv[h]:] = 0.0
    return qz, kz, vz


def test_ranked_decode_kernel_parity():
    """Per-head rank clamp: on zero-padded data the clamped kernel is
    BITWISE the full-rank kernel (skipped blocks contribute exactly
    zero) and matches the truncating reference oracle."""
    rng = np.random.default_rng(0)
    B, KV, G, dq, dv, T, bt, rb = 3, 4, 2, 32, 24, 64, 16, 8
    q = rng.normal(size=(B, KV * G, dq)).astype(np.float32)
    k = rng.normal(size=(B, T, KV, dq)).astype(np.float32)
    v = rng.normal(size=(B, T, KV, dv)).astype(np.float32)
    lengths = np.array([5, 37, 64], np.int32)
    rq = np.array([8, 16, 32, 24], np.int32)
    rv = np.array([24, 8, 16, 24], np.int32)
    qz, kz, vz = _zero_pad(q, k, v, rq, rv, G)
    scale = 1.0 / np.sqrt(dq)
    out = flash_decode_ranked(qz, kz, vz, lengths, rq, rv, scale=scale,
                              block_t=bt, rank_block=rb, interpret=True)
    out_full = flash_decode_ranked(
        qz, kz, vz, lengths, np.full(KV, dq, np.int32),
        np.full(KV, dv, np.int32), scale=scale, block_t=bt,
        rank_block=rb, interpret=True)
    assert (np.asarray(out) == np.asarray(out_full)).all()
    oracle = ref.decode_attention_ref(qz, kz, vz, lengths, scale=scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               atol=5e-5, rtol=5e-5)
    # the oracle's explicit truncation path agrees on UNpadded data
    oracle_trunc = ref.decode_attention_ref(q, k, v, lengths, scale=scale,
                                            qk_ranks=rq, vo_ranks=rv)
    np.testing.assert_allclose(np.asarray(oracle_trunc),
                               np.asarray(oracle), atol=5e-5, rtol=5e-5)


def test_ranked_paged_decode_kernel_parity():
    rng = np.random.default_rng(1)
    B, KV, G, dq, dv, T, pt, rb = 3, 4, 2, 32, 24, 64, 8, 8
    q = rng.normal(size=(B, KV * G, dq)).astype(np.float32)
    lengths = np.array([5, 37, 64], np.int32)
    rq = np.array([8, 16, 32, 24], np.int32)
    rv = np.array([24, 8, 16, 24], np.int32)
    n_p = T // pt
    N = B * n_p + 1
    pool_k = rng.normal(size=(N, pt, KV, dq)).astype(np.float32)
    pool_v = rng.normal(size=(N, pt, KV, dv)).astype(np.float32)
    qz = q.copy()
    for h in range(KV):
        qz[:, h * G:(h + 1) * G, rq[h]:] = 0.0
        pool_k[:, :, h, rq[h]:] = 0.0
        pool_v[:, :, h, rv[h]:] = 0.0
    table = rng.permutation(N - 1)[:B * n_p].reshape(B, n_p).astype(np.int32)
    scale = 1.0 / np.sqrt(dq)
    out = paged_flash_decode_ranked(qz, pool_k, pool_v, table, lengths,
                                    rq, rv, scale=scale, rank_block=rb,
                                    interpret=True)
    out_full = paged_flash_decode_ranked(
        qz, pool_k, pool_v, table, lengths, np.full(KV, dq, np.int32),
        np.full(KV, dv, np.int32), scale=scale, rank_block=rb,
        interpret=True)
    assert (np.asarray(out) == np.asarray(out_full)).all()
    oracle = ref.paged_decode_attention_ref(qz, pool_k, pool_v, table,
                                            lengths, scale=scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               atol=5e-5, rtol=5e-5)


@pytest.mark.skipif(jax.device_count() < 2 or jax.device_count() % 2,
                    reason="needs an even multi-device host")
def test_tp_token_identity_under_nonuniform_plan():
    """tp=2 serving under a non-uniform RankBudget is token-identical
    to tp=1 — rank_balanced_partition re-plans from head_loads()."""
    import jax.numpy as jnp

    from repro.models import init_lm_params
    from repro.serve import Engine, EngineConfig, Request

    cfg0 = _cfg()
    params0 = init_lm_params(cfg0, jax.random.PRNGKey(0))
    blocks = [dict(b) for b in params0["blocks"]]
    attn = dict(blocks[0]["attn"])
    damp = jnp.asarray([1.0, 0.25])[:, None, None, None]
    for name in ("wq", "wv"):
        attn[name] = attn[name] * damp
    blocks[0] = {**blocks[0], "attn": attn}
    dp, dcfg, extras = clover_decompose(
        {**params0, "blocks": blocks}, cfg0, peft=False)
    plan = plan_rank_budget(extras, dcfg, budget=0.5)
    assert len({r for t in _flat(plan) for r in t}) > 1   # non-uniform
    params, cfg = apply_rank_budget(dp, dcfg, plan)

    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg0.vocab_size, n).astype(np.int32)
               for n in (7, 13)]
    streams = []
    for tp in (1, 2):
        ecfg = EngineConfig(slots=2, max_len=48, prefill_chunk=8,
                            paged=True, page_tokens=8, tp=tp,
                            kernel_impl="interpret", rank_budget=plan)
        eng = Engine(params, cfg, ecfg)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=6)
                for i, p in enumerate(prompts)]
        eng.run(reqs)
        streams.append([r.generated for r in reqs])
    assert streams[0] == streams[1]


@pytest.mark.slow
def test_tp_nonuniform_subprocess():
    """Same identity on ANY host: a fresh process forces 4 host devices
    (the main process may see one — conftest never sets XLA_FLAGS)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=4"
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.core import (apply_rank_budget, clover_decompose,
                                plan_rank_budget)
        from repro.models import init_lm_params
        from repro.serve import Engine, EngineConfig, Request
        cfg0 = get_config("musicgen-large").reduced()
        params0 = init_lm_params(cfg0, jax.random.PRNGKey(0))
        blocks = [dict(b) for b in params0["blocks"]]
        attn = dict(blocks[0]["attn"])
        damp = jnp.asarray([1.0, 0.25])[:, None, None, None]
        for name in ("wq", "wv"):
            attn[name] = attn[name] * damp
        blocks[0] = {**blocks[0], "attn": attn}
        dp, dcfg, extras = clover_decompose(
            {**params0, "blocks": blocks}, cfg0, peft=False)
        plan = plan_rank_budget(extras, dcfg, budget=0.5)
        params, cfg = apply_rank_budget(dp, dcfg, plan)
        rng = np.random.default_rng(5)
        prompts = [rng.integers(0, cfg0.vocab_size, n).astype(np.int32)
                   for n in (7, 13)]
        base = EngineConfig(slots=2, max_len=48, prefill_chunk=8,
                            paged=True, page_tokens=8,
                            kernel_impl="interpret", rank_budget=plan)
        out = []
        for ecfg in (base, dataclasses.replace(base, tp=2)):
            eng = Engine(params, cfg, ecfg)
            reqs = [Request(uid=i, prompt=p, max_new_tokens=6)
                    for i, p in enumerate(prompts)]
            eng.run(reqs)
            out.append([r.generated for r in reqs])
        assert out[0] == out[1], out
        print("TP_BUDGET_MATCH")
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    res = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "TP_BUDGET_MATCH" in res.stdout
