"""Hypothesis property-based tests on the system's invariants."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (requirements-dev.txt); "
           "minimal installs skip them instead of failing collection")
from hypothesis import given, settings, strategies as st  # noqa: E402
from hypothesis.stateful import (  # noqa: E402
    RuleBasedStateMachine, invariant, rule)

from repro.configs import get_config
from repro.core import svd_lowrank_product, snap_rank
from repro.core.decompose import svd_tall
from repro.kernels import ops, ref
from repro.models import init_lm_params
from repro.optim import warmup_cosine
from repro.serve import Engine, EngineConfig, Request
from repro.serve.memory import PageAllocator

from pool_model import PoolLifecycle  # noqa: E402  (tests/pool_model.py)

# example counts / deadlines come from the named profiles registered in
# conftest.py ("dev" default, "ci" in the CI slow leg) — only tests
# that put a MODEL in the loop pin their own small max_examples


@given(m=st.integers(8, 64), n=st.integers(8, 64), d=st.integers(1, 8),
       seed=st.integers(0, 2**16))
def test_qr_trick_svd_reconstructs(m, n, d, seed):
    """svd_lowrank_product(A, B) == SVD of A@B.T for ANY shapes d<=min."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    A = jax.random.normal(k1, (m, d))
    B = jax.random.normal(k2, (n, d))
    U, S, Vt = svd_lowrank_product(A, B)
    np.testing.assert_allclose(np.asarray((U * S) @ Vt),
                               np.asarray(A @ B.T), atol=1e-3)
    assert bool(jnp.all(S >= -1e-6))
    assert bool(jnp.all(S[:-1] >= S[1:] - 1e-5))


@given(m=st.integers(8, 96), d=st.integers(1, 16), seed=st.integers(0, 99))
def test_svd_tall_orthonormal(m, d, seed):
    if m < d:
        m = d
    W = jax.random.normal(jax.random.PRNGKey(seed), (m, d))
    U, S, Vt = svd_tall(W)
    np.testing.assert_allclose(np.asarray(U.T @ U), np.eye(d), atol=1e-4)
    np.testing.assert_allclose(np.asarray((U * S) @ Vt), np.asarray(W),
                               atol=1e-3)


@given(r=st.integers(1, 256), mult=st.sampled_from([1, 8, 16]),
       d=st.sampled_from([64, 80, 128]))
def test_snap_rank_invariants(r, mult, d):
    s = snap_rank(r, mult, d)
    assert 1 <= s <= d
    assert s % mult == 0 or s == d or mult == 1
    assert s >= min(r, d) or s == d  # never snaps below the request (cap d)


@given(B=st.integers(1, 3), S=st.sampled_from([16, 48]),
       H=st.sampled_from([2, 4]), G=st.sampled_from([1, 2]),
       dq=st.sampled_from([8, 24]), dv=st.sampled_from([8, 16]),
       seed=st.integers(0, 99))
@settings(max_examples=10, deadline=None)
def test_flash_attention_property(B, S, H, G, dq, dv, seed):
    """Kernel == oracle across randomly drawn shape combinations."""
    KV = max(1, H // G)
    H = KV * G
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, dq))
    k = jax.random.normal(ks[1], (B, S, KV, dq))
    v = jax.random.normal(ks[2], (B, S, KV, dv))
    o_ref = ref.attention_ref(q, k, v, causal=True)
    o_pal = ops.clover_attention(q, k, v, causal=True, impl="interpret",
                                 block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               atol=1e-4, rtol=1e-4)


@given(warmup=st.integers(1, 50), total=st.integers(60, 500),
       step=st.integers(0, 499))
def test_schedule_bounded(warmup, total, step):
    v = float(warmup_cosine(jnp.asarray(step), warmup=warmup, total=total))
    assert 0.0 <= v <= 1.0 + 1e-6


@given(n_pages=st.integers(1, 24), page_tokens=st.integers(1, 8),
       slots=st.integers(1, 4),
       ops_seq=st.lists(st.tuples(st.sampled_from(["ensure", "release"]),
                                  st.integers(0, 3), st.integers(0, 64)),
                        max_size=40))
def test_page_allocator_invariants(n_pages, page_tokens, slots, ops_seq):
    """Arbitrary ensure/release interleavings never double-allocate a
    page, always return freed pages, and keep capacity accounting
    exact (free + used == n_pages; ensure is all-or-nothing)."""
    table_pages = -(-64 // page_tokens)       # fits every requested size
    a = PageAllocator(n_pages, page_tokens, slots, table_pages)
    for op, slot, n_tokens in ops_seq:
        slot %= slots
        if op == "ensure":
            before = len(a.tables[slot])
            want = a.pages_for(n_tokens)
            ok = a.ensure(slot, n_tokens)
            if ok:
                assert len(a.tables[slot]) == max(before, want)
            else:       # all-or-nothing: failure changes nothing
                assert len(a.tables[slot]) == before
                assert want - before > a.free_pages or want > a.table_pages
        else:
            owned = len(a.tables[slot])
            freed = a.release(slot)
            assert freed == owned and a.tables[slot] == []
        # global invariants after every operation
        allocated = [p for t in a.tables for p in t]
        assert len(allocated) == len(set(allocated))        # no double-alloc
        assert set(allocated).isdisjoint(a.free_list)
        assert len(allocated) + a.free_pages == a.n_pages   # exact accounting
        assert a.sentinel not in allocated


class PrefixPoolMachine(RuleBasedStateMachine):
    """Random admit / match / COW-write / preempt / retire / evict /
    spill / restore interleavings over the REAL ``PageAllocator`` +
    ``PrefixCache`` + ``HostTier`` (the shared ``PoolLifecycle`` driver
    — tests/pool_model.py — mirrors serve.engine's host-side sequence
    lifecycle).  Tokens come from a tiny alphabet so prefixes collide
    constantly — maximal sharing stress.  The undersized host tier
    (DESIGN.md §12) makes every ``evict`` rule a spill (with LRU drops)
    and every ``admit`` a potential hash-keyed restore, which must
    return byte-identical content.  ``PoolLifecycle.check`` asserts
    after every rule: refcounts match the actual reference multiset
    (and are >= 0), no page is both free and mapped, no double-free,
    every trie node's page is refcounted, pool conservation (free +
    unique mapped-or-indexed == n_pages), and the host tier inside its
    budget with exact spill/drop accounting."""

    def __init__(self):
        super().__init__()
        self.pool = PoolLifecycle(host_pages=4)

    @rule(data=st.data())
    def admit(self, data):
        free = self.pool.free_slots()
        if not free:
            return
        L = data.draw(st.integers(1, self.pool.table * self.pool.pt - 8))
        toks = data.draw(st.lists(st.integers(0, 2),
                                  min_size=L, max_size=L))
        self.pool.admit(free[0], toks)

    @rule(data=st.data())
    def cow_write(self, data):
        active = self.pool.active_slots()
        if not active:
            return
        s = data.draw(st.sampled_from(active))
        take = data.draw(st.integers(1, 6))
        grow = data.draw(st.lists(st.integers(0, 2),
                                  min_size=take, max_size=take))
        self.pool.write(s, take, np.asarray(grow, np.int32))

    @rule(data=st.data())
    def preempt_or_retire(self, data):
        """Preemption and retirement are the SAME pool transaction
        (publish committed full pages, decref everything) — one rule
        covers both lifecycle exits."""
        active = self.pool.active_slots()
        if active:
            self.pool.close(data.draw(st.sampled_from(active)))

    @rule(data=st.data())
    def cancel_or_shed(self, data):
        """Cancellation, shedding, deadline timeout and fault-requeue
        all release WITHOUT publishing (DESIGN.md §11): the pool and
        trie must end exactly as if the sequence never ran — distinct
        from ``preempt_or_retire``, which publishes first."""
        active = self.pool.active_slots()
        if active:
            self.pool.drop(data.draw(st.sampled_from(active)))

    @rule(n=st.integers(1, 4))
    def evict(self, n):
        self.pool.evict(n)

    @invariant()
    def invariants_hold(self):
        self.pool.check()


TestPrefixPoolMachine = PrefixPoolMachine.TestCase
# the CI slow leg runs this under the "ci" hypothesis profile
# (HYPOTHESIS_PROFILE=ci: >= 200 examples); locally "dev" keeps it fast
TestPrefixPoolMachine.pytestmark = [pytest.mark.slow]


@functools.lru_cache(maxsize=1)
def _spec_model():
    cfg = get_config("musicgen-large").reduced()
    return init_lm_params(cfg, jax.random.PRNGKey(7)), cfg


@given(seed=st.integers(0, 2**16),
       n_prompts=st.integers(1, 3),
       k=st.integers(1, 4),
       draft_ratio=st.sampled_from([0.0, 0.5, 0.9]),
       tight_pool=st.booleans())
@settings(max_examples=6, deadline=None)
def test_speculative_engine_exact(seed, n_prompts, k, draft_ratio,
                                  tight_pool):
    """For ANY prompt mix, draft rank and k, the speculative paged
    engine's greedy streams are token-identical to the non-speculative
    dense engine — including across forced preemption+requeue when the
    page pool is undersized (tight_pool)."""
    params, cfg = _spec_model()
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size,
                            int(rng.integers(2, 10))).astype(np.int32)
               for _ in range(n_prompts)]
    max_new = 6
    # n_pages=6 (24 tokens) forces preemption whenever two sequences
    # decode concurrently; 0 = uncontended pool
    ecfg_spec = EngineConfig(slots=2, max_len=16, prefill_chunk=4,
                             paged=True, page_tokens=4,
                             n_pages=6 if tight_pool else 0,
                             spec_k=k, draft_rank_ratio=draft_ratio)
    ecfg_base = EngineConfig(slots=2, max_len=16, prefill_chunk=4)

    def streams(ecfg):
        eng = Engine(params, cfg, ecfg)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new)
                for i, p in enumerate(prompts)]
        eng.run(reqs)
        return eng, [r.generated for r in reqs]

    _, base = streams(ecfg_base)
    eng, spec = streams(ecfg_spec)
    assert spec == base
    if eng.spec_rounds:
        assert eng.accepted_per_round >= 1.0
        assert max(eng.accept_hist) <= k + 1


@given(seed=st.integers(0, 999), T=st.integers(2, 40),
       d=st.sampled_from([4, 8]))
@settings(max_examples=10, deadline=None)
def test_wkv6_state_consistency(seed, T, d):
    """Splitting a sequence at any point and carrying S is equivalent to
    one pass (the recurrence's semigroup property)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    B, Hh = 1, 2
    r = jax.random.normal(ks[0], (B, Hh, T, d))
    k = jax.random.normal(ks[1], (B, Hh, T, d)) * 0.5
    v = jax.random.normal(ks[2], (B, Hh, T, d))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, Hh, T, d)) * 0.3)
    u = jax.random.normal(ks[4], (Hh, d)) * 0.1
    o_full, s_full = ref.wkv6_ref(r, k, v, logw, u)
    cut = T // 2
    if cut == 0:
        return
    sl = lambda t, a, b: t[:, :, a:b]  # noqa: E731
    o1, s1 = ref.wkv6_ref(sl(r, 0, cut), sl(k, 0, cut), sl(v, 0, cut),
                          sl(logw, 0, cut), u)
    o2, s2 = ref.wkv6_ref(sl(r, cut, T), sl(k, cut, T), sl(v, cut, T),
                          sl(logw, cut, T), u, s0=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 2)),
                               np.asarray(o_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               atol=1e-4)
