"""Cross-layout exactness matrix: ONE parameterized sweep pinning every
serving configuration to the same oracle.

{dense, paged, paged+prefix-cache} x {spec_k 0, 2} x {prune 0.0, 0.5}
must all emit BYTE-IDENTICAL greedy streams to the isolated whole-
prompt reference — layouts and speculative decoding change WHEN tokens
are computed and WHERE their K/V lives, never WHICH tokens come out.
This supersedes the ad-hoc per-feature exactness tests that used to be
scattered across test_serve/test_paged/test_spec (kept there as thin
wrappers over ``run_layout_case``).

The prefix-cache layout runs its trace TWICE through one engine: the
cold pass fills the trie, the warm replay must hit it (every request
resumes past cached pages) and still match the oracle token-for-token.

The ``tp`` axis replays cells through the rank-balanced
``ShardedExecutor`` (DESIGN.md §10) — parallelism changes WHERE the
math runs, never WHICH tokens come out, so tp > 1 cells assert the
same byte-identical streams.  They need ``jax.device_count() >= tp``
(the CI sharded leg forces 4 host devices via XLA_FLAGS; single-device
runs skip them).
"""
import dataclasses
import functools

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import clover_decompose, clover_prune
from repro.models import init_lm_params
from repro.serve import Engine, EngineConfig, Request, greedy_reference

LAYOUTS = ("dense", "paged", "prefix")
SPEC_KS = (0, 2)
PRUNES = (0.0, 0.5)
TPS = (1, 2)
MAX_NEW = 4


@functools.lru_cache(maxsize=None)
def _pruned_model(prune: float):
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    if prune > 0:
        dp, dcfg, _ = clover_decompose(params, cfg, peft=False)
        params, cfg = clover_prune(dp, dcfg, qk_ratio=prune,
                                   vo_ratio=prune)
    return params, cfg


@functools.lru_cache(maxsize=None)
def _trace(prune: float):
    """Mixed-length prompts sharing a common prefix (so the prefix
    layout gets real hits): sub-chunk, multi-chunk and page-aligned
    lengths all appear.  Returns (prompts, reference streams)."""
    _, cfg = _pruned_model(prune)
    sys_p = (np.arange(8, dtype=np.int32) * 3 + 1) % cfg.vocab_size
    rng = np.random.default_rng(42)
    tails = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
             for n in (2, 5, 8)]
    prompts = ([np.concatenate([sys_p, t]) for t in tails]
               + [rng.integers(0, cfg.vocab_size, 3).astype(np.int32)])
    params, cfg = _pruned_model(prune)
    refs = [greedy_reference(params, cfg, p, MAX_NEW) for p in prompts]
    return tuple(map(tuple, (tuple(p) for p in prompts))), tuple(
        map(tuple, refs))


def run_layout_case(layout: str, spec_k: int, prune: float, tp: int = 1):
    """Run one matrix cell and assert stream identity vs the oracle.
    Returns the engine for wrapper tests that check extra properties."""
    params, cfg = _pruned_model(prune)
    prompts_t, refs = _trace(prune)
    prompts = [np.asarray(p, np.int32) for p in prompts_t]
    ecfg = EngineConfig(slots=2, max_len=32, prefill_chunk=4,
                        spec_k=spec_k, draft_rank_ratio=0.5,
                        paged=(layout != "dense"),
                        page_tokens=4,
                        prefix_cache=(layout == "prefix"), tp=tp)
    eng = Engine(params, cfg, ecfg)
    passes = 2 if layout == "prefix" else 1
    for pass_i in range(passes):
        reqs = [Request(uid=100 * pass_i + i, prompt=p,
                        max_new_tokens=MAX_NEW)
                for i, p in enumerate(prompts)]
        eng.run(reqs)
        for r, want in zip(reqs, refs):
            assert r.done and tuple(r.generated) == want, \
                (layout, spec_k, prune, pass_i, r.uid)
        if layout == "prefix" and pass_i == 1:
            # the warm replay really did resume past cached pages
            assert all(r.cached_tokens > 0 for r in reqs[:-1])
    return eng


@pytest.mark.parametrize("prune", PRUNES)
@pytest.mark.parametrize("spec_k", SPEC_KS)
@pytest.mark.parametrize("layout", LAYOUTS)
@pytest.mark.parametrize("tp", TPS)
def test_layout_exactness_matrix(tp, layout, spec_k, prune):
    if tp > jax.device_count() or jax.device_count() % tp:
        pytest.skip(f"tp={tp} needs a device count divisible by {tp} "
                    f"(have {jax.device_count()}; run under XLA_FLAGS="
                    "--xla_force_host_platform_device_count=4)")
    eng = run_layout_case(layout, spec_k, prune, tp=tp)
    # the compile contract survives every cell PER PARALLELISM DEGREE:
    # 2 base shapes, +1 page copy once a COW fired, +2 with speculation
    budget = 2 + (1 if layout == "prefix" else 0) + (2 if spec_k else 0)
    shapes = eng.compiled_shapes()
    assert shapes is None or 2 <= shapes <= budget
    assert eng.exe.tp == tp


def test_tp2_interpret_kernel_cell():
    """tp=2 paged+prefix+spec with ``kernel_impl="interpret"``: the
    sharded executor now COMPILES the Pallas kernel paths per shard
    (the silent XLA demotion is gone), and the stream must still match
    the dense tp=1 whole-prompt oracle token-for-token — across a cold
    pass and a warm prefix-cache replay (which drives the shard_map'd
    page-copy kernel through COW faults)."""
    if jax.device_count() < 2 or jax.device_count() % 2:
        pytest.skip("needs an even device count >= 2 (CI sharded leg)")
    params, cfg = _pruned_model(0.5)
    prompts_t, refs = _trace(0.5)
    prompts = [np.asarray(p, np.int32) for p in prompts_t]
    ecfg = EngineConfig(slots=2, max_len=32, prefill_chunk=4,
                        spec_k=2, draft_rank_ratio=0.5, paged=True,
                        page_tokens=4, prefix_cache=True, tp=2,
                        kernel_impl="interpret")
    eng = Engine(params, cfg, ecfg)
    report = eng.exe.kernel_report()
    assert report["decode_step"] == "interpret+shard_map(model=2)"
    assert report["page_copy"] == "interpret+shard_map(model=2)"
    for pass_i in range(2):
        reqs = [Request(uid=100 * pass_i + i, prompt=p, max_new_tokens=MAX_NEW)
                for i, p in enumerate(prompts)]
        eng.run(reqs)
        for r, want in zip(reqs, refs):
            assert r.done and tuple(r.generated) == want, (pass_i, r.uid)
        if pass_i == 1:
            assert all(r.cached_tokens > 0 for r in reqs[:-1])
    shapes = eng.compiled_shapes()
    assert shapes is None or 2 <= shapes <= 5   # 2 base +2 spec +1 COW


@pytest.mark.parametrize("layout", ("dense", "prefix"))
def test_tp_streams_identical_to_local(layout):
    """tp=2 cells must be TOKEN-IDENTICAL to the tp=1 engine (not just
    to the oracle): same requests, same engine config, executor
    swapped.  Compares the full request streams side by side."""
    if jax.device_count() < 2 or jax.device_count() % 2:
        pytest.skip("needs an even device count >= 2 (CI sharded leg)")
    params, cfg = _pruned_model(0.5)
    prompts_t, _ = _trace(0.5)
    prompts = [np.asarray(p, np.int32) for p in prompts_t]
    base = EngineConfig(slots=2, max_len=32, prefill_chunk=4,
                        paged=(layout != "dense"), page_tokens=4,
                        prefix_cache=(layout == "prefix"))
    streams = []
    for ecfg in (base, dataclasses.replace(base, tp=2)):
        eng = Engine(params, cfg, ecfg)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=MAX_NEW)
                for i, p in enumerate(prompts)]
        eng.run(reqs)
        streams.append([tuple(r.generated) for r in reqs])
    assert streams[0] == streams[1]
