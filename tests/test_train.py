"""Training substrate: optimizer, data, checkpointing, fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import SyntheticConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import init_lm_params
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         warmup_cosine, warmup_linear)
from repro.train.checkpoint import CheckpointManager
from repro.train.step import TrainConfig, make_train_step, make_opt_state
from repro.train.supervisor import Supervisor, WorkerFailure, StragglerStats


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0]), "b": jnp.array(2.0)}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0)

    def loss(p):
        return jnp.sum(jnp.square(p["w"])) + jnp.square(p["b"])

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, cfg)
    assert float(loss(params)) < 1e-3


def test_adamw_respects_none_leaves():
    params = {"a": jnp.ones(3), "frozen": None}
    grads = {"a": jnp.ones(3), "frozen": None}
    opt = adamw_init(params)
    p, o, gn = adamw_update(grads, opt, params, AdamWConfig(lr=0.1))
    assert p["frozen"] is None and o["m"]["frozen"] is None
    assert float(gn) > 0


def test_grad_clip():
    params = {"a": jnp.zeros(4)}
    grads = {"a": jnp.full(4, 100.0)}
    opt = adamw_init(params)
    _, _, gn = adamw_update(grads, opt, params,
                            AdamWConfig(lr=0.0, grad_clip=1.0))
    assert abs(float(gn) - 200.0) < 1e-3  # pre-clip norm reported


def test_schedules():
    s = jnp.arange(0, 100)
    lr = warmup_cosine(s, warmup=10, total=100)
    assert float(lr[0]) == 0.0
    assert abs(float(lr[10]) - 1.0) < 0.05
    assert float(lr[99]) < 0.2
    lr2 = warmup_linear(s, warmup=10, total=100)
    assert float(lr2[99]) <= 0.02


# ---------------------------------------------------------------------------
# e2e loss decrease (the "train a model a few steps" smoke)
# ---------------------------------------------------------------------------

def test_train_loss_decreases():
    cfg = get_config("musicgen-large").reduced()
    key = jax.random.PRNGKey(0)
    params = init_lm_params(cfg, key)
    mesh = make_host_mesh()
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, weight_decay=0.0),
                       warmup_steps=2, total_steps=30, remat=True)
    step, _ = make_train_step(cfg, tcfg, mesh)
    opt = make_opt_state(params)
    data = SyntheticLM(SyntheticConfig(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=4))
    jstep = jax.jit(step, donate_argnums=(0, 1))
    losses = []
    for i, batch in zip(range(25), data):
        params, opt, m = jstep(params, opt,
                               {k: jnp.asarray(v) for k, v in batch.items()})
        losses.append(float(m["loss"]))
    # single-batch losses are noisy at this tiny scale: require a clear
    # endpoint drop AND a windowed-mean decrease, not one lucky batch
    assert losses[-1] < losses[0] * 0.95, losses[::6]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), losses[::6]


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_resumable():
    c = SyntheticConfig(vocab_size=100, seq_len=16, global_batch=4, seed=7)
    a = SyntheticLM(c)
    batches = [next(a) for _ in range(5)]
    # resume from step 3 on a fresh instance
    b = SyntheticLM(c)
    b.load_state_dict({"step": 3, "seed": 7})
    nxt = next(b)
    np.testing.assert_array_equal(nxt["tokens"], batches[3]["tokens"])
    # pure addressing
    np.testing.assert_array_equal(a.batch_at(1)["labels"],
                                  batches[1]["labels"])
    # labels are next-token shifted
    np.testing.assert_array_equal(batches[0]["tokens"][:, 1:],
                                  batches[0]["labels"][:, :-1])


def test_data_identity_mismatch_rejected():
    c = SyntheticConfig(vocab_size=100, seq_len=16, global_batch=4, seed=7)
    b = SyntheticLM(c)
    with pytest.raises(AssertionError):
        b.load_state_dict({"step": 3, "seed": 8})


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "blocks": (jnp.zeros((2, 2)), jnp.full((3,), 7.0)),
            "none": None}
    cm = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    cm.save(5, tree, {"note": "x"})
    assert cm.latest_step() == 5
    restored, extra = cm.restore(5, tree)
    assert extra["note"] == "x"
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(
                tree, is_leaf=lambda x: x is None)[0],
            jax.tree_util.tree_flatten_with_path(
                restored, is_leaf=lambda x: x is None)[0]):
        if a is None:
            assert b is None
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k_gc(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        cm.save(s, {"x": jnp.ones(2) * s})
    assert cm.all_steps() == [3, 4]


def test_checkpoint_async_and_atomic(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=3, async_write=True)
    cm.save(1, {"x": jnp.arange(10)})
    cm.wait()
    assert cm.all_steps() == [1]
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_supervisor_restart_resumes_exactly(tmp_path):
    """A mid-run failure rolls back to the checkpoint and replays to the
    identical final state (counter-addressed data => bit-exact)."""
    def run(fail_at):
        cm = CheckpointManager(str(tmp_path / f"f{fail_at}"), keep=3,
                               async_write=False)
        sup = Supervisor(cm, ckpt_every=4)
        state = {"x": jnp.zeros(())}
        failed = {"done": False}

        def step_fn(st, i):
            if i == fail_at and not failed["done"]:
                failed["done"] = True
                raise WorkerFailure("injected")
            x = st["x"] + (i + 1) * 0.5
            return {"x": x}, {"x": float(x)}

        rep = sup.run(
            state=state, step_fn=step_fn,
            save_tree=lambda st: ({"x": st["x"]}, {}),
            restore_tree=lambda tree, extra: {"x": tree["x"]},
            start_step=0, total_steps=12)
        return float(rep.metrics_history[-1]["x"]), rep.restarts

    clean, r0 = run(fail_at=-1)
    failed, r1 = run(fail_at=6)
    assert r0 == 0 and r1 == 1
    assert clean == failed   # bit-exact resume


def test_straggler_watchdog():
    st = StragglerStats()
    flagged = 0
    for i in range(20):
        flagged += int(st.update(i, 0.1 + (5.0 if i == 15 else 0.0)))
    assert flagged == 1
    assert st.flagged[0]["step"] == 15
