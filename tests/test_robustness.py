"""Robustness layer (DESIGN.md §11): request validation, priorities,
deadlines, cancellation, fault-injection recovery, the watchdog, and
``Engine.stats()``.

The exactness bar everywhere: whatever the overload policy or fault
schedule does, a request that completes (DONE) emits a stream
token-identical to the fault-free uncontended replay, and a request
that exits early (SHED / TIMED_OUT / CANCELLED) leaves the allocator,
trie, and refcounts exactly as if it had never run.
"""
import functools

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_lm_params
from repro.serve import (CANCELLED, DONE, QUEUED, SHED, TIMED_OUT,
                         Engine, EngineConfig, FaultPlan, Request)


@functools.lru_cache(maxsize=1)
def _model():
    cfg = get_config("musicgen-large").reduced()
    return init_lm_params(cfg, jax.random.PRNGKey(3)), cfg


def _prompt(seed, lo=3, hi=10):
    rng = np.random.default_rng(seed)
    _, cfg = _model()
    return rng.integers(0, cfg.vocab_size,
                        int(rng.integers(lo, hi))).astype(np.int32)


# ---------------------------------------------------------------------------
# Request validation (construction + submit)
# ---------------------------------------------------------------------------

def test_request_validation_names_the_field():
    with pytest.raises(ValueError, match="Request.prompt"):
        Request(uid=0, prompt=np.array([], np.int32))
    with pytest.raises(ValueError, match="Request.prompt"):
        Request(uid=0, prompt=np.zeros((2, 2), np.int32))
    with pytest.raises(ValueError, match="Request.prompt"):
        Request(uid=0, prompt=np.array([0.5, 1.5]))
    with pytest.raises(ValueError, match="Request.max_new_tokens"):
        Request(uid=0, prompt=np.arange(3, dtype=np.int32),
                max_new_tokens=0)
    with pytest.raises(ValueError, match="Request.temperature"):
        Request(uid=0, prompt=np.arange(3, dtype=np.int32),
                temperature=-1.0)
    with pytest.raises(ValueError, match="Request.priority"):
        Request(uid=0, prompt=np.arange(3, dtype=np.int32), priority=-1)
    with pytest.raises(ValueError, match="Request.deadline_steps"):
        Request(uid=0, prompt=np.arange(3, dtype=np.int32),
                deadline_steps=0)


def test_submit_validates_against_engine_capacity():
    params, cfg = _model()
    eng = Engine(params, cfg, EngineConfig(slots=1, max_len=8))
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(uid=0, prompt=np.arange(6, dtype=np.int32),
                           max_new_tokens=6))
    epg = Engine(params, cfg, EngineConfig(slots=1, max_len=32,
                                           paged=True, page_tokens=4,
                                           n_pages=2))
    with pytest.raises(ValueError, match="pool"):
        epg.submit(Request(uid=0, prompt=np.arange(8, dtype=np.int32),
                           max_new_tokens=8))
    # a rejected submit leaves the scheduler empty — nothing wedges
    assert not eng.sched.busy and not epg.sched.busy


# ---------------------------------------------------------------------------
# cancellation
# ---------------------------------------------------------------------------

def test_cancel_queued_and_running():
    params, cfg = _model()
    eng = Engine(params, cfg, EngineConfig(slots=1, max_len=64,
                                           prefill_chunk=4, paged=True,
                                           page_tokens=4, n_pages=16))
    a = Request(uid=0, prompt=_prompt(0), max_new_tokens=8)
    b = Request(uid=1, prompt=_prompt(1), max_new_tokens=8)
    eng.submit(a)
    eng.submit(b)
    eng.step()                   # admits a (slots=1); b stays queued
    assert a.status != QUEUED and b.status == QUEUED
    assert eng.cancel(1)         # cancel the QUEUED request
    assert b.status == CANCELLED and b.done and b.generated == []
    assert eng.cancel(0)         # cancel the RUNNING request
    assert a.status == CANCELLED and a.done
    # pages freed through the decref path: pool fully free, queue empty
    assert eng.alloc.free_pages == eng.alloc.n_pages
    assert not eng.sched.busy
    eng.alloc.assert_consistent(eng.prefix)
    # unknown / already-terminal uids report False
    assert not eng.cancel(0) and not eng.cancel(99)


def test_cancelled_request_never_ran_for_the_pool():
    """Allocator + trie state after cancel == before the request was
    ever submitted (the tentpole's 'as if it never ran' contract)."""
    params, cfg = _model()
    eng = Engine(params, cfg, EngineConfig(slots=2, max_len=64,
                                           prefill_chunk=4, paged=True,
                                           page_tokens=4,
                                           prefix_cache=True))
    before = (list(eng.alloc.free_list), len(eng.prefix))
    r = Request(uid=0, prompt=_prompt(2, 8, 12), max_new_tokens=8)
    eng.submit(r)
    eng.step()
    eng.step()                   # mid-prefill
    assert eng.cancel(0)
    after = (list(eng.alloc.free_list), len(eng.prefix))
    assert sorted(before[0]) == sorted(after[0])
    assert before[1] == after[1] == 0      # nothing published
    eng.alloc.assert_consistent(eng.prefix)


# ---------------------------------------------------------------------------
# deadlines: timeout + admission shedding
# ---------------------------------------------------------------------------

def test_running_past_deadline_times_out_with_partial_stream():
    params, cfg = _model()
    eng = Engine(params, cfg, EngineConfig(slots=1, max_len=64,
                                           prefill_chunk=4, paged=True,
                                           page_tokens=4, n_pages=16))
    r = Request(uid=0, prompt=_prompt(3, 4, 6), max_new_tokens=32,
                deadline_steps=6)
    eng.run([r], max_steps=50)
    assert r.status == TIMED_OUT and r.done
    # partial stream flushed: some tokens, fewer than requested
    assert 0 < len(r.generated) < 32
    assert r.finish_step - r.submit_step >= 6
    assert eng.alloc.free_pages == eng.alloc.n_pages
    assert eng.stats()["counters"]["timed_out"] == 1


def test_unmeetable_deadline_shed_at_admission():
    params, cfg = _model()
    eng = Engine(params, cfg, EngineConfig(slots=1, max_len=64,
                                           prefill_chunk=4))
    # needs >= 2 prefill chunks + 7 decode steps; deadline 2 is
    # provably unmeetable -> shed before any compute is spent.  The
    # shedder only fires under CONTENTION from strictly-higher-priority
    # work (an uncontended doomed request runs to its deadline and
    # flushes a partial stream instead), hence ok's priority=1.
    doomed = Request(uid=0, prompt=_prompt(4, 8, 9), max_new_tokens=8,
                     deadline_steps=2)
    ok = Request(uid=1, prompt=_prompt(5, 4, 6), max_new_tokens=4,
                 priority=1)
    eng.run([doomed, ok], max_steps=100)
    assert doomed.status == SHED and doomed.generated == []
    assert ok.status == DONE and len(ok.generated) == 4
    assert eng.stats()["counters"]["shed"] == 1


def test_feasible_deadline_not_shed():
    params, cfg = _model()
    eng = Engine(params, cfg, EngineConfig(slots=2, max_len=64,
                                           prefill_chunk=8))
    r = Request(uid=0, prompt=_prompt(6, 4, 6), max_new_tokens=4,
                deadline_steps=30)
    eng.run([r], max_steps=100)
    assert r.status == DONE and len(r.generated) == 4


# ---------------------------------------------------------------------------
# priorities
# ---------------------------------------------------------------------------

def test_high_priority_admitted_first_under_contention():
    params, cfg = _model()
    eng = Engine(params, cfg, EngineConfig(slots=1, max_len=64,
                                           prefill_chunk=8))
    lows = [Request(uid=i, prompt=_prompt(10 + i, 4, 6),
                    max_new_tokens=4) for i in range(3)]
    high = Request(uid=9, prompt=_prompt(20, 4, 6), max_new_tokens=4,
                   priority=5)
    for r in lows:
        eng.submit(r)
    eng.submit(high)             # submitted LAST, admitted first
    eng.run([], max_steps=200)
    assert all(r.status == DONE for r in lows + [high])
    # deterministic TTFT: the high class strictly beats every low
    assert high.token_steps[0] < min(r.token_steps[0] for r in lows)
    stats = eng.stats()
    assert stats["classes"][5]["ttft_steps_p95"] \
        < stats["classes"][0]["ttft_steps_p95"]


def test_default_priority_keeps_fifo_order():
    """All-default-priority admission must reproduce the historical
    FIFO exactly (baselines depend on it)."""
    params, cfg = _model()
    eng = Engine(params, cfg, EngineConfig(slots=1, max_len=64,
                                           prefill_chunk=8))
    reqs = [Request(uid=i, prompt=_prompt(30 + i, 4, 6),
                    max_new_tokens=2) for i in range(4)]
    eng.run(reqs, max_steps=200)
    firsts = [r.token_steps[0] for r in reqs]
    assert firsts == sorted(firsts)       # served in submit order


# ---------------------------------------------------------------------------
# fault injection: retry, quarantine/requeue, watchdog
# ---------------------------------------------------------------------------

def _streams(params, cfg, ecfg, seeds, faults=None, max_steps=600):
    eng = Engine(params, cfg, ecfg, faults=faults)
    reqs = [Request(uid=i, prompt=_prompt(100 + s, 4, 8),
                    max_new_tokens=6) for i, s in enumerate(seeds)]
    eng.run(reqs, max_steps=max_steps)
    return eng, reqs


def test_step_retry_recovers_exactly():
    """A bounded burst of step faults is absorbed by same-input retry:
    streams token-identical to fault-free, faults actually fired."""
    params, cfg = _model()
    ecfg = EngineConfig(slots=2, max_len=32, prefill_chunk=4)
    _, base = _streams(params, cfg, ecfg, range(3))
    plan = FaultPlan(seed=7, rates={"step": 0.3, "nan": 0.2})
    eng, faulted = _streams(params, cfg, ecfg, range(3), faults=plan)
    assert plan.total_injected > 0
    assert [r.generated for r in faulted] == [r.generated for r in base]
    assert all(r.status == DONE for r in faulted)
    c = eng.stats()["counters"]
    assert c.get("retries", 0) > 0 and c.get("faults_recovered", 0) > 0


def test_retry_exhaustion_quarantines_and_requeues_exactly():
    """max_faults lets a fault persist through every retry of a step,
    forcing quarantine + requeue — the stream must still match the
    fault-free replay exactly (re-prefill is an exact continuation)."""
    params, cfg = _model()
    ecfg = EngineConfig(slots=2, max_len=32, prefill_chunk=4,
                        step_retries=0, quarantine_steps=3)
    _, base = _streams(params, cfg, ecfg, range(3))
    plan = FaultPlan(seed=1, rates={"step": 0.25}, max_faults=4)
    eng, faulted = _streams(params, cfg, ecfg, range(3), faults=plan)
    assert plan.total_injected > 0
    assert eng.sched.requeues > 0
    assert eng.stats()["counters"].get("quarantines", 0) > 0
    assert [r.generated for r in faulted] == [r.generated for r in base]
    assert all(r.status == DONE for r in faulted)


def test_paged_fault_sites_recover_exactly():
    """alloc + page_copy + step faults on the paged prefix-cache engine:
    surviving streams still exact, allocator invariants intact."""
    params, cfg = _model()
    ecfg = EngineConfig(slots=2, max_len=32, prefill_chunk=4, paged=True,
                        page_tokens=4, n_pages=12, prefix_cache=True,
                        quarantine_steps=2)
    _, base = _streams(params, cfg, ecfg, range(4))
    plan = FaultPlan(seed=11, rates={"alloc": 0.2, "page_copy": 0.3,
                                     "step": 0.1}, max_faults=12)
    eng, faulted = _streams(params, cfg, ecfg, range(4), faults=plan)
    assert plan.total_injected > 0
    assert [r.generated for r in faulted] == [r.generated for r in base]
    eng.alloc.assert_consistent(eng.prefix)
    eng.prefix.evict(eng.alloc.n_pages)
    assert eng.alloc.free_pages == eng.alloc.n_pages


def test_watchdog_sheds_a_wedged_engine():
    """Unbounded rate-1.0 step faults wedge every step; the watchdog
    must drain the engine by shedding instead of spinning to
    max_steps."""
    params, cfg = _model()
    ecfg = EngineConfig(slots=2, max_len=32, prefill_chunk=4,
                        step_retries=1, quarantine_steps=2,
                        watchdog_steps=8)
    plan = FaultPlan(seed=0, rates={"step": 1.0})
    eng, reqs = _streams(params, cfg, ecfg, range(3), faults=plan,
                         max_steps=2000)
    assert not eng.sched.busy            # drained, not spinning
    assert eng.steps < 2000
    assert all(r.status == SHED for r in reqs)
    assert eng.watchdog_sheds == len(reqs)
    assert eng.stats()["counters"]["shed"] == len(reqs)


def test_faults_reject_donating_executor():
    params, cfg = _model()

    class FakeDonating:
        donates_state = True

    with pytest.raises(ValueError, match="donate_state"):
        Engine(params, cfg, EngineConfig(slots=1, max_len=16),
               executor=FakeDonating(), faults=FaultPlan(seed=0))


def test_fault_plan_is_deterministic_and_validated():
    a = FaultPlan(seed=3, rates={"step": 0.5})
    b = FaultPlan(seed=3, rates={"step": 0.5})
    seq_a = [a.fire("step") for _ in range(50)]
    seq_b = [b.fire("step") for _ in range(50)]
    assert seq_a == seq_b and any(seq_a) and not all(seq_a)
    with pytest.raises(ValueError, match="unknown sites"):
        FaultPlan(rates={"gremlins": 0.5})
    with pytest.raises(ValueError, match="must be in"):
        FaultPlan(rates={"step": 1.5})
    capped = FaultPlan(seed=0, rates={"step": 1.0}, max_faults=2)
    assert sum(capped.fire("step") for _ in range(10)) == 2


# ---------------------------------------------------------------------------
# stats
# ---------------------------------------------------------------------------

def test_engine_stats_shape():
    params, cfg = _model()
    eng = Engine(params, cfg, EngineConfig(slots=2, max_len=64,
                                           prefill_chunk=8, paged=True,
                                           page_tokens=8))
    reqs = [Request(uid=i, prompt=_prompt(50 + i, 4, 8), max_new_tokens=4)
            for i in range(3)]
    eng.run(reqs)
    st = eng.stats()
    assert st["counters"]["done"] == 3
    cls = st["classes"][0]
    assert cls["n_ttft_steps"] == 3
    # TTFT can legitimately be 0 steps (single-chunk prefill emits the
    # first token in the admission step); ITL is >= 1 by construction
    assert cls["ttft_steps_p50"] >= 0 and cls["itl_steps_p95"] >= 1
    assert 0.0 <= st["page_util"] <= 1.0 and st["peak_page_util"] > 0
    assert st["steps"] > 0 and st["preemptions"] == 0
    # wall-clock twins of the deterministic clocks are present too
    assert cls["n_ttft_s"] == 3 and cls["ttft_s_p95"] > 0
