"""tools/check_coverage.py: subtree aggregation and floor enforcement
over synthetic Cobertura reports."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "tools"))
import check_coverage  # noqa: E402

XML = """<?xml version="1.0"?>
<coverage line-rate="0.5">
  <sources><source>/repo</source></sources>
  <packages><package name="repro">
    <classes>
      <class filename="src/repro/serve/engine.py">
        <lines>
          <line number="1" hits="1"/><line number="2" hits="1"/>
          <line number="3" hits="1"/><line number="4" hits="0"/>
        </lines>
      </class>
      <class filename="src/repro/models/layers.py">
        <lines><line number="1" hits="0"/><line number="2" hits="0"/></lines>
      </class>
    </classes>
  </package></packages>
</coverage>
"""


def _xml(tmp_path):
    p = tmp_path / "coverage.xml"
    p.write_text(XML)
    return p


def test_subtree_filter_counts_only_matching_files(tmp_path):
    covered, valid = check_coverage.subtree_coverage(
        _xml(tmp_path), "src/repro/serve")
    assert (covered, valid) == (3, 4)          # layers.py excluded
    covered, valid = check_coverage.subtree_coverage(
        _xml(tmp_path), "src/repro")
    assert (covered, valid) == (3, 6)


def test_floor_enforced_both_ways(tmp_path):
    xml = _xml(tmp_path)
    argv = ["--xml", str(xml), "--path", "src/repro/serve"]
    assert check_coverage.main(argv + ["--floor", "0.70"]) == 0   # 75%
    assert check_coverage.main(argv + ["--floor", "0.80"]) == 1


def test_operational_errors(tmp_path):
    missing = tmp_path / "nope.xml"
    assert check_coverage.main(["--xml", str(missing)]) == 2
    xml = _xml(tmp_path)
    assert check_coverage.main(
        ["--xml", str(xml), "--path", "src/elsewhere"]) == 2
