"""Self-speculative decoding: draft/verify correctness, KV rollback in
dense and paged layouts, and the verify oracle (DESIGN.md §8)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import clover_decompose, clover_prune, draft_ranks
from repro.kernels import ops, ref
from repro.models import init_lm_params
from repro.models import transformer as T
from repro.serve import Engine, EngineConfig, Request, greedy_reference


def _setup(seed=0, prune=0.0):
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(seed))
    if prune > 0:
        dp, dcfg, _ = clover_decompose(params, cfg, peft=False)
        params, cfg = clover_prune(dp, dcfg, qk_ratio=prune, vo_ratio=prune)
    return params, cfg


def _run(params, cfg, ecfg, prompts, max_new=6):
    eng = Engine(params, cfg, ecfg)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    return eng, reqs


# ---------------------------------------------------------------------------
# model entry points
# ---------------------------------------------------------------------------

def test_verify_chunk_matches_forward():
    """verify_chunk returns the full model's logits at EVERY window
    position — identical to the whole-sequence forward pass."""
    params, cfg = _setup(prune=0.5)
    toks = jnp.arange(12, dtype=jnp.int32)[None] + 3
    full, _ = T.forward(params, cfg, toks)
    state = T.init_decode_state(cfg, 1, 32)
    state["index"] = jnp.zeros((1,), jnp.int32)
    _, state = T.prefill_chunk(params, cfg, toks[:, :7], state,
                               jnp.array([7], jnp.int32))
    lv, state = T.verify_chunk(params, cfg, toks[:, 7:], state,
                               jnp.array([5], jnp.int32))
    np.testing.assert_allclose(np.asarray(lv), np.asarray(full[:, 7:]),
                               atol=2e-4, rtol=2e-4)
    assert int(state["index"][0]) == 12


def test_draft_full_rank_is_exact_model():
    """draft_rank == (qk_dim, vo_dim) must be bit-identical to the plain
    decode step (the degenerate draft IS the model)."""
    params, cfg = _setup(prune=0.5)
    state = T.init_decode_state(cfg, 2, 16)
    state["index"] = jnp.zeros((2,), jnp.int32)
    toks = jnp.array([[4, 9, 2, 7], [1, 3, 3, 8]], jnp.int32)
    _, state = T.prefill_chunk(params, cfg, toks, state,
                               jnp.array([4, 4], jnp.int32))
    tok = jnp.array([5, 6], jnp.int32)
    l_plain, _ = T.decode_step(params, cfg, tok, dict(state))
    l_draft, _ = T.decode_step(params, cfg, tok, dict(state),
                               draft_rank=(cfg.qk_dim, cfg.vo_dim))
    np.testing.assert_array_equal(np.asarray(l_plain), np.asarray(l_draft))


def test_draft_rank_planner_applicability():
    """draft_ranks slices the NoPE tail only under partial RoPE and
    never slices Q-K under full RoPE (mirrors plan_ranks)."""
    cfg = get_config("musicgen-large").reduced()         # no RoPE: cross
    rq, rv = draft_ranks(cfg, 0.5)
    assert rq < cfg.qk_dim and rv < cfg.vo_dim
    stable = get_config("stablelm-3b").reduced()         # partial RoPE
    rq, rv = draft_ranks(stable, 0.9)
    assert rq >= stable.rope_dims                        # rotated block kept
    phi = get_config("phi3-medium-14b").reduced()        # full RoPE: intra
    rq, rv = draft_ranks(phi, 0.9)
    assert rq == phi.qk_dim and rv < phi.vo_dim


# ---------------------------------------------------------------------------
# verify oracle
# ---------------------------------------------------------------------------

def test_verify_oracle_reduces_to_decode_at_w1():
    B, H, KV, Tt, d = 2, 4, 2, 24, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, 1, H, d))
    k = jax.random.normal(ks[1], (B, Tt, KV, d))
    v = jax.random.normal(ks[2], (B, Tt, KV, d))
    lens = jnp.array([9, 23], jnp.int32)
    o_w = ref.verify_decode_attention_ref(q, k, v, lens)
    o_d = ref.decode_attention_ref(q[:, 0], k, v, lens)
    np.testing.assert_allclose(np.asarray(o_w[:, 0]), np.asarray(o_d),
                               atol=1e-6)


def test_verify_oracle_matches_causal_prefix():
    """Each window row equals a single-token decode at its own prefix
    length — the acceptance rule's correctness condition."""
    B, W, H, KV, Tt, d = 1, 4, 4, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, W, H, d))
    k = jax.random.normal(ks[1], (B, Tt, KV, d))
    v = jax.random.normal(ks[2], (B, Tt, KV, d))
    lens = jnp.array([13], jnp.int32)
    o_w = ref.verify_decode_attention_ref(q, k, v, lens)
    for j in range(W):
        o_j = ref.decode_attention_ref(q[:, j], k, v,
                                       lens - (W - 1 - j))
        np.testing.assert_allclose(np.asarray(o_w[:, j]), np.asarray(o_j),
                                   atol=1e-6)


def test_verify_oracle_ignores_rolled_back_tail():
    """Poisoning every cache position past ``lengths`` (the rejected
    draft K/V a rollback leaves behind) must not change the output."""
    B, W, H, KV, Tt, d = 2, 3, 4, 2, 20, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, W, H, d))
    k = jax.random.normal(ks[1], (B, Tt, KV, d))
    v = jax.random.normal(ks[2], (B, Tt, KV, d))
    lens = jnp.array([7, 15], jnp.int32)
    o1 = ref.verify_decode_attention_ref(q, k, v, lens)
    pos = jnp.arange(Tt)[None, :, None, None]
    poison = pos >= lens[:, None, None, None]
    o2 = ref.verify_decode_attention_ref(
        q, jnp.where(poison, 1e4, k), jnp.where(poison, -1e4, v), lens)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


# ---------------------------------------------------------------------------
# kernels: post-rollback lengths (rejected K/V stays written; only
# `lengths` shrinks — the kernels must key on lengths alone)
# ---------------------------------------------------------------------------

def test_dense_decode_kernel_post_rollback():
    B, H, KV, Tt, d = 2, 4, 2, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, H, d))
    k = jax.random.normal(ks[1], (B, Tt, KV, d))
    v = jax.random.normal(ks[2], (B, Tt, KV, d))
    lens = jnp.array([5, 21], jnp.int32)       # rolled back below written
    pos = jnp.arange(Tt)[None, :, None, None]
    poison = pos >= lens[:, None, None, None]
    kp = jnp.where(poison, 1e4, k)
    vp = jnp.where(poison, -1e4, v)
    o_ref = ref.decode_attention_ref(q, k, v, lens)
    o_pal = ops.decode_attention(q, kp, vp, lens, impl="interpret",
                                 block_t=8)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               atol=5e-5, rtol=5e-5)


def test_paged_decode_kernel_post_rollback():
    """A slot may own MORE pages than ceil(length/page_tokens) after a
    rollback; in-use-page garbage past length and whole rolled-back
    pages must both be inert."""
    B, H, KV, d, pt, n_p = 2, 4, 2, 16, 4, 6
    n_pool = B * n_p + 1
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (B, H, d))
    k_pool = jax.random.normal(ks[1], (n_pool, pt, KV, d))
    v_pool = jax.random.normal(ks[2], (n_pool, pt, KV, d))
    # every slot owns ALL n_p of its pages (pre-rollback coverage) ...
    tab = jnp.arange(B * n_p, dtype=jnp.int32).reshape(B, n_p)
    # ... but lengths rolled back to mid-page values
    lens = jnp.array([6, 13], jnp.int32)
    o1 = ops.paged_decode_attention(q, k_pool, v_pool, tab, lens,
                                    impl="interpret")
    # poison everything past each slot's rolled-back length
    flat_pos = jnp.arange(n_pool * pt).reshape(n_pool, pt)
    poison = jnp.zeros((n_pool, pt), bool)
    for b in range(B):
        for ip in range(n_p):
            page = b * n_p + ip
            valid = np.clip(int(lens[b]) - ip * pt, 0, pt)
            poison = poison.at[page, valid:].set(True)
    poison = poison.at[n_pool - 1].set(True)             # sink row too
    kp = jnp.where(poison[..., None, None], 1e4, k_pool)
    vp = jnp.where(poison[..., None, None], -1e4, v_pool)
    o2 = ops.paged_decode_attention(q, kp, vp, tab, lens, impl="interpret")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
    del flat_pos


# ---------------------------------------------------------------------------
# engine: speculative == non-speculative, token for token
# ---------------------------------------------------------------------------

def test_spec_engine_matches_nonspec_dense_and_paged():
    """Thin wrapper over the cross-layout exactness matrix
    (tests/test_matrix.py superseded the ad-hoc spec-vs-nonspec stream
    comparison): dense and paged speculative cells both match the
    oracle, hence the non-speculative streams, byte-for-byte."""
    from test_matrix import run_layout_case
    es = run_layout_case("dense", spec_k=2, prune=0.5)
    ep = run_layout_case("paged", spec_k=2, prune=0.5)
    assert es.spec_rounds > 0 and es.accepted_per_round >= 1.0
    # two non-spec shapes + at most one draft + one verify shape
    assert es.compiled_shapes() in (3, 4, None)
    assert ep.compiled_shapes() in (3, 4, None)


def test_spec_engine_full_rank_draft_accepts_everything():
    """draft_rank_ratio=0.0 degenerates the draft to the exact model:
    every proposal must be accepted (k+1 tokens per round)."""
    params, cfg = _setup(seed=1)
    prompt = np.arange(6, dtype=np.int32) + 3
    k = 3
    ecfg = EngineConfig(slots=1, max_len=32, prefill_chunk=4, spec_k=k,
                        draft_rank_ratio=0.0)
    eng, reqs = _run(params, cfg, ecfg, [prompt],
                     max_new=1 + 2 * (k + 1))    # 1 prefill + 2 full rounds
    assert reqs[0].generated == greedy_reference(params, cfg, prompt,
                                                 1 + 2 * (k + 1))
    assert eng.accepted_per_round == k + 1
    assert dict(eng.accept_hist) == {k + 1: 2}


def test_spec_engine_eos_mid_round_truncates():
    """An eos inside an accepted run stops the stream exactly where the
    one-token engine would have."""
    params, cfg = _setup(seed=1)
    prompt = np.arange(8, dtype=np.int32) + 17
    ref_toks = greedy_reference(params, cfg, prompt, 8)
    # pick an eos first occurring strictly inside the stream so at
    # least one speculative round runs before the stop
    eos = next((t for i, t in enumerate(ref_toks) if i >= 1
                and t not in ref_toks[:i]), None)
    if eos is None:
        pytest.skip("greedy stream has no late-first-occurrence token")
    stop = ref_toks.index(eos) + 1
    ecfg = EngineConfig(slots=1, max_len=32, prefill_chunk=4, eos_id=eos,
                        spec_k=4, draft_rank_ratio=0.0)
    _, reqs = _run(params, cfg, ecfg, [prompt], max_new=8)
    assert reqs[0].done
    assert reqs[0].generated == ref_toks[:stop]


def test_spec_engine_paged_preemption_stays_exact():
    """Speculative verify windows transiently demand extra pages; pool
    exhaustion must preempt-and-requeue without breaking exactness."""
    params, cfg = _setup(seed=1)
    p1 = np.arange(8, dtype=np.int32) + 3
    p2 = np.arange(8, dtype=np.int32) + 17
    ecfg = EngineConfig(slots=2, max_len=32, prefill_chunk=4, paged=True,
                        page_tokens=4, n_pages=7, spec_k=3,
                        draft_rank_ratio=0.5)
    eng, reqs = _run(params, cfg, ecfg, [p1, p2], max_new=8)
    assert eng.sched.preemptions >= 1
    for r, p in zip(reqs, (p1, p2)):
        assert r.done
        assert r.generated == greedy_reference(params, cfg, p, 8), r.uid


def test_spec_engine_interpret_kernel_path():
    """Under attn_impl="interpret" the draft decode steps run the Pallas
    flash-decode kernel on the SLICED cache view; streams must match the
    XLA spec engine."""
    params, cfg = _setup(seed=2)
    prompt = np.arange(4, dtype=np.int32) + 7
    ecfg = EngineConfig(slots=1, max_len=16, prefill_chunk=4, spec_k=2,
                        draft_rank_ratio=0.5)
    _, base = _run(params, cfg, ecfg, [prompt], max_new=4)
    cfg_i = dataclasses.replace(cfg, kernel_impl="interpret")
    _, out = _run(params, cfg_i, ecfg, [prompt], max_new=4)
    assert out[0].generated == base[0].generated


def test_spec_engine_near_capacity():
    """A request whose stream ends at max_len: the verify window's
    rejected tail transiently overhangs the committed length and must
    stay inside the engine's capacity slack."""
    params, cfg = _setup(seed=3)
    prompt = np.arange(10, dtype=np.int32) + 2
    ecfg = EngineConfig(slots=1, max_len=16, prefill_chunk=4, spec_k=5,
                        draft_rank_ratio=0.0)
    _, reqs = _run(params, cfg, ecfg, [prompt], max_new=6)  # 10 + 6 = 16
    assert reqs[0].generated == greedy_reference(params, cfg, prompt, 6)


def test_spec_engine_temperature_falls_back():
    """Sampled requests (temperature > 0) disable speculative rounds
    (the argmax acceptance rule is greedy-only); generation still
    completes."""
    params, cfg = _setup(seed=4)
    prompt = np.arange(4, dtype=np.int32) + 5
    ecfg = EngineConfig(slots=1, max_len=32, prefill_chunk=4, spec_k=3)
    eng = Engine(params, cfg, ecfg)
    req = Request(uid=0, prompt=prompt, max_new_tokens=5, temperature=0.8)
    eng.run([req])
    assert req.done and len(req.generated) == 5
    assert eng.spec_rounds == 0


def test_spec_rejected_on_recurrent_arch():
    cfg = get_config("rwkv6-1.6b").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="attention-only"):
        Engine(params, cfg, EngineConfig(slots=1, max_len=16, spec_k=2))
