"""Paged KV cache: engine equivalence vs dense, kernel parity vs the
paged oracle, allocator/preemption behavior."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import clover_decompose, clover_prune
from repro.kernels import ops, ref
from repro.models import init_lm_params
from repro.serve import Engine, EngineConfig, Request, greedy_reference
from repro.serve.memory import PageAllocator


def _streams(params, cfg, ecfg, prompts, max_new=4):
    eng = Engine(params, cfg, ecfg)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    return eng, reqs


# ---------------------------------------------------------------------------
# engine: paged vs dense equivalence
# ---------------------------------------------------------------------------

def test_paged_engine_matches_dense_mixed_trace():
    """Thin wrapper over the cross-layout exactness matrix
    (tests/test_matrix.py superseded the ad-hoc paged-vs-dense stream
    comparison): the paged cell must match the oracle byte-for-byte,
    which pins it to the dense cell transitively."""
    from test_matrix import run_layout_case
    eng = run_layout_case("paged", spec_k=0, prune=0.0)
    assert eng.compiled_shapes() in (2, None)


def test_paged_preemption_requeues_and_stays_exact():
    """A pool too small for both sequences' decode growth preempts the
    youngest (pages freed, request requeued with its generated tokens
    folded into the effective prompt) and every stream still matches
    its isolated greedy reference."""
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(1))
    p1 = np.arange(8, dtype=np.int32) + 3
    p2 = np.arange(8, dtype=np.int32) + 17
    ecfg = EngineConfig(slots=2, max_len=32, prefill_chunk=4,
                        paged=True, page_tokens=4, n_pages=5)  # 20 tokens
    eng, reqs = _streams(params, cfg, ecfg, [p1, p2], max_new=8)
    assert eng.sched.preemptions >= 1
    for r, p in zip(reqs, (p1, p2)):
        assert r.done
        assert r.generated == greedy_reference(params, cfg, p, 8), r.uid
    assert eng.compiled_shapes() in (2, None)   # survives preemption


def test_paged_admission_gates_on_pages_not_slots():
    """With more slots than the pool can hold, admission waits on free
    pages (FIFO head-of-line) and still completes every request."""
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(2))
    prompts = [np.arange(6, dtype=np.int32) + 3 * i for i in range(4)]
    # 4 slots but pages for ~2 sequences at a time (6+4=10 tok -> 3 pages)
    ecfg = EngineConfig(slots=4, max_len=32, prefill_chunk=4,
                        paged=True, page_tokens=4, n_pages=6)
    eng, reqs = _streams(params, cfg, ecfg, prompts)
    for r, p in zip(reqs, prompts):
        assert r.done
        assert r.generated == greedy_reference(params, cfg, p, 4), r.uid


def test_paged_engine_on_pruned_model():
    """The tentpole composition: pool pages live at the PRUNED rank, so
    a fixed pool holds more tokens — and streams stay exact."""
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(3))
    dp, dcfg, _ = clover_decompose(params, cfg, peft=False)
    pp, pcfg = clover_prune(dp, dcfg, qk_ratio=0.5, vo_ratio=0.5)
    eng = Engine(pp, pcfg, EngineConfig(slots=2, max_len=32, paged=True,
                                        page_tokens=4))
    k = eng.state["blocks"][0]["kv"]["k"]
    # (n_blocks, n_pages+1, page_tokens, KV, r_qk)
    assert k.ndim == 5 and k.shape[2] == 4
    assert k.shape[-1] == pcfg.clover.qk_rank < cfg.head_dim_
    prompt = np.arange(4, dtype=np.int32) + 5
    out = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=4)])
    assert out[0].generated == greedy_reference(pp, pcfg, prompt, 4)


def test_paged_capacity_guard():
    """A request that cannot ever fit the pool is rejected eagerly, like
    the dense capacity guard."""
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(params, cfg, EngineConfig(slots=1, max_len=32, paged=True,
                                           page_tokens=4, n_pages=2))
    with pytest.raises(ValueError, match="pool"):
        eng.run([Request(uid=0, prompt=np.arange(8, dtype=np.int32),
                         max_new_tokens=8)])


def test_paged_engine_interpret_kernel_decode():
    """Engine decode steps under attn_impl="interpret" run the PAGED
    Pallas kernel (scalar-prefetched page table) and must reproduce the
    XLA paged engine's greedy stream."""
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(4))
    prompt = np.arange(3, dtype=np.int32) + 7
    ecfg = EngineConfig(slots=1, max_len=16, prefill_chunk=4, paged=True,
                        page_tokens=4)
    _, base = _streams(params, cfg, ecfg, [prompt], max_new=3)
    cfg_i = dataclasses.replace(cfg, kernel_impl="interpret")
    _, out = _streams(params, cfg_i, ecfg, [prompt], max_new=3)
    assert out[0].generated == base[0].generated


# ---------------------------------------------------------------------------
# kernel: interpret parity vs the paged reference
# ---------------------------------------------------------------------------

def _rand_paged_case(key, B, H, KV, dq, dv, pt, n_p, n_pool, max_len):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, H, dq))
    k_pool = jax.random.normal(ks[1], (n_pool, pt, KV, dq))
    v_pool = jax.random.normal(ks[2], (n_pool, pt, KV, dv))
    lengths = jax.random.randint(ks[3], (B,), 1, max_len + 1)
    # disjoint page tables, sentinel (= n_pool - 1) past each row's pages
    perm = np.random.default_rng(0).permutation(n_pool - 1)
    tab = np.full((B, n_p), n_pool - 1, np.int32)
    off = 0
    for b in range(B):
        used = -(-int(lengths[b]) // pt)
        tab[b, :used] = perm[off:off + used]
        off += used
    return q, k_pool, v_pool, jnp.asarray(tab), lengths


@pytest.mark.parametrize("B,H,KV,dq,dv,pt,n_p", [
    (2, 4, 2, 32, 24, 8, 4),     # GQA, asymmetric (CLOVER-pruned shape)
    (3, 8, 1, 16, 16, 4, 6),     # MQA, partial last pages
    (1, 16, 16, 8, 8, 16, 2),    # MHA
])
def test_paged_decode_kernel_sweep(B, H, KV, dq, dv, pt, n_p):
    q, kp, vp, tab, lens = _rand_paged_case(
        jax.random.PRNGKey(B + H), B, H, KV, dq, dv, pt, n_p,
        n_pool=B * n_p + 1, max_len=n_p * pt)
    o_ref = ref.paged_decode_attention_ref(q, kp, vp, tab, lens)
    o_pal = ops.paged_decode_attention(q, kp, vp, tab, lens,
                                       impl="interpret")
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               atol=5e-5, rtol=5e-5)


def test_paged_decode_kernel_ignores_garbage_pages():
    """Poisoning the sink row and every unreferenced pool row must not
    change the output — the indirection + length mask fully isolate a
    slot from other slots' (and nobody's) pages."""
    B, H, KV, dq, dv, pt, n_p = 2, 4, 2, 16, 16, 4, 4
    n_pool = B * n_p + 1
    q, kp, vp, tab, lens = _rand_paged_case(
        jax.random.PRNGKey(9), B, H, KV, dq, dv, pt, n_p,
        n_pool=n_pool, max_len=n_p * pt)
    o1 = ops.paged_decode_attention(q, kp, vp, tab, lens, impl="interpret")
    used = set()
    for b in range(B):
        used |= {int(tab[b, i]) for i in range(-(-int(lens[b]) // pt))}
    for row in range(n_pool):
        if row not in used:
            kp = kp.at[row].set(1e4)
            vp = vp.at[row].set(-1e4)
    o2 = ops.paged_decode_attention(q, kp, vp, tab, lens, impl="interpret")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_paged_ref_matches_dense_ref():
    """Identity page table -> the paged oracle IS the dense oracle."""
    B, H, KV, T, d, pt = 2, 4, 2, 32, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, H, d))
    k = jax.random.normal(ks[1], (B, T, KV, d))
    v = jax.random.normal(ks[2], (B, T, KV, d))
    lens = jnp.array([10, 29], jnp.int32)
    n_p = T // pt
    # per-slot pages laid out contiguously in one pool
    kp = k.reshape(B * n_p, pt, KV, d)
    vp = v.reshape(B * n_p, pt, KV, d)
    tab = jnp.arange(B * n_p, dtype=jnp.int32).reshape(B, n_p)
    o_paged = ref.paged_decode_attention_ref(q, kp, vp, tab, lens)
    o_dense = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(o_paged), np.asarray(o_dense),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# allocator unit behavior (the hypothesis sweep lives in test_property.py)
# ---------------------------------------------------------------------------

def test_page_allocator_basics():
    a = PageAllocator(n_pages=6, page_tokens=4, slots=2, table_pages=8)
    assert a.ensure(0, 9)            # 3 pages
    assert a.used_pages() == 3 and a.free_pages == 3
    assert a.ensure(0, 9)            # idempotent
    assert a.used_pages() == 3
    assert a.ensure(1, 12)           # 3 more
    assert a.free_pages == 0
    assert not a.ensure(0, 13)       # exhausted: all-or-nothing, no change
    assert a.used_pages() == 6
    t = a.table_array()
    owned = set(t[t != a.sentinel].tolist())
    assert len(owned) == 6           # disjoint ownership
    assert a.release(1) == 3
    assert a.free_pages == 3
    assert a.ensure(0, 13)           # now fits
    assert np.all(a.table_array()[1] == a.sentinel)
