"""Per-arch smoke tests (assignment requirement) + decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, ASSIGNED_ARCHS, SHAPES, cell_applicable
from repro.models import (init_lm_params, forward, prefill, decode_step,
                          init_decode_state)
from repro.train.step import loss_fn


def _dropless(cfg):
    if cfg.moe:
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.0))
    return cfg


def _inputs(cfg, key, B=2, S=12):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    fe = None
    if cfg.frontend != "none":
        fe = jax.random.normal(key, (B, cfg.frontend_len, cfg.d_model)) * 0.02
    return toks, fe


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_arch_smoke_forward_and_train_step(name):
    """Reduced config: one forward + one backward on CPU; shapes + no NaN."""
    cfg = _dropless(get_config(name).reduced())
    key = jax.random.PRNGKey(0)
    params = init_lm_params(cfg, key)
    toks, fe = _inputs(cfg, key)
    logits, aux = forward(params, cfg, toks, frontend_embeds=fe)
    F = cfg.frontend_len if cfg.frontend != "none" else 0
    assert logits.shape == (2, toks.shape[1] + F, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    # one grad step flows (train smoke)
    (loss, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, cfg, toks, toks, frontend_embeds=fe, remat=True)
    assert bool(jnp.isfinite(loss))
    gn = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_arch_prefill_decode_consistency(name):
    cfg = _dropless(get_config(name).reduced())
    key = jax.random.PRNGKey(0)
    params = init_lm_params(cfg, key)
    toks, fe = _inputs(cfg, key, B=2, S=8)
    full, _ = forward(params, cfg, toks, frontend_embeds=fe)
    F = cfg.frontend_len if cfg.frontend != "none" else 0
    st = init_decode_state(cfg, 2, 8 + F + 4)
    lp, st = prefill(params, cfg, toks[:, :-1], st, frontend_embeds=fe)
    ld, st = decode_step(params, cfg, toks[:, -1], st)
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    assert float(jnp.max(jnp.abs(lp - full[:, -2]))) / scale < 1e-4
    assert float(jnp.max(jnp.abs(ld - full[:, -1]))) / scale < 1e-4


def test_cell_applicability_matrix():
    """40 cells: long_500k only for sub-quadratic archs (DESIGN.md §5)."""
    cells = [(a, s) for a in ASSIGNED_ARCHS for s in SHAPES]
    assert len(cells) == 40
    runnable = [(a, s) for a, s in cells
                if cell_applicable(get_config(a), SHAPES[s])]
    skipped = set(cells) - set(runnable)
    assert skipped == {(a, "long_500k") for a in ASSIGNED_ARCHS
                       if a not in ("rwkv6-1.6b", "jamba-v0.1-52b")}


def test_chunked_attention_matches_full():
    import repro.models.layers as L
    cfg = get_config("phi3-medium-14b").reduced()
    key = jax.random.PRNGKey(0)
    params = init_lm_params(cfg, key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    old = L.ATTN_CHUNK
    try:
        L.ATTN_CHUNK = 4
        out_c, _ = forward(params, cfg, toks)
    finally:
        L.ATTN_CHUNK = old
    out_f, _ = forward(params, cfg, toks)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_f),
                               atol=1e-4, rtol=1e-4)


def test_vocab_padding_masks_pad_ids():
    cfg = dataclasses.replace(get_config("granite-moe-1b-a400m").reduced(),
                              vocab_size=500, pad_vocab_to=128)
    assert cfg.padded_vocab == 512
    key = jax.random.PRNGKey(0)
    params = init_lm_params(cfg, key)
    toks = jax.random.randint(key, (1, 8), 0, 500)
    logits, _ = forward(params, cfg, toks)
    assert logits.shape[-1] == 512
    assert bool(jnp.all(logits[..., 500:] < -1e29))


def test_pallas_model_equivalence():
    """kernel_impl=interpret end-to-end equals the XLA path."""
    for name in ("musicgen-large", "rwkv6-1.6b"):
        cfg = get_config(name).reduced()
        key = jax.random.PRNGKey(0)
        params = init_lm_params(cfg, key)
        toks, fe = _inputs(cfg, key, B=1, S=8)
        base, _ = forward(params, cfg, toks, frontend_embeds=fe)
        cfg_p = dataclasses.replace(cfg, kernel_impl="interpret")
        out, _ = forward(params, cfg_p, toks, frontend_embeds=fe)
        scale = float(jnp.max(jnp.abs(base))) + 1e-6
        assert float(jnp.max(jnp.abs(out - base))) / scale < 1e-3, name
