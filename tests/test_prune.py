"""Pruning planner: CLOVER-vs-vanilla quality ordering, shapes, snapping."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import (clover_decompose, clover_prune, vanilla_prune,
                        plan_ranks, threshold_ratios, snap_rank)
from repro.models import init_lm_params, forward, init_decode_state


def _setup(name="gpt2-xl", seed=0):
    cfg = get_config(name).reduced()
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.0))
    key = jax.random.PRNGKey(seed)
    params = init_lm_params(cfg, key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    return cfg, params, toks


def test_snap_rank():
    assert snap_rank(45, 8, 128) == 48
    assert snap_rank(1, 8, 128) == 8
    assert snap_rank(128, 8, 128) == 128
    assert snap_rank(200, 8, 128) == 128
    assert snap_rank(7, 1, 32) == 7


def test_plan_ranks_partial_rope_keeps_rotated_block():
    cfg = get_config("stablelm-3b")          # rotary_pct=0.25, d=80
    qk, vo = plan_ranks(cfg, 0.5, 0.5)
    assert qk >= cfg.rope_dims               # rotated block never pruned
    assert vo <= cfg.head_dim_


def test_plan_ranks_intra_mode_no_qk_prune():
    cfg = get_config("phi3-medium-14b")      # full RoPE
    qk, vo = plan_ranks(cfg, 0.9, 0.5)
    assert qk == cfg.head_dim_               # Q-K pruning illegal
    assert vo < cfg.head_dim_


@pytest.mark.parametrize("ratio", [0.25, 0.5])
def test_clover_beats_vanilla(ratio):
    """Paper Table 1's ordering: at equal ratio, CLOVER's logits error is
    smaller than vanilla magnitude pruning (already at random init)."""
    cfg, params, toks = _setup("gpt2-xl")
    base, _ = forward(params, cfg, toks)
    dp, dcfg, _ = clover_decompose(params, cfg, peft=False)
    cp, ccfg = clover_prune(dp, dcfg, qk_ratio=ratio, vo_ratio=ratio)
    cl, _ = forward(cp, ccfg, toks)
    vp, vcfg = vanilla_prune(params, cfg, qk_ratio=ratio, vo_ratio=ratio)
    vl, _ = forward(vp, vcfg, toks)
    e_c = float(jnp.mean(jnp.abs(cl - base)))
    e_v = float(jnp.mean(jnp.abs(vl - base)))
    assert e_c < e_v, f"ratio {ratio}: clover {e_c} !< vanilla {e_v}"


def test_pruned_kv_cache_shrinks():
    """The KV cache stores K at r_qk and V at r_vo — the decode-memory
    win the paper targets."""
    cfg, params, _ = _setup("musicgen-large")
    dp, dcfg, _ = clover_decompose(params, cfg, peft=False)
    pp, pcfg = clover_prune(dp, dcfg, qk_ratio=0.5, vo_ratio=0.25)
    st = init_decode_state(pcfg, 2, 32)
    k = st["blocks"][0]["kv"]["k"]
    v = st["blocks"][0]["kv"]["v"]
    assert k.shape[-1] == pcfg.clover.qk_rank < cfg.head_dim_
    assert v.shape[-1] == pcfg.clover.vo_rank < cfg.head_dim_


def test_prune_monotone_in_ratio():
    """More pruning -> monotonically non-decreasing logits error."""
    cfg, params, toks = _setup("musicgen-large")
    base, _ = forward(params, cfg, toks)
    dp, dcfg, _ = clover_decompose(params, cfg, peft=False)
    errs = []
    for r in (0.0, 0.25, 0.5, 0.75):
        pp, pcfg = clover_prune(dp, dcfg, qk_ratio=r, vo_ratio=r)
        lg, _ = forward(pp, pcfg, toks)
        errs.append(float(jnp.mean(jnp.abs(lg - base))))
    assert errs == sorted(errs), errs
    assert errs[0] < 1e-4              # ratio 0 == pure orthogonalization


def test_gqa_prune_preserves_shared_kv():
    """Grouped CLOVER prunes the SHARED K/V directions per group."""
    cfg, params, toks = _setup("jamba-v0.1-52b")
    base, _ = forward(params, cfg, toks)
    dp, dcfg, _ = clover_decompose(params, cfg, peft=False)
    pp, pcfg = clover_prune(dp, dcfg, qk_ratio=0.5, vo_ratio=0.5)
    lg, _ = forward(pp, pcfg, toks)
    # sanity: error bounded and shapes consistent across the group
    # (jamba's attention sits at pattern position 4 in the 1:7 interleave)
    j = next(i for i, (m, _) in enumerate(pcfg.pattern) if m == "attn")
    attn = pp["blocks"][j]["attn"]
    assert attn["wk"].shape[-1] == pcfg.clover.qk_rank
    assert attn["wq"].shape[-1] == pcfg.clover.qk_rank
    assert float(jnp.mean(jnp.abs(lg - base))) < 10.0


def test_threshold_planner():
    cfg, params, _ = _setup("musicgen-large")
    _, dcfg, extras = clover_decompose(params, cfg, peft=False)
    plan = threshold_ratios(extras, dcfg, qk_thresh=1e-6, vo_thresh=1e-6)
    assert plan["qk_keep"] == cfg.head_dim_   # nothing below threshold
    plan2 = threshold_ratios(extras, dcfg, qk_thresh=1e9, vo_thresh=1e9)
    assert plan2["qk_keep"] <= cfg.clover.rank_multiple
