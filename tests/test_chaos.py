"""Seeded chaos soak (DESIGN.md §11): the paged prefix-cache engine
driven for hundreds-to-thousands of steps under a random ``FaultPlan``
plus overload machinery (priorities, deadlines, mid-trace cancels),
with the allocator/trie invariants re-verified after EVERY engine step
(``PageAllocator.assert_consistent`` — the same checker the
tests/pool_model.py reference lifecycle delegates to).

Gates, per the tentpole's exactness contract:
  * zero invariant violations at any step (pool conservation, no
    double-free, refcounts == reference multiset, trie child counts);
  * every submitted request reaches a terminal state and is accounted
    for in the metrics;
  * every DONE stream is token-identical to the fault-free,
    uncontended replay; every early-exit stream is a PREFIX of it;
  * at drain, evicting the trie returns the pool to fully free.
"""
import functools

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_lm_params
from repro.serve import (DONE, Engine, EngineConfig, FaultPlan, Request)


@functools.lru_cache(maxsize=1)
def _model():
    cfg = get_config("musicgen-large").reduced()
    return init_lm_params(cfg, jax.random.PRNGKey(5)), cfg


def _requests(rng, n, vocab):
    reqs = []
    for i in range(n):
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(0, vocab,
                                int(rng.integers(3, 9))).astype(np.int32),
            max_new_tokens=int(rng.integers(2, 7)),
            priority=int(rng.integers(0, 3)),
            deadline_steps=(int(rng.integers(15, 40))
                            if rng.random() < 0.3 else None)))
    return reqs


def _reference(params, cfg, reqs):
    """Fault-free, uncontended replay: same prompts, no deadlines, no
    page pressure — the oracle every surviving stream must match."""
    eng = Engine(params, cfg, EngineConfig(slots=4, max_len=32,
                                           prefill_chunk=4))
    clones = [Request(uid=r.uid, prompt=r.prompt,
                      max_new_tokens=r.max_new_tokens) for r in reqs]
    eng.run(clones)
    assert all(r.status == DONE for r in clones)
    return {r.uid: r.generated for r in clones}


def _soak(seed, n_requests, max_steps, intensity):
    params, cfg = _model()
    rng = np.random.default_rng(seed)
    reqs = _requests(rng, n_requests, cfg.vocab_size)
    ref = _reference(params, cfg, reqs)
    ecfg = EngineConfig(slots=3, max_len=32, prefill_chunk=4, paged=True,
                        page_tokens=4, n_pages=10, prefix_cache=True,
                        step_retries=1, quarantine_steps=2,
                        watchdog_steps=16)
    plan = FaultPlan.chaos(seed=seed, intensity=intensity)
    eng = Engine(params, cfg, ecfg, faults=plan)
    # arrival trickle + pinned mid-trace cancels, all seeded
    arrivals = {i: r for i, r in enumerate(reqs)}
    arrive_at = sorted(int(rng.integers(0, max_steps // 3))
                       for _ in reqs)
    cancels = {int(rng.integers(5, max_steps // 2)): r.uid
               for r in rng.choice(reqs, size=max(1, n_requests // 6),
                                   replace=False)}
    submitted = 0
    for step in range(max_steps):
        while submitted < len(reqs) and arrive_at[submitted] <= step:
            eng.submit(arrivals[submitted])
            submitted += 1
        if step in cancels:
            eng.cancel(cancels[step])
        eng.step()
        eng.alloc.assert_consistent(eng.prefix,
                                    context=f"seed {seed} step {step}")
        if submitted == len(reqs) and not eng.sched.busy:
            break
    assert submitted == len(reqs) and not eng.sched.busy, \
        "engine failed to drain under chaos"
    # every request terminal and accounted for
    assert all(r.done for r in reqs)
    assert eng.metrics.n_terminal == len(reqs)
    # exactness: DONE == oracle; early exits are prefixes of it
    for r in reqs:
        if r.status == DONE:
            assert r.generated == ref[r.uid], (seed, r.uid, r.status)
        else:
            assert r.generated == ref[r.uid][:len(r.generated)], \
                (seed, r.uid, r.status)
    # drain the trie: the pool must return to fully free
    eng.prefix.evict(eng.alloc.n_pages)
    eng.alloc.assert_consistent(eng.prefix, context=f"seed {seed} drain")
    assert eng.alloc.free_pages == eng.alloc.n_pages
    return eng, plan


def test_chaos_soak_smoke():
    """Always-runs: one seed, a few hundred steps, moderate fault
    pressure on every site."""
    eng, plan = _soak(seed=0, n_requests=10, max_steps=400,
                      intensity=0.05)
    assert plan.total_injected > 0          # chaos actually happened
    assert eng.steps > 0


def test_chaos_zero_intensity_matches_fault_free():
    """A zero-rate plan must not perturb anything: every request that
    survives the overload policy is exact, and nothing injects."""
    eng, plan = _soak(seed=3, n_requests=8, max_steps=400, intensity=0.0)
    assert plan.total_injected == 0
    assert eng.stats()["counters"].get("quarantines", 0) == 0


@pytest.mark.slow
@pytest.mark.parametrize("seed", [1, 2, 7])
def test_chaos_soak_long(seed):
    """Thousands of engine steps per seed under sustained fault
    pressure — the CI slow leg's endurance gate."""
    eng, plan = _soak(seed=seed, n_requests=24, max_steps=2500,
                      intensity=0.08)
    assert plan.total_injected > 0
    c = eng.stats()["counters"]
    # sustained pressure must actually exercise the recovery machinery
    assert c.get("retries", 0) + c.get("quarantines", 0) > 0
