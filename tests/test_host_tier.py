"""Hierarchical KV (DESIGN.md §12): HostTier LRU semantics, the
evict→spill→restore lifecycle (spill happens BEFORE the HBM free,
restore is bitwise re-prefill), the bounded ``host_copy`` fault
fallback, and the host-enabled lifecycle random walk."""
import functools

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.models import init_lm_params
from repro.serve import Engine, EngineConfig, Request, greedy_reference
from repro.serve.faults import FaultPlan
from repro.serve.memory import HostTier, PageAllocator, PrefixCache

from pool_model import PoolLifecycle


@functools.lru_cache(maxsize=1)
def _model(seed=0):
    cfg = get_config("musicgen-large").reduced()
    return init_lm_params(cfg, jax.random.PRNGKey(seed)), cfg


def _host_cfg(**kw):
    base = dict(slots=2, max_len=40, prefill_chunk=4, paged=True,
                page_tokens=4, prefix_cache=True, host_pages=8)
    base.update(kw)
    return EngineConfig(**base)


# ---------------------------------------------------------------------------
# HostTier: LRU ring unit semantics
# ---------------------------------------------------------------------------

def test_host_tier_lru_overflow_drops_oldest():
    h = HostTier(2)
    h.put(b"a", 1)
    h.put(b"b", 2)
    h.put(b"c", 3)                       # overflow: a is LRU, dropped
    assert (h.spills, h.dropped, len(h)) == (3, 1, 2)
    assert b"a" not in h and h.get(b"a") is None
    assert h.get(b"b") == 2 and h.get(b"c") == 3
    assert (h.hits, h.misses) == (2, 1)
    assert h.hit_rate == pytest.approx(2 / 3)


def test_host_tier_touch_protects_from_eviction():
    h = HostTier(2)
    h.put(b"a", 1)
    h.put(b"b", 2)
    assert h.get(b"a") == 1              # a becomes MRU
    h.put(b"c", 3)                       # b is now the LRU victim
    assert b"b" not in h and b"a" in h and b"c" in h
    # re-putting an existing key refreshes in place, never drops
    h.put(b"a", 1)
    assert (len(h), h.dropped) == (2, 1)


def test_host_tier_capacity_validated():
    with pytest.raises(AssertionError):
        HostTier(0)


def test_engine_config_guards_host_pages():
    with pytest.raises(ValueError):
        EngineConfig(slots=1, max_len=16, host_pages=-1)
    with pytest.raises(ValueError):    # host tier needs the prefix trie
        EngineConfig(slots=1, max_len=16, paged=True, page_tokens=4,
                     host_pages=4)


# ---------------------------------------------------------------------------
# trie eviction: spill-before-free ordering
# ---------------------------------------------------------------------------

def test_evict_spills_page_content_before_free():
    """The spill hook must read the page while it is still allocated —
    eviction copies out, THEN decrefs (DESIGN.md §12 ordering)."""
    a = PageAllocator(n_pages=8, page_tokens=4, slots=2, table_pages=8)
    t = PrefixCache(a, salt=("t",))
    t.host = HostTier(8)
    reads = []

    def reader(page):
        assert page not in a.free_list, "spill read a freed page"
        assert a.refcount[page] >= 1
        reads.append(page)
        return ("rows", page)

    t.page_reader = reader
    toks = np.arange(12, dtype=np.int32)
    assert a.ensure(0, 12)
    t.insert(toks, a.tables[0])
    pages = list(a.tables[0][:3])
    a.release(0)                          # trie-only now
    assert t.evict(3) == 3
    assert sorted(reads) == sorted(pages)
    assert all(p in a.free_list for p in pages)   # really freed after
    # spilled under the chunk-chain hashes, content intact
    for i, key in enumerate(t.chain_hashes(toks, 3)):
        assert t.host.get(key) == ("rows", pages[i])
    assert t.host.spills == 3 and t.host.dropped == 0
    a.assert_consistent(t, context="spill")


def test_evict_without_reader_spills_nothing():
    """A trie with a host tier but no page_reader (no executor wired)
    must evict exactly as before — spill is strictly opt-in."""
    a = PageAllocator(n_pages=8, page_tokens=4, slots=2, table_pages=8)
    t = PrefixCache(a, salt=("t",))
    t.host = HostTier(4)
    toks = np.arange(8, dtype=np.int32)
    assert a.ensure(0, 8)
    t.insert(toks, a.tables[0])
    a.release(0)
    assert t.evict(2) == 2
    assert len(t.host) == 0 and t.host.spills == 0


# ---------------------------------------------------------------------------
# engine: restore == re-prefill, bitwise
# ---------------------------------------------------------------------------

def test_restore_is_bitwise_reprefill():
    """Evict a prompt's pages through the host tier, replay the prompt:
    the restored stream is token-identical AND the restored page bytes
    equal the originally-prefilled ones — restore ≡ re-prefill."""
    params, cfg = _model()
    prompt = (np.arange(20, dtype=np.int32) * 5 + 2) % cfg.vocab_size
    want = greedy_reference(params, cfg, prompt, 4)
    eng = Engine(params, cfg, _host_cfg())

    cold = Request(uid=0, prompt=prompt, max_new_tokens=4)
    eng.run([cold])
    assert cold.generated == want
    pages = eng.prefix.match(prompt)
    assert len(pages) == 5
    cold_rows = [eng.exe.read_page(eng.state, p) for p in pages]

    assert eng.prefix.evict(100) == 5          # all 5 spill host-side
    assert eng.host.spills == 5 and len(eng.host) == 5
    assert eng.alloc.free_pages == eng.alloc.n_pages

    warm = Request(uid=1, prompt=prompt, max_new_tokens=4)
    eng.run([warm])
    assert warm.generated == want
    assert warm.cached_tokens == 19            # full hit resumes at L-1
    assert eng.host.restores == 5
    restored = eng.prefix.match(prompt)
    assert len(restored) == 5
    for old, page in zip(cold_rows, restored):
        for a, b in zip(old, eng.exe.read_page(eng.state, page)):
            np.testing.assert_array_equal(a, b)
    st = eng.stats()
    assert st["counters"].get("host_restored_pages") == 5
    # 5 hits + the cold admission's probe of the then-empty tier
    assert st["host_hit_rate"] == pytest.approx(5 / 6)
    eng.alloc.assert_consistent(eng.prefix, context="restore")


def test_partial_host_hit_restores_consecutive_prefix_only():
    """Dropping a middle page from the host tier must stop the restore
    at the gap (restores stay consecutive from the trie hit) and
    re-prefill the rest — stream still exact."""
    params, cfg = _model()
    prompt = (np.arange(20, dtype=np.int32) * 3 + 1) % cfg.vocab_size
    want = greedy_reference(params, cfg, prompt, 4)
    eng = Engine(params, cfg, _host_cfg())
    eng.run([Request(uid=0, prompt=prompt, max_new_tokens=4)])
    eng.prefix.evict(100)
    del eng.host._slots[eng.prefix.chain_hashes(prompt, 5)[2]]

    warm = Request(uid=1, prompt=prompt, max_new_tokens=4)
    eng.run([warm])
    assert warm.generated == want
    assert eng.host.restores == 2              # pages 0-1 only
    assert warm.cached_tokens == 8
    eng.alloc.assert_consistent(eng.prefix, context="partial-restore")


def test_host_copy_fault_falls_back_to_reprefill():
    """With every host->device restore batch failing, the engine must
    give up on the host hits, re-prefill, and keep allocator + trie
    consistent at every step — strictly more work, never a wrong
    token (DESIGN.md §11/§12)."""
    params, cfg = _model()
    prompt = (np.arange(20, dtype=np.int32) * 7 + 3) % cfg.vocab_size
    want = greedy_reference(params, cfg, prompt, 4)
    faults = FaultPlan(seed=1, rates={"host_copy": 1.0})
    eng = Engine(params, cfg, _host_cfg(), faults=faults)
    eng.run([Request(uid=0, prompt=prompt, max_new_tokens=4)])
    eng.prefix.evict(100)
    assert eng.host.spills == 5

    warm = Request(uid=1, prompt=prompt, max_new_tokens=4)
    eng.submit(warm)
    while not warm.done:
        eng.step()
        eng.alloc.assert_consistent(eng.prefix, context="fault-step")
    assert warm.generated == want
    assert eng.host.restores == 0              # every batch failed
    st = eng.stats()
    assert st["counters"].get("host_restore_fallbacks", 0) >= 1
    assert st["counters"].get("retries", 0) >= 1
    assert faults.injected["host_copy"] >= 1
    assert "host_restored_pages" not in st["counters"]


# ---------------------------------------------------------------------------
# lifecycle random walk with the host tier attached (the no-hypothesis
# counterpart of the spill/restore PrefixPoolMachine transitions)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_pool_lifecycle_walk_with_host_tier(seed):
    rng = np.random.default_rng(seed)
    pool = PoolLifecycle(n_pages=12, page_tokens=4, slots=3,
                         table_pages=10, host_pages=4)
    for _ in range(300):
        op = rng.integers(0, 6)
        if op == 0 and pool.free_slots():
            L = int(rng.integers(1, pool.table * pool.pt - 8))
            pool.admit(pool.free_slots()[0],
                       rng.integers(0, 3, L).astype(np.int32))
        elif op in (1, 2) and pool.active_slots():
            s = int(rng.choice(pool.active_slots()))
            take = int(rng.integers(1, 7))
            pool.write(s, take, rng.integers(0, 3, take).astype(np.int32))
        elif op == 3 and pool.active_slots():
            pool.close(int(rng.choice(pool.active_slots())))
        elif op == 4 and pool.active_slots():
            pool.drop(int(rng.choice(pool.active_slots())))
        else:
            pool.evict(int(rng.integers(1, 5)))
        pool.check()
    while pool.active_slots():
        pool.close(pool.active_slots()[0])
        pool.check()
    pool.evict(pool.alloc.n_pages)
    pool.check()
    assert pool.alloc.free_pages == pool.alloc.n_pages
    assert pool.host.spills > 0        # the walk exercised the tier
