"""benchmarks/run.py driver: the in-process module-chaining bug.

serve_bench's tp > 1 cells need >= 2 devices, and XLA only honors
``--xla_force_host_platform_device_count`` before jax first
initializes.  When benchmarks.run chained serve_bench after another
module that imported jax (kernel_bench), serve_bench's own import-time
guard came too late: the tp cells could not form a mesh and were
silently SKIPPED, dropping their gated baseline keys while the run
still reported ALL CHECKS PASS.  Two fixes, both pinned here:

  * the driver itself sets the flag before ANY benchmark module import
    (``benchmarks/run.py``), so chained runs see 4 host devices;
  * serve_bench now RAISES when a requested tp degree cannot form a
    mesh, so a future regression fails loudly instead of passing with
    a hole in the baseline coverage.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")
ROOT = os.path.join(os.path.dirname(__file__), os.pardir)


def _env(**extra):
    env = dict(os.environ, PYTHONPATH=os.pathsep.join((SRC, ROOT)))
    env.pop("XLA_FLAGS", None)      # the driver must not need outside help
    env.update(extra)
    return env


def test_run_driver_forces_host_devices_before_jax():
    """Importing benchmarks.run first must make any later jax import
    (kernel_bench's is the real case) see the forced host devices."""
    prog = textwrap.dedent("""
        import benchmarks.run          # must set XLA_FLAGS itself
        import jax                     # what kernel_bench does next
        assert jax.device_count() >= 4, jax.device_count()
        print("DEVICES_OK")
    """)
    res = subprocess.run([sys.executable, "-c", prog], env=_env(),
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "DEVICES_OK" in res.stdout


def test_serve_bench_raises_when_tp_mesh_impossible(tmp_path):
    """A tp cell that cannot form its mesh must RAISE, never skip:
    jax is pinned to one device BEFORE serve_bench imports (exactly
    the chained-module failure mode), so the first tp=2 cell of the
    adapter scenario must die with the mesh error."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = ""
        os.environ["SERVE_BENCH_SCENARIO"] = "adapter"
        import jax                     # too late for serve_bench's guard
        assert jax.device_count() == 1, jax.device_count()
        import benchmarks.serve_bench as sb
        # shrink the trace: this test is about the guard, not the gates
        sb.ADAPTER_TENANTS = 1
        sb.ADAPTER_WAVES = 1
        sb.ADAPTER_MAX_NEW = 2
        try:
            sb.run(verbose=False)
        except RuntimeError as e:
            assert "cannot form" in str(e), e
            print("RAISED_OK")
        else:
            raise SystemExit("tp cell silently skipped")
    """)
    res = subprocess.run([sys.executable, "-c", prog], env=_env(),
                         cwd=tmp_path, capture_output=True, text=True,
                         timeout=1200)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "RAISED_OK" in res.stdout


@pytest.mark.slow
def test_chained_modules_keep_tp_keys(tmp_path):
    """The real regression: kernel_bench then serve_bench in ONE driver
    process must still produce the tp2 baseline keys (scenario filter
    keeps the runtime bounded; the adapter scenario has tp cells).
    BENCH_OUTPUT_DIR keeps this run off the committed repo-root
    trajectory files."""
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.run",
         "--only", "kernel_bench", "--only", "serve_bench"],
        env=_env(SERVE_BENCH_SCENARIO="adapter",
                 BENCH_OUTPUT_DIR=str(tmp_path)),
        cwd=tmp_path, capture_output=True, text=True, timeout=1200)
    assert res.returncode == 0, (res.stdout[-2000:], res.stderr[-2000:])
    data = json.loads((tmp_path / "BENCH_serve.json").read_text())
    tp2 = {r[0] for r in data["rows"]
           if r[0].endswith("_tp2") and r[1] == "tokens_per_step"}
    assert tp2, "tp2 cells silently dropped from the chained run"
