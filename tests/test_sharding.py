"""Distribution: spec validity, multi-device pjit equivalence (subprocess
with forced host devices), elastic restore, pipeline, compression."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, ASSIGNED_ARCHS
from repro.launch.mesh import make_host_mesh
from repro.models import init_lm_params, init_decode_state
from repro.parallel import sharding as sh

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str, devices: int = 8) -> str:
    """Run code in a fresh process with N forced host devices."""
    prog = (f"import os\n"
            f"os.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={devices}'\n"
            + textwrap.dedent(code))
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# spec validity for every arch on production-shaped meshes (no devices
# needed: divisibility logic is pure)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_param_specs_divisible(name):
    cfg = get_config(name).reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    mesh = make_host_mesh()          # (1, 1) on CPU
    specs = sh.param_specs(params, mesh)
    # every spec entry must divide its dim (mesh extents are 1 -> trivial
    # here; the real check runs inside the dry-run on 512 devices).
    for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(
                    x, jax.sharding.PartitionSpec))[0]):
        assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)


def test_rules_drop_nondivisible():
    rules = sh.ShardingRules()

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    spec = rules.spec((sh.HEADS, None), (40, 128), FakeMesh())
    assert spec[0] is None               # 40 % 16 != 0 -> replicated
    spec = rules.spec((sh.HEADS, None), (32, 128), FakeMesh())
    assert spec[0] == "model"


def test_decode_state_specs_structure():
    cfg = get_config("jamba-v0.1-52b").reduced()
    st = init_decode_state(cfg, 4, 32)
    mesh = make_host_mesh()
    specs = sh.decode_state_specs(st, mesh)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))[0]
    assert len(flat) == len(jax.tree_util.tree_leaves(st))


def test_make_host_mesh_rejects_nondivisible_model():
    """A tp degree that does not divide the device count must die with
    a CLEAR ValueError naming both numbers — not jax.make_mesh's
    cryptic reshape failure."""
    n = jax.device_count()
    bad = n + 1                          # never divides n (n >= 1)
    with pytest.raises(ValueError, match=f"model={bad} does not divide"):
        make_host_mesh(model=bad)
    with pytest.raises(ValueError, match="must be >= 1"):
        make_host_mesh(model=0)
    with pytest.raises(ValueError, match="devices"):
        make_host_mesh(data=n + 1, model=1)
    mesh = make_host_mesh(model=1)       # the happy path still works
    assert mesh.shape == {"data": n, "model": 1}


# ---------------------------------------------------------------------------
# multi-device equivalence: sharded pjit train step == single-device step
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_pjit_train_step_matches_single_device():
    out = _run_subprocess("""
        import dataclasses, json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import init_lm_params
        from repro.launch.mesh import make_mesh
        from repro.optim import AdamWConfig
        from repro.parallel import sharding as sh
        from repro.train.step import TrainConfig, make_train_step, make_opt_state

        cfg = get_config('musicgen-large').reduced()
        key = jax.random.PRNGKey(0)
        params = init_lm_params(cfg, key)
        toks = jax.random.randint(key, (8, 16), 0, cfg.vocab_size)
        batch = {'tokens': toks, 'labels': toks}
        tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3), warmup_steps=1,
                           total_steps=10, remat=True)

        def run(mesh):
            step, _ = make_train_step(cfg, tcfg, mesh)
            opt = make_opt_state(params)
            pspec = sh.param_specs(params, mesh)
            p_sh = sh.shardings(pspec, mesh)
            o_sh = {'m': p_sh, 'v': p_sh,
                    'step': jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec())}
            d_sh = jax.sharding.NamedSharding(mesh, sh.data_specs(mesh))
            with mesh:
                j = jax.jit(step, in_shardings=(p_sh, o_sh,
                                                {'tokens': d_sh, 'labels': d_sh}),
                            out_shardings=None)
                p2, o2, m = j(params, opt, batch)
            return float(m['loss']), p2

        loss_1, p1 = run(make_mesh((1, 1), ('data', 'model')))
        loss_8, p8 = run(make_mesh((4, 2), ('data', 'model')))
        # host-side compare: the two trees live on different meshes
        diff = max(float(np.max(np.abs(
            np.asarray(a, np.float32) - np.asarray(b, np.float32))))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p8)))
        print(json.dumps({'loss_1': loss_1, 'loss_8': loss_8, 'diff': diff}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert abs(res["loss_1"] - res["loss_8"]) < 1e-3, res
    assert res["diff"] < 5e-2, res


@pytest.mark.slow
def test_moe_sharded_dispatch_matches_local():
    """Per-shard-capacity MoE on a 4-way data mesh == the local path when
    dropless (capacity_factor=0 -> nothing dropped either way)."""
    out = _run_subprocess("""
        import dataclasses, json
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import init_lm_params, forward
        from repro.launch.mesh import make_mesh

        cfg = get_config('granite-moe-1b-a400m').reduced()
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=0.0))
        key = jax.random.PRNGKey(0)
        params = init_lm_params(cfg, key)
        toks = jax.random.randint(key, (8, 16), 0, cfg.vocab_size)
        base, _ = forward(params, cfg, toks)      # no mesh: local path
        mesh = make_mesh((4, 2), ('data', 'model'))
        with mesh:
            out, _ = jax.jit(lambda p, t: forward(p, cfg, t))(params, toks)
        err = float(jnp.max(jnp.abs(out - base)))
        print(json.dumps({'err': err}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["err"] < 1e-3, res


@pytest.mark.slow
def test_elastic_checkpoint_reshard():
    """Save under a (4,2) mesh, restore onto (2,2) and single-device."""
    out = _run_subprocess("""
        import json, tempfile
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import init_lm_params
        from repro.launch.mesh import make_mesh
        from repro.parallel import sharding as sh
        from repro.train.checkpoint import CheckpointManager

        cfg = get_config('stablelm-3b').reduced()
        params = init_lm_params(cfg, jax.random.PRNGKey(0))
        mesh_a = make_mesh((4, 2), ('data', 'model'))
        sh_a = sh.shardings(sh.param_specs(params, mesh_a), mesh_a)
        sharded = jax.tree.map(jax.device_put, params, sh_a)
        d = tempfile.mkdtemp()
        cm = CheckpointManager(d, async_write=False)
        cm.save(1, sharded)
        mesh_b = make_mesh((2, 2), ('data', 'model'))
        sh_b = sh.shardings(sh.param_specs(params, mesh_b), mesh_b)
        restored, _ = cm.restore(1, params, sh_b)
        diff = max(float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(params),
                            jax.tree.leaves(restored)))
        print(json.dumps({'diff': diff}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["diff"] == 0.0, res


@pytest.mark.slow
def test_pipeline_matches_sequential():
    out = _run_subprocess("""
        import json
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.parallel.pipeline import pipeline_apply

        p_stages = 4
        D = 16
        key = jax.random.PRNGKey(0)
        Ws = jax.random.normal(key, (p_stages, D, D)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(1), (16, D))

        def stage(w, h):
            return jnp.tanh(h @ w['w'])

        params = {'w': Ws}
        # sequential reference
        ref = x
        for i in range(p_stages):
            ref = stage({'w': Ws[i]}, ref)
        mesh = make_mesh((p_stages,), ('pipe',))
        out = pipeline_apply(params, x, mesh, stage, n_microbatches=8)
        err = float(jnp.max(jnp.abs(out - ref)))
        print(json.dumps({'err': err}))
    """, devices=4)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["err"] < 1e-5, res


@pytest.mark.slow
def test_grad_compression_cross_pod():
    out = _run_subprocess("""
        import json
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.parallel.compress import (compress_cross_pod,
                                             compress_cross_pod_ef,
                                             init_residual)
        mesh = make_mesh((4, 2), ('pod', 'data'))
        g = {'w': jnp.linspace(-1, 1, 64).reshape(8, 8)}
        with mesh:
            avg = jax.jit(lambda t: compress_cross_pod(t, mesh))(g)
        # identical replicas -> average == input (up to int8 quantization)
        err = float(jnp.max(jnp.abs(avg['w'] - g['w'])))
        res = init_residual(g)
        with mesh:
            avg2, r2 = jax.jit(
                lambda t, r: compress_cross_pod_ef(t, r, mesh))(g, res)
        err2 = float(jnp.max(jnp.abs(avg2['w'] - g['w'])))
        # error feedback captures exactly what quantization lost
        recon = float(jnp.max(jnp.abs(avg2['w'] + r2['w'] - g['w'])))
        print(json.dumps({'err': err, 'err2': err2, 'recon': recon}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["err"] < 1e-2, res      # int8 quantization noise
    assert res["recon"] < 1e-5, res    # EF residual is exact
