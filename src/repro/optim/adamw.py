"""AdamW with decoupled weight decay, global-norm clipping, LR schedules.

Moments are stored in f32 regardless of param dtype (bf16-safe master
scaling happens in the update, not in storage of params — params keep
their dtype; at bf16 this is the standard "bf16 params + f32 moments"
memory/stability point).  ``None`` leaves (frozen halves from
``peft.partition``) pass through untouched, so CLOVER-S fine-tuning uses
the same optimizer on the trainable half only.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0     # 0 disables


def _map(f, *trees):
    return jax.tree.map(f, *trees, is_leaf=lambda x: x is None)


def adamw_init(params: Params) -> Params:
    zeros = _map(lambda p: None if p is None
                 else jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(
        lambda z: None if z is None else jnp.zeros_like(z), zeros,
        is_leaf=lambda x: x is None),
        "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree) if g is not None]
    return jnp.sqrt(sum(leaves)) if leaves else jnp.zeros(())


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return _map(lambda g: None if g is None else g * scale, grads), gn


def adamw_update(grads: Params, opt_state: Params, params: Params,
                 cfg: AdamWConfig, lr_scale: jnp.ndarray = 1.0,
                 ) -> Tuple[Params, Params, jnp.ndarray]:
    """Returns (new_params, new_opt_state, pre-clip grad norm)."""
    if cfg.grad_clip > 0:
        grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gn = global_norm(grads)
    step = opt_state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        if p is None or g is None:
            return None, None, None
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * jnp.square(gf)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:   # no decay on norms/bias
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    # explicit flatten/unflatten: the param tree contains tuples as
    # INTERNAL nodes ("blocks"), so tuple-valued tree.map leaves are not
    # distinguishable — operate on leaf lists instead.
    is_none = lambda x: x is None  # noqa: E731
    treedef = jax.tree_util.tree_structure(params, is_leaf=is_none)
    flat = [jax.tree_util.tree_leaves(t, is_leaf=is_none)
            for t in (params, grads, opt_state["m"], opt_state["v"])]
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(*flat):
        np_, nm, nv = upd(p, g, m, v)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    unf = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)  # noqa: E731
    return (unf(new_p),
            {"m": unf(new_m), "v": unf(new_v), "step": step}, gn)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def warmup_cosine(step, *, warmup: int, total: int, floor: float = 0.1):
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(1.0, warmup)
    prog = jnp.clip((s - warmup) / jnp.maximum(1.0, total - warmup), 0, 1)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup, warm, cos)


def warmup_linear(step, *, warmup: int, total: int):
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(1.0, warmup)
    decay = jnp.clip(1.0 - (s - warmup) / jnp.maximum(1.0, total - warmup),
                     0, 1)
    return jnp.where(s < warmup, warm, decay)
