"""Optimizers and schedules (pure JAX, no optax dependency)."""
from repro.optim.adamw import (  # noqa: F401
    AdamWConfig, adamw_init, adamw_update, warmup_cosine, warmup_linear,
    global_norm, clip_by_global_norm)
