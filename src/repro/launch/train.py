"""End-to-end training driver (reduced scale on CPU, production on TPU).

Wires together: config -> model init -> sharded train step -> synthetic
data -> checkpoint manager -> fault-tolerance supervisor.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch musicgen-large \
      --steps 50 --batch 8 --seq 64 --reduced
  ... --peft clover      # CLOVER-S fine-tuning instead of full training
  ... --clover-prune 0.5 # prune first, then train (recovery setting)
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Any, Dict, Optional

import jax

from repro.configs import get_config
from repro.core import clover_decompose, clover_prune
from repro.data import SyntheticConfig, SyntheticLM, make_global_batch
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.optim import AdamWConfig
from repro.parallel import sharding as sh
from repro.train.checkpoint import CheckpointManager
from repro.train.step import (TrainConfig, make_opt_state,
                              make_train_step)
from repro.train.supervisor import Supervisor, WorkerFailure


def build(arch: str, *, reduced: bool, batch: int, seq: int,
          steps: int, peft: Optional[str], prune_ratio: float,
          lr: float, seed: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(seed)
    params = T.init_lm_params(cfg, key)

    if prune_ratio > 0:
        params, cfg, _ = clover_decompose(params, cfg,
                                          peft=(peft == "clover"))
        params, cfg = clover_prune(params, cfg, qk_ratio=prune_ratio,
                                   vo_ratio=prune_ratio)
    elif peft == "clover":
        params, cfg, _ = clover_decompose(params, cfg, peft=True)

    mesh = make_host_mesh()
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=lr, weight_decay=0.0 if peft else 0.1),
        warmup_steps=max(2, steps // 20),
        total_steps=steps,
        remat=True,
        peft_mode=(peft == "clover"))
    step_fn, _ = make_train_step(cfg, tcfg, mesh)
    opt_state = make_opt_state(params, peft_mode=tcfg.peft_mode)

    data = SyntheticLM(SyntheticConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch,
        seed=seed))
    jitted = jax.jit(step_fn, donate_argnums=(0, 1))
    return cfg, mesh, params, opt_state, data, jitted


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="musicgen-large")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--peft", choices=["clover"], default=None)
    ap.add_argument("--clover-prune", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a WorkerFailure at this step (FT demo)")
    args = ap.parse_args(argv)

    cfg, mesh, params, opt_state, data, jitted = build(
        args.arch, reduced=args.reduced, batch=args.batch, seq=args.seq,
        steps=args.steps, peft=args.peft, prune_ratio=args.clover_prune,
        lr=args.lr)

    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    sup = Supervisor(ckpt, ckpt_every=args.ckpt_every)
    state: Dict[str, Any] = {"params": params, "opt": opt_state,
                             "data": data}
    spec = sh.data_specs(mesh)
    failed_once = {"done": False}

    def step_fn(st, i):
        if i == args.fail_at and not failed_once["done"]:
            failed_once["done"] = True
            raise WorkerFailure(f"injected failure at step {i}")
        batch_np = st["data"].batch_at(i)
        batch = make_global_batch(batch_np, mesh, spec)
        with mesh:
            p, o, metrics = jitted(st["params"], st["opt"], batch)
        st = {"params": p, "opt": o, "data": st["data"]}
        st["data"].step = i + 1
        return st, metrics

    def save_tree(st):
        return ({"params": st["params"], "opt": st["opt"]},
                {"data": st["data"].state_dict()})

    def restore_tree(tree, extra):
        data.load_state_dict(extra["data"])
        return {"params": tree["params"], "opt": tree["opt"],
                "data": data}

    def metrics_cb(i, m):
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:5d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}")

    t0 = time.time()
    rep = sup.run(state=state, step_fn=step_fn, save_tree=save_tree,
                  restore_tree=restore_tree, start_step=0,
                  total_steps=args.steps, metrics_cb=metrics_cb)
    dt = time.time() - t0
    print(f"done: {rep.steps_run} steps ({rep.restarts} restarts, "
          f"{len(rep.stragglers)} stragglers flagged) in {dt:.1f}s; "
          f"final loss {rep.metrics_history[-1]['loss']:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
