"""Production mesh definitions.

Meshes are built by FUNCTIONS (never at module import) so importing this
module never touches jax device state — conftest.py and the smoke tests
must keep seeing the single real CPU device.

Axis semantics:
  pod   — data-parallel replicas across pods (slow DCI links); gradients
          cross this axis once per step (optionally int8-compressed).
  data  — intra-pod data parallel + FSDP: the batch AND the d_model dim
          of every weight shard here (MaxText-style "fsdp" axis).
  model — tensor/expert parallel: heads, ff, experts, vocab.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Arbitrary mesh (tests, small deployments, pipeline experiments)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh(data: Optional[int] = None, model: int = 1):
    """Mesh over whatever devices exist (CPU tests: usually (1, 1)).

    ``model`` must divide ``jax.device_count()`` (and ``data * model``
    must consume exactly the available devices when ``data`` is given)
    — otherwise ``jax.make_mesh`` dies deep inside a reshape with no
    hint of which axis is wrong, so validate here and say so.
    """
    n = jax.device_count()
    if model < 1:
        raise ValueError(f"model axis must be >= 1, got {model}")
    if n % model != 0:
        raise ValueError(
            f"model={model} does not divide jax.device_count()={n}; "
            f"pick a tensor-parallel degree from the divisors of {n} "
            "(CPU tests: export XLA_FLAGS="
            "--xla_force_host_platform_device_count=N first)")
    if data is None:
        data = n // model
    if data * model != n:
        raise ValueError(
            f"mesh ({data}, {model}) needs {data * model} devices but "
            f"jax.device_count()={n}")
    return jax.make_mesh((data, model), ("data", "model"))


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def batch_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes the global batch shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
