import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax-importing import: jax locks the device count on
#   first backend init.  This file is the ONLY place the 512 placeholder
#   devices exist; tests and benches see the single real CPU device.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and report memory / cost / collective analysis.

Usage:
  python -m repro.launch.dryrun --arch phi3-medium-14b --shape train_4k
  python -m repro.launch.dryrun --all                  # 40-cell sweep
  python -m repro.launch.dryrun --all --multi-pod      # (2,16,16) pass
  python -m repro.launch.dryrun --all --json out.json  # for benchmarks

The compile (no execution, no allocation beyond placeholder metadata)
proves the sharding config is coherent: any sharding mismatch,
compile-time OOM, or unsupported collective fails the cell.
"""
import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, SHAPES, cell_applicable, get_config
from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.optim import AdamWConfig
from repro.parallel import hlo as H
from repro.parallel import sharding as sh
from repro.train.step import TrainConfig, make_train_step, make_opt_state


def production_cfg(name: str) -> ArchConfig:
    """Full assigned config at production numerics: bf16 params/compute,
    vocab padded to 128 so the logits shard on the model axis."""
    return dataclasses.replace(get_config(name),
                               param_dtype="bfloat16",
                               compute_dtype="bfloat16",
                               pad_vocab_to=128)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train:   {tokens, labels} (+frontend_embeds)
    prefill: {tokens} (+frontend_embeds) — full prompt
    decode:  {token} — one new token against a seq_len KV cache
    """
    B, S = shape.global_batch, shape.seq_len
    F = cfg.frontend_len if cfg.frontend != "none" else 0
    n_tok = S - F  # backbone sees exactly seq_len positions
    i32 = jnp.int32
    cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    out: Dict[str, Any] = {}
    if shape.kind == "train":
        out["tokens"] = jax.ShapeDtypeStruct((B, n_tok), i32)
        out["labels"] = jax.ShapeDtypeStruct((B, n_tok), i32)
        if F:
            out["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, F, cfg.d_model), cdt)
    elif shape.kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((B, n_tok), i32)
        if F:
            out["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, F, cfg.d_model), cdt)
    else:  # decode
        out["token"] = jax.ShapeDtypeStruct((B,), i32)
    return out


def _eval_shape_params(cfg: ArchConfig):
    return jax.eval_shape(
        lambda k: T.init_lm_params(cfg, k), jax.random.PRNGKey(0))


def _eval_shape_state(cfg: ArchConfig, batch: int, max_len: int):
    return jax.eval_shape(
        lambda: T.init_decode_state(cfg, batch, max_len))


# Default microbatch count for train cells: global batch 256 -> 16 per
# device on the data axis -> microbatch 2/device.  Keeps 14B-52B train
# steps inside 16GB/chip (see EXPERIMENTS.md §Dry-run).
TRAIN_MICROBATCHES = 8

# Per-arch memory tuning for the train shape (EXPERIMENTS.md §Dry-run):
# deepest model also groups remat so layer carries shrink 2x.
TRAIN_TUNING = {
    "deepseek-coder-33b": {"microbatches": 16, "remat_group": 2},
}


def lower_cell(cfg: ArchConfig, shape: ShapeConfig, mesh,
               rules: Optional[sh.ShardingRules] = None, *,
               remat: bool = True,
               microbatches: int = TRAIN_MICROBATCHES):
    """Lower the cell's step on ``mesh``; returns the jax Lowered."""
    rules = rules or sh.ShardingRules()
    ins = input_specs(cfg, shape)
    params_s = _eval_shape_params(cfg)
    pspec = sh.param_specs(params_s, mesh, rules)
    p_sh = sh.shardings(pspec, mesh)
    bspec = sh.data_specs(mesh, rules, global_batch=shape.global_batch)
    baxes = bspec[0]   # batch mesh axes, or None if batch doesn't divide

    from jax.sharding import NamedSharding, PartitionSpec as P
    tok_sh = NamedSharding(mesh, bspec)
    fe_sh = NamedSharding(mesh, P(baxes, None, None))

    if shape.kind == "train":
        tcfg = TrainConfig(optimizer=AdamWConfig(), remat=remat,
                           microbatches=microbatches)
        step, _ = make_train_step(cfg, tcfg, mesh, rules)
        opt_s = jax.eval_shape(lambda p: make_opt_state(p), params_s)
        ospec = {"m": pspec, "v": pspec, "step": P()}
        o_sh = sh.shardings(ospec, mesh)
        batch_sh = {"tokens": tok_sh, "labels": tok_sh}
        batch_shapes = {"tokens": ins["tokens"], "labels": ins["labels"]}
        if "frontend_embeds" in ins:
            batch_sh["frontend_embeds"] = fe_sh
            batch_shapes["frontend_embeds"] = ins["frontend_embeds"]
        jitted = jax.jit(step,
                         in_shardings=(p_sh, o_sh, batch_sh),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
        with mesh:
            return jitted.lower(params_s, opt_s, batch_shapes)

    if shape.kind == "prefill":
        state_s = _eval_shape_state(cfg, shape.global_batch, shape.seq_len)
        sspec = sh.decode_state_specs(state_s, mesh, rules)
        s_sh = sh.shardings(sspec, mesh)
        logits_sh = NamedSharding(mesh, P(baxes, None))

        def prefill_fn(params, tokens, state, fe=None):
            return T.prefill(params, cfg, tokens, state, frontend_embeds=fe)

        args = [params_s, ins["tokens"], state_s]
        in_sh = [p_sh, tok_sh, s_sh]
        if "frontend_embeds" in ins:
            args.append(ins["frontend_embeds"])
            in_sh.append(fe_sh)
        jitted = jax.jit(prefill_fn,
                         in_shardings=tuple(in_sh),
                         out_shardings=(logits_sh, s_sh),
                         donate_argnums=(2,))
        with mesh:
            return jitted.lower(*args)

    # decode: one token against a filled cache of seq_len
    state_s = _eval_shape_state(cfg, shape.global_batch, shape.seq_len)
    sspec = sh.decode_state_specs(state_s, mesh, rules)
    s_sh = sh.shardings(sspec, mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P
    tok1_sh = NamedSharding(mesh, P(baxes))
    logits_sh = NamedSharding(mesh, P(baxes, None))

    def decode_fn(params, token, state):
        return T.decode_step(params, cfg, token, state)

    jitted = jax.jit(decode_fn,
                     in_shardings=(p_sh, tok1_sh, s_sh),
                     out_shardings=(logits_sh, s_sh),
                     donate_argnums=(2,))
    with mesh:
        return jitted.lower(params_s, ins["token"], state_s)


def calibrated_costs(cfg: ArchConfig, shape: ShapeConfig, mesh,
                     rules: Optional[sh.ShardingRules] = None, *,
                     remat: bool = True) -> Dict[str, float]:
    """Exact cost terms despite ``lax.scan``: XLA's cost_analysis counts a
    while body ONCE, so a stacked-layer model under-reports flops/bytes/
    collectives by ~n_blocks.  Unrolling is exact but compiles for many
    minutes per cell on one CPU core.  Instead, compile the SAME model at
    n_layers = period and 2*period (trip counts 1 and 2 — compiles in
    seconds) and finite-difference:

        per_block = cost(2p) - cost(p);  fixed = cost(p) - per_block
        total     = fixed + n_blocks * per_block

    This captures per-layer collectives, remat recompute, everything —
    because both compiles go through the identical partitioner."""
    out = {}
    costs = []
    for mult in (1, 2):
        small = dataclasses.replace(cfg, n_layers=cfg.period * mult,
                                    remat_group=1, unroll_layers=True)
        lowered = lower_cell(small, shape, mesh, rules, remat=remat,
                             microbatches=1)
        compiled = lowered.compile()
        rl = H.roofline_from_compiled(compiled)
        costs.append((rl.flops, rl.hbm_bytes, rl.coll_bytes,
                      dict(rl.coll_detail)))
    n = cfg.n_blocks
    for i, name in enumerate(("flops", "hbm_bytes", "coll_bytes")):
        per_block = costs[1][i] - costs[0][i]
        fixed = costs[0][i] - per_block
        out[name] = max(0.0, fixed + n * per_block)
    detail = {}
    for k in set(costs[0][3]) | set(costs[1][3]):
        pb = costs[1][3].get(k, 0) - costs[0][3].get(k, 0)
        fx = costs[0][3].get(k, 0) - pb
        v = max(0, fx + n * pb)
        if v:
            detail[k] = v
    out["coll_detail"] = detail
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             rules: Optional[sh.ShardingRules] = None,
             unroll: bool = False, remat: bool = True,
             cfg_overrides: Optional[Dict[str, Any]] = None,
             verbose: bool = True) -> Dict[str, Any]:
    cfg = production_cfg(arch)
    if unroll:
        cfg = dataclasses.replace(cfg, unroll_layers=True)
    tuning = dict(TRAIN_TUNING.get(arch, {})) if shape_name.startswith(
        "train") else {}
    microbatches = tuning.pop("microbatches", TRAIN_MICROBATCHES)
    if tuning:
        cfg = dataclasses.replace(cfg, **tuning)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    if not cell_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "long_500k needs sub-quadratic mixing "
                          "(DESIGN.md §5)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh, rules, remat=remat,
                         microbatches=microbatches)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = H.memory_per_device(compiled)
    # cost terms via finite-difference calibration (microbatches=1 so the
    # terms cover the FULL global batch; memory comes from the real
    # microbatched compile above).  See calibrated_costs docstring.
    cal = calibrated_costs(cfg, shape, mesh, rules, remat=remat)
    rl = H.Roofline(
        flops=cal["flops"], hbm_bytes=cal["hbm_bytes"],
        coll_bytes=cal["coll_bytes"], coll_detail=cal["coll_detail"],
        t_compute=cal["flops"] / H.PEAK_FLOPS,
        t_memory=cal["hbm_bytes"] / H.HBM_BW,
        t_collective=cal["coll_bytes"] / (H.ICI_BW * 4))
    n_chips = mesh.size
    # MODEL_FLOPS: 6 N D for train, 2 N D for inference (per token);
    # MoE uses active params.  Per-device = global / chips.
    n_active = cfg.n_params(active_only=True)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * n_active * tokens / n_chips

    res = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "mesh": tuple(mesh.shape.values()), "multi_pod": multi_pod,
        "lower_s": round(t1 - t0, 1), "compile_s": round(t2 - t1, 1),
        "bytes_per_device_gib": round(mem["total_gib"], 3),
        "flops_per_device": rl.flops,
        "hbm_bytes_per_device": rl.hbm_bytes,
        "collective_bytes": rl.coll_bytes,
        "collective_detail": {k: v for k, v in rl.coll_detail.items() if v},
        "t_compute_s": rl.t_compute,
        "t_memory_s": rl.t_memory,
        "t_collective_s": rl.t_collective,
        "dominant": rl.dominant,
        "model_flops_per_device": model_flops,
        "useful_flops_ratio": model_flops / max(rl.flops, 1.0),
        "roofline_fraction": rl.fraction(model_flops),
    }
    if verbose:
        print(f"[{arch} x {shape_name}] mesh={res['mesh']} "
              f"mem={res['bytes_per_device_gib']}GiB "
              f"compute={rl.t_compute*1e3:.2f}ms "
              f"memory={rl.t_memory*1e3:.2f}ms "
              f"collective={rl.t_collective*1e3:.2f}ms "
              f"dominant={rl.dominant} "
              f"roofline={res['roofline_fraction']:.3f}")
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for a in ASSIGNED_ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch and --shape, or --all"
        cells = [(args.arch, args.shape)]

    results = []
    failed = []
    for a, s in cells:
        try:
            results.append(run_cell(a, s, multi_pod=args.multi_pod))
        except Exception as e:  # noqa: BLE001 — report and continue sweep
            print(f"[{a} x {s}] FAILED: {type(e).__name__}: {e}",
                  file=sys.stderr)
            failed.append((a, s, f"{type(e).__name__}: {e}"))
            results.append({"arch": a, "shape": s, "status": "failed",
                            "error": f"{type(e).__name__}: {e}"})
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1, default=str)
    ok = sum(1 for r in results if r["status"] == "ok")
    sk = sum(1 for r in results if r["status"] == "skipped")
    print(f"\n{ok} ok, {sk} skipped, {len(failed)} failed "
          f"of {len(results)} cells")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
