"""Serving: batched engine over CLOVER-rank KV caches."""
from repro.serve.engine import (  # noqa: F401
    Engine, EngineConfig, Request, Scheduler, greedy_reference)
