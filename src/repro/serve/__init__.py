"""Serving: batched engine over (optionally paged) CLOVER-rank KV
caches with copy-on-write prefix caching, a hierarchical host-RAM
spill tier, rank-balanced tensor parallelism, an overload-safe
robustness layer, and multi-tenant SV-adapter serving (DESIGN.md §13:
``core.peft.AdapterRegistry`` + ``Request.adapter_id``).

Package layout (DESIGN.md §6, §8-§13):
  * ``config``    — ``EngineConfig``
  * ``memory``    — ``PageAllocator``, ``PrefixCache``, ``HostTier``
    (host-global; §6, §9, §12)
  * ``scheduler`` — ``Request``, ``Scheduler``, slot phases, request
    lifecycle statuses (QUEUED .. DONE/SHED/TIMED_OUT/CANCELLED)
  * ``executor``  — ``Executor`` protocol, ``LocalExecutor``,
    ``ShardedExecutor`` (compiled entries + device placement; §10)
  * ``faults``    — ``FaultPlan`` deterministic fault injection,
    ``FaultError`` (§11)
  * ``metrics``   — ``ServeMetrics`` behind ``Engine.stats()``
  * ``engine``    — ``Engine`` orchestration, ``greedy_reference``

The names below are compatibility re-exports: ``from repro.serve
import Engine, PageAllocator, ...`` keeps working across the split.
"""
from repro.serve.config import EngineConfig  # noqa: F401
from repro.serve.engine import Engine, greedy_reference  # noqa: F401
from repro.serve.executor import (  # noqa: F401
    Executor, LocalExecutor, ShardedExecutor)
from repro.serve.faults import FaultError, FaultPlan  # noqa: F401
from repro.serve.memory import (  # noqa: F401
    PageAllocator, PrefixCache, rank_pool_bytes)
from repro.serve.metrics import ServeMetrics  # noqa: F401
from repro.serve.scheduler import (  # noqa: F401
    CANCELLED, DONE, QUEUED, RUNNING, SHED, TERMINAL, TIMED_OUT,
    Request, Scheduler)
