"""Serving: batched engine over (optionally paged) CLOVER-rank KV
caches with copy-on-write prefix caching."""
from repro.serve.engine import (  # noqa: F401
    Engine, EngineConfig, PageAllocator, PrefixCache, Request, Scheduler,
    greedy_reference)
