"""Serving: batched engine over (optionally paged) CLOVER-rank KV
caches with copy-on-write prefix caching and rank-balanced tensor
parallelism.

Package layout (DESIGN.md §10):
  * ``config``    — ``EngineConfig``
  * ``memory``    — ``PageAllocator``, ``PrefixCache`` (host-global)
  * ``scheduler`` — ``Request``, ``Scheduler``, slot phases
  * ``executor``  — ``Executor`` protocol, ``LocalExecutor``,
    ``ShardedExecutor`` (compiled entries + device placement)
  * ``engine``    — ``Engine`` orchestration, ``greedy_reference``

The names below are compatibility re-exports: ``from repro.serve
import Engine, PageAllocator, ...`` keeps working across the split.
"""
from repro.serve.config import EngineConfig  # noqa: F401
from repro.serve.engine import Engine, greedy_reference  # noqa: F401
from repro.serve.executor import (  # noqa: F401
    Executor, LocalExecutor, ShardedExecutor)
from repro.serve.memory import PageAllocator, PrefixCache  # noqa: F401
from repro.serve.scheduler import Request, Scheduler  # noqa: F401
