"""Serving: batched engine over (optionally paged) CLOVER-rank KV caches."""
from repro.serve.engine import (  # noqa: F401
    Engine, EngineConfig, PageAllocator, Request, Scheduler,
    greedy_reference)
