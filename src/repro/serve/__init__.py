"""Serving: batched engine over CLOVER-rank KV caches."""
from repro.serve.engine import Engine, EngineConfig, Request  # noqa: F401
