"""Scheduling policy: admission, chunk planning, preemption, retirement.

Everything here is HOST-side numpy bookkeeping — the device sees
nothing but the fixed step shapes the executor compiles, and nothing in
this module depends on the KV layout beyond the allocator/trie handles
it is given, or on the parallelism degree at all (the same Scheduler
drives the local and the sharded executor — DESIGN.md §10's "planning
is layout-agnostic" contract).

Request lifecycle (DESIGN.md §11)::

    QUEUED --admit--> RUNNING --retire--> DONE
       |                 |  \\--preempt/requeue--> QUEUED
       |                 +--cancel--> CANCELLED
       |                 +--deadline--> TIMED_OUT
       +--cancel--> CANCELLED
       +--provably-unmeetable deadline--> SHED

The three non-DONE terminal states all release the slot's pages through
the SAME decref path preemption uses (``PageAllocator.release``) but —
unlike preemption — never publish into the prefix trie: a shed,
cancelled, or timed-out request must leave the allocator, trie, and
refcounts exactly as if it had never run.

Multi-tenant serving (DESIGN.md §13): ``Request.adapter_id`` names the
tenant's SV adapter; every trie ``match``/``insert`` this module issues
folds that id into the walk key (``Request._trie_extra``), so prefix
hits never cross adapters — K/V cached under one tenant's singular
values encode different hidden states than another's.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.serve.config import EngineConfig
from repro.serve.memory import PageAllocator, PrefixCache

# slot phases
PREFILL = "prefill"     # prompt tokens remain; consumed chunk-wise
TAIL = "tail"           # recurrent archs: < C prompt tokens remain,
                        # fed one-by-one through the decode step
DECODE = "decode"       # generating one token per engine step

# request statuses
QUEUED = "QUEUED"
RUNNING = "RUNNING"
DONE = "DONE"           # ran to completion (max_new_tokens or EOS)
SHED = "SHED"           # dropped by policy: unmeetable deadline / watchdog
TIMED_OUT = "TIMED_OUT"  # running past its deadline; partial stream kept
CANCELLED = "CANCELLED"  # client cancel via Engine.cancel(uid)
TERMINAL = frozenset({DONE, SHED, TIMED_OUT, CANCELLED})


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0            # 0 = greedy
    # scheduling class: higher admits first; under overload the
    # watchdog and deadline shedder sacrifice lower priorities first
    priority: int = 0
    # SV-adapter tenant (DESIGN.md §13): an AdapterRegistry id.  0 is
    # the reserved identity adapter — bitwise the base model — and the
    # only valid id on an engine built without a registry.
    adapter_id: int = 0
    # deadline in ENGINE STEPS after submission (None = none): the
    # request must reach a terminal state within this many steps or it
    # is timed out (running) / shed (queued and provably unmeetable)
    deadline_steps: Optional[int] = None
    # filled by the engine:
    status: str = QUEUED
    generated: List[int] = field(default_factory=list)
    # prefix-cache hit size at the LAST admission: prompt tokens whose
    # K/V came from shared pages (their prefill chunks were skipped)
    cached_tokens: int = 0
    # serving metrics, wall clock (monotonic): submit time, one stamp
    # per emitted token (token_times[0] is first-token / end of prefill)
    t_submit: float = 0.0
    token_times: List[float] = field(default_factory=list)
    # serving metrics, deterministic clock (engine step indices) —
    # bit-reproducible TTFT/ITL, what the overload benchmark gates on
    submit_step: int = -1
    token_steps: List[int] = field(default_factory=list)
    finish_step: int = -1
    # queue ordering ticket (set by the scheduler; preemption/requeue
    # reuse it to keep head-of-queue position)
    _seq: int = field(default=0, repr=False, compare=False)

    def __post_init__(self):
        p = np.asarray(self.prompt)
        if p.ndim != 1 or p.size == 0:
            raise ValueError(
                f"Request.prompt (uid={self.uid}): expected a non-empty "
                f"1-D token array, got shape {p.shape}")
        if not np.issubdtype(p.dtype, np.integer):
            raise ValueError(
                f"Request.prompt (uid={self.uid}): expected integer "
                f"tokens, got dtype {p.dtype}")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"Request.max_new_tokens (uid={self.uid})="
                f"{self.max_new_tokens}: must be >= 1")
        if self.temperature < 0.0:
            raise ValueError(
                f"Request.temperature (uid={self.uid})="
                f"{self.temperature}: must be >= 0")
        if not isinstance(self.priority, (int, np.integer)) \
                or self.priority < 0:
            raise ValueError(
                f"Request.priority (uid={self.uid})={self.priority!r}: "
                "must be an int >= 0")
        if self.deadline_steps is not None and self.deadline_steps < 1:
            raise ValueError(
                f"Request.deadline_steps (uid={self.uid})="
                f"{self.deadline_steps}: must be None or >= 1")
        if not isinstance(self.adapter_id, (int, np.integer)) \
                or self.adapter_id < 0:
            raise ValueError(
                f"Request.adapter_id (uid={self.uid})="
                f"{self.adapter_id!r}: must be an int >= 0")

    @property
    def _trie_extra(self) -> Tuple:
        """Prefix-trie key extension (DESIGN.md §13): adapter 0 maps to
        ``()`` so identity-tenant caches stay hash-identical to builds
        without adapters."""
        return (self.adapter_id,) if self.adapter_id else ()

    @property
    def done(self) -> bool:
        """True once the request reached ANY terminal state.  (Kept as
        the historical name; ``status`` distinguishes DONE from
        SHED/TIMED_OUT/CANCELLED.)"""
        return self.status in TERMINAL


class Scheduler:
    """Admission / chunking / preemption / retirement policy with
    per-slot phases.

    With a ``PageAllocator`` (paged mode) admission is gated on free
    pages for the effective prompt, retirement frees pages, and
    ``preempt`` requeues a sequence at the queue head with its
    generated tokens folded into the effective prompt (greedy
    continuation is exact).

    With a ``PrefixCache`` (paged + ``EngineConfig.prefix_cache``)
    admission additionally matches the longest cached page-aligned
    prefix of the effective prompt, maps those pages READ-ONLY into the
    slot's table and resumes chunked prefill at the first uncached
    token (``resume``); prefill completion / preemption / retirement
    publish the sequence's full-page run back into the trie so later
    requests (including the preempted sequence itself) skip the
    redundant prefill compute.  With a host spill tier under the trie
    (``EngineConfig.host_pages``, DESIGN.md §12) the engine installs a
    ``restore`` callback that admission invokes AFTER ``ensure`` — it
    copies spilled page content back into the slot's freshly allocated
    pages and the resume point advances over the restored run too.

    Admission is PRIORITY-AWARE: the next candidate is the highest
    priority queued request, FIFO within a class — with every request
    at the default priority the order is exactly the historical FIFO.
    Head-of-line blocking on page exhaustion is kept (the best
    candidate waits for pages rather than being overtaken; an overtake
    would let a stream of small requests starve it forever).
    """

    def __init__(self, ecfg: EngineConfig, recurrent: bool,
                 allocator: Optional[PageAllocator] = None,
                 prefix: Optional[PrefixCache] = None,
                 metrics=None):
        self.ecfg = ecfg
        self.chunk = ecfg.chunk
        self.recurrent = recurrent
        self.alloc = allocator
        self.prefix = prefix
        self.metrics = metrics
        self.queue: List[Request] = []
        n = ecfg.slots
        self.slot_req: List[Optional[Request]] = [None] * n
        # effective prompt per slot: the request's prompt plus any
        # tokens generated before a preemption (greedy continuation)
        self.slot_prompt: List[Optional[np.ndarray]] = [None] * n
        self.phase: List[Optional[str]] = [None] * n
        self.pos = np.zeros(n, np.int64)        # prompt tokens consumed
        self.fresh = np.zeros(n, bool)          # needs state reset
        self.last_token = np.zeros(n, np.int32)
        self.slot_seq = np.zeros(n, np.int64)   # admission order (age)
        # prefix-cache resume point per slot: the first position THIS
        # tenure writes (0 without a hit).  Positions below it are
        # served by read-only shared pages.
        self.resume = np.zeros(n, np.int64)
        # slots benched after fault-retry exhaustion: not admittable
        # until the engine step counter reaches the recorded value
        self.quarantined = np.zeros(n, np.int64)
        # engine step clock (the engine refreshes this every step;
        # deterministic timestamps and deadlines are measured in it)
        self.now_step = 0
        self._admit_counter = 0
        self._submit_counter = 0
        # requeued/preempted requests take decreasing negative tickets
        # so the LAST one requeued sorts first within its priority
        # class — exactly the historical deque.appendleft order
        self._requeue_counter = -1
        self.preemptions = 0
        self.requeues = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0
        # host-tier restore hook (hierarchical KV, DESIGN.md §12): the
        # engine assigns a callable ``(slot, eff_prompt, hit_pages,
        # trie_extra) -> n_restored`` that probes the host spill tier
        # for pages beyond the trie hit and copies them back into the
        # slot's own freshly allocated pages (``trie_extra`` is the
        # request's adapter key — DESIGN.md §13).  None = no host tier.
        # Restore runs THROUGH admission because only here are the
        # slot's pages already ensured and the resume point still
        # unfixed.
        self.restore = None

    # -- admission -----------------------------------------------------
    def submit(self, req: Request):
        """Validate ``req`` against this engine's capacity and enqueue
        it.  Malformed requests fail HERE, loudly, with the field named
        — never mid-trace inside ``admit``."""
        L = len(np.asarray(req.prompt))
        if L + req.max_new_tokens > self.ecfg.max_len:
            raise ValueError(
                f"Request.prompt (uid={req.uid}): prompt length {L} + "
                f"max_new_tokens {req.max_new_tokens} exceeds "
                f"EngineConfig.max_len={self.ecfg.max_len}")
        if self.alloc is not None:
            need = self.alloc.pages_for(
                L + req.max_new_tokens + self.ecfg.spec_k)
            if need > self.alloc.n_pages:
                raise ValueError(
                    f"Request.prompt (uid={req.uid}): needs {need} KV "
                    f"pages (prompt {L} + max_new_tokens "
                    f"{req.max_new_tokens} + spec overhang "
                    f"{self.ecfg.spec_k}) but the pool only has "
                    f"{self.alloc.n_pages}")
        req.t_submit = time.monotonic()
        req.submit_step = self.now_step
        req.status = QUEUED
        req._seq = self._submit_counter
        self._submit_counter += 1
        self.queue.append(req)

    def _next_candidate(self) -> Optional[Request]:
        if not self.queue:
            return None
        return min(self.queue, key=lambda r: (-r.priority, r._seq))

    def admit(self):
        free = [s for s in range(self.ecfg.slots)
                if self.slot_req[s] is None
                and self.now_step >= self.quarantined[s]]
        for s in free:
            req = self._next_candidate()
            if req is None:
                break
            eff = (req.prompt if not req.generated else
                   np.concatenate([np.asarray(req.prompt, np.int32),
                                   np.asarray(req.generated, np.int32)]))
            L = len(eff)
            remaining = req.max_new_tokens - len(req.generated)
            # submit() validated the request; these are invariants
            assert L > 0 and L + remaining <= self.ecfg.max_len
            resume = 0
            if self.alloc is not None:
                # speculative verify windows transiently overhang
                # the committed length by up to spec_k tokens
                slack = self.ecfg.spec_k
                assert (self.alloc.pages_for(L + remaining + slack)
                        <= self.alloc.n_pages)
                hit_pages = 0
                if self.prefix is not None:
                    pages = self.prefix.match(eff, extra=req._trie_extra)
                    if pages and self.alloc.map_shared(s, pages):
                        # at least one token must remain to prefill
                        # (its logits seed generation); a FULL hit
                        # resumes at L-1 and the rewrite of that
                        # position COWs the shared last page
                        pt = self.alloc.page_tokens
                        hit_pages = len(pages)
                        resume = min(hit_pages * pt, L - 1)
                ok = self.alloc.ensure(s, L)
                if not ok and self.prefix is not None:
                    # cached-but-idle prefixes are reclaimable
                    # bytes: evict LRU trie pages nobody maps and
                    # retry (matched pages are slot-mapped now, so
                    # eviction can never touch THIS hit)
                    short = (self.alloc.pages_for(L)
                             - len(self.alloc.tables[s])
                             - self.alloc.free_pages)
                    if short > 0 and self.prefix.evict(short) > 0:
                        ok = self.alloc.ensure(s, L)
                if not ok:
                    # head-of-line: the best candidate waits for pages
                    # (undo the shared mapping so the trie can evict)
                    self.alloc.release(s)
                    break
                if self.restore is not None:
                    # hierarchical KV (DESIGN.md §12): pages beyond the
                    # trie hit may survive in the HOST tier — ensure()
                    # just allocated the slot's own pages for them, so
                    # the engine can copy spilled bytes back instead of
                    # re-prefilling.  On a host_copy fault the callback
                    # returns what it managed (possibly 0); the resume
                    # point only ever advances over RESTORED pages.
                    n_rest = self.restore(s, eff, hit_pages,
                                          req._trie_extra)
                    if n_rest > 0:
                        resume = min((hit_pages + n_rest)
                                     * self.alloc.page_tokens, L - 1)
            self.queue.remove(req)
            req.cached_tokens = resume
            req.status = RUNNING
            if resume > 0:
                self.prefix_hits += 1
                self.prefix_hit_tokens += resume
            self.slot_req[s] = req
            self.slot_prompt[s] = eff
            self.pos[s] = resume
            self.resume[s] = resume
            self.fresh[s] = True
            self.slot_seq[s] = self._admit_counter
            self._admit_counter += 1
            self.phase[s] = self._prefill_phase(L, resume)

    def _prefill_phase(self, L: int, pos: int) -> str:
        if self.recurrent and L - pos < self.chunk:
            return TAIL          # padded window would corrupt state
        return PREFILL

    # -- deadlines / cancellation / shedding --------------------------
    def _min_steps(self, req: Request) -> int:
        """LOWER bound on engine steps needed to finish ``req`` if
        admitted right now: best-case prefill (a full prefix-cache hit
        skips all but one chunk) plus best-case decode (EOS can stop
        after the first token; speculation commits up to spec_window
        per step).  Used for PROVABLE infeasibility only — an optimistic
        bound sheds nothing that had any chance."""
        L = len(req.prompt) + len(req.generated)
        C = self.chunk
        if self.prefix is not None:
            prefill = 1
        elif self.recurrent:
            full, tail = divmod(L, C)
            prefill = full + tail if tail else full
        else:
            prefill = -(-L // C)
        min_new = 1 if self.ecfg.eos_id >= 0 else \
            req.max_new_tokens - len(req.generated)
        W = self.ecfg.spec_window if self.ecfg.spec_k > 0 else 1
        return prefill + -(-max(0, min_new - 1) // W)

    def _terminal(self, req: Request, status: str):
        req.status = status
        req.finish_step = self.now_step
        if self.metrics is not None:
            self.metrics.on_terminal(req)

    def _finish_slot(self, s: int, status: str):
        """Retire a RUNNING slot into a non-DONE terminal state: pages
        decref'd through the same path preemption uses, but NOTHING is
        published to the trie — allocator/trie/refcounts end exactly as
        if the request had never run."""
        req = self.slot_req[s]
        assert req is not None
        if self.alloc is not None:
            self.alloc.release(s)
        self.slot_req[s] = None
        self.slot_prompt[s] = None
        self.phase[s] = None
        self._terminal(req, status)

    def enforce_deadlines(self):
        """Called once per engine step, before admission: time out
        running slots past their deadline, and shed LOW-PRIORITY queued
        requests whose deadline is PROVABLY unmeetable even in the best
        case.  "Low-priority" means strictly-higher-priority work is
        pending — under contention a doomed request's slot time is
        better spent on someone who can still win, but an uncontended
        doomed request is allowed to run to its deadline and flush a
        PARTIAL stream (clients prefer a truncated answer to none)."""
        now = self.now_step
        for s, req in enumerate(self.slot_req):
            if req is None or req.deadline_steps is None:
                continue
            if now >= req.submit_step + req.deadline_steps:
                self._finish_slot(s, TIMED_OUT)
        pending = self.queue + [r for r in self.slot_req if r is not None]
        pmax = max((r.priority for r in pending), default=0)
        doomed = [r for r in self.queue
                  if r.deadline_steps is not None
                  and r.priority < pmax
                  and now + self._min_steps(r) - 1
                  >= r.submit_step + r.deadline_steps]
        for req in doomed:
            self.queue.remove(req)
            self._terminal(req, SHED)

    def cancel(self, uid: int) -> bool:
        """Client cancellation: queued requests leave the queue;
        running slots retire through the no-publish decref path.
        Returns False when ``uid`` is unknown or already terminal."""
        for req in self.queue:
            if req.uid == uid:
                self.queue.remove(req)
                self._terminal(req, CANCELLED)
                return True
        for s, req in enumerate(self.slot_req):
            if req is not None and req.uid == uid:
                self._finish_slot(s, CANCELLED)
                return True
        return False

    def shed(self, uid_or_slot: Tuple[str, int]):
        """Watchdog shedding: ('queue', uid) or ('slot', s)."""
        kind, key = uid_or_slot
        if kind == "queue":
            for req in self.queue:
                if req.uid == key:
                    self.queue.remove(req)
                    self._terminal(req, SHED)
                    return
        else:
            self._finish_slot(key, SHED)

    def requeue(self, s: int, quarantine_until: int):
        """Fault recovery: bench slot ``s`` until the engine step clock
        reaches ``quarantine_until`` and requeue its request at the
        head of its priority class (ticket reuse, like preemption).
        No publish — after a fault the device-side pages are suspect,
        so re-admission re-prefills from the host-held token stream."""
        req = self.slot_req[s]
        assert req is not None
        if self.alloc is not None:
            self.alloc.release(s)
        self.slot_req[s] = None
        self.slot_prompt[s] = None
        self.phase[s] = None
        req.status = QUEUED
        req._seq = self._requeue_counter
        self._requeue_counter -= 1
        self.queue.append(req)
        self.quarantined[s] = quarantine_until
        self.requeues += 1

    # -- planning ------------------------------------------------------
    def has_chunk_work(self) -> bool:
        return any(p == PREFILL for p in self.phase)

    def planned_writes(self, decode_width: int = 1) -> np.ndarray:
        """(slots,) KV positions the NEXT step will write per active
        slot — what must be page-covered before the step runs.  TAIL
        and PREFILL writes always land inside the prompt coverage
        allocated at admission; only decode growth can demand pages.
        ``decode_width`` > 1 is a speculative round: every decoding
        slot writes a (k+1)-wide draft+verify window."""
        n, C = self.ecfg.slots, self.chunk
        take = np.zeros(n, np.int64)
        chunk_step = self.has_chunk_work()
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if chunk_step:
                if self.phase[s] == PREFILL:
                    take[s] = min(C, len(self.slot_prompt[s])
                                  - int(self.pos[s]))
                elif self.phase[s] == DECODE and not self.recurrent:
                    take[s] = 1
            else:
                take[s] = decode_width
        return take

    def plan_chunk(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Build the (slots, C) window batch.  PREFILL slots consume up
        to C prompt tokens (recurrent archs: exactly C — guaranteed by
        the phase); DECODE slots ride with length 1 on attention-only
        archs; everything else idles with length 0."""
        n, C = self.ecfg.slots, self.chunk
        tokens = np.zeros((n, C), np.int32)
        lengths = np.zeros(n, np.int32)
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if self.phase[s] == PREFILL:
                prompt = self.slot_prompt[s]
                take = min(C, len(prompt) - int(self.pos[s]))
                tokens[s, :take] = prompt[self.pos[s]:self.pos[s] + take]
                lengths[s] = take
            elif self.phase[s] == DECODE and not self.recurrent:
                tokens[s, 0] = self.last_token[s]
                lengths[s] = 1
        fresh = self.fresh & (lengths > 0)
        self.fresh &= ~fresh
        return tokens, lengths, fresh

    def plan_decode(self) -> Tuple[np.ndarray, np.ndarray]:
        """One token per slot: TAIL slots feed their next prompt token,
        DECODE slots their last sampled token."""
        n = self.ecfg.slots
        tokens = np.zeros(n, np.int32)
        active = np.zeros(n, bool)
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            active[s] = True
            if self.phase[s] == TAIL:
                tokens[s] = self.slot_prompt[s][self.pos[s]]
            else:
                tokens[s] = self.last_token[s]
        fresh = self.fresh & active
        self.fresh &= ~fresh
        return tokens, fresh

    # -- post-step transitions ----------------------------------------
    def advance_chunk(self, lengths: np.ndarray) -> List[int]:
        """Apply a chunk step's progress.  Returns slots whose logits
        row is a real next-token distribution to sample from."""
        sample = []
        for s, req in enumerate(self.slot_req):
            if req is None or lengths[s] == 0:
                continue
            if self.phase[s] == PREFILL:
                self.pos[s] += int(lengths[s])
                if self.pos[s] == len(self.slot_prompt[s]):
                    self.phase[s] = DECODE
                    # the prompt's K/V is fully written: publish its
                    # full-page run so CONCURRENT requests with the
                    # same prefix already share it
                    self._publish(s, len(self.slot_prompt[s]))
                    sample.append(s)
                else:
                    self.phase[s] = self._prefill_phase(
                        len(self.slot_prompt[s]), int(self.pos[s]))
            else:                                   # riding decode slot
                sample.append(s)
        return sample

    def advance_decode(self) -> List[int]:
        sample = []
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if self.phase[s] == TAIL:
                self.pos[s] += 1
                if self.pos[s] == len(self.slot_prompt[s]):
                    self.phase[s] = DECODE
                    sample.append(s)
            else:
                sample.append(s)
        return sample

    # -- preemption / retirement --------------------------------------
    def _publish(self, s: int, n_valid: int):
        """Publish slot ``s``'s first ``n_valid`` cached positions (its
        committed K/V) into the prefix trie, rounded DOWN to full
        pages.  Keyed on the sequence's actual token stream (prompt +
        generated) — content-addressed, so it is correct for any
        sampling temperature and any preemption history."""
        if self.prefix is None:
            return
        req = self.slot_req[s]
        stream = np.asarray(req.prompt, np.int32)
        if req.generated:
            stream = np.concatenate(
                [stream, np.asarray(req.generated, np.int32)])
        n_full = int(n_valid) // self.alloc.page_tokens
        if n_full > 0:
            self.prefix.insert(stream, self.alloc.tables[s][:n_full],
                               extra=req._trie_extra)

    def preempt(self, s: int, n_valid: int = 0):
        """Release slot ``s`` (decref its pages) and requeue its request
        at the head of its priority class.  Generated tokens are kept
        on the request; they join the effective prompt on re-admission,
        so the re-prefill reproduces the stream exactly and generation
        continues from where it stopped.  With a prefix cache the
        committed full-page run (``n_valid`` positions) is published
        first, so re-admission resumes from the trie instead of
        re-prefilling — pages are decref'd, not freed."""
        req = self.slot_req[s]
        assert req is not None
        if self.alloc is not None:
            self._publish(s, n_valid)
            self.alloc.release(s)
        self.slot_req[s] = None
        self.slot_prompt[s] = None
        self.phase[s] = None
        req.status = QUEUED
        req._seq = self._requeue_counter
        self._requeue_counter -= 1
        self.queue.append(req)
        self.preemptions += 1

    def retire(self, written: Optional[np.ndarray] = None):
        """Retire finished DECODE slots.  ``written`` (engine's host
        mirror of per-slot committed cache lengths) bounds what the
        prefix trie may index on retirement."""
        for s, req in enumerate(self.slot_req):
            if req is None or self.phase[s] != DECODE:
                continue
            if (len(req.generated) >= req.max_new_tokens
                    or (self.ecfg.eos_id >= 0 and req.generated
                        and req.generated[-1] == self.ecfg.eos_id)):
                if self.alloc is not None:
                    if written is not None:
                        self._publish(s, int(written[s]))
                    self.alloc.release(s)
                self.slot_req[s] = None
                self.slot_prompt[s] = None
                self.phase[s] = None
                self._terminal(req, DONE)

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_req)
