"""Scheduling policy: admission, chunk planning, preemption, retirement.

Everything here is HOST-side numpy bookkeeping — the device sees
nothing but the fixed step shapes the executor compiles, and nothing in
this module depends on the KV layout beyond the allocator/trie handles
it is given, or on the parallelism degree at all (the same Scheduler
drives the local and the sharded executor — DESIGN.md §10's "planning
is layout-agnostic" contract).
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.serve.config import EngineConfig
from repro.serve.memory import PageAllocator, PrefixCache

# slot phases
PREFILL = "prefill"     # prompt tokens remain; consumed chunk-wise
TAIL = "tail"           # recurrent archs: < C prompt tokens remain,
                        # fed one-by-one through the decode step
DECODE = "decode"       # generating one token per engine step


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0            # 0 = greedy
    # filled by the engine:
    generated: List[int] = field(default_factory=list)
    done: bool = False
    # prefix-cache hit size at the LAST admission: prompt tokens whose
    # K/V came from shared pages (their prefill chunks were skipped)
    cached_tokens: int = 0
    # serving metrics (monotonic clock): submit time, one stamp per
    # emitted token (token_times[0] is first-token / end of prefill)
    t_submit: float = 0.0
    token_times: List[float] = field(default_factory=list)


class Scheduler:
    """Admission / chunking / preemption / retirement policy with
    per-slot phases.

    With a ``PageAllocator`` (paged mode) admission is gated on free
    pages for the effective prompt, retirement frees pages, and
    ``preempt`` requeues a sequence at the queue head with its
    generated tokens folded into the effective prompt (greedy
    continuation is exact).

    With a ``PrefixCache`` (paged + ``EngineConfig.prefix_cache``)
    admission additionally matches the longest cached page-aligned
    prefix of the effective prompt, maps those pages READ-ONLY into the
    slot's table and resumes chunked prefill at the first uncached
    token (``resume``); prefill completion / preemption / retirement
    publish the sequence's full-page run back into the trie so later
    requests (including the preempted sequence itself) skip the
    redundant prefill compute.
    """

    def __init__(self, ecfg: EngineConfig, recurrent: bool,
                 allocator: Optional[PageAllocator] = None,
                 prefix: Optional[PrefixCache] = None):
        self.ecfg = ecfg
        self.chunk = ecfg.chunk
        self.recurrent = recurrent
        self.alloc = allocator
        self.prefix = prefix
        self.queue: collections.deque = collections.deque()
        n = ecfg.slots
        self.slot_req: List[Optional[Request]] = [None] * n
        # effective prompt per slot: the request's prompt plus any
        # tokens generated before a preemption (greedy continuation)
        self.slot_prompt: List[Optional[np.ndarray]] = [None] * n
        self.phase: List[Optional[str]] = [None] * n
        self.pos = np.zeros(n, np.int64)        # prompt tokens consumed
        self.fresh = np.zeros(n, bool)          # needs state reset
        self.last_token = np.zeros(n, np.int32)
        self.slot_seq = np.zeros(n, np.int64)   # admission order (age)
        # prefix-cache resume point per slot: the first position THIS
        # tenure writes (0 without a hit).  Positions below it are
        # served by read-only shared pages.
        self.resume = np.zeros(n, np.int64)
        self._admit_counter = 0
        self.preemptions = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0

    # -- admission -----------------------------------------------------
    def submit(self, req: Request):
        req.t_submit = time.monotonic()
        self.queue.append(req)

    def admit(self):
        for s in range(self.ecfg.slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue[0]
                eff = (req.prompt if not req.generated else
                       np.concatenate([np.asarray(req.prompt, np.int32),
                                       np.asarray(req.generated, np.int32)]))
                L = len(eff)
                remaining = req.max_new_tokens - len(req.generated)
                assert L > 0, "empty prompt"
                assert L + remaining <= self.ecfg.max_len, \
                    "request exceeds KV capacity"
                resume = 0
                if self.alloc is not None:
                    # speculative verify windows transiently overhang
                    # the committed length by up to spec_k tokens
                    slack = self.ecfg.spec_k
                    assert (self.alloc.pages_for(L + remaining + slack)
                            <= self.alloc.n_pages), \
                        "request exceeds page pool"
                    if self.prefix is not None:
                        pages = self.prefix.match(eff)
                        if pages and self.alloc.map_shared(s, pages):
                            # at least one token must remain to prefill
                            # (its logits seed generation); a FULL hit
                            # resumes at L-1 and the rewrite of that
                            # position COWs the shared last page
                            pt = self.alloc.page_tokens
                            resume = min(len(pages) * pt, L - 1)
                    ok = self.alloc.ensure(s, L)
                    if not ok and self.prefix is not None:
                        # cached-but-idle prefixes are reclaimable
                        # bytes: evict LRU trie pages nobody maps and
                        # retry (matched pages are slot-mapped now, so
                        # eviction can never touch THIS hit)
                        short = (self.alloc.pages_for(L)
                                 - len(self.alloc.tables[s])
                                 - self.alloc.free_pages)
                        if short > 0 and self.prefix.evict(short) > 0:
                            ok = self.alloc.ensure(s, L)
                    if not ok:
                        # FIFO head-of-line: wait for pages (undo the
                        # shared mapping so the trie can evict them)
                        self.alloc.release(s)
                        break
                self.queue.popleft()
                req.cached_tokens = resume
                if resume > 0:
                    self.prefix_hits += 1
                    self.prefix_hit_tokens += resume
                self.slot_req[s] = req
                self.slot_prompt[s] = eff
                self.pos[s] = resume
                self.resume[s] = resume
                self.fresh[s] = True
                self.slot_seq[s] = self._admit_counter
                self._admit_counter += 1
                self.phase[s] = self._prefill_phase(L, resume)

    def _prefill_phase(self, L: int, pos: int) -> str:
        if self.recurrent and L - pos < self.chunk:
            return TAIL          # padded window would corrupt state
        return PREFILL

    # -- planning ------------------------------------------------------
    def has_chunk_work(self) -> bool:
        return any(p == PREFILL for p in self.phase)

    def planned_writes(self, decode_width: int = 1) -> np.ndarray:
        """(slots,) KV positions the NEXT step will write per active
        slot — what must be page-covered before the step runs.  TAIL
        and PREFILL writes always land inside the prompt coverage
        allocated at admission; only decode growth can demand pages.
        ``decode_width`` > 1 is a speculative round: every decoding
        slot writes a (k+1)-wide draft+verify window."""
        n, C = self.ecfg.slots, self.chunk
        take = np.zeros(n, np.int64)
        chunk_step = self.has_chunk_work()
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if chunk_step:
                if self.phase[s] == PREFILL:
                    take[s] = min(C, len(self.slot_prompt[s])
                                  - int(self.pos[s]))
                elif self.phase[s] == DECODE and not self.recurrent:
                    take[s] = 1
            else:
                take[s] = decode_width
        return take

    def plan_chunk(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Build the (slots, C) window batch.  PREFILL slots consume up
        to C prompt tokens (recurrent archs: exactly C — guaranteed by
        the phase); DECODE slots ride with length 1 on attention-only
        archs; everything else idles with length 0."""
        n, C = self.ecfg.slots, self.chunk
        tokens = np.zeros((n, C), np.int32)
        lengths = np.zeros(n, np.int32)
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if self.phase[s] == PREFILL:
                prompt = self.slot_prompt[s]
                take = min(C, len(prompt) - int(self.pos[s]))
                tokens[s, :take] = prompt[self.pos[s]:self.pos[s] + take]
                lengths[s] = take
            elif self.phase[s] == DECODE and not self.recurrent:
                tokens[s, 0] = self.last_token[s]
                lengths[s] = 1
        fresh = self.fresh & (lengths > 0)
        self.fresh &= ~fresh
        return tokens, lengths, fresh

    def plan_decode(self) -> Tuple[np.ndarray, np.ndarray]:
        """One token per slot: TAIL slots feed their next prompt token,
        DECODE slots their last sampled token."""
        n = self.ecfg.slots
        tokens = np.zeros(n, np.int32)
        active = np.zeros(n, bool)
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            active[s] = True
            if self.phase[s] == TAIL:
                tokens[s] = self.slot_prompt[s][self.pos[s]]
            else:
                tokens[s] = self.last_token[s]
        fresh = self.fresh & active
        self.fresh &= ~fresh
        return tokens, fresh

    # -- post-step transitions ----------------------------------------
    def advance_chunk(self, lengths: np.ndarray) -> List[int]:
        """Apply a chunk step's progress.  Returns slots whose logits
        row is a real next-token distribution to sample from."""
        sample = []
        for s, req in enumerate(self.slot_req):
            if req is None or lengths[s] == 0:
                continue
            if self.phase[s] == PREFILL:
                self.pos[s] += int(lengths[s])
                if self.pos[s] == len(self.slot_prompt[s]):
                    self.phase[s] = DECODE
                    # the prompt's K/V is fully written: publish its
                    # full-page run so CONCURRENT requests with the
                    # same prefix already share it
                    self._publish(s, len(self.slot_prompt[s]))
                    sample.append(s)
                else:
                    self.phase[s] = self._prefill_phase(
                        len(self.slot_prompt[s]), int(self.pos[s]))
            else:                                   # riding decode slot
                sample.append(s)
        return sample

    def advance_decode(self) -> List[int]:
        sample = []
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if self.phase[s] == TAIL:
                self.pos[s] += 1
                if self.pos[s] == len(self.slot_prompt[s]):
                    self.phase[s] = DECODE
                    sample.append(s)
            else:
                sample.append(s)
        return sample

    # -- preemption / retirement --------------------------------------
    def _publish(self, s: int, n_valid: int):
        """Publish slot ``s``'s first ``n_valid`` cached positions (its
        committed K/V) into the prefix trie, rounded DOWN to full
        pages.  Keyed on the sequence's actual token stream (prompt +
        generated) — content-addressed, so it is correct for any
        sampling temperature and any preemption history."""
        if self.prefix is None:
            return
        req = self.slot_req[s]
        stream = np.asarray(req.prompt, np.int32)
        if req.generated:
            stream = np.concatenate(
                [stream, np.asarray(req.generated, np.int32)])
        n_full = int(n_valid) // self.alloc.page_tokens
        if n_full > 0:
            self.prefix.insert(stream, self.alloc.tables[s][:n_full])

    def preempt(self, s: int, n_valid: int = 0):
        """Release slot ``s`` (decref its pages) and requeue its request
        at the queue HEAD.  Generated tokens are kept on the request;
        they join the effective prompt on re-admission, so the
        re-prefill reproduces the stream exactly and generation
        continues from where it stopped.  With a prefix cache the
        committed full-page run (``n_valid`` positions) is published
        first, so re-admission resumes from the trie instead of
        re-prefilling — pages are decref'd, not freed."""
        req = self.slot_req[s]
        assert req is not None
        if self.alloc is not None:
            self._publish(s, n_valid)
            self.alloc.release(s)
        self.slot_req[s] = None
        self.slot_prompt[s] = None
        self.phase[s] = None
        self.queue.appendleft(req)
        self.preemptions += 1

    def retire(self, written: Optional[np.ndarray] = None):
        """Retire finished DECODE slots.  ``written`` (engine's host
        mirror of per-slot committed cache lengths) bounds what the
        prefix trie may index on retirement."""
        for s, req in enumerate(self.slot_req):
            if req is None or self.phase[s] != DECODE:
                continue
            if (len(req.generated) >= req.max_new_tokens
                    or (self.ecfg.eos_id >= 0 and req.generated
                        and req.generated[-1] == self.ecfg.eos_id)):
                req.done = True
                if self.alloc is not None:
                    if written is not None:
                        self._publish(s, int(written[s]))
                    self.alloc.release(s)
                self.slot_req[s] = None
                self.slot_prompt[s] = None
                self.phase[s] = None

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_req)
