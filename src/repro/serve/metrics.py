"""Per-request / per-priority-class serving metrics (DESIGN.md §11).

The engine records two clocks for every request:

  * DETERMINISTIC steps — ``Request.submit_step`` / ``token_steps`` /
    ``finish_step`` are engine step indices.  TTFT/ITL in steps are
    bit-reproducible across runs and machines, which is what the
    overload benchmark gates on (high-priority p95 TTFT strictly
    better than low-priority under the same trace).
  * WALL time — ``t_submit`` / ``token_times`` (seconds), reported
    alongside but never gated.

``ServeMetrics`` aggregates per priority class at request TERMINATION
(any terminal status: DONE, SHED, TIMED_OUT, CANCELLED), so a single
``snapshot()`` at drain sees every request exactly once.
"""
from __future__ import annotations

import collections
from typing import Dict, Optional

import numpy as np


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, dtype=np.float64), q))


class ServeMetrics:
    """Terminal-event aggregator behind ``Engine.stats()``."""

    def __init__(self):
        # lifecycle counters: done/shed/timed_out/cancelled plus event
        # counters the engine bumps directly (retries, quarantines,
        # watchdog_sheds, faults_recovered, and the host spill tier's
        # host_restored_pages / host_restore_fallbacks — DESIGN.md §12)
        self.counters = collections.Counter()
        # priority -> per-class latency samples
        self.classes: Dict[int, Dict[str, list]] = {}

    def _cls(self, priority: int) -> Dict[str, list]:
        return self.classes.setdefault(priority, {
            "ttft_steps": [], "itl_steps": [],
            "ttft_s": [], "itl_s": [],
        })

    def bump(self, name: str, n: int = 1) -> None:
        self.counters[name] += n

    def on_terminal(self, req) -> None:
        """Record a request reaching a terminal status.  Latencies are
        only defined for requests that emitted tokens; shed-at-admission
        requests contribute counters only."""
        self.counters[req.status.lower()] += 1
        cls = self._cls(req.priority)
        if req.token_steps:
            cls["ttft_steps"].append(req.token_steps[0] - req.submit_step)
            cls["itl_steps"].extend(np.diff(req.token_steps).tolist())
        if req.token_times:
            cls["ttft_s"].append(req.token_times[0] - req.t_submit)
            cls["itl_s"].extend(np.diff(req.token_times).tolist())

    @property
    def n_terminal(self) -> int:
        return sum(self.counters[k] for k in
                   ("done", "shed", "timed_out", "cancelled"))

    def snapshot(self) -> dict:
        """Counters + per-class p50/p95 latency summary."""
        out = {"counters": dict(self.counters), "classes": {}}
        for prio in sorted(self.classes):
            cls, row = self.classes[prio], {}
            for key in ("ttft_steps", "itl_steps", "ttft_s", "itl_s"):
                xs = cls[key]
                if xs:
                    row[f"{key}_p50"] = _pct(xs, 50)
                    row[f"{key}_p95"] = _pct(xs, 95)
                    row[f"n_{key}"] = len(xs)
            out["classes"][prio] = row
        return out

    def ttft_p95_steps(self, priority: int) -> Optional[float]:
        """Deterministic p95 TTFT for one class (None if no samples) —
        the quantity the overload gate compares across classes."""
        xs = self.classes.get(priority, {}).get("ttft_steps", [])
        return _pct(xs, 95) if xs else None
