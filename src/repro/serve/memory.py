"""KV-page memory management: the refcounted page allocator and the
copy-on-write prefix-cache trie (DESIGN.md §6, §9).

Both are HOST-side and layout-global: one ``PageAllocator`` (and one
``PrefixCache``) serves the whole engine regardless of parallelism —
page ids are the same on every model shard, each shard just stores its
own heads' slice of every page (``parallel.sharding.serve_state_specs``).
That is why the trie can stay host-global under tensor parallelism
while the pools it indexes are sharded along heads (DESIGN.md §10).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np


class PageAllocator:
    """Refcounted free-list allocator over the global KV page pool.

    Host-side owner of the page tables for the device pools built by
    ``T.init_decode_state_paged``: ``n_pages`` real pages plus one spare
    garbage row (id ``sentinel == n_pages``) that un-allocated
    page-table entries address, so padded windows and idle slots write
    harmlessly off to the side instead of into another slot's pages.

    With prefix caching (DESIGN.md §9) a page can be referenced by
    several slot tables at once AND by the host-side prefix trie
    (``PrefixCache``): ``refcount[p]`` counts every such reference, and
    a page returns to the free list exactly when its count hits zero.
    Shared pages are read-only to their mappers; a slot that must write
    one first clones it (``cow``) and repoints its own table entry.

    Invariants (property-tested in tests/test_property.py):
      * refcounts are >= 0 and a page is free iff its count is 0;
      * no page is both on the free list and mapped/indexed anywhere;
      * ``free_pages + unique mapped-or-indexed pages == n_pages``;
      * ``ensure`` is all-or-nothing; ``release`` decrefs exactly the
        slot's pages (no double-free).
    """

    def __init__(self, n_pages: int, page_tokens: int, slots: int,
                 table_pages: int):
        assert n_pages >= 1 and page_tokens >= 1 and table_pages >= 1
        self.n_pages = n_pages
        self.page_tokens = page_tokens
        self.table_pages = table_pages          # static page-table width
        self.sentinel = n_pages                 # the garbage-sink row
        self.free_list: List[int] = list(range(n_pages))
        self.refcount: List[int] = [0] * n_pages
        self.tables: List[List[int]] = [[] for _ in range(slots)]

    @property
    def free_pages(self) -> int:
        return len(self.free_list)

    def used_pages(self) -> int:
        """UNIQUE pages in use (shared pages count once — the number
        actually unavailable to new sequences)."""
        return self.n_pages - len(self.free_list)

    def utilization(self) -> float:
        return self.used_pages() / max(1, self.n_pages)

    def pages_for(self, n_tokens: int) -> int:
        return -(-int(n_tokens) // self.page_tokens)

    # -- refcounting ---------------------------------------------------
    def _alloc_page(self) -> int:
        page = self.free_list.pop()
        assert self.refcount[page] == 0, page
        self.refcount[page] = 1
        return page

    def incref(self, page: int):
        assert 0 <= page < self.n_pages and self.refcount[page] > 0, \
            f"incref of unowned page {page}"
        self.refcount[page] += 1

    def decref(self, page: int) -> bool:
        """Drop one reference; True if the page was freed."""
        assert self.refcount[page] > 0, f"double free of page {page}"
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            self.free_list.append(page)
            return True
        return False

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot``'s table to cover positions [0, n_tokens);
        all-or-nothing.  Returns False on pool exhaustion (caller
        evicts/preempts) or if the static table width would overflow."""
        want = self.pages_for(n_tokens)
        need = want - len(self.tables[slot])
        if need <= 0:
            return True
        if need > len(self.free_list) or want > self.table_pages:
            return False
        for _ in range(need):
            self.tables[slot].append(self._alloc_page())
        return True

    def map_shared(self, slot: int, pages: List[int]) -> bool:
        """Append already-owned pages (a prefix-trie hit) READ-ONLY to
        the end of ``slot``'s table; each gains one reference.  The
        mapper must never scatter into them without ``cow`` first."""
        if len(self.tables[slot]) + len(pages) > self.table_pages:
            return False
        for p in pages:
            self.incref(p)
            self.tables[slot].append(p)
        return True

    def cow(self, slot: int, idx: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write fault on table entry ``idx``: if the page is
        shared, allocate a fresh page, repoint the slot's entry and
        drop its reference on the old one.  Returns (src, dst) for the
        caller's device-side content copy, or None when the page was
        exclusively owned (no copy needed).  Caller must check
        ``free_pages`` first; raises on an empty pool."""
        old = self.tables[slot][idx]
        if self.refcount[old] == 1:
            return None
        new = self._alloc_page()
        self.tables[slot][idx] = new
        self.decref(old)
        return (old, new)

    def release(self, slot: int) -> int:
        """Drop the slot's reference on all of its pages.  Returns the
        number of pages unmapped (shared pages survive via their other
        references — e.g. the prefix trie's)."""
        pages = self.tables[slot]
        self.tables[slot] = []
        for p in pages:
            self.decref(p)
        return len(pages)

    def table_array(self) -> np.ndarray:
        """(slots, table_pages) int32 device view; sentinel-padded."""
        t = np.full((len(self.tables), self.table_pages), self.sentinel,
                    np.int32)
        for s, pages in enumerate(self.tables):
            t[s, :len(pages)] = pages
        return t

    def assert_consistent(self, prefix=None, context: str = ""):
        """Raise AssertionError unless every allocator invariant holds
        (refcounts match the reference multiset rebuilt from the slot
        tables plus the optional ``prefix`` trie; a page is free iff
        unreferenced; no duplicate free-list entries; pool conserved;
        no table wider than the static width; no sentinel mapped).

        This is the ONE checker the property tests, the chaos soak, and
        serve_bench's overload scenario all call — the chaos harness's
        'zero invariant violations' gate is literally this function
        after every engine step."""
        where = f" [{context}]" if context else ""
        refs: Dict[int, int] = {}
        for s, pages in enumerate(self.tables):
            assert len(pages) <= self.table_pages, \
                f"slot {s} table wider than static width{where}"
            for p in pages:
                assert 0 <= p < self.n_pages, \
                    f"slot {s} maps out-of-pool page {p}{where}"
                refs[p] = refs.get(p, 0) + 1
        if prefix is not None:
            for p in prefix.pages():
                assert 0 <= p < self.n_pages, \
                    f"trie indexes out-of-pool page {p}{where}"
                refs[p] = refs.get(p, 0) + 1
            for key, node in prefix.nodes.items():
                n_kids = sum(1 for nd in prefix.nodes.values()
                             if nd["parent_key"] == key)
                assert node["children"] == n_kids, \
                    f"trie child count drift at {node['id']}{where}"
        free = set(self.free_list)
        assert len(free) == len(self.free_list), \
            f"duplicate free-list entries{where}"
        for p in range(self.n_pages):
            want = refs.get(p, 0)
            if prefix is None:
                # without the trie handle, pages it holds look
                # unreferenced from here — only check mapped pages
                if want == 0:
                    continue
            assert self.refcount[p] == want, \
                (f"page {p}: refcount {self.refcount[p]} != "
                 f"{want} references{where}")
            assert (p in free) == (want == 0), \
                f"page {p}: free-list / refcount disagree{where}"
        if prefix is not None:
            assert len(free) + len(refs) == self.n_pages, \
                (f"pool not conserved: {len(free)} free + {len(refs)} "
                 f"referenced != {self.n_pages}{where}")


class PrefixCache:
    """Host-side radix index over PAGE-ALIGNED token prefixes
    (DESIGN.md §9) — automatic prefix caching for the paged engine.

    Each node covers exactly one full KV page: the node for the first
    ``i`` pages of a token stream is keyed on ``(salt, stream[: i *
    page_tokens])``, and holds the pool page whose K/V encode those
    ``page_tokens`` positions given the preceding prefix.  ``salt``
    folds in the model's rank plan (prune ratio / CLOVER ranks / page
    size) AND — under tensor parallelism — the executor's head-partition
    plan, so caches produced under different pruning or a different
    head->shard layout never alias even if the engine were rebuilt over
    the same allocator.

    The trie holds one reference on every indexed page (see
    ``PageAllocator``).  ``match`` walks the longest cached run for a
    prompt and bumps each node's LRU clock; ``insert`` publishes a
    finished/preempted/prefilled sequence's full-page run (first writer
    wins — an existing node keeps its page); ``evict`` reclaims LRU
    leaf nodes whose page no slot maps (refcount == 1: only the trie's
    own reference is left).
    """

    def __init__(self, alloc: PageAllocator, salt: Tuple = ()):
        self.alloc = alloc
        self.pt = alloc.page_tokens
        # the salt IS the root: two caches with different rank plans
        # have disjoint key spaces from the first page on
        self._root = ("root", salt)
        # radix keying: (parent node id, this page's pt tokens) -> node
        # {"id", "page", "clock", "children", "parent_key"} — each walk
        # step hashes ONE page of tokens, so match/insert are O(L), not
        # O(L^2) re-serializations of the whole prefix per depth
        self.nodes: Dict[tuple, dict] = {}
        self._next_id = 1
        self._clock = 0
        self.inserted = 0
        self.evicted = 0

    def _chunk(self, tokens: np.ndarray, i: int) -> bytes:
        """Page ``i``'s token content (0-based), as a hashable key."""
        return np.asarray(tokens[i * self.pt:(i + 1) * self.pt],
                          np.int32).tobytes()

    def __len__(self) -> int:
        return len(self.nodes)

    def pages(self) -> set:
        return {n["page"] for n in self.nodes.values()}

    def match(self, tokens: np.ndarray) -> List[int]:
        """Longest cached page run that is a prefix of ``tokens``.
        Returns the page ids in position order (possibly empty) and
        LRU-touches every node on the path."""
        self._clock += 1
        pages: List[int] = []
        parent = self._root
        for i in range(len(tokens) // self.pt):
            node = self.nodes.get((parent, self._chunk(tokens, i)))
            if node is None:
                break
            node["clock"] = self._clock
            pages.append(node["page"])
            parent = node["id"]
        return pages

    def insert(self, tokens: np.ndarray, pages: List[int]):
        """Publish a full-page run: page ``i`` holds K/V for positions
        [i*pt, (i+1)*pt) of ``tokens``.  Existing nodes win (their page
        stays; the duplicate remains the caller's private copy)."""
        n = min(len(tokens) // self.pt, len(pages))
        self._clock += 1
        parent_id, parent_key = self._root, None
        for i in range(n):
            key = (parent_id, self._chunk(tokens, i))
            node = self.nodes.get(key)
            if node is None:
                self.alloc.incref(pages[i])
                node = {"id": self._next_id, "page": pages[i],
                        "clock": self._clock, "children": 0,
                        "parent_key": parent_key}
                self._next_id += 1
                self.nodes[key] = node
                if parent_key is not None:
                    self.nodes[parent_key]["children"] += 1
                self.inserted += 1
            else:
                node["clock"] = self._clock
            parent_id, parent_key = node["id"], key

    def evict(self, n_pages: int) -> int:
        """Free up to ``n_pages`` pool pages by dropping LRU LEAF nodes
        nobody maps (page refcount == 1).  Leaf-first keeps every
        surviving node's prefix path intact.  One scan builds the
        clock-ordered candidate list; a parent whose last child is
        dropped re-enters consideration within the same call."""
        freed = 0
        candidates = sorted(
            (k for k, nd in self.nodes.items()
             if nd["children"] == 0
             and self.alloc.refcount[nd["page"]] == 1),
            key=lambda k: self.nodes[k]["clock"], reverse=True)
        while freed < n_pages and candidates:
            key = candidates.pop()
            node = self.nodes.get(key)
            if (node is None or node["children"] != 0
                    or self.alloc.refcount[node["page"]] != 1):
                continue            # state moved under us: re-derived
            self.nodes.pop(key)
            pk = node["parent_key"]
            if pk is not None and pk in self.nodes:
                parent = self.nodes[pk]
                parent["children"] -= 1
                if (parent["children"] == 0
                        and self.alloc.refcount[parent["page"]] == 1):
                    # keep clock order: parents are older than the
                    # children that just left, append-then-sort is
                    # overkill for the one element — insert at the end
                    # (oldest side) of the reversed list
                    candidates.append(pk)
                    candidates.sort(
                        key=lambda k: self.nodes[k]["clock"],
                        reverse=True)
            self.alloc.decref(node["page"])
            self.evicted += 1
            freed += 1
        return freed
