"""KV-page memory management: the refcounted page allocator, the
copy-on-write prefix-cache trie, and the host-RAM spill tier under it
(DESIGN.md §6, §9, §12).

Everything here is HOST-side and layout-global: one ``PageAllocator``
(and one ``PrefixCache``) serves the whole engine regardless of
parallelism — page ids are the same on every model shard, each shard
just stores its own heads' slice of every page
(``parallel.sharding.serve_state_specs``).  That is why the trie can
stay host-global under tensor parallelism while the pools it indexes
are sharded along heads (DESIGN.md §10).

The ``HostTier`` (DESIGN.md §12) holds BYTE COPIES of evicted trie
pages keyed by each node's content chain hash — never page references
— so spilled pages are genuinely freed and every allocator invariant
(``assert_consistent``, the property-test state machine) holds
unchanged with the tier enabled.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import numpy as np


def _hash_chain(parent_digest: bytes, chunk: bytes) -> bytes:
    """One link of a trie node's content chain hash: the digest of a
    node covering pages [0, i] is a pure function of the salt and the
    token bytes of pages 0..i, independent of node ids (which are NOT
    stable across evictions — the whole reason the host tier keys on
    this chain instead of on trie structure)."""
    return hashlib.blake2b(parent_digest + chunk, digest_size=16).digest()


class HostTier:
    """Host-RAM spill tier under the prefix cache (DESIGN.md §12).

    An LRU-bounded ring of spilled KV pages: ``capacity`` page slots,
    each holding the device->host byte copy of one evicted trie page
    (a list of per-KV-leaf numpy slabs, opaque to this class) keyed by
    the trie node's chunk-chain hash.  ``put`` overwrites an existing
    key in place (same content by construction — the key IS the
    content address) and drops the least-recently-used slot on
    overflow; ``get`` is an LRU touch.  Values are COPIES, never page
    references, so the tier is invisible to the allocator's refcount
    invariants: a spilled page really is free HBM.
    """

    def __init__(self, capacity: int):
        assert capacity >= 1
        self.capacity = capacity
        self._slots: "OrderedDict[bytes, Any]" = OrderedDict()
        # lifetime counters (Engine.stats() reports them)
        self.spills = 0         # pages copied device->host at eviction
        self.restores = 0       # pages copied host->device at admission
        self.dropped = 0        # LRU overflow: oldest slot discarded
        self.hits = 0           # get() found the key
        self.misses = 0         # get() did not

    def __len__(self) -> int:
        return len(self._slots)

    def __contains__(self, key: bytes) -> bool:
        return key in self._slots

    def put(self, key: bytes, rows) -> None:
        """Store one spilled page's host bytes under its chain hash;
        evicts the LRU slot when full (host capacity is a budget too)."""
        if key in self._slots:
            self._slots.move_to_end(key)
            self._slots[key] = rows
        else:
            if len(self._slots) >= self.capacity:
                self._slots.popitem(last=False)
                self.dropped += 1
            self._slots[key] = rows
        self.spills += 1

    def get(self, key: bytes):
        """The host bytes for ``key`` (LRU touch), or None."""
        rows = self._slots.get(key)
        if rows is None:
            self.misses += 1
            return None
        self.hits += 1
        self._slots.move_to_end(key)
        return rows

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


class PageAllocator:
    """Refcounted free-list allocator over the global KV page pool.

    Host-side owner of the page tables for the device pools built by
    ``T.init_decode_state_paged``: ``n_pages`` real pages plus one spare
    garbage row (id ``sentinel == n_pages``) that un-allocated
    page-table entries address, so padded windows and idle slots write
    harmlessly off to the side instead of into another slot's pages.

    With prefix caching (DESIGN.md §9) a page can be referenced by
    several slot tables at once AND by the host-side prefix trie
    (``PrefixCache``): ``refcount[p]`` counts every such reference, and
    a page returns to the free list exactly when its count hits zero.
    Shared pages are read-only to their mappers; a slot that must write
    one first clones it (``cow``) and repoints its own table entry.

    Invariants (property-tested in tests/test_property.py):
      * refcounts are >= 0 and a page is free iff its count is 0;
      * no page is both on the free list and mapped/indexed anywhere;
      * ``free_pages + unique mapped-or-indexed pages == n_pages``;
      * ``ensure`` is all-or-nothing; ``release`` decrefs exactly the
        slot's pages (no double-free).
    """

    def __init__(self, n_pages: int, page_tokens: int, slots: int,
                 table_pages: int):
        assert n_pages >= 1 and page_tokens >= 1 and table_pages >= 1
        self.n_pages = n_pages
        self.page_tokens = page_tokens
        self.table_pages = table_pages          # static page-table width
        self.sentinel = n_pages                 # the garbage-sink row
        self.free_list: List[int] = list(range(n_pages))
        self.refcount: List[int] = [0] * n_pages
        self.tables: List[List[int]] = [[] for _ in range(slots)]

    @property
    def free_pages(self) -> int:
        return len(self.free_list)

    def used_pages(self) -> int:
        """UNIQUE pages in use (shared pages count once — the number
        actually unavailable to new sequences)."""
        return self.n_pages - len(self.free_list)

    def utilization(self) -> float:
        return self.used_pages() / max(1, self.n_pages)

    def pages_for(self, n_tokens: int) -> int:
        return -(-int(n_tokens) // self.page_tokens)

    # -- refcounting ---------------------------------------------------
    def _alloc_page(self) -> int:
        page = self.free_list.pop()
        assert self.refcount[page] == 0, page
        self.refcount[page] = 1
        return page

    def incref(self, page: int):
        assert 0 <= page < self.n_pages and self.refcount[page] > 0, \
            f"incref of unowned page {page}"
        self.refcount[page] += 1

    def decref(self, page: int) -> bool:
        """Drop one reference; True if the page was freed."""
        assert self.refcount[page] > 0, f"double free of page {page}"
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            self.free_list.append(page)
            return True
        return False

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot``'s table to cover positions [0, n_tokens);
        all-or-nothing.  Returns False on pool exhaustion (caller
        evicts/preempts) or if the static table width would overflow."""
        want = self.pages_for(n_tokens)
        need = want - len(self.tables[slot])
        if need <= 0:
            return True
        if need > len(self.free_list) or want > self.table_pages:
            return False
        for _ in range(need):
            self.tables[slot].append(self._alloc_page())
        return True

    def map_shared(self, slot: int, pages: List[int]) -> bool:
        """Append already-owned pages (a prefix-trie hit) READ-ONLY to
        the end of ``slot``'s table; each gains one reference.  The
        mapper must never scatter into them without ``cow`` first."""
        if len(self.tables[slot]) + len(pages) > self.table_pages:
            return False
        for p in pages:
            self.incref(p)
            self.tables[slot].append(p)
        return True

    def cow(self, slot: int, idx: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write fault on table entry ``idx``: if the page is
        shared, allocate a fresh page, repoint the slot's entry and
        drop its reference on the old one.  Returns (src, dst) for the
        caller's device-side content copy, or None when the page was
        exclusively owned (no copy needed).  Caller must check
        ``free_pages`` first; raises on an empty pool."""
        old = self.tables[slot][idx]
        if self.refcount[old] == 1:
            return None
        new = self._alloc_page()
        self.tables[slot][idx] = new
        self.decref(old)
        return (old, new)

    def release(self, slot: int) -> int:
        """Drop the slot's reference on all of its pages.  Returns the
        number of pages unmapped (shared pages survive via their other
        references — e.g. the prefix trie's)."""
        pages = self.tables[slot]
        self.tables[slot] = []
        for p in pages:
            self.decref(p)
        return len(pages)

    def table_array(self) -> np.ndarray:
        """(slots, table_pages) int32 device view; sentinel-padded."""
        t = np.full((len(self.tables), self.table_pages), self.sentinel,
                    np.int32)
        for s, pages in enumerate(self.tables):
            t[s, :len(pages)] = pages
        return t

    def assert_consistent(self, prefix=None, context: str = ""):
        """Raise AssertionError unless every allocator invariant holds
        (refcounts match the reference multiset rebuilt from the slot
        tables plus the optional ``prefix`` trie; a page is free iff
        unreferenced; no duplicate free-list entries; pool conserved;
        no table wider than the static width; no sentinel mapped).

        This is the ONE checker the property tests, the chaos soak, and
        serve_bench's overload scenario all call — the chaos harness's
        'zero invariant violations' gate is literally this function
        after every engine step."""
        where = f" [{context}]" if context else ""
        refs: Dict[int, int] = {}
        for s, pages in enumerate(self.tables):
            assert len(pages) <= self.table_pages, \
                f"slot {s} table wider than static width{where}"
            for p in pages:
                assert 0 <= p < self.n_pages, \
                    f"slot {s} maps out-of-pool page {p}{where}"
                refs[p] = refs.get(p, 0) + 1
        if prefix is not None:
            for p in prefix.pages():
                assert 0 <= p < self.n_pages, \
                    f"trie indexes out-of-pool page {p}{where}"
                refs[p] = refs.get(p, 0) + 1
            for key, node in prefix.nodes.items():
                n_kids = sum(1 for nd in prefix.nodes.values()
                             if nd["parent_key"] == key)
                assert node["children"] == n_kids, \
                    f"trie child count drift at {node['id']}{where}"
        free = set(self.free_list)
        assert len(free) == len(self.free_list), \
            f"duplicate free-list entries{where}"
        for p in range(self.n_pages):
            want = refs.get(p, 0)
            if prefix is None:
                # without the trie handle, pages it holds look
                # unreferenced from here — only check mapped pages
                if want == 0:
                    continue
            assert self.refcount[p] == want, \
                (f"page {p}: refcount {self.refcount[p]} != "
                 f"{want} references{where}")
            assert (p in free) == (want == 0), \
                f"page {p}: free-list / refcount disagree{where}"
        if prefix is not None:
            assert len(free) + len(refs) == self.n_pages, \
                (f"pool not conserved: {len(free)} free + {len(refs)} "
                 f"referenced != {self.n_pages}{where}")


class PrefixCache:
    """Host-side radix index over PAGE-ALIGNED token prefixes
    (DESIGN.md §9) — automatic prefix caching for the paged engine.

    Each node covers exactly one full KV page: the node for the first
    ``i`` pages of a token stream is keyed on ``(salt, stream[: i *
    page_tokens])``, and holds the pool page whose K/V encode those
    ``page_tokens`` positions given the preceding prefix.  ``salt``
    folds in the model's rank plan (prune ratio / CLOVER ranks / page
    size) AND — under tensor parallelism — the executor's head-partition
    plan, so caches produced under different pruning or a different
    head->shard layout never alias even if the engine were rebuilt over
    the same allocator.

    The trie holds one reference on every indexed page (see
    ``PageAllocator``).  ``match`` walks the longest cached run for a
    prompt and bumps each node's LRU clock; ``insert`` publishes a
    finished/preempted/prefilled sequence's full-page run (first writer
    wins — an existing node keeps its page); ``evict`` reclaims LRU
    leaf nodes whose page no slot maps (refcount == 1: only the trie's
    own reference is left).

    With a ``HostTier`` attached (``host`` + ``page_reader``, both set
    by the engine — DESIGN.md §12), eviction SPILLS each dropped page
    device->host before freeing it: the page's bytes survive under the
    node's content chain hash (``hhash``, computed at insert), so a
    later admission can restore them into fresh pages instead of
    re-prefilling.  The spill is a byte copy, never a reference — the
    allocator sees an ordinary eviction.

    ``match``/``insert``/``chain_hashes`` take an ``extra`` key tuple
    that extends the salt PER CALL — multi-tenant serving folds the
    request's adapter id in here (DESIGN.md §13), so sequences under
    different SV adapters partition into disjoint subtries (and
    disjoint host-tier key spaces) even when their token streams are
    identical: their K/V encode different hidden states.  The default
    ``extra=()`` is bit-identical to the un-keyed cache.
    """

    def __init__(self, alloc: PageAllocator, salt: Tuple = ()):
        self.alloc = alloc
        self.pt = alloc.page_tokens
        # the salt IS the root: two caches with different rank plans
        # have disjoint key spaces from the first page on
        self._root = ("root", salt)
        # ... and it also roots the content chain hashes the host tier
        # keys on, so spilled pages from different rank plans/head
        # layouts can never alias either
        self._root_hash = hashlib.blake2b(repr(self._root).encode(),
                                          digest_size=16).digest()
        # radix keying: (parent node id, this page's pt tokens) -> node
        # {"id", "page", "clock", "children", "parent_key", "hhash"} —
        # each walk step hashes ONE page of tokens, so match/insert are
        # O(L), not O(L^2) re-serializations of the whole prefix per
        # depth
        self.nodes: Dict[tuple, dict] = {}
        self._next_id = 1
        self._clock = 0
        self.inserted = 0
        self.evicted = 0
        # host spill tier (DESIGN.md §12): the engine installs both —
        # ``host`` is the HostTier, ``page_reader`` a callable
        # page_id -> host byte slabs (the executor's device->host read).
        # With either unset, evict simply drops pages (PR 4 behavior).
        self.host: Optional[HostTier] = None
        self.page_reader = None

    def _chunk(self, tokens: np.ndarray, i: int) -> bytes:
        """Page ``i``'s token content (0-based), as a hashable key."""
        return np.asarray(tokens[i * self.pt:(i + 1) * self.pt],
                          np.int32).tobytes()

    def _rooted(self, extra: Tuple) -> Tuple[Any, bytes]:
        """(root id, root hash) for a walk keyed by ``extra`` on top of
        the engine salt.  ``extra=()`` returns the plain root — the
        legacy key space, so adapter-free callers (and adapter id 0,
        which its caller maps to ``()``) hash identically to builds
        that predate the parameter (DESIGN.md §13)."""
        if not extra:
            return self._root, self._root_hash
        root = (self._root, tuple(extra))
        return root, hashlib.blake2b(
            self._root_hash + repr(tuple(extra)).encode(),
            digest_size=16).digest()

    def chain_hashes(self, tokens: np.ndarray, n: int,
                     extra: Tuple = ()) -> List[bytes]:
        """Content chain hashes of ``tokens``' first ``n`` full pages:
        entry ``i`` is the digest a trie node covering pages [0, i]
        carries (``hhash``) — and the key its page spills under.  Pure
        function of (salt, extra, token bytes), so admission can probe
        the host tier for pages the trie no longer remembers."""
        out: List[bytes] = []
        _, h = self._rooted(extra)
        for i in range(n):
            h = _hash_chain(h, self._chunk(tokens, i))
            out.append(h)
        return out

    def __len__(self) -> int:
        return len(self.nodes)

    def pages(self) -> set:
        return {n["page"] for n in self.nodes.values()}

    def match(self, tokens: np.ndarray, extra: Tuple = ()) -> List[int]:
        """Longest cached page run that is a prefix of ``tokens``
        under the ``extra`` key (adapter isolation — DESIGN.md §13).
        Returns the page ids in position order (possibly empty) and
        LRU-touches every node on the path."""
        self._clock += 1
        pages: List[int] = []
        parent, _ = self._rooted(extra)
        for i in range(len(tokens) // self.pt):
            node = self.nodes.get((parent, self._chunk(tokens, i)))
            if node is None:
                break
            node["clock"] = self._clock
            pages.append(node["page"])
            parent = node["id"]
        return pages

    def insert(self, tokens: np.ndarray, pages: List[int],
               extra: Tuple = ()):
        """Publish a full-page run under the ``extra`` key: page ``i``
        holds K/V for positions [i*pt, (i+1)*pt) of ``tokens``.
        Existing nodes win (their page stays; the duplicate remains the
        caller's private copy)."""
        n = min(len(tokens) // self.pt, len(pages))
        self._clock += 1
        root_id, root_hash = self._rooted(extra)
        parent_id, parent_key = root_id, None
        parent_hash = root_hash
        for i in range(n):
            chunk = self._chunk(tokens, i)
            key = (parent_id, chunk)
            node = self.nodes.get(key)
            if node is None:
                self.alloc.incref(pages[i])
                node = {"id": self._next_id, "page": pages[i],
                        "clock": self._clock, "children": 0,
                        "parent_key": parent_key,
                        # the content chain hash the host tier keys on
                        # (stable across evictions, unlike node ids)
                        "hhash": _hash_chain(parent_hash, chunk)}
                self._next_id += 1
                self.nodes[key] = node
                if parent_key is not None:
                    self.nodes[parent_key]["children"] += 1
                self.inserted += 1
            else:
                node["clock"] = self._clock
            parent_id, parent_key = node["id"], key
            parent_hash = node["hhash"]

    def evict(self, n_pages: int) -> int:
        """Free up to ``n_pages`` pool pages by dropping LRU LEAF nodes
        nobody maps (page refcount == 1).  Leaf-first keeps every
        surviving node's prefix path intact.  One scan builds the
        clock-ordered candidate list; a parent whose last child is
        dropped re-enters consideration within the same call.

        With the host tier attached, each dropped page is SPILLED
        (device->host byte copy under the node's chain hash) just
        before its decref frees it — ordering that matters for
        donation safety: eviction always runs before the step call
        that could consume the pool buffer (DESIGN.md §12)."""
        freed = 0
        candidates = sorted(
            (k for k, nd in self.nodes.items()
             if nd["children"] == 0
             and self.alloc.refcount[nd["page"]] == 1),
            key=lambda k: self.nodes[k]["clock"], reverse=True)
        while freed < n_pages and candidates:
            key = candidates.pop()
            node = self.nodes.get(key)
            if (node is None or node["children"] != 0
                    or self.alloc.refcount[node["page"]] != 1):
                continue            # state moved under us: re-derived
            self.nodes.pop(key)
            pk = node["parent_key"]
            if pk is not None and pk in self.nodes:
                parent = self.nodes[pk]
                parent["children"] -= 1
                if (parent["children"] == 0
                        and self.alloc.refcount[parent["page"]] == 1):
                    # keep clock order: parents are older than the
                    # children that just left, append-then-sort is
                    # overkill for the one element — insert at the end
                    # (oldest side) of the reversed list
                    candidates.append(pk)
                    candidates.sort(
                        key=lambda k: self.nodes[k]["clock"],
                        reverse=True)
            if self.host is not None and self.page_reader is not None:
                # spill BEFORE free: the device read must complete while
                # the page is still live, and eviction always runs ahead
                # of the step call that could consume (donate) the pool
                # buffer (DESIGN.md §12)
                self.host.put(node["hhash"],
                              self.page_reader(node["page"]))
            self.alloc.decref(node["page"])
            self.evicted += 1
            freed += 1
        return freed


def rank_pool_bytes(plan, *, page_tokens: int, n_pages: int,
                    dtype_bytes: int = 4) -> Dict[str, Any]:
    """Analytic per-layer KV page-pool accounting under a non-uniform
    ``core.prune.RankBudget`` (DESIGN.md §14).

    The PHYSICAL pools are sized by the plan's global max widths — the
    transformer lax.scans a stacked state pytree, so every layer's pool
    shares one shape ``(n_pages + 1, page_tokens, KV, max_rank)``.
    This helper reports what those bytes BUY per layer: the bytes the
    kept ranks actually use (``kept``), the uniform-max footprint the
    stack allocates (``allocated``), and the layerwise breakdown — the
    quantity serve_bench scenario 9 gates and the number a non-stacked
    (per-layer-buffer) deployment would allocate outright.

    plan: ``RankBudget``;  page_tokens / n_pages: pool geometry (the
    spare garbage row is counted, matching the real pools);
    dtype_bytes: cache element width (4 = f32 pools).
    Returns {"per_layer": [((j, b), kept_bytes), ...] in stack order,
    "kept": total kept bytes, "allocated": uniform-max total bytes}.
    """
    rows = (n_pages + 1) * page_tokens
    per_layer = []
    kept = 0
    allocated = 0
    dq, dv = plan.qk_width, plan.vo_width
    for j, qk_tab in enumerate(plan.qk_ranks):
        vo_tab = plan.vo_ranks[j]
        for b, qk_heads in enumerate(qk_tab):
            vo_heads = vo_tab[b]
            if not qk_heads and not vo_heads:
                continue                      # non-attention position
            layer = rows * dtype_bytes * (sum(qk_heads) + sum(vo_heads))
            per_layer.append(((j, b), layer))
            kept += layer
            allocated += rows * dtype_bytes * (dq + dv) * len(qk_heads)
    return {"per_layer": per_layer, "kept": kept, "allocated": allocated}
