"""Engine configuration — the one dataclass every serve module reads
(knobs for DESIGN.md §6, §8, §9, §10, §11, §12; each field cites its
section inline).

Lives in its own module so ``memory`` / ``scheduler`` / ``executor`` /
``engine`` can all import it without cycles.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core.prune import RankBudget


@dataclass(frozen=True)
class EngineConfig:
    slots: int = 4                      # concurrent sequences
    max_len: int = 512                  # KV capacity per slot
    eos_id: int = -1                    # -1: never stop on token
    prefill_chunk: int = 64             # prompt tokens consumed per chunk step
    # -- paged KV cache (DESIGN.md §6) --------------------------------
    paged: bool = False                 # page the KV cache
    page_tokens: int = 8                # tokens per KV page
    # pool size in pages; 0 -> slots * ceil(capacity / page_tokens),
    # i.e. no memory pressure (every slot can reach full capacity).
    # Size it below that to overcommit: admission then gates on free
    # pages and exhaustion preempts the youngest sequence.
    n_pages: int = 0
    # -- automatic prefix caching (DESIGN.md §9, requires paged) ------
    # share KV pages across requests with a common page-aligned token
    # prefix (system prompts, few-shot templates, replayed chats): a
    # host-side trie indexes retired/prefilled full-page runs, admission
    # maps hits read-only and skips their prefill chunks, and writes
    # into a shared page copy-on-write it first (kernels/page_copy.py).
    # Attention-only architectures only (recurrent state is not
    # page-addressable).
    prefix_cache: bool = False
    # -- hierarchical KV: host-RAM spill tier (DESIGN.md §12) ---------
    # > 0 adds a host-memory tier of this many pages under the prefix
    # cache (requires prefix_cache): LRU trie eviction copies each
    # dropped page device->host (keyed by the trie node's chunk-chain
    # hash) before freeing its HBM page, and admission restores a
    # host-tier hit by copying the bytes into the slot's own freshly
    # allocated pages, then resuming chunked prefill at the first
    # truly-uncached token.  0 disables (evicted pages are simply
    # dropped, the PR 4 behavior).
    host_pages: int = 0
    # -- self-speculative decoding (DESIGN.md §8) ---------------------
    # 0 disables; k > 0: every pure-decode step, a rank-sliced DRAFT
    # pass over the SAME weights proposes k tokens per slot and one
    # (slots, k+1) verify step accepts a greedy prefix — up to k+1
    # tokens per step instead of 1.  Greedy streams stay exactly
    # token-identical to the non-speculative engine; requires an
    # attention-only architecture (recurrent state cannot roll back).
    spec_k: int = 0
    # fraction of every head's CURRENT rank the draft slices off (the
    # leading directions are kept — CLOVER's factors are sorted, so the
    # draft's cache view is literally cache[..., :r]; no second cache)
    draft_rank_ratio: float = 0.5
    # -- rank-balanced tensor parallelism (DESIGN.md §10) -------------
    # > 1 selects the ShardedExecutor: params and KV/page pools shard
    # along heads over a ("data", "model") host mesh with model=tp,
    # the head -> shard assignment planned by
    # ``core.prune.rank_balanced_partition`` so every shard carries
    # ~equal pruned FLOPs/bytes.  tp must divide jax.device_count()
    # (CPU tests: XLA_FLAGS=--xla_force_host_platform_device_count=N).
    # Greedy streams are token-identical to tp=1; scheduling is
    # unchanged (parallelism never alters WHICH tokens are computed).
    tp: int = 1
    # -- kernel dispatch (kernels.ops.resolve, DESIGN.md §10) ---------
    # "" inherits ArchConfig.kernel_impl; any other alias overrides it
    # for this engine: "ref" | "xla" | "pallas" | "interpret".  The
    # executor resolves the alias ONCE per (platform, mesh) into a
    # frozen KernelDispatch — under tp > 1 the flash-decode /
    # paged-decode / page-copy kernels then run per shard via
    # shard_map.  Unknown aliases fail here, loudly, not at trace time.
    kernel_impl: str = ""
    # -- robustness (DESIGN.md §11) -----------------------------------
    # A failed compiled step (raised, or returned non-finite logits) is
    # retried with the SAME inputs up to step_retries times; when retry
    # is exhausted the active slots are quarantined for
    # quarantine_steps engine steps and their requests requeued (exact
    # continuation — generated tokens fold into the effective prompt,
    # same as preemption).  The watchdog sheds the lowest-priority
    # request when no slot makes progress for watchdog_steps
    # consecutive steps (0 disables), so a wedged engine degrades
    # instead of spinning to max_steps.
    step_retries: int = 2
    quarantine_steps: int = 8
    watchdog_steps: int = 64
    # Donating the device state buffer into compiled steps saves a copy
    # on TPU/GPU but makes same-input retry impossible (the input
    # buffer is consumed).  Fault injection therefore requires
    # donate_state=False on donating platforms; CPU never donates.
    donate_state: bool = True
    # -- non-uniform rank budgets (DESIGN.md §14) ---------------------
    # A spectrum-planned ``core.prune.RankBudget`` describing the
    # engine's non-uniform per-layer/per-head kept ranks.  The engine
    # does NOT apply it (callers run ``apply_rank_budget`` on the
    # weights first — the engine validates the plan's global max widths
    # against cfg.qk_dim/vo_dim); holding it here (a) folds
    # ``plan.salt()`` into the prefix-trie salt so caches never cross
    # budgets, and (b) re-plans the tp head partition from
    # ``plan.head_loads()`` so shards balance PLANNED rank work, not
    # the uniform maximum.  None -> uniform ranks, prior behavior.
    rank_budget: Optional[RankBudget] = None

    def __post_init__(self):
        if self.kernel_impl not in ("",) + self._IMPLS:
            raise ValueError(
                f"EngineConfig.kernel_impl={self.kernel_impl!r}: expected "
                "'' (inherit ArchConfig.kernel_impl) or one of "
                f"{self._IMPLS}")
        if self.host_pages < 0:
            raise ValueError(
                f"EngineConfig.host_pages={self.host_pages}: must be "
                ">= 0 (0 disables the host spill tier)")
        if self.host_pages > 0 and not self.prefix_cache:
            raise ValueError(
                f"EngineConfig.host_pages={self.host_pages} requires "
                "prefix_cache=True: the host tier spills and restores "
                "prefix-trie pages, which only exist with the prefix "
                "cache enabled")
        if self.step_retries < 0:
            raise ValueError(
                f"EngineConfig.step_retries={self.step_retries}: must be "
                ">= 0")
        if self.quarantine_steps < 0:
            raise ValueError(
                f"EngineConfig.quarantine_steps={self.quarantine_steps}: "
                "must be >= 0")
        if self.watchdog_steps < 0:
            raise ValueError(
                f"EngineConfig.watchdog_steps={self.watchdog_steps}: "
                "must be >= 0 (0 disables the watchdog)")

    _IMPLS = ("ref", "xla", "pallas", "interpret")

    @property
    def chunk(self) -> int:
        """Effective chunk size — the ONE clamp both the Scheduler's
        planning and the Engine's capacity/page-table sizing use."""
        return max(1, min(self.prefill_chunk, self.max_len))

    @property
    def spec_window(self) -> int:
        """Verify-step window width (pending token + k drafts)."""
        return self.spec_k + 1

    @property
    def capacity(self) -> int:
        """Per-slot KV capacity: max_len rounded up to a chunk multiple
        PLUS spare room, so every window write [index, index+W) with
        index <= max_len stays in bounds — dense dynamic_update_slice
        never clamps (a clamped write would shift backwards over valid
        history) and paged position->page lookups never fall off the
        table.  W is the chunk size or, with speculation on, the
        (k+1)-wide verify window whose rejected tail transiently
        overhangs the committed length.  The spare tail is beyond every
        causal horizon, hence never readable."""
        C = self.chunk
        spare = max(C, self.spec_window if self.spec_k > 0 else 1)
        return ((self.max_len + C - 1) // C * C
                + (spare + C - 1) // C * C)

    @property
    def table_pages(self) -> int:
        """Static per-slot page-table width (paged mode)."""
        pt = self.page_tokens
        return (self.capacity + pt - 1) // pt

    @property
    def pool_pages(self) -> int:
        """Resolved pool size: ``n_pages``, or the no-pressure default
        where every slot can reach full capacity."""
        return self.n_pages or self.slots * self.table_pages
