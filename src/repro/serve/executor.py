"""Executors: compiled step functions, device placement, and the
donate/alias contracts behind one small protocol (DESIGN.md §10).

The engine plans WHAT happens each step (host-side numpy: admission,
chunking, page coverage, speculative acceptance); an ``Executor`` owns
HOW a planned step executes: it holds the (possibly sharded) params,
builds the decode state where the step functions expect it, compiles
``prefill_chunk`` / ``decode_step`` / ``verify_chunk`` / the draft pass
/ the COW page copy / the host-tier page restore (DESIGN.md §12)
exactly once each, and decides buffer donation.  Everything above the
protocol is layout- and parallelism-agnostic — the same
``Engine``/``Scheduler`` drive both executors below.

* ``LocalExecutor`` — single device, params as given.  The compiled-
  shape contract: 2 step shapes (chunk + decode), +2 with speculation,
  +1 once a COW page copy fires, +1 once a host-tier restore fires.
* ``ShardedExecutor`` — rank-balanced tensor parallelism: a
  ``("data", "model")`` host mesh (``launch.mesh.make_host_mesh``),
  params and KV/page pools sharded along HEADS
  (``parallel.sharding.serve_rules`` / ``serve_state_specs``) with the
  head -> shard assignment planned by
  ``core.prune.rank_balanced_partition`` so every shard carries ~equal
  pruned FLOPs/bytes.  The same step functions compile under the mesh
  (GSPMD partitions the per-head einsums; the Pallas hot-path kernels
  run per shard via shard_map — ``kernels.ops.resolve(impl, mesh)``;
  the ambient-mesh ``constrain`` hints in models/ keep activations
  batch-sharded), so the two-shape contract holds PER PARALLELISM
  DEGREE.  Scheduling, page ids and the prefix trie stay host-global —
  each shard stores its own heads' slice of every page.

Donation: the decode state is the big buffer (KV pools); every step
consumes the previous state and the engine drops its reference, so the
state argument is donated to the compiled call where the platform
supports aliasing (TPU/GPU; CPU silently copies, so we skip it there
rather than spam warnings).  The DRAFT pass is the one exception: the
engine re-uses the pre-draft state for the verify step, so draft state
is never donated.

Multi-tenant SV adapters (DESIGN.md §13): the executor holds the
stacked adapter gather bank (``AdapterRegistry.bank()``), placed like
the params (sharded along heads under tp), and every step entry takes
a per-slot ``(slots,)`` adapter-id vector; the bank gather is traced,
so adapter traffic mixes never add compiled shapes.  The bank is an
engine-lifetime constant passed alongside the params — never donated.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Dict, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, MIXER_ATTN, MLP_RWKV
from repro.models import transformer as T
from repro.serve.config import EngineConfig

Params = Dict[str, Any]


def is_recurrent(cfg: ArchConfig) -> bool:
    return any(mixer != MIXER_ATTN or mlp == MLP_RWKV
               for mixer, mlp in cfg.pattern)


def validate_kernel_parallelism(cfg: ArchConfig, tp: int) -> None:
    """Loud, early rejection of (kernel impl, parallelism) combos that
    cannot work — replacing the silent ``kernel_impl="xla"`` demotion
    the sharded executor used to ship (which hid a 100% kernel-coverage
    loss under tp > 1).  Since the attention kernels moved under
    shard_map, only one genuinely-impossible combo remains: recurrent
    (mamba/rwkv) token mixers carry cross-step state per head, and
    their kernels (``mamba_scan``/``wkv6``) have no shard_map
    partitioning — there is no per-shard state threading to run them
    on.  Attention kernels compose with any tp; KV-head counts that do
    not divide the mesh degrade per kernel to replicated execution
    (correct, just not parallel — see ``parallel.sharding
    .kernel_axes``).  Also rejects unknown impl aliases (via
    ``kernels.ops.resolve``) before anything compiles."""
    from repro.kernels import ops as kops
    dispatch = kops.resolve(cfg.kernel_impl)    # raises on bad aliases
    if tp > 1 and dispatch.kernel_path and is_recurrent(cfg):
        raise ValueError(
            f"kernel_impl={dispatch.requested or dispatch.impl!r} with "
            f"tp={tp} is unsupported on recurrent (mamba/rwkv) "
            "architectures: mamba_scan/wkv6 carry cross-step recurrent "
            "state and are not shard_map-partitioned, so the kernel "
            "path cannot run per shard.  Use kernel_impl='xla' for "
            "sharded recurrent serving.")


def _mask_like(flags: jnp.ndarray, leaf: jnp.ndarray) -> jnp.ndarray:
    """(B,) bool -> broadcastable to a stacked state leaf (nb, B, ...)."""
    return flags.reshape((1, flags.shape[0]) + (1,) * (leaf.ndim - 2))


def _is_kv(path) -> bool:
    return any(getattr(p, "key", None) == "kv" for p in path)


def _reset_fresh(state: Params, fresh: jnp.ndarray,
                 resume: jnp.ndarray) -> Params:
    """Zero recurrent state of freshly admitted slots and set their
    index to ``resume`` (0 normally; the first uncached position on a
    prefix-cache hit — the cached prefix's K/V is already present in
    the slot's read-only shared pages).  KV caches keep their stale
    contents — masked by the per-slot index (dense: the slot's own
    region; paged: freshly allocated pages hold a previous owner's
    data, masked until overwritten by the new one)."""

    def z(path, leaf):
        if _is_kv(path):
            return leaf
        return jnp.where(_mask_like(fresh, leaf), jnp.zeros_like(leaf), leaf)

    return {"blocks": jax.tree_util.tree_map_with_path(z, state["blocks"]),
            "index": jnp.where(fresh, resume, state["index"])}


def _merge_inactive(old_blocks, new_blocks, active: jnp.ndarray):
    """Keep inactive slots' recurrent state across a chunk step (their
    padded garbage window must not advance it).  KV caches are taken
    wholesale: inactive slots' garbage writes land at [index, index+C),
    which is either masked (beyond each slot's causal horizon),
    overwritten by that slot's own future writes before it becomes
    readable, or (paged) routed via sentinel table entries into the
    pool's garbage row."""

    def sel(path, old, new):
        if _is_kv(path):
            return new
        return jnp.where(_mask_like(active, old), new, old)

    return jax.tree_util.tree_map_with_path(sel, old_blocks, new_blocks)


def _dev(x):
    return None if x is None else jnp.asarray(x)


def _select_adapters(bank, ids):
    """Gather per-slot SV-adapter scales out of the stacked bank:
    ``(nb, A, H, d)`` -> ``(nb, B, H, d)`` per pattern position.  Runs
    INSIDE the compiled step — the adapter mix is data, not shape, so
    multi-tenant traffic never changes the jit signature (DESIGN.md
    §13)."""
    if bank is None:
        return None
    return tuple(
        None if entry is None else
        {k: jnp.take(v, ids, axis=1) for k, v in entry.items()}
        for entry in bank)


def _donation_supported() -> bool:
    # CPU "supports" donation only by warning and copying — skip it
    return jax.local_devices()[0].platform in ("tpu", "gpu")


def _put_tree(tree: Params, specs: Params, mesh) -> Params:
    from jax.sharding import NamedSharding
    flat, treedef = jax.tree_util.tree_flatten(tree)
    flat_specs = treedef.flatten_up_to(specs)
    return treedef.unflatten(
        [jax.device_put(x, NamedSharding(mesh, s))
         for x, s in zip(flat, flat_specs)])


def _constrain_tree(tree: Params, specs: Params) -> Params:
    """with_sharding_constraint over a tree of PartitionSpecs (trace
    time, mesh ambient) — pins jit OUTPUT shardings to the init-time
    placement so step outputs feed the next step on the same layout and
    the jit cache never sees a second sharding signature."""
    flat, treedef = jax.tree_util.tree_flatten(tree)
    flat_specs = treedef.flatten_up_to(specs)
    return treedef.unflatten(
        [jax.lax.with_sharding_constraint(x, s)
         for x, s in zip(flat, flat_specs)])


class Executor(Protocol):
    """What the engine needs from an execution backend.

    All array arguments are host (numpy) values except ``state``, which
    is whatever ``init_state`` returned (device-resident, possibly
    sharded) and is threaded engine -> executor -> engine unchanged in
    structure.  ``pages`` / ``wfloor`` are None in dense mode.  Every
    step method returns ``(logits, new_state)`` with logits gatherable
    via ``np.asarray``.
    """
    tp: int
    draft_rank: Optional[Tuple[int, int]]

    def init_state(self) -> Params:
        """Build (and place) the decode-state tree."""

    def prefill_chunk(self, state, tokens, lengths, fresh, resume,
                      pages, wfloor, aids=None):
        """(slots, C) chunk step -> (last-valid logits, new state).
        ``aids``: optional (slots,) adapter-id vector (all entries)."""

    def decode_step(self, state, tok, fresh, resume, pages, wfloor,
                    aids=None):
        """(slots,) one-token step -> (logits, new state)."""

    def draft_step(self, state, tok, pages, wfloor, aids=None):
        """Rank-sliced draft pass; ``state`` is NOT consumed."""

    def verify_chunk(self, state, tokens, lengths, pages, wfloor,
                     aids=None):
        """(slots, k+1) verify window -> (per-position logits, state)."""

    def page_copy(self, state, src, dst) -> Params:
        """Clone page contents src[i] -> dst[i] across all pools."""

    def read_page(self, state, page):
        """Device->host byte copy of one pool row per KV leaf (spill)."""

    def page_restore(self, state, rows, dst) -> Params:
        """Scatter host-held page content into pool rows (restore)."""

    def commit_index(self, state, index) -> Params:
        """Replace the per-slot index with a host value (rollback)."""

    def compiled_shapes(self) -> Optional[int]:
        """Total jit cache entries, or None if not introspectable."""

    def plan_salt(self) -> Tuple:
        """Cache-key component describing the executor's layout."""

    @property
    def spec_enabled(self) -> bool:
        """Whether draft/verify entries were compiled."""
        return False


class LocalExecutor:
    """Single-device executor — params used where they are."""

    def __init__(self, params: Params, cfg: ArchConfig,
                 ecfg: EngineConfig, *, adapter_bank=None):
        self.cfg = cfg
        self.ecfg = ecfg
        self.tp = 1
        self.recurrent = is_recurrent(cfg)
        self.params = self._place_params(params)
        # stacked per-tenant SV-adapter scales (AdapterRegistry.bank()),
        # placed like the params; engine-lifetime constant, never donated
        self.abank = self._place_adapters(adapter_bank)
        cfg = self._compile_cfg(cfg)
        # the ONE resolved dispatch every compiled entry traces with
        self.dispatch = cfg.kernel_impl
        # donation consumes the input state buffer, which forbids the
        # engine's same-input step retry (DESIGN.md §11) — EngineConfig
        # can switch it off; CPU never donates anyway
        donate = _donation_supported() and ecfg.donate_state
        self.donates_state = donate

        def jit(fn, state_argnum=None):
            if state_argnum is not None and donate:
                return jax.jit(fn, donate_argnums=(state_argnum,))
            return jax.jit(fn)

        def chunk_fn(params, tokens, lengths, fresh, resume, pages,
                     wfloor, abank, aids, state):
            st = _reset_fresh(state, fresh, resume)
            logits, new = T.prefill_chunk(params, cfg, tokens, st, lengths,
                                          pages=pages, write_floor=wfloor,
                                          adapters=_select_adapters(abank,
                                                                    aids))
            blocks = _merge_inactive(st["blocks"], new["blocks"],
                                     lengths > 0)
            return logits, self._pin_state(
                {"blocks": blocks, "index": new["index"]})

        def decode_fn(params, tok, fresh, resume, pages, wfloor, abank,
                      aids, state):
            logits, new = T.decode_step(params, cfg, tok,
                                        _reset_fresh(state, fresh, resume),
                                        pages=pages, write_floor=wfloor,
                                        adapters=_select_adapters(abank,
                                                                  aids))
            return logits, self._pin_state(new)

        self._chunk = jit(chunk_fn, state_argnum=9)
        self._decode = jit(decode_fn, state_argnum=8)
        # batched page-content clone backing copy-on-write faults: the
        # ONE extra compiled shape prefix caching adds (a no-op without
        # it — compiled_shapes() counts it only once it runs)
        dispatch = self.dispatch

        def copy_fn(blocks, src, dst):
            def cp(path, leaf):
                if _is_kv(path):
                    return dispatch.page_copy(leaf, src, dst)
                return leaf

            return self._pin_blocks(
                jax.tree_util.tree_map_with_path(cp, blocks))

        self._copy = jit(copy_fn, state_argnum=0) if ecfg.paged else None

        # host-tier restore scatter (DESIGN.md §12): one fixed-width
        # batch shape, reusing page_copy's row-to-row slab contract —
        # the +1 compiled shape hierarchical KV adds (only engines
        # with a host tier ever compile it)
        def restore_fn(blocks, rows, dst):
            it = iter(rows)

            def rs(path, leaf):
                if _is_kv(path):
                    return dispatch.page_restore(leaf, next(it), dst)
                return leaf

            return self._pin_blocks(
                jax.tree_util.tree_map_with_path(rs, blocks))

        self._restore = (jit(restore_fn, state_argnum=0)
                         if ecfg.paged and ecfg.host_pages > 0 else None)
        self._draft = self._verify = None
        self.draft_rank: Optional[Tuple[int, int]] = None
        if ecfg.spec_k > 0 and not self.recurrent:
            from repro.core.prune import draft_ranks
            dr = draft_ranks(cfg, ecfg.draft_rank_ratio)
            # full-width "draft" degenerates to the exact model — skip
            # the slicing so XLA compiles the identical program
            self.draft_rank = (None if dr == (cfg.qk_dim, cfg.vo_dim)
                               else dr)

            def draft_fn(params, tok, pages, wfloor, abank, aids, state):
                # NEVER donate state here: the engine reuses the
                # pre-draft state for the verify step
                logits, new = T.decode_step(params, cfg, tok, state,
                                            pages=pages, write_floor=wfloor,
                                            draft_rank=self.draft_rank,
                                            adapters=_select_adapters(abank,
                                                                      aids))
                return logits, self._pin_state(new)

            def verify_fn(params, tokens, lengths, pages, wfloor, abank,
                          aids, state):
                logits, new = T.verify_chunk(params, cfg, tokens, state,
                                             lengths, pages=pages,
                                             write_floor=wfloor,
                                             adapters=_select_adapters(abank,
                                                                       aids))
                return logits, self._pin_state(new)

            self._draft = jit(draft_fn)
            self._verify = jit(verify_fn, state_argnum=7)

    # -- placement hooks (overridden by ShardedExecutor) ---------------
    def _place_params(self, params: Params) -> Params:
        return params

    def _place_adapters(self, bank):
        if bank is None:
            return None
        return jax.tree.map(jnp.asarray, bank)

    def _aids(self, aids):
        """Per-slot adapter ids as a device vector; identity (0) when
        the engine passes none.  Always None without a bank, so the
        adapter-free jit signature is byte-identical to pre-adapter
        builds."""
        if self.abank is None:
            return None
        if aids is None:
            return jnp.zeros((self.ecfg.slots,), jnp.int32)
        return jnp.asarray(aids, jnp.int32)

    def _place_state(self, state: Params) -> Params:
        return state

    def _pin_state(self, state: Params) -> Params:
        """Constrain an output state to the init placement (no-op on a
        single device)."""
        return state

    def _pin_blocks(self, blocks) -> Params:
        return blocks

    def _compile_cfg(self, cfg: ArchConfig) -> ArchConfig:
        """The config the step functions are traced with:
        ``kernel_impl`` resolved once into a frozen ``KernelDispatch``
        (platform-canonical; no mesh on a single device)."""
        from repro.kernels import ops as kops
        return dataclasses.replace(cfg,
                                   kernel_impl=kops.resolve(cfg.kernel_impl))

    def _ctx(self):
        """Mesh context the compiled calls run under (no-op locally)."""
        return contextlib.nullcontext()

    # -- protocol ------------------------------------------------------
    @property
    def spec_enabled(self) -> bool:
        return self._draft is not None

    def init_state(self) -> Params:
        cfg, ecfg = self.cfg, self.ecfg
        if ecfg.paged:
            state = T.init_decode_state_paged(cfg, ecfg.slots,
                                              ecfg.pool_pages,
                                              ecfg.page_tokens)
        else:
            state = T.init_decode_state(cfg, ecfg.slots, ecfg.capacity)
            # per-slot positions: (slots,) index vector so slots at
            # different depths coexist in one batch
            state["index"] = jnp.zeros((ecfg.slots,), jnp.int32)
        return self._place_state(state)

    def prefill_chunk(self, state, tokens, lengths, fresh, resume,
                      pages, wfloor, aids=None):
        with self._ctx():
            return self._chunk(self.params, jnp.asarray(tokens),
                               jnp.asarray(lengths), jnp.asarray(fresh),
                               jnp.asarray(resume), _dev(pages),
                               _dev(wfloor), self.abank, self._aids(aids),
                               state)

    def decode_step(self, state, tok, fresh, resume, pages, wfloor,
                    aids=None):
        with self._ctx():
            return self._decode(self.params, jnp.asarray(tok),
                                jnp.asarray(fresh), jnp.asarray(resume),
                                _dev(pages), _dev(wfloor), self.abank,
                                self._aids(aids), state)

    def draft_step(self, state, tok, pages, wfloor, aids=None):
        with self._ctx():
            return self._draft(self.params, jnp.asarray(tok), _dev(pages),
                               _dev(wfloor), self.abank, self._aids(aids),
                               state)

    def verify_chunk(self, state, tokens, lengths, pages, wfloor,
                     aids=None):
        with self._ctx():
            return self._verify(self.params, jnp.asarray(tokens),
                                jnp.asarray(lengths), _dev(pages),
                                _dev(wfloor), self.abank,
                                self._aids(aids), state)

    def page_copy(self, state, src, dst) -> Params:
        with self._ctx():
            blocks = self._copy(state["blocks"], jnp.asarray(src),
                                jnp.asarray(dst))
        return {"blocks": blocks, "index": state["index"]}

    def read_page(self, state, page):
        """Device->host spill read: pool row ``page`` of every KV leaf,
        as numpy, in tree-traversal order (``page_restore`` consumes
        the same order).  ``np.asarray`` BLOCKS until the transfer
        completes — the page's bytes are safely on the host before the
        caller frees the HBM page or a donating step consumes the pool
        buffer (DESIGN.md §12's spill-before-free ordering)."""
        out = []

        def rd(path, leaf):
            if _is_kv(path):
                out.append(np.asarray(leaf[:, page]))
            return leaf

        jax.tree_util.tree_map_with_path(rd, state["blocks"])
        return out

    def page_restore(self, state, rows, dst) -> Params:
        """Host->device restore scatter: slab ``rows[leaf][:, i]`` lands
        in pool row ``dst[i]`` of the matching KV leaf.  ``rows`` is a
        list of (n_blocks, W, page_tokens, KV, r) arrays in the same
        tree order ``read_page`` produces; short batches arrive
        zero-padded with sentinel dst entries (one fixed W = no new
        compiled shapes per batch size)."""
        with self._ctx():
            blocks = self._restore(state["blocks"],
                                   tuple(jnp.asarray(r) for r in rows),
                                   jnp.asarray(dst))
        return {"blocks": blocks, "index": state["index"]}

    def commit_index(self, state, index) -> Params:
        """Replace the per-slot index with a host value (the engine's
        speculative rollback) WITHOUT perturbing the next step's jit
        signature — the sharded executor re-commits it to the index's
        placement."""
        return {"blocks": state["blocks"], "index": jnp.asarray(index)}

    def compiled_shapes(self) -> Optional[int]:
        """Total jit cache entries across all step functions — the
        executor's contract is that this never exceeds 2 without
        speculation (dense AND paged: the page table is shape-static),
        4 with it (one draft shape + one verify shape on top), plus at
        most 1 for the fixed-width page-copy batch once a prefix-cache
        copy-on-write fault has fired, plus at most 1 for the
        fixed-width host-tier restore batch once a spilled prefix is
        restored — PER PARALLELISM DEGREE (each executor owns its own
        jit closures).  Returns None if the jit cache isn't
        introspectable (private API drift)."""
        fns = [f for f in (self._chunk, self._decode, self._copy,
                           self._restore, self._draft, self._verify)
               if f is not None]
        sizes = [getattr(f, "_cache_size", None) for f in fns]
        if any(s is None for s in sizes):
            return None
        return sum(s() for s in sizes)

    def plan_salt(self) -> Tuple:
        return ()

    def kernel_report(self) -> Dict[str, str]:
        """What each compiled entry ACTUALLY runs — ground truth for
        ``examples/serve_pruned`` reporting (the old executor could
        claim "pallas" while silently tracing XLA under tp > 1).  The
        hot one-token steps (decode/draft) take the flash-decode
        kernels on the kernel path; chunked prefill/verify windows
        (S > 1) always take the masked einsum path."""
        d = self.dispatch
        hot = (d.describe()
               if (d.kernel_path and not self.recurrent
                   and self.cfg.attn_logit_softcap == 0) else "xla")
        rep = {"decode_step": hot, "prefill_chunk": "xla"}
        if self._draft is not None:
            rep["draft_step"] = hot
            rep["verify_chunk"] = "xla"
        if self._copy is not None:
            rep["page_copy"] = d.describe() if d.kernel_path else "ref"
        if self._restore is not None:
            rep["page_restore"] = d.describe() if d.kernel_path else "ref"
        return rep


class ShardedExecutor(LocalExecutor):
    """Rank-balanced tensor-parallel executor (DESIGN.md §10).

    Builds a ``("data", "model")`` mesh with ``model=tp`` over the host
    devices, plans the head -> shard assignment from the per-head
    CLOVER rank loads (``rank_balanced_partition`` — equal head counts,
    ~equal pruned FLOPs/bytes), PERMUTES the attention head axes to
    realize the plan, and places params/state with the serving rules:
    heads / ff / vocab over "model", slot batch over "data", KV and
    page pools sharded along their KV-HEAD axis.  The page allocator
    and prefix trie stay host-global — page ids mean the same thing on
    every shard.  ``plan_salt`` folds the head layout into the prefix-
    cache salt so rank-plan/layout reuse stays correct.

    Greedy streams are token-identical to the LocalExecutor for
    ATTENTION-ONLY architectures: the head permutation is exact
    (attention sums over heads), scheduling never observes the layout,
    and per-step logits drift only ~1e-6 (cross-shard reduction
    order), far below greedy argmax gaps.  Recurrent (mamba/rwkv)
    archs still serve correctly but INTEGRATE that drift step over
    step, so their sharded streams may diverge from tp=1 on a
    near-tie — the same reason they are excluded from speculative
    rollback.  Heads that do not divide ``tp`` degrade to replication
    (the sharding rules drop non-divisible dims) — correct, just not
    parallel.

    Pallas step kernels run PER SHARD: ``_compile_cfg`` resolves
    ``kernel_impl`` against the executor's mesh, so the flash-decode /
    paged-decode / page-copy calls inside the step functions trace
    under ``shard_map`` with serve-rules operand specs
    (``kernels.ops.KernelDispatch``).  Page ids stay host-global — the
    pools' page-row axis is replicated, so the scalar-prefetched page
    tables cross the shard boundary untranslated and each shard reads
    its own KV-head slice of the same rows.  Per-(slot, kv-head) grid
    cells are independent, so per-shard kernel outputs are bitwise
    identical to the single-device kernels.  The one combo that cannot
    run per shard — recurrent kernels under tp > 1 — is rejected with
    a ``ValueError`` up front (``validate_kernel_parallelism``), never
    silently demoted.
    """

    def __init__(self, params: Params, cfg: ArchConfig,
                 ecfg: EngineConfig, *, tp: Optional[int] = None,
                 plan=None, adapter_bank=None):
        from repro.core.prune import head_rank_loads, rank_balanced_partition
        from repro.launch.mesh import make_host_mesh
        tp = int(tp if tp is not None else ecfg.tp)
        if tp < 1:
            raise ValueError(f"tensor-parallel degree must be >= 1: {tp}")
        validate_kernel_parallelism(cfg, tp)    # before anything compiles
        self.mesh = make_host_mesh(model=tp)    # clear error on misfit
        has_attn = any(m == MIXER_ATTN for m, _ in cfg.pattern)
        if plan is None and has_attn and cfg.n_kv_heads % tp == 0:
            plan = rank_balanced_partition(head_rank_loads(cfg), tp,
                                           group=cfg.q_per_kv)
        self.plan = plan
        super().__init__(params, cfg, ecfg, adapter_bank=adapter_bank)
        self.tp = tp

    def _place_params(self, params: Params) -> Params:
        from repro.core.prune import permute_attention_heads
        from repro.parallel import sharding as sh
        if self.plan is not None and not self.plan.identity:
            params = permute_attention_heads(params, self.cfg, self.plan)
        rules = sh.serve_rules()
        specs = sh.param_specs(params, self.mesh, rules)
        return _put_tree(params, specs, self.mesh)

    def _place_adapters(self, bank):
        """Adapter bank sharded like the weights it scales: the
        ``(nb, A, H, d)`` head axis follows the ``s_qk``/``s_vo`` rules
        (permuted by the rank-balance plan, split over "model"; a head
        count that does not divide tp degrades to replication exactly
        as the weight specs do)."""
        if bank is None:
            return None
        from repro.parallel import sharding as sh
        if self.plan is not None and not self.plan.identity:
            perm = jnp.asarray(self.plan.q_perm, jnp.int32)
            bank = jax.tree.map(lambda a: jnp.take(a, perm, axis=2), bank)
        rules = sh.serve_rules()

        def place(a):
            spec = rules.spec((None, None, sh.HEADS, None), a.shape,
                              self.mesh)
            return jax.device_put(
                a, jax.sharding.NamedSharding(self.mesh, spec))

        return jax.tree.map(place, bank)

    def _place_state(self, state: Params) -> Params:
        from repro.parallel import sharding as sh
        self._state_specs = sh.serve_state_specs(state, self.mesh,
                                                 paged=self.ecfg.paged)
        return _put_tree(state, self._state_specs, self.mesh)

    def _pin_state(self, state: Params) -> Params:
        specs = getattr(self, "_state_specs", None)
        if specs is None:       # traced before init_state: leave free
            return state
        return _constrain_tree(state, specs)

    def _pin_blocks(self, blocks) -> Params:
        specs = getattr(self, "_state_specs", None)
        if specs is None:
            return blocks
        return _constrain_tree(blocks, specs["blocks"])

    def commit_index(self, state, index) -> Params:
        from jax.sharding import NamedSharding
        idx = jax.device_put(
            jnp.asarray(index),
            NamedSharding(self.mesh, self._state_specs["index"]))
        return {"blocks": state["blocks"], "index": idx}

    def _compile_cfg(self, cfg: ArchConfig) -> ArchConfig:
        """Resolve ``kernel_impl`` AGAINST THE MESH: the step functions
        then trace the Pallas/interpret kernels per shard via shard_map
        (the silent ``kernel_impl="xla"`` demotion that used to live
        here is gone)."""
        from repro.kernels import ops as kops
        return dataclasses.replace(
            cfg, kernel_impl=kops.resolve(cfg.kernel_impl, mesh=self.mesh))

    def _ctx(self):
        return self.mesh      # Mesh is a reusable context manager

    def plan_salt(self) -> Tuple:
        if self.plan is not None:
            return self.plan.salt()
        return ("tp", self.tp)

    def shard_load_fractions(self):
        """Per-shard fraction of the total per-token KV bytes / pruned
        attention FLOPs — what the rank-balanced partition equalized.
        Every shard maps the same page IDS; these fractions are how the
        pool's BYTES split across shards."""
        if self.plan is None:
            return [1.0 / self.tp] * self.tp
        tot = sum(self.plan.loads) or 1.0
        return [ld / tot for ld in self.plan.loads]
