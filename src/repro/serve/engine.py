"""Engine: chunked-prefill continuous batching over CLOVER-rank KV
caches — the ORCHESTRATOR of the serve package.

Each engine step every slot is either decoding one token or consuming a
fixed-size chunk of its prompt, so prefill interleaves with decode and
the engine compiles exactly TWO step shapes regardless of the
prompt-length mix (plus two with speculation, plus one once a
copy-on-write page clone fires, plus one once a host-tier restore
fires).  The division of labor:

  * ``scheduler.Scheduler``  — WHAT happens: admission, phase tracking,
    chunk planning, preemption, retirement (host numpy).
  * ``memory.PageAllocator`` / ``memory.PrefixCache`` — WHERE K/V
    lives: refcounted pages, copy-on-write prefix sharing (host).
  * ``executor.LocalExecutor`` / ``executor.ShardedExecutor`` — HOW a
    planned step executes: compiled entries, device placement,
    donation, tensor parallelism (DESIGN.md §10).  The engine never
    touches a mesh, a sharding or a jit cache — swap the executor and
    nothing here changes.

KV layout is DENSE (``EngineConfig.paged=False``: per-slot caches) or
PAGED (one global pool per attention layer + host page tables); paged
mode optionally shares pages across sequences by page-aligned token
prefix (``prefix_cache``, DESIGN.md §9), optionally backed by a
host-RAM spill tier (``host_pages``, DESIGN.md §12: trie eviction
copies page bytes device->host before freeing, admission restores them
instead of re-prefilling), and every pure-decode step can
run self-speculatively (``spec_k``, DESIGN.md §8).  ``tp > 1`` serves
the same streams over head-sharded params/pools (DESIGN.md §10).  All
compositions emit greedy streams token-identical to the isolated
whole-prompt reference (``greedy_reference``).

MULTI-TENANT SV ADAPTERS (DESIGN.md §13): an optional
``core.peft.AdapterRegistry`` gives every request a per-tenant set of
CLOVER singular values.  The engine ships the registry's stacked
gather bank to the executor once, passes each step a per-slot
(slots,) adapter-id vector built from slot state, and keys the prefix
trie (and host spill tier) by adapter id so cached K/V never crosses
tenants.  Adapter id 0 is the identity — streams are bitwise the base
model's, and an engine without a registry is byte-for-byte the
pre-adapter build.

ROBUSTNESS (DESIGN.md §11): every compiled call runs behind a guard
that (a) optionally injects deterministic faults from a ``FaultPlan``
and (b) always validates the returned logits are finite.  A failed
call is retried with the SAME inputs (host bookkeeping only mutates
AFTER a call succeeds, and the state buffer is not donated when faults
are enabled); on retry exhaustion the step aborts, active slots are
quarantined and their requests requeued for an exact re-prefill
continuation.  A progress watchdog sheds the lowest-priority request
when nothing moves for ``watchdog_steps`` steps, and per-step deadline
enforcement times out / sheds requests through the scheduler.
Surviving streams under ANY fault schedule stay token-identical to the
fault-free replay.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.peft import AdapterRegistry
from repro.core.prune import rank_balanced_partition
from repro.models import transformer as T
from repro.serve.config import EngineConfig
from repro.serve.executor import (Executor, LocalExecutor, ShardedExecutor,
                                  is_recurrent, validate_kernel_parallelism)
from repro.serve.faults import FaultError, FaultPlan
from repro.serve.memory import HostTier, PageAllocator, PrefixCache
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import DONE, Request, Scheduler

Params = Dict[str, Any]


def greedy_reference(params: Params, cfg: ArchConfig, prompt,
                     n: int) -> List[int]:
    """Isolated whole-prompt greedy decode via the full forward pass —
    the exactness oracle engine streams are checked against (chunked
    prefill must reproduce it token-for-token)."""
    seq = list(prompt)
    gen = []
    for _ in range(n):
        logits, _ = T.forward(params, cfg, jnp.asarray(seq)[None, :])
        tok = int(jnp.argmax(logits[0, -1]))
        gen.append(tok)
        seq.append(tok)
    return gen


class _StepAbort(Exception):
    """A step failed after exhausting its retries: unwind to recovery
    (quarantine + requeue) without touching host bookkeeping."""


class Engine:
    def __init__(self, params: Params, cfg: ArchConfig, ecfg: EngineConfig,
                 rng: Optional[jax.Array] = None,
                 executor: Optional[Executor] = None,
                 faults: Optional[FaultPlan] = None,
                 adapters: Optional[AdapterRegistry] = None):
        if ecfg.kernel_impl:        # per-engine kernel dispatch override
            cfg = dataclasses.replace(cfg, kernel_impl=ecfg.kernel_impl)
        # impossible (impl, parallelism, arch) combos fail HERE, loudly,
        # before any executor state exists or anything compiles
        validate_kernel_parallelism(cfg, ecfg.tp)
        if ecfg.rank_budget is not None:
            plan = ecfg.rank_budget
            if (plan.qk_width != cfg.qk_dim
                    or plan.vo_width != cfg.vo_dim):
                raise ValueError(
                    f"EngineConfig.rank_budget widths ({plan.qk_width}, "
                    f"{plan.vo_width}) do not match cfg ({cfg.qk_dim}, "
                    f"{cfg.vo_dim}): run core.prune.apply_rank_budget on "
                    "the weights first and serve its returned cfg — the "
                    "engine validates plans, it does not apply them")
        self.cfg = cfg
        self.ecfg = ecfg
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        recurrent = is_recurrent(cfg)
        if ecfg.spec_k > 0 and recurrent:
            raise ValueError(
                "speculative decoding requires an attention-only "
                "architecture: recurrent (mamba/rwkv) state cannot roll "
                "back rejected draft tokens")
        if ecfg.prefix_cache:
            if not ecfg.paged:
                raise ValueError("prefix_cache requires paged=True: only "
                                 "pages can be shared across sequences")
            if recurrent:
                raise ValueError(
                    "prefix caching requires an attention-only "
                    "architecture: recurrent (mamba/rwkv) state is not "
                    "page-addressable, so a cached page run cannot "
                    "reconstruct it")
        self.adapters = adapters
        if executor is None:
            bank = adapters.bank() if adapters is not None else None
            if ecfg.tp > 1:
                # re-plan the head partition per rank budget: shards
                # should balance PLANNED per-head rank work, not the
                # uniform maximum (DESIGN.md §14)
                part = None
                if (ecfg.rank_budget is not None
                        and cfg.n_kv_heads % ecfg.tp == 0):
                    part = rank_balanced_partition(
                        ecfg.rank_budget.head_loads(), ecfg.tp,
                        group=cfg.q_per_kv)
                executor = ShardedExecutor(params, cfg, ecfg, plan=part,
                                           adapter_bank=bank)
            else:
                executor = LocalExecutor(params, cfg, ecfg,
                                         adapter_bank=bank)
        elif adapters is not None:
            raise ValueError(
                "pass adapters OR a pre-built executor, not both: the "
                "registry's gather bank must be placed at executor "
                "construction (LocalExecutor(..., adapter_bank=...))")
        self.exe = executor
        if faults is not None and getattr(executor, "donates_state", False):
            raise ValueError(
                "fault injection requires EngineConfig.donate_state="
                "False on this platform: same-input step retry cannot "
                "reuse a donated state buffer")
        self.faults = faults
        self.state = executor.init_state()
        if ecfg.paged:
            self.alloc: Optional[PageAllocator] = PageAllocator(
                ecfg.pool_pages, ecfg.page_tokens, ecfg.slots,
                ecfg.table_pages)
        else:
            self.alloc = None
        self.prefix: Optional[PrefixCache] = None
        self.host: Optional[HostTier] = None
        if ecfg.prefix_cache:
            # the trie key folds in the rank plan AND the executor's
            # head-partition plan: caches produced under a different
            # prune ratio / CLOVER rank / page size / head layout must
            # never alias (their K/V live in a different basis)
            salt = (cfg.name, cfg.qk_dim, cfg.vo_dim, cfg.clover.enabled,
                    cfg.clover.qk_rank, cfg.clover.vo_rank,
                    ecfg.page_tokens) + tuple(executor.plan_salt())
            if ecfg.rank_budget is not None:
                # non-uniform budgets zero different rank tails per
                # head: pages written under one plan are garbage under
                # another even at identical global widths
                salt = salt + tuple(ecfg.rank_budget.salt())
            self.prefix = PrefixCache(self.alloc, salt=salt)
            if ecfg.host_pages > 0:
                # hierarchical KV (DESIGN.md §12): trie eviction spills
                # through page_reader (a BLOCKING device->host read of
                # self.state's pool — always before the donated step
                # that could consume the buffer), admission restores
                # through the scheduler hook installed below
                self.host = HostTier(ecfg.host_pages)
                self.prefix.host = self.host
                self.prefix.page_reader = (
                    lambda page: self.exe.read_page(self.state, page))
        self.metrics = ServeMetrics()
        self.sched = Scheduler(ecfg, recurrent, self.alloc, self.prefix,
                               metrics=self.metrics)
        if self.host is not None:
            self.sched.restore = self._restore_pages
        # host mirror of state["index"] (tokens written per slot this
        # tenure) — drives page coverage without device round-trips
        self.written = np.zeros(ecfg.slots, np.int64)
        # deterministic engine step clock (never resets across run()s)
        self.steps = 0
        # progress watchdog: monotone work counters + the last step any
        # of them moved (tokens committed, prompt tokens prefilled, or
        # a request reaching a terminal state all count as progress)
        self._tokens_committed = 0
        self._prefill_consumed = 0
        self._last_progress = 0
        self.watchdog_sheds = 0
        self._alloc_fault = False
        # serving stats
        self.max_active = 0
        self.peak_page_util = 0.0
        # speculative-decoding stats: emitted-tokens-per-round histogram
        # {n_emitted: rounds} — mean > 1.0 is the wall-clock win
        self.spec_rounds = 0
        self.accept_hist: Dict[int, int] = collections.defaultdict(int)
        # per-adapter serving stats (DESIGN.md §13)
        self.adapter_tokens: Dict[int, int] = collections.defaultdict(int)
        self.adapter_done: Dict[int, int] = collections.defaultdict(int)
        if adapters is not None:
            # count completions per tenant at the single point every
            # terminal transition already funnels through
            base = self.metrics.on_terminal

            def _on_terminal(req):
                if req.status == DONE:
                    self.adapter_done[req.adapter_id] += 1
                base(req)
            self.metrics.on_terminal = _on_terminal

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        n = 1 if self.adapters is None else len(self.adapters)
        if req.adapter_id >= n:
            raise ValueError(
                f"Request.adapter_id (uid={req.uid})={req.adapter_id}: "
                + (f"registry has {n} adapters" if self.adapters
                   is not None else
                   "engine built without an AdapterRegistry (only the "
                   "identity adapter 0 exists)"))
        self.sched.submit(req)

    def cancel(self, uid: int) -> bool:
        """Client cancellation: terminal state CANCELLED, pages freed
        through the preemption decref path, nothing published.  False
        when ``uid`` is unknown or already terminal."""
        return self.sched.cancel(uid)

    def compiled_shapes(self) -> Optional[int]:
        """Executor jit-cache total (see Executor.compiled_shapes)."""
        return self.exe.compiled_shapes()

    def stats(self) -> dict:
        """Serving metrics snapshot: lifecycle/fault counters, per-
        priority-class TTFT/ITL percentiles (deterministic steps and
        wall clock), scheduler and pool counters."""
        out = self.metrics.snapshot()
        out["steps"] = self.steps
        out["max_active"] = self.max_active
        out["preemptions"] = self.sched.preemptions
        out["requeues"] = self.sched.requeues
        out["watchdog_sheds"] = self.watchdog_sheds
        if self.prefix is not None:
            out["prefix_hits"] = self.sched.prefix_hits
            out["prefix_hit_tokens"] = self.sched.prefix_hit_tokens
        if self.host is not None:
            out["host_spills"] = self.host.spills
            out["host_restores"] = self.host.restores
            out["host_dropped"] = self.host.dropped
            out["host_hit_rate"] = self.host.hit_rate
            out["host_pages_used"] = len(self.host)
        if self.alloc is not None:
            out["page_util"] = self.alloc.utilization()
            out["peak_page_util"] = self.peak_page_util
            out["free_pages"] = self.alloc.free_pages
        if self.ecfg.spec_k > 0:
            out["accepted_per_round"] = self.accepted_per_round
        if self.adapters is not None:
            out["adapter_tokens"] = dict(sorted(
                self.adapter_tokens.items()))
            out["adapter_done"] = dict(sorted(self.adapter_done.items()))
        if self.faults is not None:
            out["faults_injected"] = self.faults.summary()
        return out

    def _slot_aids(self) -> Optional[np.ndarray]:
        """(slots,) adapter-id vector for the NEXT compiled step: each
        active slot's tenant, identity (0) for idle rows.  None without
        a registry so the executor keeps the adapter-free jit
        signature (DESIGN.md §13)."""
        if self.adapters is None:
            return None
        return np.asarray([0 if r is None else r.adapter_id
                           for r in self.sched.slot_req], np.int32)

    def _sample(self, logits: np.ndarray, temp: float) -> int:
        if temp <= 0:
            return int(np.argmax(logits))
        self.rng, k = jax.random.split(self.rng)
        return int(jax.random.categorical(k, jnp.asarray(logits) / temp))

    def _emit(self, slots: List[int], logits: np.ndarray):
        now = time.monotonic()
        for s in slots:
            req = self.sched.slot_req[s]
            tok = self._sample(logits[s], req.temperature)
            req.generated.append(tok)
            req.token_times.append(now)
            req.token_steps.append(self.steps)
            self.sched.last_token[s] = tok
            self._tokens_committed += 1
            self.adapter_tokens[req.adapter_id] += 1

    # -- fault guards (DESIGN.md §11) ----------------------------------
    def _guarded(self, name: str, active: np.ndarray, fn, *args):
        """Run a compiled step entry behind the fault boundary: inject
        scheduled failures, ALWAYS validate the active logits rows are
        finite, retry with the same inputs on failure (sound because
        the engine mutates host bookkeeping only after this returns,
        and the state buffer is not donated).  Raises ``_StepAbort``
        when retries are exhausted."""
        retries = (0 if getattr(self.exe, "donates_state", False)
                   else self.ecfg.step_retries)
        err = None
        for attempt in range(retries + 1):
            try:
                if self.faults is not None and self.faults.fire("step"):
                    raise FaultError(f"injected {name} failure")
                logits, state = fn(*args)
                logits = np.asarray(logits)
                if self.faults is not None and self.faults.fire("nan"):
                    logits = np.where(np.ones_like(logits, bool),
                                      np.nan, logits)
                if not np.isfinite(logits[active]).all():
                    raise FaultError(f"non-finite logits from {name}")
                if attempt > 0:
                    self.metrics.bump("faults_recovered")
                return logits, state
            except FaultError as e:
                err = e
                if attempt < retries:
                    self.metrics.bump("retries")
        raise _StepAbort(f"{name}: {err}")

    def _guarded_copy(self, src: np.ndarray, dst: np.ndarray):
        """Page-content clone behind the same retry discipline."""
        retries = (0 if getattr(self.exe, "donates_state", False)
                   else self.ecfg.step_retries)
        for attempt in range(retries + 1):
            try:
                if self.faults is not None \
                        and self.faults.fire("page_copy"):
                    raise FaultError("injected page-copy failure")
                state = self.exe.page_copy(self.state, src, dst)
                if attempt > 0:
                    self.metrics.bump("faults_recovered")
                return state
            except FaultError:
                if attempt < retries:
                    self.metrics.bump("retries")
        raise _StepAbort("page_copy: injected failure persisted")

    # -- hierarchical KV: host-tier restore (DESIGN.md §12) ------------
    def _guarded_restore(self, rows, dst: np.ndarray) -> bool:
        """One fixed-width restore batch behind the fault boundary.
        Unlike ``_guarded_copy`` this NEVER raises: restore runs inside
        admission, outside ``step()``'s abort/recover scope, and giving
        up is always safe — the caller re-prefills whatever the failed
        batch would have restored (bounded, exact fallback).  Injection
        fires BEFORE the compiled call, so retry inputs are intact."""
        retries = (0 if getattr(self.exe, "donates_state", False)
                   else self.ecfg.step_retries)
        for attempt in range(retries + 1):
            try:
                if self.faults is not None \
                        and self.faults.fire("host_copy"):
                    raise FaultError("injected host-copy failure")
                self.state = self.exe.page_restore(self.state, rows, dst)
                if attempt > 0:
                    self.metrics.bump("faults_recovered")
                return True
            except FaultError:
                if attempt < retries:
                    self.metrics.bump("retries")
        self.metrics.bump("host_restore_fallbacks")
        return False

    def _restore_pages(self, s: int, eff: np.ndarray, hit_pages: int,
                       extra: Tuple = ()) -> int:
        """Admission restore hook (installed as ``Scheduler.restore``):
        probe the host tier for the pages of ``eff`` beyond the trie
        hit and copy every CONSECUTIVE hit back into the slot's own
        pages — ``ensure`` already allocated them, refcount 1, so the
        writes need no COW.  The restored run is then published into
        the trie (those pages are cached again, device-resident) and
        prefill resumes after it.  Returns pages restored; 0 on a miss
        or when a ``host_copy`` fault exhausts its retries, in which
        case the un-restored tokens are simply re-prefilled."""
        host, alloc = self.host, self.alloc
        pt = alloc.page_tokens
        n_full = len(eff) // pt
        if n_full <= hit_pages:
            return 0
        # ``extra`` is the admitting request's adapter key: the restore
        # probe and the re-publish below both carry it, so spilled
        # pages partition by tenant exactly like the trie they fell
        # out of (DESIGN.md §13)
        hashes = self.prefix.chain_hashes(eff, n_full, extra=extra)
        hits = []
        for i in range(hit_pages, n_full):
            rows = host.get(hashes[i])
            if rows is None:
                break               # restores must stay consecutive
            hits.append(rows)
        if not hits:
            return 0
        # fixed-width batches like _copy_pages: ONE compiled shape —
        # dst padding repeats the sentinel, rows padding is zero slabs
        # (identical content on the duplicate target, so scatter order
        # is irrelevant; see kernels/ref.page_restore_ref)
        W = max(1, self.ecfg.slots)
        snt = alloc.sentinel
        restored = 0
        while restored < len(hits):
            batch = hits[restored:restored + W]
            dst = [alloc.tables[s][hit_pages + restored + j]
                   for j in range(len(batch))]
            dst += [snt] * (W - len(batch))
            rows = [np.stack(list(slabs) + [np.zeros_like(slabs[0])]
                             * (W - len(batch)), axis=1)
                    for slabs in zip(*batch)]
            if not self._guarded_restore(rows,
                                         np.asarray(dst, np.int32)):
                break
            restored += len(batch)
        if restored > 0:
            host.restores += restored
            self.metrics.bump("host_restored_pages", restored)
            self.prefix.insert(eff,
                               alloc.tables[s][:hit_pages + restored],
                               extra=extra)
        return restored

    def _recover(self):
        """Retry-exhausted step: quarantine every active slot until the
        step clock passes the bench window and requeue its request (no
        publish — after a fault the device-side pages are suspect; the
        re-prefill from host-held tokens is an exact continuation,
        identical to the preemption path)."""
        until = self.steps + 1 + self.ecfg.quarantine_steps
        for s in range(self.ecfg.slots):
            if self.sched.slot_req[s] is not None:
                self.sched.requeue(s, until)
        self.metrics.bump("quarantines")

    def _watchdog_shed(self):
        """No counter moved for ``watchdog_steps`` steps while work was
        pending: shed the lowest-priority (then youngest) request —
        queued victims before running ones — instead of spinning to
        ``max_steps``."""
        sched = self.sched
        if sched.queue:
            victim = min(sched.queue, key=lambda r: (r.priority, -r._seq))
            sched.shed(("queue", victim.uid))
        else:
            cands = [s for s in range(self.ecfg.slots)
                     if sched.slot_req[s] is not None]
            if not cands:
                return
            victim = min(cands, key=lambda s: (
                sched.slot_req[s].priority, -sched.slot_seq[s]))
            sched.shed(("slot", victim))
        self.watchdog_sheds += 1
        self.metrics.bump("watchdog_sheds")

    # -- paged page-coverage / COW / preemption ------------------------
    def _cover_writes(self, s: int, take_s: int, pairs: List) -> bool:
        """Page-cover slot ``s``'s next write window [written, written +
        take) AND copy-on-write any SHARED page inside it (a prefix-hit
        resume rewriting the last cached position, or any future writer
        of a trie-indexed page): the page content is cloned into a
        fresh page (``pairs`` collects the (src, dst) device copies)
        and the slot's table repointed, so the shared original — and
        every other sequence reading it — is never mutated.  False ->
        the pool is exhausted mid-way; caller reclaims and retries
        (partial progress is safe: completed COWs stay valid)."""
        alloc = self.alloc
        if take_s <= 0:
            return True
        if self.faults is not None and self.faults.fire("alloc"):
            # injected transient exhaustion: report failure WITHOUT
            # touching the allocator so the caller's retry is free
            self._alloc_fault = True
            return False
        start = int(self.written[s])
        end = start + take_s
        if not alloc.ensure(s, end):
            return False
        if self.prefix is None:
            return True         # sharing is impossible without the trie
        pt = alloc.page_tokens
        for idx in range(start // pt, (end - 1) // pt + 1):
            page = alloc.tables[s][idx]
            if alloc.refcount[page] > 1:
                if not alloc.free_pages:
                    return False
                pairs.append(alloc.cow(s, idx))
        return True

    def _copy_pages(self, pairs: List[Tuple[int, int]]):
        """Clone page contents src -> dst across every layer's pools in
        fixed-width batches (ONE compiled shape; short batches pad with
        sentinel->sentinel self-copies).  Pairs execute in list order —
        a page freed after serving as a src may be reallocated as a
        later dst, never the reverse, so in-order is always correct."""
        W = max(1, self.ecfg.slots)
        snt = self.alloc.sentinel
        for i in range(0, len(pairs), W):
            batch = list(pairs[i:i + W])
            batch += [(snt, snt)] * (W - len(batch))
            src = np.asarray([p[0] for p in batch], np.int32)
            dst = np.asarray([p[1] for p in batch], np.int32)
            self.state = self._guarded_copy(src, dst)

    def _ensure_pages(self, decode_width: int = 1):
        """Cover every active slot's upcoming writes with pages (COW
        faults included), oldest sequence first (the FIFO head has page
        priority).  On pool exhaustion the reclaim ladder is: retry
        transient INJECTED exhaustion a bounded number of times (a real
        co-tenant backs off too), then evict LRU unmapped prefix-cache
        pages (cached-but-idle prefixes are the cheapest bytes to
        drop), then preempt-and-requeue the YOUNGEST active sequence
        (vLLM-style) and retry, instead of crashing mid-trace."""
        sched, alloc = self.sched, self.alloc
        take = sched.planned_writes(decode_width)
        order = sorted((s for s in range(self.ecfg.slots)
                        if sched.slot_req[s] is not None),
                       key=lambda s: sched.slot_seq[s])
        pairs: List[Tuple[int, int]] = []
        for s in order:
            streak = 0
            while sched.slot_req[s] is not None:
                if self._cover_writes(s, int(take[s]), pairs):
                    break
                if self._alloc_fault:
                    self._alloc_fault = False
                    if streak < self.ecfg.step_retries:
                        streak += 1
                        self.metrics.bump("retries")
                        continue
                    # persistent injected exhaustion: escalate to the
                    # real reclaim ladder below (eviction/preemption
                    # keep streams exact, so escalation is always safe)
                # batched shortfall: coverage may be short several
                # pages (a COW fault on top needs at most one more)
                short = max(1, alloc.pages_for(
                    int(self.written[s] + take[s]))
                    - len(alloc.tables[s]) - alloc.free_pages + 1)
                if self.prefix is not None and self.prefix.evict(short):
                    continue
                victims = [v for v in range(self.ecfg.slots)
                           if sched.slot_req[v] is not None]
                victim = max(victims, key=lambda v: sched.slot_seq[v])
                if victim == s and len(victims) == 1:
                    if self.faults is not None:
                        # only reachable via injected exhaustion
                        # (admission guarantees a lone sequence fits):
                        # abort the step and requeue instead of dying
                        raise _StepAbort(
                            "injected allocator exhaustion persisted")
                    # admission guarantees a lone sequence always fits
                    raise RuntimeError(
                        f"page pool exhausted: slot {s} needs "
                        f"{alloc.pages_for(int(self.written[s] + take[s]))}"
                        f" pages, pool has {alloc.n_pages}")
                sched.preempt(victim, n_valid=int(self.written[victim]))
        if pairs:
            self._copy_pages(pairs)

    # -- speculative round (DESIGN.md §8) ------------------------------
    def _spec_due(self) -> bool:
        """A speculative round replaces the plain decode step when the
        engine has a draft, no slot has prompt tokens left to chunk,
        and every active request is greedy (the acceptance rule below
        is exact only for argmax sampling)."""
        sched = self.sched
        if not self.exe.spec_enabled or sched.has_chunk_work():
            return False
        reqs = [r for r in sched.slot_req if r is not None]
        return bool(reqs) and all(r.temperature <= 0 for r in reqs)

    def _spec_round(self, pages, wfloor) -> None:
        """One speculative round over all active slots (all in DECODE):
        the rank-sliced DRAFT pass proposes ``k`` tokens per slot
        autoregressively, then ONE (slots, k+1) verify window scores
        every position with the full model.  Each slot commits its
        longest draft prefix matching the full model's argmaxes plus
        the bonus token — between 1 and k+1 tokens, never diverging
        from the non-speculative greedy stream — and the per-slot index
        rolls back over the rejected tail (dense and paged alike this
        is a pure length decrement: rejected K/V sits beyond every
        causal horizon until overwritten, the invariant padded chunk
        writes already rely on)."""
        sched, ecfg = self.sched, self.ecfg
        k, W = ecfg.spec_k, ecfg.spec_window
        slots = ecfg.slots
        active = np.array([r is not None for r in sched.slot_req])
        aids = self._slot_aids()
        n0 = self.written.copy()
        # draft k tokens; the draft's K/V writes land in the shared
        # cache but its state is DISCARDED — the verify step below
        # rewrites all k+1 positions at full rank from the pre-draft
        # state, so nothing the draft wrote is ever read by the model
        tok = sched.last_token.copy()
        drafts = np.zeros((slots, k), np.int32)
        dstate = self.state
        for j in range(k):
            logits, dstate = self._guarded(
                "draft_step", active, self.exe.draft_step,
                dstate, tok, pages, wfloor, aids)
            tok = np.argmax(logits, axis=-1).astype(np.int32)
            drafts[:, j] = tok
        tokens = np.zeros((slots, W), np.int32)
        tokens[:, 0] = sched.last_token        # pending, not yet cached
        tokens[:, 1:] = drafts
        lengths = np.where(active, W, 0).astype(np.int32)
        logits, self.state = self._guarded(
            "verify_chunk", active, self.exe.verify_chunk,
            self.state, tokens, lengths, pages, wfloor, aids)
        targets = np.argmax(logits, axis=-1)                   # (slots, W)
        now = time.monotonic()
        self.spec_rounds += 1
        for s in range(slots):
            if not active[s]:
                continue
            req = sched.slot_req[s]
            a = 0
            while a < k and drafts[s, a] == targets[s, a]:
                a += 1
            out = [int(t) for t in drafts[s, :a]] + [int(targets[s, a])]
            # honor max_new_tokens / eos exactly as the one-token path
            # would have: anything past the stop point is dropped (the
            # slot retires this step, so the over-committed cache tail
            # is unreachable)
            out = out[:req.max_new_tokens - len(req.generated)]
            if ecfg.eos_id >= 0 and ecfg.eos_id in out:
                out = out[:out.index(ecfg.eos_id) + 1]
            for t in out:
                req.generated.append(t)
                req.token_times.append(now)
                req.token_steps.append(self.steps)
                self._tokens_committed += 1
                self.adapter_tokens[req.adapter_id] += 1
            self.accept_hist[len(out)] += 1
            sched.last_token[s] = targets[s, a]
            self.written[s] = n0[s] + a + 1
        # roll back: commit per-slot lengths (idle slots advanced by 0)
        self.state = self.exe.commit_index(self.state,
                                           self.written.astype(np.int32))

    @property
    def accepted_per_round(self) -> float:
        """Mean tokens emitted per speculative slot-round (>= 1.0;
        1.0 = nothing ever accepted, k+1 = every draft accepted)."""
        n = sum(self.accept_hist.values())
        return (sum(a * c for a, c in self.accept_hist.items()) / n
                if n else 0.0)

    # ------------------------------------------------------------------
    def _step_inner(self) -> int:
        """The pre-robustness step body: plan, execute one compiled
        step, apply its progress, retire.  Raises ``_StepAbort`` (from
        the guards) with NO host bookkeeping applied for the aborted
        call — the caller recovers."""
        sched = self.sched
        spec = self._spec_due()
        pages = wfloor = None
        # newly admitted slots restart their tenure at their resume
        # point — 0, or the first uncached position on a prefix hit
        # (the device index follows via the executor's fresh-reset at
        # plan time; the host mirror drives page coverage, COW
        # detection AND the speculative rollback's index commit)
        for s in range(self.ecfg.slots):
            if sched.slot_req[s] is not None and sched.fresh[s]:
                self.written[s] = int(sched.resume[s])
        # pin IDLE rows' index at 0 via the same fresh-reset the newly
        # admitted rows use: decode steps advance every row's device
        # index (+1, active or not), so a long-idle slot's index would
        # otherwise run past its page table and its scatter lane could
        # alias another slot's live page (see models/layers.py)
        active_rows = np.array([r is not None for r in sched.slot_req])
        self.written[~active_rows] = 0
        resume = np.where(active_rows, sched.resume, 0).astype(np.int32)
        if self.alloc is not None:
            self._ensure_pages(self.ecfg.spec_window if spec else 1)
            pages = self.alloc.table_array()
            # defense in depth: scatter-writes below each slot's resume
            # point are rerouted to the garbage row on device, so even
            # a host-side COW bug cannot corrupt a shared cached prefix
            wfloor = resume
            self.peak_page_util = max(self.peak_page_util,
                                      self.alloc.utilization())
        # recompute after _ensure_pages: preemption may have idled slots
        active = np.array([r is not None for r in sched.slot_req])
        aids = self._slot_aids()
        self.max_active = max(self.max_active, int(active.sum()))
        if sched.has_chunk_work():
            tokens, lengths, fresh = sched.plan_chunk()
            logits, self.state = self._guarded(
                "prefill_chunk", lengths > 0, self.exe.prefill_chunk,
                self.state, tokens, lengths, fresh | ~active,
                resume, pages, wfloor, aids)
            self.written += lengths        # device: index += lengths
            self._prefill_consumed += int(lengths.sum())
            self._emit(sched.advance_chunk(lengths), logits)
        elif spec and active.any():
            self._spec_round(pages, wfloor)
        elif active.any():
            tokens, fresh = sched.plan_decode()
            logits, self.state = self._guarded(
                "decode_step", active, self.exe.decode_step,
                self.state, tokens, fresh | ~active,
                resume, pages, wfloor, aids)
            self.written += 1              # device: index += 1, all slots
            self._emit(sched.advance_decode(), logits)
        else:
            return 0
        sched.retire(self.written)
        return len([r for r in sched.slot_req if r is not None])

    def _progress_marker(self) -> Tuple[int, int, int]:
        return (self._tokens_committed, self._prefill_consumed,
                self.metrics.n_terminal)

    def step(self) -> int:
        """One engine step: advance the deterministic clock, enforce
        deadlines, admit, run one compiled step behind the fault
        boundary, recover from an aborted step, feed the watchdog.
        Returns the number of active slots after the step."""
        sched = self.sched
        sched.now_step = self.steps
        sched.enforce_deadlines()
        sched.admit()
        before = self._progress_marker()
        try:
            n_active = self._step_inner()
        except _StepAbort:
            self._recover()
            n_active = 0
        if self._progress_marker() != before:
            self._last_progress = self.steps
        elif (self.ecfg.watchdog_steps > 0 and sched.busy
              and self.steps - self._last_progress
              >= self.ecfg.watchdog_steps):
            self._watchdog_shed()
            self._last_progress = self.steps
        self.steps += 1
        return n_active

    def run(self, requests: List[Request], max_steps: int = 100000,
            ) -> List[Request]:
        for r in requests:
            self.submit(r)
        steps = 0
        while self.sched.busy and steps < max_steps:
            self.step()
            steps += 1
        return requests
