"""Batched serving engine with slot-based continuous batching.

The engine owns one decode-state tree (KV caches at the CLOVER-pruned
ranks r_qk/r_vo — the paper's memory win applies to every cached token)
with a fixed number of slots.  Requests are queued, admitted into free
slots, prefilled (one slot at a time, via the single-slot prefill jit),
then all active slots decode together in lockstep — the standard
continuous-batching scheme reduced to its JAX-friendly core: all shapes
static, per-slot progress tracked host-side.

Because prefill writes into a batch=1 view and decode runs the full slot
batch, the engine works unchanged on CPU (tests) and under a mesh with
sharded state (production: see launch/serve_demo example).
"""
from __future__ import annotations

import collections
import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as T

Params = Dict[str, Any]


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0            # 0 = greedy
    # filled by the engine:
    generated: List[int] = field(default_factory=list)
    done: bool = False


@dataclass(frozen=True)
class EngineConfig:
    slots: int = 4                      # concurrent sequences
    max_len: int = 512                  # KV capacity per slot
    eos_id: int = -1                    # -1: never stop on token


class Engine:
    def __init__(self, params: Params, cfg: ArchConfig, ecfg: EngineConfig,
                 rng: Optional[jax.Array] = None):
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.state = T.init_decode_state(cfg, ecfg.slots, ecfg.max_len)
        # per-slot positions: the decode state carries a (slots,) index
        # vector so slots at different depths coexist in one batch
        self.state["index"] = jnp.zeros((ecfg.slots,), jnp.int32)
        # per-slot host bookkeeping
        self.slot_req: List[Optional[Request]] = [None] * ecfg.slots
        self.slot_pos = np.zeros(ecfg.slots, np.int32)   # tokens written
        self.last_token = np.zeros(ecfg.slots, np.int32)
        self.queue: collections.deque = collections.deque()
        self._decode = jax.jit(
            lambda p, tok, st: T.decode_step(p, cfg, tok, st))
        self._prefill_len: Dict[int, Any] = {}

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _prefill_fn(self, length: int):
        """Length-bucketed jitted single-slot prefill."""
        if length not in self._prefill_len:
            cfg = self.cfg

            def fn(params, tokens, state, slot):
                # fresh (zero) slot state: stale KV is masked anyway, but
                # stale SSM/RWKV recurrent states would leak across
                # requests — prefill always starts from zeros.
                sub = jax.tree.map(
                    lambda a: jnp.zeros((a.shape[0], 1) + a.shape[2:],
                                        a.dtype)
                    if a.ndim >= 2 else a, state["blocks"])
                st1 = {"blocks": sub, "index": jnp.zeros((), jnp.int32)}
                logits, st1 = T.prefill(params, cfg, tokens, st1)
                merged = jax.tree.map(
                    lambda full, s: jax.lax.dynamic_update_slice_in_dim(
                        full, s.astype(full.dtype), slot, 1)
                    if full.ndim >= 2 else full,
                    state["blocks"], st1["blocks"])
                new_index = state["index"].at[slot].set(tokens.shape[1])
                return logits[0], {"blocks": merged, "index": new_index}
            self._prefill_len[length] = jax.jit(fn)
        return self._prefill_len[length]

    def _sample(self, logits: np.ndarray, temp: float) -> int:
        if temp <= 0:
            return int(np.argmax(logits))
        self.rng, k = jax.random.split(self.rng)
        return int(jax.random.categorical(k, jnp.asarray(logits) / temp))

    # ------------------------------------------------------------------
    def _admit(self):
        for s in range(self.ecfg.slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.popleft()
                L = len(req.prompt)
                assert L + req.max_new_tokens <= self.ecfg.max_len, \
                    "request exceeds KV capacity"
                fn = self._prefill_fn(L)
                logits, self.state = fn(
                    self.params, jnp.asarray(req.prompt)[None, :],
                    self.state, s)
                tok = self._sample(np.asarray(logits), req.temperature)
                req.generated.append(tok)
                self.slot_req[s] = req
                self.slot_pos[s] = L
                self.last_token[s] = tok

    def _retire(self):
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if (len(req.generated) >= req.max_new_tokens
                    or (self.ecfg.eos_id >= 0
                        and req.generated[-1] == self.ecfg.eos_id)):
                req.done = True
                self.slot_req[s] = None

    def step(self) -> int:
        """Admit + one lockstep decode over all active slots.
        Returns number of active slots after the step."""
        self._admit()
        active = [s for s, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        # one lockstep decode; each slot reads/writes at ITS index
        logits, self.state = self._decode(
            self.params, jnp.asarray(self.last_token), self.state)
        logits = np.asarray(logits)
        for s in active:
            req = self.slot_req[s]
            tok = self._sample(logits[s], req.temperature)
            req.generated.append(tok)
            self.last_token[s] = tok
            self.slot_pos[s] += 1
        self._retire()
        return len([r for r in self.slot_req if r is not None])

    def run(self, requests: List[Request], max_steps: int = 10000,
            ) -> List[Request]:
        for r in requests:
            self.submit(r)
        steps = 0
        while (self.queue or any(self.slot_req)) and steps < max_steps:
            self.step()
            steps += 1
        return requests
