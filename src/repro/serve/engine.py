"""Chunked-prefill continuous batching over CLOVER-rank KV caches.

The engine owns one decode-state tree (KV caches at the pruned ranks
r_qk/r_vo — the paper's memory win applies to every cached token) with a
fixed number of slots.  Each engine step every slot is either decoding
one token or consuming a fixed-size CHUNK of its prompt, so prefill
interleaves with decode instead of stalling it, and the whole engine
compiles exactly TWO step shapes regardless of the prompt-length mix:

  * chunk step  — (slots, C) tokens with per-slot valid lengths; each
    slot writes its window into its caches at its own offset.  Decoding
    slots ride along with length 1 (a chunk step of one valid token IS a
    decode step), so admission never stalls generation.
  * decode step — (slots,) one token per slot; the cheap shape used
    whenever no slot has prompt tokens left to chunk.

KV layout is either DENSE (``EngineConfig.paged=False``: per-slot
``(slots, capacity, KV, r)`` caches — every slot reserves full capacity
regardless of its actual length) or PAGED (``paged=True``: one global
pool ``(n_pages + 1, page_tokens, KV, r)`` per attention layer plus
host-side per-slot page tables, managed by ``PageAllocator``).  Paging
converts CLOVER's bytes-per-token win into CONCURRENCY: smaller rank ->
more tokens per page -> more resident sequences per HBM byte, so a pool
sized like a dense ``slots x max_len`` cache admits strictly more
simultaneous sequences when real lengths are shorter than max_len.
Admission is gated on free pages (not free slots), sequences grow
on demand during decode, and on pool exhaustion the YOUNGEST sequence is
preempted and requeued (its pages freed, its generated tokens folded
into the effective prompt so the greedy stream continues exactly on
re-admission) instead of crashing.  Both layouts compile the same two
step shapes; every paged result is checkable against the dense engine
token-for-token.

PAGED mode can additionally share pages ACROSS sequences
(``EngineConfig.prefix_cache``, DESIGN.md §9): a host-side trie
(``PrefixCache``) indexes full-page runs of finished / prefilled /
preempted sequences by their page-aligned token prefix, admission maps
the longest hit read-only into the new slot's table and resumes chunked
prefill at the first uncached token (TTFT collapses to one step on full
hits), and any write landing in a shared page copy-on-writes it first
(``kernels/page_copy.py``) so speculative rollback, preemption and
chunk padding can never mutate a page another sequence reads.  Because
CLOVER pruning makes each page denser in tokens, every shared
system-prompt page multiplies the rank win: the same pool bytes admit
strictly more concurrent sequences.

Scheduling policy lives in ``Scheduler``: admission from a FIFO queue
into free slots, per-slot phase tracking (PREFILL -> [TAIL ->] DECODE),
retirement on eos / max_new_tokens (freeing pages in paged mode).
Architectures with recurrent state (mamba / rwkv mixers or rwkv
channel-mix) cannot take padded windows — padding tokens would advance
their recurrent state — so for those the scheduler only chunks FULL
windows and feeds the remainder (< C prompt tokens) through decode steps
(TAIL phase); decoding slots hold during their chunk steps and their
states are merged back unchanged.

Everything is shape-static and works unchanged on CPU (tests) and under
a mesh with sharded state.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, MIXER_ATTN, MLP_RWKV
from repro.models import transformer as T

Params = Dict[str, Any]

# slot phases
PREFILL = "prefill"     # prompt tokens remain; consumed chunk-wise
TAIL = "tail"           # recurrent archs: < C prompt tokens remain,
                        # fed one-by-one through the decode step
DECODE = "decode"       # generating one token per engine step


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0            # 0 = greedy
    # filled by the engine:
    generated: List[int] = field(default_factory=list)
    done: bool = False
    # prefix-cache hit size at the LAST admission: prompt tokens whose
    # K/V came from shared pages (their prefill chunks were skipped)
    cached_tokens: int = 0
    # serving metrics (monotonic clock): submit time, one stamp per
    # emitted token (token_times[0] is first-token / end of prefill)
    t_submit: float = 0.0
    token_times: List[float] = field(default_factory=list)


@dataclass(frozen=True)
class EngineConfig:
    slots: int = 4                      # concurrent sequences
    max_len: int = 512                  # KV capacity per slot
    eos_id: int = -1                    # -1: never stop on token
    prefill_chunk: int = 64             # prompt tokens consumed per chunk step
    # -- paged KV cache (DESIGN.md §6) --------------------------------
    paged: bool = False                 # page the KV cache
    page_tokens: int = 8                # tokens per KV page
    # pool size in pages; 0 -> slots * ceil(capacity / page_tokens),
    # i.e. no memory pressure (every slot can reach full capacity).
    # Size it below that to overcommit: admission then gates on free
    # pages and exhaustion preempts the youngest sequence.
    n_pages: int = 0
    # -- automatic prefix caching (DESIGN.md §9, requires paged) ------
    # share KV pages across requests with a common page-aligned token
    # prefix (system prompts, few-shot templates, replayed chats): a
    # host-side trie indexes retired/prefilled full-page runs, admission
    # maps hits read-only and skips their prefill chunks, and writes
    # into a shared page copy-on-write it first (kernels/page_copy.py).
    # Attention-only architectures only (recurrent state is not
    # page-addressable).
    prefix_cache: bool = False
    # -- self-speculative decoding (DESIGN.md §8) ---------------------
    # 0 disables; k > 0: every pure-decode step, a rank-sliced DRAFT
    # pass over the SAME weights proposes k tokens per slot and one
    # (slots, k+1) verify step accepts a greedy prefix — up to k+1
    # tokens per step instead of 1.  Greedy streams stay exactly
    # token-identical to the non-speculative engine; requires an
    # attention-only architecture (recurrent state cannot roll back).
    spec_k: int = 0
    # fraction of every head's CURRENT rank the draft slices off (the
    # leading directions are kept — CLOVER's factors are sorted, so the
    # draft's cache view is literally cache[..., :r]; no second cache)
    draft_rank_ratio: float = 0.5

    @property
    def chunk(self) -> int:
        """Effective chunk size — the ONE clamp both the Scheduler's
        planning and the Engine's capacity/page-table sizing use."""
        return max(1, min(self.prefill_chunk, self.max_len))

    @property
    def spec_window(self) -> int:
        """Verify-step window width (pending token + k drafts)."""
        return self.spec_k + 1

    @property
    def capacity(self) -> int:
        """Per-slot KV capacity: max_len rounded up to a chunk multiple
        PLUS spare room, so every window write [index, index+W) with
        index <= max_len stays in bounds — dense dynamic_update_slice
        never clamps (a clamped write would shift backwards over valid
        history) and paged position->page lookups never fall off the
        table.  W is the chunk size or, with speculation on, the
        (k+1)-wide verify window whose rejected tail transiently
        overhangs the committed length.  The spare tail is beyond every
        causal horizon, hence never readable."""
        C = self.chunk
        spare = max(C, self.spec_window if self.spec_k > 0 else 1)
        return ((self.max_len + C - 1) // C * C
                + (spare + C - 1) // C * C)


class PageAllocator:
    """Refcounted free-list allocator over the global KV page pool.

    Host-side owner of the page tables for the device pools built by
    ``T.init_decode_state_paged``: ``n_pages`` real pages plus one spare
    garbage row (id ``sentinel == n_pages``) that un-allocated
    page-table entries address, so padded windows and idle slots write
    harmlessly off to the side instead of into another slot's pages.

    With prefix caching (DESIGN.md §9) a page can be referenced by
    several slot tables at once AND by the host-side prefix trie
    (``PrefixCache``): ``refcount[p]`` counts every such reference, and
    a page returns to the free list exactly when its count hits zero.
    Shared pages are read-only to their mappers; a slot that must write
    one first clones it (``cow``) and repoints its own table entry.

    Invariants (property-tested in tests/test_property.py):
      * refcounts are >= 0 and a page is free iff its count is 0;
      * no page is both on the free list and mapped/indexed anywhere;
      * ``free_pages + unique mapped-or-indexed pages == n_pages``;
      * ``ensure`` is all-or-nothing; ``release`` decrefs exactly the
        slot's pages (no double-free).
    """

    def __init__(self, n_pages: int, page_tokens: int, slots: int,
                 table_pages: int):
        assert n_pages >= 1 and page_tokens >= 1 and table_pages >= 1
        self.n_pages = n_pages
        self.page_tokens = page_tokens
        self.table_pages = table_pages          # static page-table width
        self.sentinel = n_pages                 # the garbage-sink row
        self.free_list: List[int] = list(range(n_pages))
        self.refcount: List[int] = [0] * n_pages
        self.tables: List[List[int]] = [[] for _ in range(slots)]

    @property
    def free_pages(self) -> int:
        return len(self.free_list)

    def used_pages(self) -> int:
        """UNIQUE pages in use (shared pages count once — the number
        actually unavailable to new sequences)."""
        return self.n_pages - len(self.free_list)

    def utilization(self) -> float:
        return self.used_pages() / max(1, self.n_pages)

    def pages_for(self, n_tokens: int) -> int:
        return -(-int(n_tokens) // self.page_tokens)

    # -- refcounting ---------------------------------------------------
    def _alloc_page(self) -> int:
        page = self.free_list.pop()
        assert self.refcount[page] == 0, page
        self.refcount[page] = 1
        return page

    def incref(self, page: int):
        assert 0 <= page < self.n_pages and self.refcount[page] > 0, \
            f"incref of unowned page {page}"
        self.refcount[page] += 1

    def decref(self, page: int) -> bool:
        """Drop one reference; True if the page was freed."""
        assert self.refcount[page] > 0, f"double free of page {page}"
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            self.free_list.append(page)
            return True
        return False

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot``'s table to cover positions [0, n_tokens);
        all-or-nothing.  Returns False on pool exhaustion (caller
        evicts/preempts) or if the static table width would overflow."""
        want = self.pages_for(n_tokens)
        need = want - len(self.tables[slot])
        if need <= 0:
            return True
        if need > len(self.free_list) or want > self.table_pages:
            return False
        for _ in range(need):
            self.tables[slot].append(self._alloc_page())
        return True

    def map_shared(self, slot: int, pages: List[int]) -> bool:
        """Append already-owned pages (a prefix-trie hit) READ-ONLY to
        the end of ``slot``'s table; each gains one reference.  The
        mapper must never scatter into them without ``cow`` first."""
        if len(self.tables[slot]) + len(pages) > self.table_pages:
            return False
        for p in pages:
            self.incref(p)
            self.tables[slot].append(p)
        return True

    def cow(self, slot: int, idx: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write fault on table entry ``idx``: if the page is
        shared, allocate a fresh page, repoint the slot's entry and
        drop its reference on the old one.  Returns (src, dst) for the
        caller's device-side content copy, or None when the page was
        exclusively owned (no copy needed).  Caller must check
        ``free_pages`` first; raises on an empty pool."""
        old = self.tables[slot][idx]
        if self.refcount[old] == 1:
            return None
        new = self._alloc_page()
        self.tables[slot][idx] = new
        self.decref(old)
        return (old, new)

    def release(self, slot: int) -> int:
        """Drop the slot's reference on all of its pages.  Returns the
        number of pages unmapped (shared pages survive via their other
        references — e.g. the prefix trie's)."""
        pages = self.tables[slot]
        self.tables[slot] = []
        for p in pages:
            self.decref(p)
        return len(pages)

    def table_array(self) -> np.ndarray:
        """(slots, table_pages) int32 device view; sentinel-padded."""
        t = np.full((len(self.tables), self.table_pages), self.sentinel,
                    np.int32)
        for s, pages in enumerate(self.tables):
            t[s, :len(pages)] = pages
        return t


class PrefixCache:
    """Host-side radix index over PAGE-ALIGNED token prefixes
    (DESIGN.md §9) — automatic prefix caching for the paged engine.

    Each node covers exactly one full KV page: the node for the first
    ``i`` pages of a token stream is keyed on ``(salt, stream[: i *
    page_tokens])``, and holds the pool page whose K/V encode those
    ``page_tokens`` positions given the preceding prefix.  ``salt``
    folds in the model's rank plan (prune ratio / CLOVER ranks / page
    size), so caches produced under different pruning never alias even
    if the engine were rebuilt over the same allocator.

    The trie holds one reference on every indexed page (see
    ``PageAllocator``).  ``match`` walks the longest cached run for a
    prompt and bumps each node's LRU clock; ``insert`` publishes a
    finished/preempted/prefilled sequence's full-page run (first writer
    wins — an existing node keeps its page); ``evict`` reclaims LRU
    leaf nodes whose page no slot maps (refcount == 1: only the trie's
    own reference is left).
    """

    def __init__(self, alloc: PageAllocator, salt: Tuple = ()):
        self.alloc = alloc
        self.pt = alloc.page_tokens
        # the salt IS the root: two caches with different rank plans
        # have disjoint key spaces from the first page on
        self._root = ("root", salt)
        # radix keying: (parent node id, this page's pt tokens) -> node
        # {"id", "page", "clock", "children", "parent_key"} — each walk
        # step hashes ONE page of tokens, so match/insert are O(L), not
        # O(L^2) re-serializations of the whole prefix per depth
        self.nodes: Dict[tuple, dict] = {}
        self._next_id = 1
        self._clock = 0
        self.inserted = 0
        self.evicted = 0

    def _chunk(self, tokens: np.ndarray, i: int) -> bytes:
        """Page ``i``'s token content (0-based), as a hashable key."""
        return np.asarray(tokens[i * self.pt:(i + 1) * self.pt],
                          np.int32).tobytes()

    def __len__(self) -> int:
        return len(self.nodes)

    def pages(self) -> set:
        return {n["page"] for n in self.nodes.values()}

    def match(self, tokens: np.ndarray) -> List[int]:
        """Longest cached page run that is a prefix of ``tokens``.
        Returns the page ids in position order (possibly empty) and
        LRU-touches every node on the path."""
        self._clock += 1
        pages: List[int] = []
        parent = self._root
        for i in range(len(tokens) // self.pt):
            node = self.nodes.get((parent, self._chunk(tokens, i)))
            if node is None:
                break
            node["clock"] = self._clock
            pages.append(node["page"])
            parent = node["id"]
        return pages

    def insert(self, tokens: np.ndarray, pages: List[int]):
        """Publish a full-page run: page ``i`` holds K/V for positions
        [i*pt, (i+1)*pt) of ``tokens``.  Existing nodes win (their page
        stays; the duplicate remains the caller's private copy)."""
        n = min(len(tokens) // self.pt, len(pages))
        self._clock += 1
        parent_id, parent_key = self._root, None
        for i in range(n):
            key = (parent_id, self._chunk(tokens, i))
            node = self.nodes.get(key)
            if node is None:
                self.alloc.incref(pages[i])
                node = {"id": self._next_id, "page": pages[i],
                        "clock": self._clock, "children": 0,
                        "parent_key": parent_key}
                self._next_id += 1
                self.nodes[key] = node
                if parent_key is not None:
                    self.nodes[parent_key]["children"] += 1
                self.inserted += 1
            else:
                node["clock"] = self._clock
            parent_id, parent_key = node["id"], key

    def evict(self, n_pages: int) -> int:
        """Free up to ``n_pages`` pool pages by dropping LRU LEAF nodes
        nobody maps (page refcount == 1).  Leaf-first keeps every
        surviving node's prefix path intact.  One scan builds the
        clock-ordered candidate list; a parent whose last child is
        dropped re-enters consideration within the same call."""
        freed = 0
        candidates = sorted(
            (k for k, nd in self.nodes.items()
             if nd["children"] == 0
             and self.alloc.refcount[nd["page"]] == 1),
            key=lambda k: self.nodes[k]["clock"], reverse=True)
        while freed < n_pages and candidates:
            key = candidates.pop()
            node = self.nodes.get(key)
            if (node is None or node["children"] != 0
                    or self.alloc.refcount[node["page"]] != 1):
                continue            # state moved under us: re-derived
            self.nodes.pop(key)
            pk = node["parent_key"]
            if pk is not None and pk in self.nodes:
                parent = self.nodes[pk]
                parent["children"] -= 1
                if (parent["children"] == 0
                        and self.alloc.refcount[parent["page"]] == 1):
                    # keep clock order: parents are older than the
                    # children that just left, append-then-sort is
                    # overkill for the one element — insert at the end
                    # (oldest side) of the reversed list
                    candidates.append(pk)
                    candidates.sort(
                        key=lambda k: self.nodes[k]["clock"],
                        reverse=True)
            self.alloc.decref(node["page"])
            self.evicted += 1
            freed += 1
        return freed


class Scheduler:
    """Admission / chunking / preemption / retirement policy with
    per-slot phases.

    Host-side bookkeeping only — the device sees nothing but the two
    fixed step shapes the engine compiles.  With a ``PageAllocator``
    (paged mode) admission is gated on free pages for the effective
    prompt, retirement frees pages, and ``preempt`` requeues a sequence
    at the queue head with its generated tokens folded into the
    effective prompt (greedy continuation is exact).

    With a ``PrefixCache`` (paged + ``EngineConfig.prefix_cache``)
    admission additionally matches the longest cached page-aligned
    prefix of the effective prompt, maps those pages READ-ONLY into the
    slot's table and resumes chunked prefill at the first uncached
    token (``resume``); prefill completion / preemption / retirement
    publish the sequence's full-page run back into the trie so later
    requests (including the preempted sequence itself) skip the
    redundant prefill compute.
    """

    def __init__(self, ecfg: EngineConfig, recurrent: bool,
                 allocator: Optional[PageAllocator] = None,
                 prefix: Optional["PrefixCache"] = None):
        self.ecfg = ecfg
        self.chunk = ecfg.chunk
        self.recurrent = recurrent
        self.alloc = allocator
        self.prefix = prefix
        self.queue: collections.deque = collections.deque()
        n = ecfg.slots
        self.slot_req: List[Optional[Request]] = [None] * n
        # effective prompt per slot: the request's prompt plus any
        # tokens generated before a preemption (greedy continuation)
        self.slot_prompt: List[Optional[np.ndarray]] = [None] * n
        self.phase: List[Optional[str]] = [None] * n
        self.pos = np.zeros(n, np.int64)        # prompt tokens consumed
        self.fresh = np.zeros(n, bool)          # needs state reset
        self.last_token = np.zeros(n, np.int32)
        self.slot_seq = np.zeros(n, np.int64)   # admission order (age)
        # prefix-cache resume point per slot: the first position THIS
        # tenure writes (0 without a hit).  Positions below it are
        # served by read-only shared pages.
        self.resume = np.zeros(n, np.int64)
        self._admit_counter = 0
        self.preemptions = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0

    # -- admission -----------------------------------------------------
    def submit(self, req: Request):
        req.t_submit = time.monotonic()
        self.queue.append(req)

    def admit(self):
        for s in range(self.ecfg.slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue[0]
                eff = (req.prompt if not req.generated else
                       np.concatenate([np.asarray(req.prompt, np.int32),
                                       np.asarray(req.generated, np.int32)]))
                L = len(eff)
                remaining = req.max_new_tokens - len(req.generated)
                assert L > 0, "empty prompt"
                assert L + remaining <= self.ecfg.max_len, \
                    "request exceeds KV capacity"
                resume = 0
                if self.alloc is not None:
                    # speculative verify windows transiently overhang
                    # the committed length by up to spec_k tokens
                    slack = self.ecfg.spec_k
                    assert (self.alloc.pages_for(L + remaining + slack)
                            <= self.alloc.n_pages), \
                        "request exceeds page pool"
                    if self.prefix is not None:
                        pages = self.prefix.match(eff)
                        if pages and self.alloc.map_shared(s, pages):
                            # at least one token must remain to prefill
                            # (its logits seed generation); a FULL hit
                            # resumes at L-1 and the rewrite of that
                            # position COWs the shared last page
                            pt = self.alloc.page_tokens
                            resume = min(len(pages) * pt, L - 1)
                    ok = self.alloc.ensure(s, L)
                    if not ok and self.prefix is not None:
                        # cached-but-idle prefixes are reclaimable
                        # bytes: evict LRU trie pages nobody maps and
                        # retry (matched pages are slot-mapped now, so
                        # eviction can never touch THIS hit)
                        short = (self.alloc.pages_for(L)
                                 - len(self.alloc.tables[s])
                                 - self.alloc.free_pages)
                        if short > 0 and self.prefix.evict(short) > 0:
                            ok = self.alloc.ensure(s, L)
                    if not ok:
                        # FIFO head-of-line: wait for pages (undo the
                        # shared mapping so the trie can evict them)
                        self.alloc.release(s)
                        break
                self.queue.popleft()
                req.cached_tokens = resume
                if resume > 0:
                    self.prefix_hits += 1
                    self.prefix_hit_tokens += resume
                self.slot_req[s] = req
                self.slot_prompt[s] = eff
                self.pos[s] = resume
                self.resume[s] = resume
                self.fresh[s] = True
                self.slot_seq[s] = self._admit_counter
                self._admit_counter += 1
                self.phase[s] = self._prefill_phase(L, resume)

    def _prefill_phase(self, L: int, pos: int) -> str:
        if self.recurrent and L - pos < self.chunk:
            return TAIL          # padded window would corrupt state
        return PREFILL

    # -- planning ------------------------------------------------------
    def has_chunk_work(self) -> bool:
        return any(p == PREFILL for p in self.phase)

    def planned_writes(self, decode_width: int = 1) -> np.ndarray:
        """(slots,) KV positions the NEXT step will write per active
        slot — what must be page-covered before the step runs.  TAIL
        and PREFILL writes always land inside the prompt coverage
        allocated at admission; only decode growth can demand pages.
        ``decode_width`` > 1 is a speculative round: every decoding
        slot writes a (k+1)-wide draft+verify window."""
        n, C = self.ecfg.slots, self.chunk
        take = np.zeros(n, np.int64)
        chunk_step = self.has_chunk_work()
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if chunk_step:
                if self.phase[s] == PREFILL:
                    take[s] = min(C, len(self.slot_prompt[s])
                                  - int(self.pos[s]))
                elif self.phase[s] == DECODE and not self.recurrent:
                    take[s] = 1
            else:
                take[s] = decode_width
        return take

    def plan_chunk(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Build the (slots, C) window batch.  PREFILL slots consume up
        to C prompt tokens (recurrent archs: exactly C — guaranteed by
        the phase); DECODE slots ride with length 1 on attention-only
        archs; everything else idles with length 0."""
        n, C = self.ecfg.slots, self.chunk
        tokens = np.zeros((n, C), np.int32)
        lengths = np.zeros(n, np.int32)
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if self.phase[s] == PREFILL:
                prompt = self.slot_prompt[s]
                take = min(C, len(prompt) - int(self.pos[s]))
                tokens[s, :take] = prompt[self.pos[s]:self.pos[s] + take]
                lengths[s] = take
            elif self.phase[s] == DECODE and not self.recurrent:
                tokens[s, 0] = self.last_token[s]
                lengths[s] = 1
        fresh = self.fresh & (lengths > 0)
        self.fresh &= ~fresh
        return tokens, lengths, fresh

    def plan_decode(self) -> Tuple[np.ndarray, np.ndarray]:
        """One token per slot: TAIL slots feed their next prompt token,
        DECODE slots their last sampled token."""
        n = self.ecfg.slots
        tokens = np.zeros(n, np.int32)
        active = np.zeros(n, bool)
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            active[s] = True
            if self.phase[s] == TAIL:
                tokens[s] = self.slot_prompt[s][self.pos[s]]
            else:
                tokens[s] = self.last_token[s]
        fresh = self.fresh & active
        self.fresh &= ~fresh
        return tokens, fresh

    # -- post-step transitions ----------------------------------------
    def advance_chunk(self, lengths: np.ndarray) -> List[int]:
        """Apply a chunk step's progress.  Returns slots whose logits
        row is a real next-token distribution to sample from."""
        sample = []
        for s, req in enumerate(self.slot_req):
            if req is None or lengths[s] == 0:
                continue
            if self.phase[s] == PREFILL:
                self.pos[s] += int(lengths[s])
                if self.pos[s] == len(self.slot_prompt[s]):
                    self.phase[s] = DECODE
                    # the prompt's K/V is fully written: publish its
                    # full-page run so CONCURRENT requests with the
                    # same prefix already share it
                    self._publish(s, len(self.slot_prompt[s]))
                    sample.append(s)
                else:
                    self.phase[s] = self._prefill_phase(
                        len(self.slot_prompt[s]), int(self.pos[s]))
            else:                                   # riding decode slot
                sample.append(s)
        return sample

    def advance_decode(self) -> List[int]:
        sample = []
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if self.phase[s] == TAIL:
                self.pos[s] += 1
                if self.pos[s] == len(self.slot_prompt[s]):
                    self.phase[s] = DECODE
                    sample.append(s)
            else:
                sample.append(s)
        return sample

    # -- preemption / retirement --------------------------------------
    def _publish(self, s: int, n_valid: int):
        """Publish slot ``s``'s first ``n_valid`` cached positions (its
        committed K/V) into the prefix trie, rounded DOWN to full
        pages.  Keyed on the sequence's actual token stream (prompt +
        generated) — content-addressed, so it is correct for any
        sampling temperature and any preemption history."""
        if self.prefix is None:
            return
        req = self.slot_req[s]
        stream = np.asarray(req.prompt, np.int32)
        if req.generated:
            stream = np.concatenate(
                [stream, np.asarray(req.generated, np.int32)])
        n_full = int(n_valid) // self.alloc.page_tokens
        if n_full > 0:
            self.prefix.insert(stream, self.alloc.tables[s][:n_full])

    def preempt(self, s: int, n_valid: int = 0):
        """Release slot ``s`` (decref its pages) and requeue its request
        at the queue HEAD.  Generated tokens are kept on the request;
        they join the effective prompt on re-admission, so the
        re-prefill reproduces the stream exactly and generation
        continues from where it stopped.  With a prefix cache the
        committed full-page run (``n_valid`` positions) is published
        first, so re-admission resumes from the trie instead of
        re-prefilling — pages are decref'd, not freed."""
        req = self.slot_req[s]
        assert req is not None
        if self.alloc is not None:
            self._publish(s, n_valid)
            self.alloc.release(s)
        self.slot_req[s] = None
        self.slot_prompt[s] = None
        self.phase[s] = None
        self.queue.appendleft(req)
        self.preemptions += 1

    def retire(self, written: Optional[np.ndarray] = None):
        """Retire finished DECODE slots.  ``written`` (engine's host
        mirror of per-slot committed cache lengths) bounds what the
        prefix trie may index on retirement."""
        for s, req in enumerate(self.slot_req):
            if req is None or self.phase[s] != DECODE:
                continue
            if (len(req.generated) >= req.max_new_tokens
                    or (self.ecfg.eos_id >= 0 and req.generated
                        and req.generated[-1] == self.ecfg.eos_id)):
                req.done = True
                if self.alloc is not None:
                    if written is not None:
                        self._publish(s, int(written[s]))
                    self.alloc.release(s)
                self.slot_req[s] = None
                self.slot_prompt[s] = None
                self.phase[s] = None

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_req)


def greedy_reference(params: Params, cfg: ArchConfig, prompt,
                     n: int) -> List[int]:
    """Isolated whole-prompt greedy decode via the full forward pass —
    the exactness oracle engine streams are checked against (chunked
    prefill must reproduce it token-for-token)."""
    seq = list(prompt)
    gen = []
    for _ in range(n):
        logits, _ = T.forward(params, cfg, jnp.asarray(seq)[None, :])
        tok = int(jnp.argmax(logits[0, -1]))
        gen.append(tok)
        seq.append(tok)
    return gen


def _is_recurrent(cfg: ArchConfig) -> bool:
    return any(mixer != MIXER_ATTN or mlp == MLP_RWKV
               for mixer, mlp in cfg.pattern)


def _mask_like(flags: jnp.ndarray, leaf: jnp.ndarray) -> jnp.ndarray:
    """(B,) bool -> broadcastable to a stacked state leaf (nb, B, ...)."""
    return flags.reshape((1, flags.shape[0]) + (1,) * (leaf.ndim - 2))


def _is_kv(path) -> bool:
    return any(getattr(p, "key", None) == "kv" for p in path)


def _reset_fresh(state: Params, fresh: jnp.ndarray,
                 resume: jnp.ndarray) -> Params:
    """Zero recurrent state of freshly admitted slots and set their
    index to ``resume`` (0 normally; the first uncached position on a
    prefix-cache hit — the cached prefix's K/V is already present in
    the slot's read-only shared pages).  KV caches keep their stale
    contents — masked by the per-slot index (dense: the slot's own
    region; paged: freshly allocated pages hold a previous owner's
    data, masked until overwritten by the new one)."""

    def z(path, leaf):
        if _is_kv(path):
            return leaf
        return jnp.where(_mask_like(fresh, leaf), jnp.zeros_like(leaf), leaf)

    return {"blocks": jax.tree_util.tree_map_with_path(z, state["blocks"]),
            "index": jnp.where(fresh, resume, state["index"])}


def _merge_inactive(old_blocks, new_blocks, active: jnp.ndarray):
    """Keep inactive slots' recurrent state across a chunk step (their
    padded garbage window must not advance it).  KV caches are taken
    wholesale: inactive slots' garbage writes land at [index, index+C),
    which is either masked (beyond each slot's causal horizon),
    overwritten by that slot's own future writes before it becomes
    readable, or (paged) routed via sentinel table entries into the
    pool's garbage row."""

    def sel(path, old, new):
        if _is_kv(path):
            return new
        return jnp.where(_mask_like(active, old), new, old)

    return jax.tree_util.tree_map_with_path(sel, old_blocks, new_blocks)


class Engine:
    def __init__(self, params: Params, cfg: ArchConfig, ecfg: EngineConfig,
                 rng: Optional[jax.Array] = None):
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        cap = ecfg.capacity        # see EngineConfig.capacity
        if ecfg.paged:
            pt = ecfg.page_tokens
            table_pages = (cap + pt - 1) // pt
            n_pages = ecfg.n_pages or ecfg.slots * table_pages
            self.alloc: Optional[PageAllocator] = PageAllocator(
                n_pages, pt, ecfg.slots, table_pages)
            self.state = T.init_decode_state_paged(cfg, ecfg.slots,
                                                   n_pages, pt)
        else:
            self.alloc = None
            self.state = T.init_decode_state(cfg, ecfg.slots, cap)
            # per-slot positions: (slots,) index vector so slots at
            # different depths coexist in one batch
            self.state["index"] = jnp.zeros((ecfg.slots,), jnp.int32)
        recurrent = _is_recurrent(cfg)
        if ecfg.spec_k > 0 and recurrent:
            raise ValueError(
                "speculative decoding requires an attention-only "
                "architecture: recurrent (mamba/rwkv) state cannot roll "
                "back rejected draft tokens")
        self.prefix: Optional[PrefixCache] = None
        if ecfg.prefix_cache:
            if not ecfg.paged:
                raise ValueError("prefix_cache requires paged=True: only "
                                 "pages can be shared across sequences")
            if recurrent:
                raise ValueError(
                    "prefix caching requires an attention-only "
                    "architecture: recurrent (mamba/rwkv) state is not "
                    "page-addressable, so a cached page run cannot "
                    "reconstruct it")
            # the trie key folds in the rank plan: caches produced under
            # a different prune ratio / CLOVER rank / page size must
            # never alias (their K/V live in a different basis)
            salt = (cfg.name, cfg.qk_dim, cfg.vo_dim, cfg.clover.enabled,
                    cfg.clover.qk_rank, cfg.clover.vo_rank,
                    ecfg.page_tokens)
            self.prefix = PrefixCache(self.alloc, salt=salt)
        self.sched = Scheduler(ecfg, recurrent, self.alloc, self.prefix)
        # host mirror of state["index"] (tokens written per slot this
        # tenure) — drives page coverage without device round-trips
        self.written = np.zeros(ecfg.slots, np.int64)
        # serving stats
        self.max_active = 0
        self.peak_page_util = 0.0
        # speculative-decoding stats: emitted-tokens-per-round histogram
        # {n_emitted: rounds} — mean > 1.0 is the wall-clock win
        self.spec_rounds = 0
        self.accept_hist: Dict[int, int] = collections.defaultdict(int)

        def chunk_fn(params, tokens, lengths, fresh, resume, pages, wfloor,
                     state):
            st = _reset_fresh(state, fresh, resume)
            logits, new = T.prefill_chunk(params, cfg, tokens, st, lengths,
                                          pages=pages, write_floor=wfloor)
            blocks = _merge_inactive(st["blocks"], new["blocks"],
                                     lengths > 0)
            return logits, {"blocks": blocks, "index": new["index"]}

        def decode_fn(params, tok, fresh, resume, pages, wfloor, state):
            return T.decode_step(params, cfg, tok,
                                 _reset_fresh(state, fresh, resume),
                                 pages=pages, write_floor=wfloor)

        self._chunk = jax.jit(chunk_fn)
        self._decode = jax.jit(decode_fn)
        # batched page-content clone backing copy-on-write faults: the
        # ONE extra compiled shape prefix caching adds (a no-op without
        # it — compiled_shapes() counts it only once it runs)
        kimpl = (cfg.kernel_impl
                 if cfg.kernel_impl in ("pallas", "interpret") else "ref")

        def copy_fn(blocks, src, dst):
            from repro.kernels import ops as kops

            def cp(path, leaf):
                if _is_kv(path):
                    return kops.page_copy(leaf, src, dst, impl=kimpl)
                return leaf

            return jax.tree_util.tree_map_with_path(cp, blocks)

        self._copy = jax.jit(copy_fn) if ecfg.paged else None
        self._draft = self._verify = None
        if ecfg.spec_k > 0:
            from repro.core.prune import draft_ranks
            dr = draft_ranks(cfg, ecfg.draft_rank_ratio)
            # full-width "draft" degenerates to the exact model — skip
            # the slicing so XLA compiles the identical program
            self.draft_rank = (None if dr == (cfg.qk_dim, cfg.vo_dim)
                               else dr)

            def draft_fn(params, tok, pages, wfloor, state):
                return T.decode_step(params, cfg, tok, state, pages=pages,
                                     write_floor=wfloor,
                                     draft_rank=self.draft_rank)

            def verify_fn(params, tokens, lengths, pages, wfloor, state):
                return T.verify_chunk(params, cfg, tokens, state, lengths,
                                      pages=pages, write_floor=wfloor)

            self._draft = jax.jit(draft_fn)
            self._verify = jax.jit(verify_fn)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.sched.submit(req)

    def compiled_shapes(self) -> Optional[int]:
        """Total jit cache entries across all step functions — the
        engine's contract is that this never exceeds 2 without
        speculation (dense AND paged: the page table is shape-static),
        4 with it (one draft shape + one verify shape on top), plus at
        most 1 for the fixed-width page-copy batch once a prefix-cache
        copy-on-write fault has fired.  Returns None if the jit cache
        isn't introspectable (private API drift)."""
        fns = [f for f in (self._chunk, self._decode, self._copy,
                           self._draft, self._verify) if f is not None]
        sizes = [getattr(f, "_cache_size", None) for f in fns]
        if any(s is None for s in sizes):
            return None
        return sum(s() for s in sizes)

    def _sample(self, logits: np.ndarray, temp: float) -> int:
        if temp <= 0:
            return int(np.argmax(logits))
        self.rng, k = jax.random.split(self.rng)
        return int(jax.random.categorical(k, jnp.asarray(logits) / temp))

    def _emit(self, slots: List[int], logits: np.ndarray):
        now = time.monotonic()
        for s in slots:
            req = self.sched.slot_req[s]
            tok = self._sample(logits[s], req.temperature)
            req.generated.append(tok)
            req.token_times.append(now)
            self.sched.last_token[s] = tok

    # -- paged page-coverage / COW / preemption ------------------------
    def _cover_writes(self, s: int, take_s: int, pairs: List) -> bool:
        """Page-cover slot ``s``'s next write window [written, written +
        take) AND copy-on-write any SHARED page inside it (a prefix-hit
        resume rewriting the last cached position, or any future writer
        of a trie-indexed page): the page content is cloned into a
        fresh page (``pairs`` collects the (src, dst) device copies)
        and the slot's table repointed, so the shared original — and
        every other sequence reading it — is never mutated.  False ->
        the pool is exhausted mid-way; caller reclaims and retries
        (partial progress is safe: completed COWs stay valid)."""
        alloc = self.alloc
        if take_s <= 0:
            return True
        start = int(self.written[s])
        end = start + take_s
        if not alloc.ensure(s, end):
            return False
        if self.prefix is None:
            return True         # sharing is impossible without the trie
        pt = alloc.page_tokens
        for idx in range(start // pt, (end - 1) // pt + 1):
            page = alloc.tables[s][idx]
            if alloc.refcount[page] > 1:
                if not alloc.free_pages:
                    return False
                pairs.append(alloc.cow(s, idx))
        return True

    def _copy_pages(self, pairs: List[Tuple[int, int]]):
        """Clone page contents src -> dst across every layer's pools in
        fixed-width batches (ONE compiled shape; short batches pad with
        sentinel->sentinel self-copies).  Pairs execute in list order —
        a page freed after serving as a src may be reallocated as a
        later dst, never the reverse, so in-order is always correct."""
        W = max(1, self.ecfg.slots)
        snt = self.alloc.sentinel
        for i in range(0, len(pairs), W):
            batch = list(pairs[i:i + W])
            batch += [(snt, snt)] * (W - len(batch))
            src = jnp.asarray([p[0] for p in batch], jnp.int32)
            dst = jnp.asarray([p[1] for p in batch], jnp.int32)
            self.state["blocks"] = self._copy(self.state["blocks"],
                                              src, dst)

    def _ensure_pages(self, decode_width: int = 1):
        """Cover every active slot's upcoming writes with pages (COW
        faults included), oldest sequence first (the FIFO head has page
        priority).  On pool exhaustion the reclaim ladder is: evict LRU
        unmapped prefix-cache pages first (cached-but-idle prefixes are
        the cheapest bytes to drop), then preempt-and-requeue the
        YOUNGEST active sequence (vLLM-style) and retry, instead of
        crashing mid-trace."""
        sched, alloc = self.sched, self.alloc
        take = sched.planned_writes(decode_width)
        order = sorted((s for s in range(self.ecfg.slots)
                        if sched.slot_req[s] is not None),
                       key=lambda s: sched.slot_seq[s])
        pairs: List[Tuple[int, int]] = []
        for s in order:
            while sched.slot_req[s] is not None:
                if self._cover_writes(s, int(take[s]), pairs):
                    break
                # batched shortfall: coverage may be short several
                # pages (a COW fault on top needs at most one more)
                short = max(1, alloc.pages_for(
                    int(self.written[s] + take[s]))
                    - len(alloc.tables[s]) - alloc.free_pages + 1)
                if self.prefix is not None and self.prefix.evict(short):
                    continue
                victims = [v for v in range(self.ecfg.slots)
                           if sched.slot_req[v] is not None]
                victim = max(victims, key=lambda v: sched.slot_seq[v])
                if victim == s and len(victims) == 1:
                    # admission guarantees a lone sequence always fits
                    raise RuntimeError(
                        f"page pool exhausted: slot {s} needs "
                        f"{alloc.pages_for(int(self.written[s] + take[s]))}"
                        f" pages, pool has {alloc.n_pages}")
                sched.preempt(victim, n_valid=int(self.written[victim]))
        if pairs:
            self._copy_pages(pairs)

    # -- speculative round (DESIGN.md §8) ------------------------------
    def _spec_due(self) -> bool:
        """A speculative round replaces the plain decode step when the
        engine has a draft, no slot has prompt tokens left to chunk,
        and every active request is greedy (the acceptance rule below
        is exact only for argmax sampling)."""
        sched = self.sched
        if self._draft is None or sched.has_chunk_work():
            return False
        reqs = [r for r in sched.slot_req if r is not None]
        return bool(reqs) and all(r.temperature <= 0 for r in reqs)

    def _spec_round(self, pages) -> None:
        """One speculative round over all active slots (all in DECODE):
        the rank-sliced DRAFT pass proposes ``k`` tokens per slot
        autoregressively, then ONE (slots, k+1) verify window scores
        every position with the full model.  Each slot commits its
        longest draft prefix matching the full model's argmaxes plus
        the bonus token — between 1 and k+1 tokens, never diverging
        from the non-speculative greedy stream — and the per-slot index
        rolls back over the rejected tail (dense and paged alike this
        is a pure length decrement: rejected K/V sits beyond every
        causal horizon until overwritten, the invariant padded chunk
        writes already rely on)."""
        sched, ecfg = self.sched, self.ecfg
        k, W = ecfg.spec_k, ecfg.spec_window
        slots = ecfg.slots
        active = np.array([r is not None for r in sched.slot_req])
        n0 = self.written.copy()
        # draft k tokens; the draft's K/V writes land in the shared
        # cache but its state is DISCARDED — the verify step below
        # rewrites all k+1 positions at full rank from the pre-draft
        # state, so nothing the draft wrote is ever read by the model
        tok = sched.last_token.copy()
        drafts = np.zeros((slots, k), np.int32)
        dstate = self.state
        wfloor = (jnp.asarray(sched.resume.astype(np.int32))
                  if self.alloc is not None else None)
        for j in range(k):
            logits, dstate = self._draft(self.params, jnp.asarray(tok),
                                         pages, wfloor, dstate)
            tok = np.argmax(np.asarray(logits), axis=-1).astype(np.int32)
            drafts[:, j] = tok
        tokens = np.zeros((slots, W), np.int32)
        tokens[:, 0] = sched.last_token        # pending, not yet cached
        tokens[:, 1:] = drafts
        lengths = np.where(active, W, 0).astype(np.int32)
        logits, self.state = self._verify(
            self.params, jnp.asarray(tokens), jnp.asarray(lengths), pages,
            wfloor, self.state)
        targets = np.argmax(np.asarray(logits), axis=-1)       # (slots, W)
        now = time.monotonic()
        self.spec_rounds += 1
        for s in range(slots):
            if not active[s]:
                continue
            req = sched.slot_req[s]
            a = 0
            while a < k and drafts[s, a] == targets[s, a]:
                a += 1
            out = [int(t) for t in drafts[s, :a]] + [int(targets[s, a])]
            # honor max_new_tokens / eos exactly as the one-token path
            # would have: anything past the stop point is dropped (the
            # slot retires this step, so the over-committed cache tail
            # is unreachable)
            out = out[:req.max_new_tokens - len(req.generated)]
            if ecfg.eos_id >= 0 and ecfg.eos_id in out:
                out = out[:out.index(ecfg.eos_id) + 1]
            for t in out:
                req.generated.append(t)
                req.token_times.append(now)
            self.accept_hist[len(out)] += 1
            sched.last_token[s] = targets[s, a]
            self.written[s] = n0[s] + a + 1
        # roll back: commit per-slot lengths (idle slots advanced by 0)
        self.state["index"] = jnp.asarray(self.written.astype(np.int32))

    @property
    def accepted_per_round(self) -> float:
        """Mean tokens emitted per speculative slot-round (>= 1.0;
        1.0 = nothing ever accepted, k+1 = every draft accepted)."""
        n = sum(self.accept_hist.values())
        return (sum(a * c for a, c in self.accept_hist.items()) / n
                if n else 0.0)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Admit + one chunk, decode, or speculative step over all
        slots.  Returns the number of active slots after the step."""
        sched = self.sched
        sched.admit()
        spec = self._spec_due()
        pages = wfloor = None
        # newly admitted slots restart their tenure at their resume
        # point — 0, or the first uncached position on a prefix hit
        # (the device index follows via _reset_fresh at plan time; the
        # host mirror drives page coverage, COW detection AND the
        # speculative rollback's index commit)
        for s in range(self.ecfg.slots):
            if sched.slot_req[s] is not None and sched.fresh[s]:
                self.written[s] = int(sched.resume[s])
        resume = jnp.asarray(sched.resume.astype(np.int32))
        if self.alloc is not None:
            self._ensure_pages(self.ecfg.spec_window if spec else 1)
            pages = jnp.asarray(self.alloc.table_array())
            # defense in depth: scatter-writes below each slot's resume
            # point are rerouted to the garbage row on device, so even
            # a host-side COW bug cannot corrupt a shared cached prefix
            wfloor = resume
            self.peak_page_util = max(self.peak_page_util,
                                      self.alloc.utilization())
        self.max_active = max(self.max_active, len(
            [r for r in sched.slot_req if r is not None]))
        if sched.has_chunk_work():
            tokens, lengths, fresh = sched.plan_chunk()
            logits, self.state = self._chunk(
                self.params, jnp.asarray(tokens), jnp.asarray(lengths),
                jnp.asarray(fresh), resume, pages, wfloor, self.state)
            self.written += lengths        # device: index += lengths
            self._emit(sched.advance_chunk(lengths), np.asarray(logits))
        elif spec and any(r is not None for r in sched.slot_req):
            self._spec_round(pages)
        elif any(r is not None for r in sched.slot_req):
            tokens, fresh = sched.plan_decode()
            logits, self.state = self._decode(
                self.params, jnp.asarray(tokens), jnp.asarray(fresh),
                resume, pages, wfloor, self.state)
            self.written += 1              # device: index += 1, all slots
            self._emit(sched.advance_decode(), np.asarray(logits))
        else:
            return 0
        sched.retire(self.written)
        return len([r for r in sched.slot_req if r is not None])

    def run(self, requests: List[Request], max_steps: int = 100000,
            ) -> List[Request]:
        for r in requests:
            self.submit(r)
        steps = 0
        while self.sched.busy and steps < max_steps:
            self.step()
            steps += 1
        return requests
