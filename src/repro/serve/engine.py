"""Chunked-prefill continuous batching over CLOVER-rank KV caches.

The engine owns one decode-state tree (KV caches at the pruned ranks
r_qk/r_vo — the paper's memory win applies to every cached token) with a
fixed number of slots.  Each engine step every slot is either decoding
one token or consuming a fixed-size CHUNK of its prompt, so prefill
interleaves with decode instead of stalling it, and the whole engine
compiles exactly TWO step shapes regardless of the prompt-length mix:

  * chunk step  — (slots, C) tokens with per-slot valid lengths; each
    slot writes its window into its caches at its own offset.  Decoding
    slots ride along with length 1 (a chunk step of one valid token IS a
    decode step), so admission never stalls generation.
  * decode step — (slots,) one token per slot; the cheap shape used
    whenever no slot has prompt tokens left to chunk.

The per-length jit cache of the previous engine (one compile per prompt
length, one prompt admitted at a time, all decoding stalled during each
prefill) is gone.

Scheduling policy lives in ``Scheduler``: admission from a FIFO queue
into free slots, per-slot phase tracking (PREFILL -> [TAIL ->] DECODE),
retirement on eos / max_new_tokens.  Architectures with recurrent state
(mamba / rwkv mixers or rwkv channel-mix) cannot take padded windows —
padding tokens would advance their recurrent state — so for those the
scheduler only chunks FULL windows and feeds the remainder (< C prompt
tokens) through decode steps (TAIL phase); decoding slots hold during
their chunk steps and their states are merged back unchanged.

Everything is shape-static and works unchanged on CPU (tests) and under
a mesh with sharded state.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, MIXER_ATTN, MLP_RWKV
from repro.models import transformer as T

Params = Dict[str, Any]

# slot phases
PREFILL = "prefill"     # prompt tokens remain; consumed chunk-wise
TAIL = "tail"           # recurrent archs: < C prompt tokens remain,
                        # fed one-by-one through the decode step
DECODE = "decode"       # generating one token per engine step


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0            # 0 = greedy
    # filled by the engine:
    generated: List[int] = field(default_factory=list)
    done: bool = False
    # serving metrics (monotonic clock): submit time, one stamp per
    # emitted token (token_times[0] is first-token / end of prefill)
    t_submit: float = 0.0
    token_times: List[float] = field(default_factory=list)


@dataclass(frozen=True)
class EngineConfig:
    slots: int = 4                      # concurrent sequences
    max_len: int = 512                  # KV capacity per slot
    eos_id: int = -1                    # -1: never stop on token
    prefill_chunk: int = 64             # prompt tokens consumed per chunk step


class Scheduler:
    """Admission / chunking / retirement policy with per-slot phases.

    Host-side bookkeeping only — the device sees nothing but the two
    fixed step shapes the engine compiles.
    """

    def __init__(self, ecfg: EngineConfig, recurrent: bool):
        self.ecfg = ecfg
        self.chunk = max(1, min(ecfg.prefill_chunk, ecfg.max_len))
        self.recurrent = recurrent
        self.queue: collections.deque = collections.deque()
        n = ecfg.slots
        self.slot_req: List[Optional[Request]] = [None] * n
        self.phase: List[Optional[str]] = [None] * n
        self.pos = np.zeros(n, np.int64)        # prompt tokens consumed
        self.fresh = np.zeros(n, bool)          # needs state reset
        self.last_token = np.zeros(n, np.int32)

    # -- admission -----------------------------------------------------
    def submit(self, req: Request):
        req.t_submit = time.monotonic()
        self.queue.append(req)

    def admit(self):
        for s in range(self.ecfg.slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.popleft()
                L = len(req.prompt)
                assert L > 0, "empty prompt"
                assert L + req.max_new_tokens <= self.ecfg.max_len, \
                    "request exceeds KV capacity"
                self.slot_req[s] = req
                self.pos[s] = 0
                self.fresh[s] = True
                self.phase[s] = self._prefill_phase(L, 0)

    def _prefill_phase(self, L: int, pos: int) -> str:
        if self.recurrent and L - pos < self.chunk:
            return TAIL          # padded window would corrupt state
        return PREFILL

    # -- planning ------------------------------------------------------
    def has_chunk_work(self) -> bool:
        return any(p == PREFILL for p in self.phase)

    def plan_chunk(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Build the (slots, C) window batch.  PREFILL slots consume up
        to C prompt tokens (recurrent archs: exactly C — guaranteed by
        the phase); DECODE slots ride with length 1 on attention-only
        archs; everything else idles with length 0."""
        n, C = self.ecfg.slots, self.chunk
        tokens = np.zeros((n, C), np.int32)
        lengths = np.zeros(n, np.int32)
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if self.phase[s] == PREFILL:
                take = min(C, len(req.prompt) - int(self.pos[s]))
                tokens[s, :take] = req.prompt[self.pos[s]:self.pos[s] + take]
                lengths[s] = take
            elif self.phase[s] == DECODE and not self.recurrent:
                tokens[s, 0] = self.last_token[s]
                lengths[s] = 1
        fresh = self.fresh & (lengths > 0)
        self.fresh &= ~fresh
        return tokens, lengths, fresh

    def plan_decode(self) -> Tuple[np.ndarray, np.ndarray]:
        """One token per slot: TAIL slots feed their next prompt token,
        DECODE slots their last sampled token."""
        n = self.ecfg.slots
        tokens = np.zeros(n, np.int32)
        active = np.zeros(n, bool)
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            active[s] = True
            if self.phase[s] == TAIL:
                tokens[s] = req.prompt[self.pos[s]]
            else:
                tokens[s] = self.last_token[s]
        fresh = self.fresh & active
        self.fresh &= ~fresh
        return tokens, fresh

    # -- post-step transitions ----------------------------------------
    def advance_chunk(self, lengths: np.ndarray) -> List[int]:
        """Apply a chunk step's progress.  Returns slots whose logits
        row is a real next-token distribution to sample from."""
        sample = []
        for s, req in enumerate(self.slot_req):
            if req is None or lengths[s] == 0:
                continue
            if self.phase[s] == PREFILL:
                self.pos[s] += int(lengths[s])
                if self.pos[s] == len(req.prompt):
                    self.phase[s] = DECODE
                    sample.append(s)
                else:
                    self.phase[s] = self._prefill_phase(
                        len(req.prompt), int(self.pos[s]))
            else:                                   # riding decode slot
                sample.append(s)
        return sample

    def advance_decode(self) -> List[int]:
        sample = []
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if self.phase[s] == TAIL:
                self.pos[s] += 1
                if self.pos[s] == len(req.prompt):
                    self.phase[s] = DECODE
                    sample.append(s)
            else:
                sample.append(s)
        return sample

    def retire(self):
        for s, req in enumerate(self.slot_req):
            if req is None or self.phase[s] != DECODE:
                continue
            if (len(req.generated) >= req.max_new_tokens
                    or (self.ecfg.eos_id >= 0 and req.generated
                        and req.generated[-1] == self.ecfg.eos_id)):
                req.done = True
                self.slot_req[s] = None
                self.phase[s] = None

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_req)


def greedy_reference(params: Params, cfg: ArchConfig, prompt,
                     n: int) -> List[int]:
    """Isolated whole-prompt greedy decode via the full forward pass —
    the exactness oracle engine streams are checked against (chunked
    prefill must reproduce it token-for-token)."""
    seq = list(prompt)
    gen = []
    for _ in range(n):
        logits, _ = T.forward(params, cfg, jnp.asarray(seq)[None, :])
        tok = int(jnp.argmax(logits[0, -1]))
        gen.append(tok)
        seq.append(tok)
    return gen


def _is_recurrent(cfg: ArchConfig) -> bool:
    return any(mixer != MIXER_ATTN or mlp == MLP_RWKV
               for mixer, mlp in cfg.pattern)


def _mask_like(flags: jnp.ndarray, leaf: jnp.ndarray) -> jnp.ndarray:
    """(B,) bool -> broadcastable to a stacked state leaf (nb, B, ...)."""
    return flags.reshape((1, flags.shape[0]) + (1,) * (leaf.ndim - 2))


def _is_kv(path) -> bool:
    return any(getattr(p, "key", None) == "kv" for p in path)


def _reset_fresh(state: Params, fresh: jnp.ndarray) -> Params:
    """Zero recurrent state + index of freshly admitted slots.  KV
    caches keep their stale contents — masked by the per-slot index."""

    def z(path, leaf):
        if _is_kv(path):
            return leaf
        return jnp.where(_mask_like(fresh, leaf), jnp.zeros_like(leaf), leaf)

    return {"blocks": jax.tree_util.tree_map_with_path(z, state["blocks"]),
            "index": jnp.where(fresh, 0, state["index"])}


def _merge_inactive(old_blocks, new_blocks, active: jnp.ndarray):
    """Keep inactive slots' recurrent state across a chunk step (their
    padded garbage window must not advance it).  KV caches are taken
    wholesale: inactive slots' garbage writes land at [index, index+C),
    which is either masked (beyond each slot's causal horizon) or
    overwritten by that slot's own future writes before it becomes
    readable."""

    def sel(path, old, new):
        if _is_kv(path):
            return new
        return jnp.where(_mask_like(active, old), new, old)

    return jax.tree_util.tree_map_with_path(sel, old_blocks, new_blocks)


class Engine:
    def __init__(self, params: Params, cfg: ArchConfig, ecfg: EngineConfig,
                 rng: Optional[jax.Array] = None):
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.sched = Scheduler(ecfg, _is_recurrent(cfg))
        C = self.sched.chunk
        # KV capacity rounded up to a chunk multiple PLUS one spare chunk:
        # every window write [index, index+C) with index <= max_len stays
        # in bounds, so dynamic_update_slice never clamps (a clamped
        # write would shift backwards over valid history).  The spare
        # tail is beyond every causal horizon, hence never readable.
        cap = (ecfg.max_len + C - 1) // C * C + C
        self.state = T.init_decode_state(cfg, ecfg.slots, cap)
        # per-slot positions: (slots,) index vector so slots at
        # different depths coexist in one batch
        self.state["index"] = jnp.zeros((ecfg.slots,), jnp.int32)

        def chunk_fn(params, tokens, lengths, fresh, state):
            st = _reset_fresh(state, fresh)
            logits, new = T.prefill_chunk(params, cfg, tokens, st, lengths)
            blocks = _merge_inactive(st["blocks"], new["blocks"],
                                     lengths > 0)
            return logits, {"blocks": blocks, "index": new["index"]}

        def decode_fn(params, tok, fresh, state):
            return T.decode_step(params, cfg, tok, _reset_fresh(state, fresh))

        self._chunk = jax.jit(chunk_fn)
        self._decode = jax.jit(decode_fn)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.sched.submit(req)

    def compiled_shapes(self) -> Optional[int]:
        """Total jit cache entries across both step functions — the
        engine's contract is that this never exceeds 2.  Returns None
        if the jit cache isn't introspectable (private API drift)."""
        sizes = [getattr(f, "_cache_size", None)
                 for f in (self._chunk, self._decode)]
        if any(s is None for s in sizes):
            return None
        return sum(s() for s in sizes)

    def _sample(self, logits: np.ndarray, temp: float) -> int:
        if temp <= 0:
            return int(np.argmax(logits))
        self.rng, k = jax.random.split(self.rng)
        return int(jax.random.categorical(k, jnp.asarray(logits) / temp))

    def _emit(self, slots: List[int], logits: np.ndarray):
        now = time.monotonic()
        for s in slots:
            req = self.sched.slot_req[s]
            tok = self._sample(logits[s], req.temperature)
            req.generated.append(tok)
            req.token_times.append(now)
            self.sched.last_token[s] = tok

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Admit + one chunk or decode step over all slots.
        Returns the number of active slots after the step."""
        sched = self.sched
        sched.admit()
        if sched.has_chunk_work():
            tokens, lengths, fresh = sched.plan_chunk()
            logits, self.state = self._chunk(
                self.params, jnp.asarray(tokens), jnp.asarray(lengths),
                jnp.asarray(fresh), self.state)
            self._emit(sched.advance_chunk(lengths), np.asarray(logits))
        elif any(r is not None for r in sched.slot_req):
            tokens, fresh = sched.plan_decode()
            logits, self.state = self._decode(
                self.params, jnp.asarray(tokens), jnp.asarray(fresh),
                self.state)
            self._emit(sched.advance_decode(), np.asarray(logits))
        else:
            return 0
        sched.retire()
        return len([r for r in sched.slot_req if r is not None])

    def run(self, requests: List[Request], max_steps: int = 100000,
            ) -> List[Request]:
        for r in requests:
            self.submit(r)
        steps = 0
        while self.sched.busy and steps < max_steps:
            self.step()
            steps += 1
        return requests
