"""Chunked-prefill continuous batching over CLOVER-rank KV caches.

The engine owns one decode-state tree (KV caches at the pruned ranks
r_qk/r_vo — the paper's memory win applies to every cached token) with a
fixed number of slots.  Each engine step every slot is either decoding
one token or consuming a fixed-size CHUNK of its prompt, so prefill
interleaves with decode instead of stalling it, and the whole engine
compiles exactly TWO step shapes regardless of the prompt-length mix:

  * chunk step  — (slots, C) tokens with per-slot valid lengths; each
    slot writes its window into its caches at its own offset.  Decoding
    slots ride along with length 1 (a chunk step of one valid token IS a
    decode step), so admission never stalls generation.
  * decode step — (slots,) one token per slot; the cheap shape used
    whenever no slot has prompt tokens left to chunk.

KV layout is either DENSE (``EngineConfig.paged=False``: per-slot
``(slots, capacity, KV, r)`` caches — every slot reserves full capacity
regardless of its actual length) or PAGED (``paged=True``: one global
pool ``(n_pages + 1, page_tokens, KV, r)`` per attention layer plus
host-side per-slot page tables, managed by ``PageAllocator``).  Paging
converts CLOVER's bytes-per-token win into CONCURRENCY: smaller rank ->
more tokens per page -> more resident sequences per HBM byte, so a pool
sized like a dense ``slots x max_len`` cache admits strictly more
simultaneous sequences when real lengths are shorter than max_len.
Admission is gated on free pages (not free slots), sequences grow
on demand during decode, and on pool exhaustion the YOUNGEST sequence is
preempted and requeued (its pages freed, its generated tokens folded
into the effective prompt so the greedy stream continues exactly on
re-admission) instead of crashing.  Both layouts compile the same two
step shapes; every paged result is checkable against the dense engine
token-for-token.

Scheduling policy lives in ``Scheduler``: admission from a FIFO queue
into free slots, per-slot phase tracking (PREFILL -> [TAIL ->] DECODE),
retirement on eos / max_new_tokens (freeing pages in paged mode).
Architectures with recurrent state (mamba / rwkv mixers or rwkv
channel-mix) cannot take padded windows — padding tokens would advance
their recurrent state — so for those the scheduler only chunks FULL
windows and feeds the remainder (< C prompt tokens) through decode steps
(TAIL phase); decoding slots hold during their chunk steps and their
states are merged back unchanged.

Everything is shape-static and works unchanged on CPU (tests) and under
a mesh with sharded state.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, MIXER_ATTN, MLP_RWKV
from repro.models import transformer as T

Params = Dict[str, Any]

# slot phases
PREFILL = "prefill"     # prompt tokens remain; consumed chunk-wise
TAIL = "tail"           # recurrent archs: < C prompt tokens remain,
                        # fed one-by-one through the decode step
DECODE = "decode"       # generating one token per engine step


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0            # 0 = greedy
    # filled by the engine:
    generated: List[int] = field(default_factory=list)
    done: bool = False
    # serving metrics (monotonic clock): submit time, one stamp per
    # emitted token (token_times[0] is first-token / end of prefill)
    t_submit: float = 0.0
    token_times: List[float] = field(default_factory=list)


@dataclass(frozen=True)
class EngineConfig:
    slots: int = 4                      # concurrent sequences
    max_len: int = 512                  # KV capacity per slot
    eos_id: int = -1                    # -1: never stop on token
    prefill_chunk: int = 64             # prompt tokens consumed per chunk step
    # -- paged KV cache (DESIGN.md §6) --------------------------------
    paged: bool = False                 # page the KV cache
    page_tokens: int = 8                # tokens per KV page
    # pool size in pages; 0 -> slots * ceil(capacity / page_tokens),
    # i.e. no memory pressure (every slot can reach full capacity).
    # Size it below that to overcommit: admission then gates on free
    # pages and exhaustion preempts the youngest sequence.
    n_pages: int = 0
    # -- self-speculative decoding (DESIGN.md §8) ---------------------
    # 0 disables; k > 0: every pure-decode step, a rank-sliced DRAFT
    # pass over the SAME weights proposes k tokens per slot and one
    # (slots, k+1) verify step accepts a greedy prefix — up to k+1
    # tokens per step instead of 1.  Greedy streams stay exactly
    # token-identical to the non-speculative engine; requires an
    # attention-only architecture (recurrent state cannot roll back).
    spec_k: int = 0
    # fraction of every head's CURRENT rank the draft slices off (the
    # leading directions are kept — CLOVER's factors are sorted, so the
    # draft's cache view is literally cache[..., :r]; no second cache)
    draft_rank_ratio: float = 0.5

    @property
    def chunk(self) -> int:
        """Effective chunk size — the ONE clamp both the Scheduler's
        planning and the Engine's capacity/page-table sizing use."""
        return max(1, min(self.prefill_chunk, self.max_len))

    @property
    def spec_window(self) -> int:
        """Verify-step window width (pending token + k drafts)."""
        return self.spec_k + 1

    @property
    def capacity(self) -> int:
        """Per-slot KV capacity: max_len rounded up to a chunk multiple
        PLUS spare room, so every window write [index, index+W) with
        index <= max_len stays in bounds — dense dynamic_update_slice
        never clamps (a clamped write would shift backwards over valid
        history) and paged position->page lookups never fall off the
        table.  W is the chunk size or, with speculation on, the
        (k+1)-wide verify window whose rejected tail transiently
        overhangs the committed length.  The spare tail is beyond every
        causal horizon, hence never readable."""
        C = self.chunk
        spare = max(C, self.spec_window if self.spec_k > 0 else 1)
        return ((self.max_len + C - 1) // C * C
                + (spare + C - 1) // C * C)


class PageAllocator:
    """Free-list allocator over the global KV page pool.

    Host-side owner of the page tables for the device pools built by
    ``T.init_decode_state_paged``: ``n_pages`` real pages plus one spare
    garbage row (id ``sentinel == n_pages``) that un-allocated
    page-table entries address, so padded windows and idle slots write
    harmlessly off to the side instead of into another slot's pages.

    Invariants (property-tested in tests/test_property.py):
      * a page id is owned by at most one slot at a time;
      * ``release`` returns exactly the slot's pages to the free list;
      * ``free_pages + used_pages() == n_pages`` at all times.
    """

    def __init__(self, n_pages: int, page_tokens: int, slots: int,
                 table_pages: int):
        assert n_pages >= 1 and page_tokens >= 1 and table_pages >= 1
        self.n_pages = n_pages
        self.page_tokens = page_tokens
        self.table_pages = table_pages          # static page-table width
        self.sentinel = n_pages                 # the garbage-sink row
        self.free_list: List[int] = list(range(n_pages))
        self.tables: List[List[int]] = [[] for _ in range(slots)]

    @property
    def free_pages(self) -> int:
        return len(self.free_list)

    def used_pages(self) -> int:
        return sum(len(t) for t in self.tables)

    def utilization(self) -> float:
        return self.used_pages() / max(1, self.n_pages)

    def pages_for(self, n_tokens: int) -> int:
        return -(-int(n_tokens) // self.page_tokens)

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot``'s table to cover positions [0, n_tokens);
        all-or-nothing.  Returns False on pool exhaustion (caller
        preempts) or if the static table width would overflow."""
        want = self.pages_for(n_tokens)
        need = want - len(self.tables[slot])
        if need <= 0:
            return True
        if need > len(self.free_list) or want > self.table_pages:
            return False
        for _ in range(need):
            self.tables[slot].append(self.free_list.pop())
        return True

    def release(self, slot: int) -> int:
        """Return all of ``slot``'s pages to the free list."""
        pages = self.tables[slot]
        self.tables[slot] = []
        self.free_list.extend(pages)
        return len(pages)

    def table_array(self) -> np.ndarray:
        """(slots, table_pages) int32 device view; sentinel-padded."""
        t = np.full((len(self.tables), self.table_pages), self.sentinel,
                    np.int32)
        for s, pages in enumerate(self.tables):
            t[s, :len(pages)] = pages
        return t


class Scheduler:
    """Admission / chunking / preemption / retirement policy with
    per-slot phases.

    Host-side bookkeeping only — the device sees nothing but the two
    fixed step shapes the engine compiles.  With a ``PageAllocator``
    (paged mode) admission is gated on free pages for the effective
    prompt, retirement frees pages, and ``preempt`` requeues a sequence
    at the queue head with its generated tokens folded into the
    effective prompt (greedy continuation is exact).
    """

    def __init__(self, ecfg: EngineConfig, recurrent: bool,
                 allocator: Optional[PageAllocator] = None):
        self.ecfg = ecfg
        self.chunk = ecfg.chunk
        self.recurrent = recurrent
        self.alloc = allocator
        self.queue: collections.deque = collections.deque()
        n = ecfg.slots
        self.slot_req: List[Optional[Request]] = [None] * n
        # effective prompt per slot: the request's prompt plus any
        # tokens generated before a preemption (greedy continuation)
        self.slot_prompt: List[Optional[np.ndarray]] = [None] * n
        self.phase: List[Optional[str]] = [None] * n
        self.pos = np.zeros(n, np.int64)        # prompt tokens consumed
        self.fresh = np.zeros(n, bool)          # needs state reset
        self.last_token = np.zeros(n, np.int32)
        self.slot_seq = np.zeros(n, np.int64)   # admission order (age)
        self._admit_counter = 0
        self.preemptions = 0

    # -- admission -----------------------------------------------------
    def submit(self, req: Request):
        req.t_submit = time.monotonic()
        self.queue.append(req)

    def admit(self):
        for s in range(self.ecfg.slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue[0]
                eff = (req.prompt if not req.generated else
                       np.concatenate([np.asarray(req.prompt, np.int32),
                                       np.asarray(req.generated, np.int32)]))
                L = len(eff)
                remaining = req.max_new_tokens - len(req.generated)
                assert L > 0, "empty prompt"
                assert L + remaining <= self.ecfg.max_len, \
                    "request exceeds KV capacity"
                if self.alloc is not None:
                    # speculative verify windows transiently overhang
                    # the committed length by up to spec_k tokens
                    slack = self.ecfg.spec_k
                    assert (self.alloc.pages_for(L + remaining + slack)
                            <= self.alloc.n_pages), \
                        "request exceeds page pool"
                    if not self.alloc.ensure(s, L):
                        break       # FIFO head-of-line: wait for pages
                self.queue.popleft()
                self.slot_req[s] = req
                self.slot_prompt[s] = eff
                self.pos[s] = 0
                self.fresh[s] = True
                self.slot_seq[s] = self._admit_counter
                self._admit_counter += 1
                self.phase[s] = self._prefill_phase(L, 0)

    def _prefill_phase(self, L: int, pos: int) -> str:
        if self.recurrent and L - pos < self.chunk:
            return TAIL          # padded window would corrupt state
        return PREFILL

    # -- planning ------------------------------------------------------
    def has_chunk_work(self) -> bool:
        return any(p == PREFILL for p in self.phase)

    def planned_writes(self, decode_width: int = 1) -> np.ndarray:
        """(slots,) KV positions the NEXT step will write per active
        slot — what must be page-covered before the step runs.  TAIL
        and PREFILL writes always land inside the prompt coverage
        allocated at admission; only decode growth can demand pages.
        ``decode_width`` > 1 is a speculative round: every decoding
        slot writes a (k+1)-wide draft+verify window."""
        n, C = self.ecfg.slots, self.chunk
        take = np.zeros(n, np.int64)
        chunk_step = self.has_chunk_work()
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if chunk_step:
                if self.phase[s] == PREFILL:
                    take[s] = min(C, len(self.slot_prompt[s])
                                  - int(self.pos[s]))
                elif self.phase[s] == DECODE and not self.recurrent:
                    take[s] = 1
            else:
                take[s] = decode_width
        return take

    def plan_chunk(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Build the (slots, C) window batch.  PREFILL slots consume up
        to C prompt tokens (recurrent archs: exactly C — guaranteed by
        the phase); DECODE slots ride with length 1 on attention-only
        archs; everything else idles with length 0."""
        n, C = self.ecfg.slots, self.chunk
        tokens = np.zeros((n, C), np.int32)
        lengths = np.zeros(n, np.int32)
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if self.phase[s] == PREFILL:
                prompt = self.slot_prompt[s]
                take = min(C, len(prompt) - int(self.pos[s]))
                tokens[s, :take] = prompt[self.pos[s]:self.pos[s] + take]
                lengths[s] = take
            elif self.phase[s] == DECODE and not self.recurrent:
                tokens[s, 0] = self.last_token[s]
                lengths[s] = 1
        fresh = self.fresh & (lengths > 0)
        self.fresh &= ~fresh
        return tokens, lengths, fresh

    def plan_decode(self) -> Tuple[np.ndarray, np.ndarray]:
        """One token per slot: TAIL slots feed their next prompt token,
        DECODE slots their last sampled token."""
        n = self.ecfg.slots
        tokens = np.zeros(n, np.int32)
        active = np.zeros(n, bool)
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            active[s] = True
            if self.phase[s] == TAIL:
                tokens[s] = self.slot_prompt[s][self.pos[s]]
            else:
                tokens[s] = self.last_token[s]
        fresh = self.fresh & active
        self.fresh &= ~fresh
        return tokens, fresh

    # -- post-step transitions ----------------------------------------
    def advance_chunk(self, lengths: np.ndarray) -> List[int]:
        """Apply a chunk step's progress.  Returns slots whose logits
        row is a real next-token distribution to sample from."""
        sample = []
        for s, req in enumerate(self.slot_req):
            if req is None or lengths[s] == 0:
                continue
            if self.phase[s] == PREFILL:
                self.pos[s] += int(lengths[s])
                if self.pos[s] == len(self.slot_prompt[s]):
                    self.phase[s] = DECODE
                    sample.append(s)
                else:
                    self.phase[s] = self._prefill_phase(
                        len(self.slot_prompt[s]), int(self.pos[s]))
            else:                                   # riding decode slot
                sample.append(s)
        return sample

    def advance_decode(self) -> List[int]:
        sample = []
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if self.phase[s] == TAIL:
                self.pos[s] += 1
                if self.pos[s] == len(self.slot_prompt[s]):
                    self.phase[s] = DECODE
                    sample.append(s)
            else:
                sample.append(s)
        return sample

    # -- preemption / retirement --------------------------------------
    def preempt(self, s: int):
        """Free slot ``s`` (pages included) and requeue its request at
        the queue HEAD.  Generated tokens are kept on the request; they
        join the effective prompt on re-admission, so the re-prefill
        reproduces the stream exactly and generation continues from
        where it stopped."""
        req = self.slot_req[s]
        assert req is not None
        if self.alloc is not None:
            self.alloc.release(s)
        self.slot_req[s] = None
        self.slot_prompt[s] = None
        self.phase[s] = None
        self.queue.appendleft(req)
        self.preemptions += 1

    def retire(self):
        for s, req in enumerate(self.slot_req):
            if req is None or self.phase[s] != DECODE:
                continue
            if (len(req.generated) >= req.max_new_tokens
                    or (self.ecfg.eos_id >= 0 and req.generated
                        and req.generated[-1] == self.ecfg.eos_id)):
                req.done = True
                self.slot_req[s] = None
                self.slot_prompt[s] = None
                self.phase[s] = None
                if self.alloc is not None:
                    self.alloc.release(s)

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_req)


def greedy_reference(params: Params, cfg: ArchConfig, prompt,
                     n: int) -> List[int]:
    """Isolated whole-prompt greedy decode via the full forward pass —
    the exactness oracle engine streams are checked against (chunked
    prefill must reproduce it token-for-token)."""
    seq = list(prompt)
    gen = []
    for _ in range(n):
        logits, _ = T.forward(params, cfg, jnp.asarray(seq)[None, :])
        tok = int(jnp.argmax(logits[0, -1]))
        gen.append(tok)
        seq.append(tok)
    return gen


def _is_recurrent(cfg: ArchConfig) -> bool:
    return any(mixer != MIXER_ATTN or mlp == MLP_RWKV
               for mixer, mlp in cfg.pattern)


def _mask_like(flags: jnp.ndarray, leaf: jnp.ndarray) -> jnp.ndarray:
    """(B,) bool -> broadcastable to a stacked state leaf (nb, B, ...)."""
    return flags.reshape((1, flags.shape[0]) + (1,) * (leaf.ndim - 2))


def _is_kv(path) -> bool:
    return any(getattr(p, "key", None) == "kv" for p in path)


def _reset_fresh(state: Params, fresh: jnp.ndarray) -> Params:
    """Zero recurrent state + index of freshly admitted slots.  KV
    caches keep their stale contents — masked by the per-slot index
    (dense: the slot's own region; paged: freshly allocated pages hold a
    previous owner's data, masked until overwritten by the new one)."""

    def z(path, leaf):
        if _is_kv(path):
            return leaf
        return jnp.where(_mask_like(fresh, leaf), jnp.zeros_like(leaf), leaf)

    return {"blocks": jax.tree_util.tree_map_with_path(z, state["blocks"]),
            "index": jnp.where(fresh, 0, state["index"])}


def _merge_inactive(old_blocks, new_blocks, active: jnp.ndarray):
    """Keep inactive slots' recurrent state across a chunk step (their
    padded garbage window must not advance it).  KV caches are taken
    wholesale: inactive slots' garbage writes land at [index, index+C),
    which is either masked (beyond each slot's causal horizon),
    overwritten by that slot's own future writes before it becomes
    readable, or (paged) routed via sentinel table entries into the
    pool's garbage row."""

    def sel(path, old, new):
        if _is_kv(path):
            return new
        return jnp.where(_mask_like(active, old), new, old)

    return jax.tree_util.tree_map_with_path(sel, old_blocks, new_blocks)


class Engine:
    def __init__(self, params: Params, cfg: ArchConfig, ecfg: EngineConfig,
                 rng: Optional[jax.Array] = None):
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        cap = ecfg.capacity        # see EngineConfig.capacity
        if ecfg.paged:
            pt = ecfg.page_tokens
            table_pages = (cap + pt - 1) // pt
            n_pages = ecfg.n_pages or ecfg.slots * table_pages
            self.alloc: Optional[PageAllocator] = PageAllocator(
                n_pages, pt, ecfg.slots, table_pages)
            self.state = T.init_decode_state_paged(cfg, ecfg.slots,
                                                   n_pages, pt)
        else:
            self.alloc = None
            self.state = T.init_decode_state(cfg, ecfg.slots, cap)
            # per-slot positions: (slots,) index vector so slots at
            # different depths coexist in one batch
            self.state["index"] = jnp.zeros((ecfg.slots,), jnp.int32)
        recurrent = _is_recurrent(cfg)
        if ecfg.spec_k > 0 and recurrent:
            raise ValueError(
                "speculative decoding requires an attention-only "
                "architecture: recurrent (mamba/rwkv) state cannot roll "
                "back rejected draft tokens")
        self.sched = Scheduler(ecfg, recurrent, self.alloc)
        # host mirror of state["index"] (tokens written per slot this
        # tenure) — drives page coverage without device round-trips
        self.written = np.zeros(ecfg.slots, np.int64)
        # serving stats
        self.max_active = 0
        self.peak_page_util = 0.0
        # speculative-decoding stats: emitted-tokens-per-round histogram
        # {n_emitted: rounds} — mean > 1.0 is the wall-clock win
        self.spec_rounds = 0
        self.accept_hist: Dict[int, int] = collections.defaultdict(int)

        def chunk_fn(params, tokens, lengths, fresh, pages, state):
            st = _reset_fresh(state, fresh)
            logits, new = T.prefill_chunk(params, cfg, tokens, st, lengths,
                                          pages=pages)
            blocks = _merge_inactive(st["blocks"], new["blocks"],
                                     lengths > 0)
            return logits, {"blocks": blocks, "index": new["index"]}

        def decode_fn(params, tok, fresh, pages, state):
            return T.decode_step(params, cfg, tok, _reset_fresh(state, fresh),
                                 pages=pages)

        self._chunk = jax.jit(chunk_fn)
        self._decode = jax.jit(decode_fn)
        self._draft = self._verify = None
        if ecfg.spec_k > 0:
            from repro.core.prune import draft_ranks
            dr = draft_ranks(cfg, ecfg.draft_rank_ratio)
            # full-width "draft" degenerates to the exact model — skip
            # the slicing so XLA compiles the identical program
            self.draft_rank = (None if dr == (cfg.qk_dim, cfg.vo_dim)
                               else dr)

            def draft_fn(params, tok, pages, state):
                return T.decode_step(params, cfg, tok, state, pages=pages,
                                     draft_rank=self.draft_rank)

            def verify_fn(params, tokens, lengths, pages, state):
                return T.verify_chunk(params, cfg, tokens, state, lengths,
                                      pages=pages)

            self._draft = jax.jit(draft_fn)
            self._verify = jax.jit(verify_fn)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.sched.submit(req)

    def compiled_shapes(self) -> Optional[int]:
        """Total jit cache entries across all step functions — the
        engine's contract is that this never exceeds 2 without
        speculation (dense AND paged: the page table is shape-static)
        and 4 with it (one draft shape + one verify shape on top).
        Returns None if the jit cache isn't introspectable (private API
        drift)."""
        fns = [f for f in (self._chunk, self._decode,
                           self._draft, self._verify) if f is not None]
        sizes = [getattr(f, "_cache_size", None) for f in fns]
        if any(s is None for s in sizes):
            return None
        return sum(s() for s in sizes)

    def _sample(self, logits: np.ndarray, temp: float) -> int:
        if temp <= 0:
            return int(np.argmax(logits))
        self.rng, k = jax.random.split(self.rng)
        return int(jax.random.categorical(k, jnp.asarray(logits) / temp))

    def _emit(self, slots: List[int], logits: np.ndarray):
        now = time.monotonic()
        for s in slots:
            req = self.sched.slot_req[s]
            tok = self._sample(logits[s], req.temperature)
            req.generated.append(tok)
            req.token_times.append(now)
            self.sched.last_token[s] = tok

    # -- paged page-coverage / preemption ------------------------------
    def _ensure_pages(self, decode_width: int = 1):
        """Cover every active slot's upcoming writes with pages, oldest
        sequence first (the FIFO head has page priority).  On pool
        exhaustion, preempt-and-requeue the YOUNGEST active sequence
        (vLLM-style) and retry, instead of crashing mid-trace."""
        sched, alloc = self.sched, self.alloc
        take = sched.planned_writes(decode_width)
        order = sorted((s for s in range(self.ecfg.slots)
                        if sched.slot_req[s] is not None),
                       key=lambda s: sched.slot_seq[s])
        for s in order:
            while sched.slot_req[s] is not None:
                if alloc.ensure(s, int(self.written[s] + take[s])):
                    break
                victims = [v for v in range(self.ecfg.slots)
                           if sched.slot_req[v] is not None]
                victim = max(victims, key=lambda v: sched.slot_seq[v])
                if victim == s and len(victims) == 1:
                    # admission guarantees a lone sequence always fits
                    raise RuntimeError(
                        f"page pool exhausted: slot {s} needs "
                        f"{alloc.pages_for(int(self.written[s] + take[s]))}"
                        f" pages, pool has {alloc.n_pages}")
                sched.preempt(victim)
                take[victim] = 0

    # -- speculative round (DESIGN.md §8) ------------------------------
    def _spec_due(self) -> bool:
        """A speculative round replaces the plain decode step when the
        engine has a draft, no slot has prompt tokens left to chunk,
        and every active request is greedy (the acceptance rule below
        is exact only for argmax sampling)."""
        sched = self.sched
        if self._draft is None or sched.has_chunk_work():
            return False
        reqs = [r for r in sched.slot_req if r is not None]
        return bool(reqs) and all(r.temperature <= 0 for r in reqs)

    def _spec_round(self, pages) -> None:
        """One speculative round over all active slots (all in DECODE):
        the rank-sliced DRAFT pass proposes ``k`` tokens per slot
        autoregressively, then ONE (slots, k+1) verify window scores
        every position with the full model.  Each slot commits its
        longest draft prefix matching the full model's argmaxes plus
        the bonus token — between 1 and k+1 tokens, never diverging
        from the non-speculative greedy stream — and the per-slot index
        rolls back over the rejected tail (dense and paged alike this
        is a pure length decrement: rejected K/V sits beyond every
        causal horizon until overwritten, the invariant padded chunk
        writes already rely on)."""
        sched, ecfg = self.sched, self.ecfg
        k, W = ecfg.spec_k, ecfg.spec_window
        slots = ecfg.slots
        active = np.array([r is not None for r in sched.slot_req])
        n0 = self.written.copy()
        # draft k tokens; the draft's K/V writes land in the shared
        # cache but its state is DISCARDED — the verify step below
        # rewrites all k+1 positions at full rank from the pre-draft
        # state, so nothing the draft wrote is ever read by the model
        tok = sched.last_token.copy()
        drafts = np.zeros((slots, k), np.int32)
        dstate = self.state
        for j in range(k):
            logits, dstate = self._draft(self.params, jnp.asarray(tok),
                                         pages, dstate)
            tok = np.argmax(np.asarray(logits), axis=-1).astype(np.int32)
            drafts[:, j] = tok
        tokens = np.zeros((slots, W), np.int32)
        tokens[:, 0] = sched.last_token        # pending, not yet cached
        tokens[:, 1:] = drafts
        lengths = np.where(active, W, 0).astype(np.int32)
        logits, self.state = self._verify(
            self.params, jnp.asarray(tokens), jnp.asarray(lengths), pages,
            self.state)
        targets = np.argmax(np.asarray(logits), axis=-1)       # (slots, W)
        now = time.monotonic()
        self.spec_rounds += 1
        for s in range(slots):
            if not active[s]:
                continue
            req = sched.slot_req[s]
            a = 0
            while a < k and drafts[s, a] == targets[s, a]:
                a += 1
            out = [int(t) for t in drafts[s, :a]] + [int(targets[s, a])]
            # honor max_new_tokens / eos exactly as the one-token path
            # would have: anything past the stop point is dropped (the
            # slot retires this step, so the over-committed cache tail
            # is unreachable)
            out = out[:req.max_new_tokens - len(req.generated)]
            if ecfg.eos_id >= 0 and ecfg.eos_id in out:
                out = out[:out.index(ecfg.eos_id) + 1]
            for t in out:
                req.generated.append(t)
                req.token_times.append(now)
            self.accept_hist[len(out)] += 1
            sched.last_token[s] = targets[s, a]
            self.written[s] = n0[s] + a + 1
        # roll back: commit per-slot lengths (idle slots advanced by 0)
        self.state["index"] = jnp.asarray(self.written.astype(np.int32))

    @property
    def accepted_per_round(self) -> float:
        """Mean tokens emitted per speculative slot-round (>= 1.0;
        1.0 = nothing ever accepted, k+1 = every draft accepted)."""
        n = sum(self.accept_hist.values())
        return (sum(a * c for a, c in self.accept_hist.items()) / n
                if n else 0.0)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Admit + one chunk, decode, or speculative step over all
        slots.  Returns the number of active slots after the step."""
        sched = self.sched
        sched.admit()
        spec = self._spec_due()
        pages = None
        # newly admitted slots restart their tenure at position 0 (the
        # device index is zeroed by _reset_fresh at plan time; the host
        # mirror must follow — it drives page coverage AND the
        # speculative rollback's index commit)
        for s in range(self.ecfg.slots):
            if sched.slot_req[s] is not None and sched.fresh[s]:
                self.written[s] = 0
        if self.alloc is not None:
            self._ensure_pages(self.ecfg.spec_window if spec else 1)
            pages = jnp.asarray(self.alloc.table_array())
            self.peak_page_util = max(self.peak_page_util,
                                      self.alloc.utilization())
        self.max_active = max(self.max_active, len(
            [r for r in sched.slot_req if r is not None]))
        if sched.has_chunk_work():
            tokens, lengths, fresh = sched.plan_chunk()
            logits, self.state = self._chunk(
                self.params, jnp.asarray(tokens), jnp.asarray(lengths),
                jnp.asarray(fresh), pages, self.state)
            self.written += lengths        # device: index += lengths
            self._emit(sched.advance_chunk(lengths), np.asarray(logits))
        elif spec and any(r is not None for r in sched.slot_req):
            self._spec_round(pages)
        elif any(r is not None for r in sched.slot_req):
            tokens, fresh = sched.plan_decode()
            logits, self.state = self._decode(
                self.params, jnp.asarray(tokens), jnp.asarray(fresh),
                pages, self.state)
            self.written += 1              # device: index += 1, all slots
            self._emit(sched.advance_decode(), np.asarray(logits))
        else:
            return 0
        sched.retire()
        return len([r for r in sched.slot_req if r is not None])

    def run(self, requests: List[Request], max_steps: int = 100000,
            ) -> List[Request]:
        for r in requests:
            self.submit(r)
        steps = 0
        while self.sched.busy and steps < max_steps:
            self.step()
            steps += 1
        return requests
