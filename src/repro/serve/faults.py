"""Deterministic fault injection at the engine's host boundaries
(DESIGN.md §11).

A ``FaultPlan`` is a SEEDED schedule of failures the engine consults at
five injection sites — the places a production serving host actually
fails:

  * ``"alloc"``     — ``PageAllocator.ensure`` reports exhaustion even
                      though pages are free (a racing co-tenant, a
                      fragmented device heap);
  * ``"step"``      — a compiled step raises before producing output
                      (XLA OOM, a preempted device, a driver hiccup);
  * ``"nan"``       — a compiled step RETURNS, but its logits are
                      non-finite (silent numerical corruption — the
                      one failure mode that would poison streams if it
                      weren't detected at the boundary);
  * ``"page_copy"`` — a COW page-content clone batch fails before
                      executing;
  * ``"host_copy"`` — a host->device restore batch (hierarchical KV's
                      spill tier, DESIGN.md §12) fails before
                      executing.  Recovery is BOUNDED by construction:
                      the engine gives up on the remaining host-tier
                      hits and falls back to re-prefilling those
                      tokens — strictly more work, never a wrong
                      token, allocator and trie untouched.

Determinism is the whole point: decision ``i`` at site ``s`` is a pure
function of ``(seed, s, i)`` — a per-site counter drives a
counter-mode RNG, so the same plan over the same trace injects the
same faults in the same order, every run.  That is what lets the chaos
harness (tests/test_chaos.py, serve_bench scenario 6) assert EXACT
properties under failure: surviving streams token-identical to a
fault-free replay, pool conservation, every request terminal.

Injection happens in ``Engine`` BEFORE the compiled call executes (or,
for ``"nan"``, by corrupting the returned logits host-side), so the
device state the engine holds is never actually damaged — recovery
(bounded same-input retry, then slot quarantine + requeue) is
therefore exact by construction, and the same recovery code handles a
REAL failure of the same shape, where re-admission rebuilds the slot
from the request's prompt + generated tokens.
"""
from __future__ import annotations

import collections
import zlib
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

# the engine's injection sites, in the order they appear in a step
SITES = ("alloc", "step", "nan", "page_copy", "host_copy")


class FaultError(RuntimeError):
    """A recoverable step failure: injected by a ``FaultPlan``, or a
    genuinely detected one (non-finite logits).  The engine retries the
    step with the same inputs up to ``EngineConfig.step_retries`` times
    before quarantining the slots and requeueing their requests."""


@dataclass
class FaultPlan:
    """Seeded, deterministic fault schedule.

    ``rates`` maps a site name (see ``SITES``) to a per-decision
    probability; absent sites never fire.  ``max_faults`` caps the
    TOTAL number of injected faults across all sites (None = no cap) —
    useful for "fail hard, then recover" tests.  ``injected`` counts
    what actually fired, per site.
    """
    seed: int = 0
    rates: Dict[str, float] = field(default_factory=dict)
    max_faults: Optional[int] = None

    def __post_init__(self):
        unknown = set(self.rates) - set(SITES)
        if unknown:
            raise ValueError(
                f"FaultPlan.rates: unknown sites {sorted(unknown)}; "
                f"expected a subset of {SITES}")
        for site, rate in self.rates.items():
            if not 0.0 <= rate <= 1.0:
                raise ValueError(
                    f"FaultPlan.rates[{site!r}]={rate}: must be in [0, 1]")
        self._calls = collections.Counter()
        self.injected = collections.Counter()

    @classmethod
    def chaos(cls, seed: int, intensity: float = 0.05,
              max_faults: Optional[int] = None) -> "FaultPlan":
        """Uniform pressure on every site — the soak-test default."""
        return cls(seed=seed, rates={s: intensity for s in SITES},
                   max_faults=max_faults)

    def fire(self, site: str) -> bool:
        """One injection decision at ``site``.  Counter-mode: decision
        ``i`` depends only on ``(seed, site, i)``, never on wall clock
        or global RNG state."""
        self._calls[site] += 1
        rate = self.rates.get(site, 0.0)
        if rate <= 0.0:
            return False
        if (self.max_faults is not None
                and self.total_injected >= self.max_faults):
            return False
        u = np.random.default_rng(
            [self.seed, zlib.crc32(site.encode()), self._calls[site]]
        ).random()
        if u < rate:
            self.injected[site] += 1
            return True
        return False

    @property
    def total_injected(self) -> int:
        return sum(self.injected.values())

    def summary(self) -> Dict[str, int]:
        """{site: injected count} for ``Engine.stats()`` reporting."""
        return {s: self.injected.get(s, 0) for s in SITES
                if self.rates.get(s, 0.0) > 0.0}
