"""Distribution: sharding rules, gradient compression, pipeline parallelism."""
from repro.parallel.sharding import (  # noqa: F401
    param_specs, data_specs, decode_state_specs, opt_specs, ShardingRules,
    serve_rules, serve_state_specs)
