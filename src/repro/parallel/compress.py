"""int8 gradient compression for the cross-pod (DCI) hop.

At 1000+ nodes the scarce resource is the inter-pod data-center
interconnect, not ICI.  XLA's own all-reduce runs over (pod, data)
jointly; we instead reassociate it:

    full-precision psum over the fast intra-pod axes (XLA, unchanged)
    int8-quantized psum over the slow "pod" axis (here)

cutting cross-pod bytes 4x (f32) / 2x (bf16).  Quantization uses a
per-tensor symmetric scale (max-abs); an int32 accumulator avoids
saturation (pod count << 2^23).  Error feedback (the residual carried to
the next step) keeps SGD convergence unbiased in expectation; the
residual tree lives alongside the optimizer state.

Implemented with shard_map over ONLY the pod axis so XLA still fuses the
intra-pod reductions around it.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

Params = Dict[str, Any]


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _psum_int8(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Quantize -> int32 psum -> dequantize (scales psum'd alongside)."""
    xf = x.astype(jnp.float32)
    q, scale = _quantize(xf)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    # each participant contributed with its own scale; approximate the
    # sum with the max scale (conservative; error goes to feedback)
    s = jax.lax.pmax(scale, axis)
    return (total.astype(jnp.float32) * s).astype(x.dtype)


def compress_cross_pod(grads: Params, mesh: Mesh,
                       residual: Optional[Params] = None,
                       ) -> Params:
    """All-reduce ``grads`` across the pod axis in int8.

    grads enter REPLICA-LOCAL per pod (i.e. already averaged intra-pod by
    XLA's handling of the data axis) and leave pod-averaged.  With
    ``residual`` (error-feedback state) the quantization error is carried
    instead of dropped; see ``compress_cross_pod_ef``.
    """
    n_pod = mesh.shape["pod"]

    def one(g):
        spec = P()  # grads replicated w.r.t. pod at this point

        @functools.partial(
            shard_map, mesh=mesh, in_specs=(spec,), out_specs=spec,
            check_rep=False)
        def psum_pod(x):
            return _psum_int8(x, "pod") / n_pod
        return psum_pod(g)

    return jax.tree.map(one, grads)


def compress_cross_pod_ef(grads: Params, residual: Params, mesh: Mesh,
                          ) -> Tuple[Params, Params]:
    """Error-feedback variant: quantize (g + residual), carry the error.

    Returns (pod-averaged grads, new residual)."""
    n_pod = mesh.shape["pod"]

    def one(g, r):
        gf = g.astype(jnp.float32) + r

        @functools.partial(
            shard_map, mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
            check_rep=False)
        def step(x):
            q, scale = _quantize(x)
            sent = q.astype(jnp.float32) * scale       # what the wire saw
            err = x - sent
            total = jax.lax.psum(q.astype(jnp.int32), "pod")
            s = jax.lax.pmax(scale, "pod")
            return (total.astype(jnp.float32) * s) / n_pod, err

        avg, err = step(gf)
        return avg.astype(g.dtype), err

    out = jax.tree.map(one, grads, residual)
    is_entry = lambda x: isinstance(x, tuple)  # noqa: E731
    new_g = jax.tree.map(lambda t: t[0], out, is_leaf=is_entry)
    new_r = jax.tree.map(lambda t: t[1], out, is_leaf=is_entry)
    return new_g, new_r


def init_residual(grads_shape: Params) -> Params:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_shape)
