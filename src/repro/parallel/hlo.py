"""Compiled-HLO analysis: collective bytes + the three roofline terms.

The dry-run's compiled artifact is the per-device SPMD program, so
``cost_analysis()`` flops/bytes and the summed collective operand bytes
are already per-chip quantities (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e, per chip):
  peak bf16 compute  197 TFLOP/s
  HBM bandwidth      819 GB/s
  ICI per link       ~50 GB/s
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "f8e4m3fnuz": 1, "f8e4m3b11fnuz": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
# '  %name = <result shapes> <opcode>(' — operands are bare %refs in the
# compiled HLO text, so sizes come from the RESULT side + group size.
_LINE_RE = re.compile(
    r"=\s*(?P<result>(?:\()?(?:[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?"
    r"(?:,\s*)?)+(?:\))?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<phase>-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota format: [n_groups,group_size]<=[total]
        return int(m.group(2))
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device OPERAND bytes of every collective, keyed by opcode.

    The compiled HLO prints operands as bare ``%refs``, so sizes derive
    from the result shapes (always printed) and the replica group size g:

      all-reduce          operand == result
      all-gather          operand == result / g   (result is gathered)
      reduce-scatter      operand == result * g   (result is scattered)
      all-to-all          operand == result
      collective-permute  operand == result

    ``-done`` halves of async pairs are skipped (counted at ``-start``).
    """
    out: Dict[str, int] = {c: 0 for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m or m.group("phase") == "-done":
            continue
        op = m.group("op")
        rb = sum(_shape_bytes(d, s)
                 for d, s in _SHAPE_RE.findall(m.group("result")))
        g = max(1, _group_size(line))
        if op == "all-gather":
            rb = rb // g
        elif op == "reduce-scatter":
            rb = rb * g
        out[op] += rb
    return out


@dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    coll_bytes: float            # per-device collective operand bytes
    coll_detail: Dict[str, int]
    t_compute: float             # seconds
    t_memory: float
    t_collective: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound(self) -> float:
        """Roof-bound step time (s) = max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def fraction(self, model_flops_per_device: float) -> float:
        """Achievable roofline fraction = useful-compute time / bound."""
        t_useful = model_flops_per_device / PEAK_FLOPS
        return t_useful / max(self.bound, 1e-30)


def roofline_from_compiled(compiled, *, ici_links: int = 4,
                           hlo_text: Optional[str] = None) -> Roofline:
    """Three roofline terms from a compiled (partitioned) executable.

    ici_links: usable ICI links per chip for the dominant collective
    direction (v5e 2D torus: 4 links; conservative default)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):   # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    cb = float(sum(coll.values()))
    return Roofline(
        flops=flops, hbm_bytes=hbm, coll_bytes=cb, coll_detail=coll,
        t_compute=flops / PEAK_FLOPS,
        t_memory=hbm / HBM_BW,
        t_collective=cb / (ICI_BW * ici_links),
    )


def memory_per_device(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        out[k] = float(getattr(ma, k, 0) or 0)
    out["total_gib"] = (out["argument_size_in_bytes"]
                        + out["temp_size_in_bytes"]) / 2**30
    return out
