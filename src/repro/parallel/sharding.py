"""Logical-axis sharding rules (MaxText-style) for every param/state tree.

Each weight leaf is matched BY NAME to a tuple of logical axes; logical
axes map to mesh axes through ``ShardingRules``; any dim whose size does
not divide its mesh-axis extent degrades to replication (None) — this is
what absorbs awkward head counts (gpt2's 25 heads, phi3's 10 KV heads,
280 Up-blocks) without per-arch special cases.

Default logical->mesh map (production):
  embed(d_model) -> "data"   (FSDP: weights gathered per-layer on use)
  heads/ff/experts/vocab -> "model"  (TP / EP)
  batch -> ("pod", "data")
  kv_seq -> "model"  (decode only: the 32k/500k KV cache is sharded
            along sequence; GSPMD inserts the flash-decoding-style
            max/sum combines.  Chosen over head-sharding because KV-head
            counts of the assigned archs rarely divide 16 — see
            DESIGN.md §7.)
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Params = Dict[str, Any]

# logical axis names
BATCH = "batch"
EMBED = "embed"        # d_model dim of weights (FSDP)
HEADS = "heads"        # query heads
KV_HEADS = "kv"        # kv heads
FF = "ff"              # MLP hidden
EXPERTS = "experts"    # MoE expert dim
VOCAB = "vocab"
KV_SEQ = "kv_seq"      # KV-cache sequence dim (decode)
REP = None             # replicated


@dataclass(frozen=True)
class ShardingRules:
    """logical axis -> mesh axis (or tuple of mesh axes, or None)."""
    rules: Dict[str, Any] = field(default_factory=lambda: {
        BATCH: ("pod", "data"),
        EMBED: "data",
        HEADS: "model",
        KV_HEADS: "model",
        FF: "model",
        EXPERTS: "model",
        VOCAB: "model",
        KV_SEQ: "model",
    })

    def mesh_axes(self, logical: Optional[str], mesh: Mesh):
        if logical is None:
            return None
        ax = self.rules.get(logical)
        if ax is None:
            return None
        if isinstance(ax, tuple):
            present = tuple(a for a in ax if a in mesh.shape)
            return present if present else None
        return ax if ax in mesh.shape else None

    def spec(self, logical_axes: Tuple[Optional[str], ...], shape,
             mesh: Mesh) -> P:
        """Resolve logical axes -> PartitionSpec, dropping non-divisible."""
        out = []
        for ax_name, dim in zip(logical_axes, shape):
            m = self.mesh_axes(ax_name, mesh)
            if m is None:
                out.append(None)
                continue
            extent = (math.prod(mesh.shape[a] for a in m)
                      if isinstance(m, tuple) else mesh.shape[m])
            out.append(m if dim % extent == 0 else None)
        return P(*out)


# ---------------------------------------------------------------------------
# per-leaf logical axes, keyed by (parent, leaf-name) patterns
# ---------------------------------------------------------------------------

# name -> logical axes for the TRAILING dims (leading stacked n_blocks
# axis is always replicated).  Names are unique across the tree.
_WEIGHT_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    # attention (wq (D,H,dq), wk/wv (D,KV,d), wo (H,dv,D))
    "wq": (EMBED, HEADS, None),
    "wv": (EMBED, KV_HEADS, None),
    "wo": (HEADS, None, EMBED),
    # clover transitions
    "s_qk": (HEADS, None, None),
    "s_vo": (HEADS, None, None),
    "k_t": (KV_HEADS, None, None),
    "up_t": (FF, None, None),           # (n_up_blocks, blk, blk)
    "up_u": (EMBED, FF, None),          # (D, n_up_blocks, blk)
    # dense mlp
    "w_gate": (EMBED, FF),
    "w_up": (EMBED, FF),
    "w_down": (FF, EMBED),
    # moe (router (D,E), experts (E,D,de) / (E,de,D), shared (D,ds))
    "router": (EMBED, None),
    "shared_up": (EMBED, FF),
    "shared_gate": (EMBED, FF),
    "shared_down": (FF, EMBED),
    # mamba
    "in_proj": (EMBED, FF),
    "conv_w": (None, FF),
    "conv_b": (FF,),
    "x_proj": (FF, None),
    "dt_proj": (None, FF),
    "dt_bias": (FF,),
    "A_log": (FF, None),
    "D": (FF,),
    "out_proj": (FF, EMBED),
    # rwkv time/channel mix
    "wr": (EMBED, FF),
    "wg": (EMBED, FF),
    "w_lora_a": (EMBED, None),
    "w_lora_b": (None, FF),
    "u": (HEADS, None),
    "out": (FF, EMBED),
    # norms / mixing coefficients / small vectors: replicated
}

# context-dependent overrides: leaf "wk" means attention K (D,KV,d) in
# "attn" but channel-mix key (D,F) in "rwkv_chan".
_CONTEXT_AXES: Dict[Tuple[str, str], Tuple[Optional[str], ...]] = {
    ("attn", "wk"): (EMBED, KV_HEADS, None),
    ("rwkv_chan", "wk"): (EMBED, FF),
    ("rwkv_time", "wk"): (EMBED, FF),
    ("rwkv_time", "wv"): (EMBED, FF),
    ("moe", "w_up"): (EXPERTS, EMBED, None),
    ("moe", "w_gate"): (EXPERTS, EMBED, None),
    ("moe", "w_down"): (EXPERTS, None, EMBED),
    ("rwkv_chan", "up_u"): (EMBED, FF, None),
    ("rwkv_chan", "up_t"): (FF, None, None),
}

_TOP_LEVEL: Dict[str, Tuple[Optional[str], ...]] = {
    "embed": (VOCAB, EMBED),
    "pos_embed": (None, EMBED),
    "lm_head": (EMBED, VOCAB),
}


def _leaf_axes(path) -> Optional[Tuple[Optional[str], ...]]:
    names = [getattr(p, "key", None) for p in path
             if getattr(p, "key", None) is not None]
    leaf = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    if (parent, leaf) in _CONTEXT_AXES:
        return _CONTEXT_AXES[(parent, leaf)]
    if leaf in _TOP_LEVEL and "blocks" not in names:
        return _TOP_LEVEL[leaf]
    return _WEIGHT_AXES.get(leaf)


def param_specs(params: Params, mesh: Mesh,
                rules: Optional[ShardingRules] = None) -> Params:
    """PartitionSpec tree matching ``params`` (init_lm_params layout)."""
    rules = rules or ShardingRules()

    def visit(path, leaf):
        names = [getattr(p, "key", None) for p in path]
        axes = _leaf_axes(path)
        if axes is None:
            return P()          # replicate (norms, scalars, biases)
        shape = leaf.shape
        in_blocks = "blocks" in names
        if in_blocks:           # leading stacked n_blocks axis
            axes = (None,) + tuple(axes)
        # pad/truncate to rank
        axes = tuple(axes)[:len(shape)]
        axes = axes + (None,) * (len(shape) - len(axes))
        return rules.spec(axes, shape, mesh)

    return jax.tree_util.tree_map_with_path(visit, params)


def data_specs(mesh: Mesh, rules: Optional[ShardingRules] = None,
               global_batch: Optional[int] = None):
    """(tokens, labels) specs: batch over (pod, data); replicated when
    the batch doesn't divide (long_500k decode has batch 1)."""
    rules = rules or ShardingRules()
    b = rules.mesh_axes(BATCH, mesh)
    if b is not None and global_batch is not None:
        extent = (math.prod(mesh.shape[a] for a in b)
                  if isinstance(b, tuple) else mesh.shape[b])
        if global_batch % extent != 0:
            b = None
    return P(b, None)


def decode_state_specs(state: Params, mesh: Mesh,
                       rules: Optional[ShardingRules] = None) -> Params:
    """Decode-state tree: KV caches (B, T, KV, d) shard batch over
    (pod,data) and the cache sequence over "model" (see module doc);
    mamba/rwkv states shard batch and the inner dim over "model"."""
    rules = rules or ShardingRules()

    def visit(path, leaf):
        names = [getattr(p, "key", None) for p in path]
        shape = leaf.shape
        if "kv" in names:                       # (nb, B, T, KV, d)
            axes = (None, BATCH, KV_SEQ, None, None)
        elif "mamba" in names and path and getattr(
                path[-1], "key", "") == "ssm":  # (nb, B, dI, dS)
            axes = (None, BATCH, FF, None)
        elif "mamba" in names:                  # conv (nb, B, dc-1, dI)
            axes = (None, BATCH, None, FF)
        elif getattr(path[-1], "key", "") == "wkv":  # (nb, B, H, d, d)
            axes = (None, BATCH, HEADS, None, None)
        elif getattr(path[-1], "key", "") == "last_x":  # (nb, B, D)
            axes = (None, BATCH, None)
        elif getattr(path[-1], "key", "") == "index":
            return P()
        else:
            axes = (None, BATCH) + (None,) * (len(shape) - 2)
        axes = tuple(axes)[:len(shape)]
        axes = axes + (None,) * (len(shape) - len(axes))
        return rules.spec(axes, shape, mesh)

    return jax.tree_util.tree_map_with_path(visit, state)


def serve_rules() -> ShardingRules:
    """Logical->mesh map for the SERVING path (DESIGN.md §10).

    Training FSDP-shards weight d_model over "data"; a serving step is
    latency-bound and its weights are read every step, so here "data"
    carries only the slot batch and weights replicate across it.  Heads
    / ff / experts / vocab shard over "model" (tensor parallel), and —
    unlike the training decode rules — the KV cache shards along its
    KV-HEAD axis, not the sequence: with CLOVER's per-head rank plan
    the head axis is where bytes and FLOPs live, and the rank-balanced
    head partition (core/prune.rank_balanced_partition) equalizes them
    per shard.  KV_SEQ stays unsharded: page ids are host-global (one
    ``PageAllocator``), every shard holds the same page rows for its
    own heads.
    """
    return ShardingRules(rules={
        BATCH: "data",
        EMBED: None,
        HEADS: "model",
        KV_HEADS: "model",
        FF: "model",
        EXPERTS: "model",
        VOCAB: "model",
        KV_SEQ: None,
    })


def serve_state_specs(state: Params, mesh: Mesh, *, paged: bool,
                      rules: Optional[ShardingRules] = None) -> Params:
    """PartitionSpec tree for the serving engine's decode state.

    KV leaves shard along the KV-HEAD axis (axis -2 in both layouts:
    dense ``(nb, B, T, KV, r)`` and paged ``(nb, n_pages+1, PT, KV,
    r)``); the dense layout additionally shards slots over "data".  The
    paged pool's page-row axis is replicated — page ids are global, the
    host-side allocator/trie address the same rows on every shard.
    Recurrent (mamba/rwkv) leaves shard only their slot axis over
    "data" (their inner dims replicate across "model" — O(1) per
    token, not worth a collective); the index vector replicates.
    """
    rules = rules or serve_rules()

    def visit(path, leaf):
        names = [getattr(p, "key", None) for p in path]
        if getattr(path[-1], "key", "") == "index":
            return P()
        if "kv" in names:
            axes = ((None, None, None, KV_HEADS, None) if paged
                    else (None, BATCH, None, KV_HEADS, None))
        else:
            axes = (None, BATCH) + (None,) * (leaf.ndim - 2)
        axes = tuple(axes)[:leaf.ndim]
        axes = axes + (None,) * (leaf.ndim - len(axes))
        # normalize to jax's canonical form (size-1 mesh axes and
        # trailing Nones dropped) so the init placement is the SAME jit
        # cache key as the constrained step outputs — a cosmetic spec
        # difference would silently double every compiled shape
        def extent(m):
            return (math.prod(mesh.shape[a] for a in m)
                    if isinstance(m, tuple) else mesh.shape[m])
        spec = tuple(m if m is None or extent(m) > 1 else None
                     for m in rules.spec(axes, leaf.shape, mesh))
        while spec and spec[-1] is None:
            spec = spec[:-1]
        return P(*spec)

    return jax.tree_util.tree_map_with_path(visit, state)


def kernel_axes(mesh: Optional[Mesh], *, batch: int, kv_heads: int,
                rules: Optional[ShardingRules] = None
                ) -> Tuple[Optional[str], Optional[str]]:
    """(batch_axis, head_axis) mesh axes for per-shard KERNEL operand
    specs — the shard_map boundary of the serving hot path
    (kernels.ops.KernelDispatch).

    Reuses ``serve_rules()``: the slot batch splits over "data", KV
    heads over "model" — the same layout ``serve_state_specs`` gives
    the KV/page pools, so the shard_map'd kernels read the pool slices
    already resident on each shard.  A dim that does not divide its
    mesh axis (or whose axis has extent 1) degrades to None exactly as
    the rules do for placement: the kernel then runs replicated along
    that axis — correct, just not parallel.  ``mesh=None`` -> fully
    local (None, None).
    """
    if mesh is None:
        return None, None
    rules = rules or serve_rules()

    def pick(logical: str, dim: int) -> Optional[str]:
        m = rules.mesh_axes(logical, mesh)
        if m is None or isinstance(m, tuple):
            # serving kernels split over single named axes only
            return None
        return m if (mesh.shape[m] > 1 and dim % mesh.shape[m] == 0) \
            else None

    return pick(BATCH, batch), pick(KV_HEADS, kv_heads)


def shard_map_call(body, mesh: Mesh, in_specs, out_specs):
    """Version-compat shard_map: ``jax.shard_map`` (jax >= 0.5) or the
    0.4.x experimental spelling — the same idiom parallel.pipeline
    uses.  Replication checking is off: the kernel bodies contain
    pallas_call, which the checker cannot see through."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(body, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def opt_specs(param_spec_tree: Params) -> Params:
    """Optimizer moments inherit the param sharding; scalars replicate."""
    return param_spec_tree


def shardings(spec_tree: Params, mesh: Mesh) -> Params:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# ambient-mesh helpers: model code can hint shardings without plumbing a
# mesh argument through every layer — a no-op when no mesh is in context
# (CPU smoke tests).
# ---------------------------------------------------------------------------

def ambient_mesh() -> Optional[Mesh]:
    """The mesh currently in context (``with mesh:`` / set_mesh), or None."""
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:  # noqa: BLE001 — internal API; degrade gracefully
        pass
    get_am = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_am is not None:  # jax >= 0.5; absent on 0.4.x
        am = get_am()
        if am is not None and getattr(am, "shape", None):
            return am
    return None


def batch_mesh_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def constrain(x, logical_axes: Tuple[Optional[str], ...],
              rules: Optional[ShardingRules] = None):
    """with_sharding_constraint by logical axes, if a mesh is ambient."""
    mesh = ambient_mesh()
    if mesh is None:
        return x
    rules = rules or ShardingRules()
    axes = tuple(logical_axes)[:x.ndim]
    axes = axes + (None,) * (x.ndim - len(axes))
    return jax.lax.with_sharding_constraint(
        x, rules.spec(axes, x.shape, mesh))
