"""GPipe-style pipeline parallelism over a "pipe" mesh axis.

The assigned production mesh has no pipeline axis (DP x TP covers the
target pods), but a framework deployed at 1000+ nodes needs PP available
when a model's layers outgrow one pod's HBM.  This module provides it as
an opt-in: a deployment chooses a mesh with a "pipe" axis and runs
``pipeline_apply`` over the stage-stacked block params.

Schedule: classic GPipe — m microbatches flush through p stages
(bubble fraction (p-1)/(m+p-1)); activations hop stages via
``jax.lax.ppermute`` under ``jax.shard_map``.  Each device holds ONLY
its stage's blocks (leading axis of ``stage_params`` is sharded on
"pipe"), so weight memory scales 1/p.

The rotation trick: every device runs the SAME stage function on its
local microbatch slot; after each of the (m + p - 1) ticks the slot
buffer rotates one hop forward.  Microbatch i enters at tick i on stage
0 and exits stage p-1 at tick i + p - 1.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

Params = Dict[str, Any]


def pipeline_apply(stage_params: Params, x: jnp.ndarray, mesh: Mesh,
                   stage_fn: Callable[[Params, jnp.ndarray], jnp.ndarray],
                   *, n_microbatches: int, axis: str = "pipe",
                   ) -> jnp.ndarray:
    """Run ``stage_fn`` as a GPipe pipeline along ``axis``.

    stage_params: tree with leading axis == n_stages (sharded on
        ``axis``); stage i's slice parameterizes stage_fn on device i.
    x: (n_microbatches * mb, ...) global batch (microbatches contiguous).
    Returns stage_{p-1} outputs re-assembled in microbatch order.
    """
    p = mesh.shape[axis]
    m = n_microbatches
    assert x.shape[0] % m == 0, (x.shape, m)
    mb = x.shape[0] // m
    assert m >= p, "GPipe wants microbatches >= stages"

    perm_fwd = [(i, (i + 1) % p) for i in range(p)]

    def body(params_local, x_local):
        # params_local: stage slice (leading axis 1); x_local: (m, mb, ...)
        params_local = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)

        n_ticks = m + p - 1
        buf = jnp.zeros((mb,) + x_local.shape[2:], x_local.dtype)
        out = jnp.zeros_like(x_local)

        def tick(carry, t):
            buf, out = carry
            # stage 0 ingests microbatch t (if any remain)
            take = jnp.clip(t, 0, m - 1)
            fresh = x_local[take]
            buf = jnp.where((stage == 0) & (t < m), fresh, buf)
            # every stage computes
            y = stage_fn(params_local, buf)
            # last stage emits microbatch (t - p + 1)
            emit_idx = jnp.clip(t - p + 1, 0, m - 1)
            emit = (stage == p - 1) & (t >= p - 1)
            out = jnp.where(
                emit,
                jax.lax.dynamic_update_slice_in_dim(
                    out, y[None], emit_idx, axis=0),
                out)
            # rotate activations forward one stage
            buf = jax.lax.ppermute(y, axis, perm_fwd)
            return (buf, out), None

        (buf, out), _ = jax.lax.scan(
            tick, (buf, out), jnp.arange(n_ticks))
        # results live on the last stage; broadcast to all (psum of
        # one-hot masked buffer keeps the shape static)
        mask = (stage == p - 1).astype(out.dtype)
        out = jax.lax.psum(out * mask, axis)
        return out

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    xr = x.reshape((m, mb) + x.shape[1:])
    if hasattr(jax, "shard_map"):
        fn = jax.shard_map(
            body, mesh=mesh,
            in_specs=(spec_params, P()),
            out_specs=P(),
            axis_names={axis}, check_vma=False)
    else:  # jax 0.4.x spelling
        from jax.experimental.shard_map import shard_map
        fn = shard_map(
            body, mesh=mesh,
            in_specs=(spec_params, P()),
            out_specs=P(),
            check_rep=False)
    out = fn(stage_params, xr)
    return out.reshape(x.shape[:1] + out.shape[2:])
