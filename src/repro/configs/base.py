"""Architecture configuration dataclasses.

Every assigned architecture is expressed as an ``ArchConfig``.  The model
zoo (``repro.models``) is entirely config-driven: a single decoder builder
consumes these and produces init/apply functions, so CLOVER, sharding, and
the launchers never special-case an architecture by name.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Mixer kinds a layer can use.
MIXER_ATTN = "attn"
MIXER_MAMBA = "mamba"
MIXER_RWKV = "rwkv"

# MLP kinds.
MLP_DENSE = "dense"
MLP_MOE = "moe"
MLP_RWKV = "rwkv_ffn"


@dataclass(frozen=True)
class MoEConfig:
    """GShard-style top-k mixture of experts."""

    n_experts: int
    top_k: int
    n_shared: int = 0            # always-on shared experts (DeepSeek/Qwen style)
    d_expert: int = 0            # per-expert FFN hidden size (0 -> cfg.d_ff)
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_loss_coef: float = 1e-2

    def padded_experts(self, ep: int) -> int:
        """Experts padded up to a multiple of the expert-parallel degree."""
        return ((self.n_experts + ep - 1) // ep) * ep


@dataclass(frozen=True)
class CloverConfig:
    """CLOVER decomposition / pruning / fine-tuning switches.

    ``qk_rank``/``vo_rank`` are the retained ranks after pruning
    (0 = full head_dim, i.e. decomposed but unpruned).
    """

    enabled: bool = False
    qk_rank: int = 0
    vo_rank: int = 0
    finetune_s: bool = False      # keep S as trainable per-head matrices
    up_block: int = 64            # MLP.Up block size for intra-layer decomposition
    # Rank snapping for TPU tiling (sublane multiple).
    rank_multiple: int = 8


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads

    # Positional encoding of the attention path.
    rope: bool = True
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0      # fraction of head_dim that is rotated
    learned_pos: bool = False    # GPT-2 style absolute positions
    max_position: int = 524288

    # Layer pattern: one (mixer, mlp) pair per position in the repeating
    # period.  n_layers must be divisible by len(pattern).
    pattern: Tuple[Tuple[str, str], ...] = ((MIXER_ATTN, MLP_DENSE),)

    moe: Optional[MoEConfig] = None
    clover: CloverConfig = field(default_factory=CloverConfig)

    # Activation for dense MLPs: "swiglu" | "gelu" | "geglu"
    mlp_act: str = "swiglu"
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    attn_logit_softcap: float = 0.0

    # Mamba (hybrid archs).
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0       # 0 -> ceil(d_model / 16)

    # RWKV6.
    rwkv_head_dim: int = 64

    # Modality frontend: "none" | "audio" | "vision".  Non-none frontends
    # are stubs per the assignment: input_specs() provides precomputed
    # frame/patch embeddings which are concatenated before the text tokens.
    frontend: str = "none"
    frontend_len: int = 0        # number of frontend embedding positions
    frontend_dim: int = 0        # embedding dim delivered by the stub (== d_model)

    # Numerics.
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    # KV-cache storage dtype ("" -> compute_dtype).  float8_e4m3fn halves
    # decode HBM traffic on top of CLOVER rank pruning (beyond-paper; the
    # paper names quantization-compose as future work).  Values are
    # upcast to compute dtype at the attention einsum.
    kv_cache_dtype: str = ""

    # Token-mixing kernel implementation:
    #   "xla"       — einsum / chunked-jnp paths (default; what the
    #                 dry-run lowers, and fastest on CPU)
    #   "pallas"    — Pallas TPU kernels (compiled; TPU runtime)
    #   "interpret" — Pallas kernels in interpret mode (CPU validation)
    kernel_impl: str = "xla"

    # Unroll the layer stack (python loop) instead of lax.scan.  Used by
    # the dry-run so cost_analysis counts every layer (XLA counts a
    # `while` body ONCE, understating flops/collectives by ~n_blocks).
    # Training keeps scan: O(period) HLO and compile time.
    unroll_layers: bool = False

    # Grouped activation checkpointing: save the residual-stream carry
    # every `remat_group` blocks and recompute inside the group during
    # backward.  Carry memory scales 1/g at ~(g-1)/g extra block
    # recompute — the deep-model (62-layer deepseek) memory lever.
    remat_group: int = 1

    # Whether long_500k is runnable (sub-quadratic / state-space path).
    supports_long_context: bool = False

    # Pad the embedding/LM-head vocab dim up to this multiple so it
    # shards on the model axis (49155- and 92553-sized vocabs would
    # otherwise replicate the (B, S, V) logits on every device).  Padded
    # ids are masked to -inf in the logits; labels never reference them.
    pad_vocab_to: int = 1

    # ---- derived helpers -------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        m = max(1, self.pad_vocab_to)
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        assert self.n_heads % self.n_kv_heads == 0
        return self.n_heads // self.n_kv_heads

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def n_blocks(self) -> int:
        assert self.n_layers % self.period == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern period {self.period}")
        return self.n_layers // self.period

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def mamba_dt_rank_(self) -> int:
        return self.mamba_dt_rank if self.mamba_dt_rank else max(1, (self.d_model + 15) // 16)

    @property
    def rwkv_n_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def qk_dim(self) -> int:
        """Per-head Q/K projection width (CLOVER-pruned rank or head_dim)."""
        if self.clover.enabled and self.clover.qk_rank:
            return self.clover.qk_rank
        return self.head_dim_

    @property
    def vo_dim(self) -> int:
        if self.clover.enabled and self.clover.vo_rank:
            return self.clover.vo_rank
        return self.head_dim_

    @property
    def rope_dims(self) -> int:
        """Number of rotated dims per head (partial RoPE support)."""
        if not self.rope:
            return 0
        r = int(self.head_dim_ * self.rotary_pct)
        return (r // 2) * 2

    def uses_mixer(self, kind: str) -> bool:
        return any(m == kind for m, _ in self.pattern)

    def n_params(self, active_only: bool = False) -> int:
        """Approximate parameter count (used for MODEL_FLOPS roofline)."""
        D, H, KV = self.d_model, self.n_heads, self.n_kv_heads
        dq, dv = self.qk_dim, self.vo_dim
        total = self.vocab_size * D  # embeddings
        if not self.tie_embeddings:
            total += self.vocab_size * D
        per_pattern = []
        for mixer, mlp in self.pattern:
            p = 2 * D  # two norms
            if mixer == MIXER_ATTN:
                p += D * H * dq + D * KV * dq + D * KV * dv + H * dv * D
            elif mixer == MIXER_MAMBA:
                dI, dS = self.mamba_d_inner, self.mamba_d_state
                dt = self.mamba_dt_rank_
                p += D * 2 * dI + dI * self.mamba_d_conv
                p += dI * (dt + 2 * dS) + dt * dI + dI * dS + dI + dI * D
            elif mixer == MIXER_RWKV:
                p += 4 * D * D + D * D  # r,k,v,g,out
                p += 2 * 64 * D          # w lora (approx)
            if mlp == MLP_DENSE:
                mult = 3 if self.mlp_act in ("swiglu", "geglu") else 2
                p += mult * D * self.d_ff
            elif mlp == MLP_MOE:
                de = self.moe.d_expert or self.d_ff
                mult = 3 if self.mlp_act in ("swiglu", "geglu") else 2
                if active_only:
                    p += (self.moe.top_k + self.moe.n_shared) * mult * D * de
                else:
                    p += (self.moe.n_experts + self.moe.n_shared) * mult * D * de
                p += D * self.moe.n_experts  # router
            elif mlp == MLP_RWKV:
                p += 2 * D * self.d_ff + D * D
            per_pattern.append(p)
        total += self.n_blocks * sum(per_pattern)
        return total

    # ---- reduced config for CPU smoke tests ------------------------------
    def reduced(self, **overrides) -> "ArchConfig":
        """Same family/topology, tiny sizes — runnable on 1 CPU core."""
        moe = self.moe
        if moe is not None:
            moe = dataclasses.replace(
                moe, n_experts=min(moe.n_experts, 4),
                top_k=min(moe.top_k, 2), n_shared=min(moe.n_shared, 1),
                d_expert=64 if moe.d_expert else 0)
        small = dict(
            n_layers=self.period * min(self.n_blocks, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=max(1, 4 * self.n_kv_heads // self.n_heads),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            max_position=1024,
            moe=moe,
            mamba_dt_rank=8,
            rwkv_head_dim=32,
            frontend_len=min(self.frontend_len, 8) if self.frontend_len else 0,
            frontend_dim=128 if self.frontend != "none" else 0,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


def jamba_pattern(attn_period: int = 8, attn_offset: int = 4,
                  moe_period: int = 2, moe_offset: int = 1) -> Tuple[Tuple[str, str], ...]:
    """Jamba's interleave: 1 attention layer per `attn_period`, MoE every
    `moe_period` layers.  Returns one full period (lcm)."""
    period = attn_period  # lcm(8, 2) == 8
    pat = []
    for i in range(period):
        mixer = MIXER_ATTN if i % attn_period == attn_offset else MIXER_MAMBA
        mlp = MLP_MOE if i % moe_period == moe_offset else MLP_DENSE
        pat.append((mixer, mlp))
    return tuple(pat)
