"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32e top-8.
"""
from repro.configs.base import ArchConfig, MoEConfig, MIXER_ATTN, MLP_MOE

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    rope=True,
    rope_theta=10000.0,
    pattern=((MIXER_ATTN, MLP_MOE),),
    moe=MoEConfig(n_experts=32, top_k=8, n_shared=0, d_expert=512),
    mlp_act="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
)
