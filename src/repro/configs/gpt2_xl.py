"""gpt2-xl — the paper's own pruning testbed (Table 1).

48L d_model=1600 25H (MHA) d_ff=6400 vocab=50257, learned absolute
positions (no RoPE) -> full cross-layer Q-K + V-O CLOVER, exactly the
paper's setting.  Not part of the assigned 10-arch pool; used by
benchmarks/table1_pruning.py at reduced scale.
"""
from repro.configs.base import ArchConfig, MIXER_ATTN, MLP_DENSE

CONFIG = ArchConfig(
    name="gpt2-xl",
    family="dense",
    n_layers=48,
    d_model=1600,
    n_heads=25,
    n_kv_heads=25,
    head_dim=64,
    d_ff=6400,
    vocab_size=50257,
    rope=False,
    learned_pos=True,
    max_position=1024,
    pattern=((MIXER_ATTN, MLP_DENSE),),
    mlp_act="gelu",
    norm="layernorm",
    tie_embeddings=True,
)
