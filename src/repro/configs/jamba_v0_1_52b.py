"""jamba-v0.1-52b [arXiv:2403.19887] — Mamba + attention 1:7 interleave, MoE.

32L d_model=4096 32H (GQA kv=8) d_ff=14336, MoE 16e top-2.
Jamba's attention layers use no positional encoding (Mamba carries
position) -> full cross-layer Q-K CLOVER applies to the attention layers.
Supports long_500k: Mamba state is O(1); the 4 attention layers use a
sequence-sharded KV cache with a shard_map flash-decoding combine.
"""
from repro.configs.base import ArchConfig, MoEConfig, jamba_pattern

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    rope=False,
    pattern=jamba_pattern(attn_period=8, attn_offset=4, moe_period=2, moe_offset=1),
    moe=MoEConfig(n_experts=16, top_k=2, n_shared=0, d_expert=14336),
    mlp_act="swiglu",
    norm="rmsnorm",
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    supports_long_context=True,
)
