"""Architecture config registry.

``get_config(arch_id)`` returns the full assigned config; every config also
exposes ``.reduced()`` for CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig, MoEConfig, CloverConfig  # noqa: F401
from repro.configs.shapes import SHAPES, ShapeConfig, cell_applicable  # noqa: F401

_MODULES = {
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
    "minitron-4b": "repro.configs.minitron_4b",
    "stablelm-3b": "repro.configs.stablelm_3b",
    "rwkv6-1.6b": "repro.configs.rwkv6_1_6b",
    "musicgen-large": "repro.configs.musicgen_large",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "internvl2-2b": "repro.configs.internvl2_2b",
    # the paper's own testbed (not in the assigned pool)
    "gpt2-xl": "repro.configs.gpt2_xl",
}

ASSIGNED_ARCHS: List[str] = [k for k in _MODULES if k != "gpt2-xl"]


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {name: get_config(name) for name in _MODULES}
