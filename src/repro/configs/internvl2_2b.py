"""internvl2-2b [arXiv:2404.16821] — InternViT + InternLM2 backbone.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.  The InternViT
frontend is a stub: input_specs() provides precomputed patch embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    rope=True,
    rope_theta=1e6,
    pattern=(("attn", "dense"),),
    mlp_act="swiglu",
    norm="rmsnorm",
    frontend="vision",
    frontend_len=256,    # stub: 256 precomputed ViT patch embeddings
    frontend_dim=2048,
)
