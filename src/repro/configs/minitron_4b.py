"""minitron-4b [arXiv:2407.14679] — pruned nemotron.

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.
"""
from repro.configs.base import ArchConfig, MIXER_ATTN, MLP_DENSE

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    rope=True,
    rope_theta=10000.0,
    pattern=((MIXER_ATTN, MLP_DENSE),),
    mlp_act="gelu",   # nemotron uses squared-relu; gelu family stands in
    norm="layernorm",
)
