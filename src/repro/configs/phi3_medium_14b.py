"""phi3-medium-14b [arXiv:2404.14219].

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352. RoPE + SwiGLU + GQA.
"""
from repro.configs.base import ArchConfig, MIXER_ATTN, MLP_DENSE

CONFIG = ArchConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    head_dim=128,
    d_ff=17920,
    vocab_size=100352,
    rope=True,
    rope_theta=10000.0,
    pattern=((MIXER_ATTN, MLP_DENSE),),
    mlp_act="swiglu",
    norm="rmsnorm",
)
