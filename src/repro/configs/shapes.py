"""Assigned input shapes and (arch x shape) cell applicability.

LM transformer shapes are seq_len x global_batch.  ``decode_*``/``long_*``
lower ``serve_step`` (one new token against a KV cache of ``seq_len``),
NOT ``train_step``.  ``long_500k`` requires a sub-quadratic token-mixing
path and is only run for SSM/hybrid archs (see DESIGN.md §5); pure
full-attention archs skip it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_applicable(arch_cfg, shape: ShapeConfig) -> bool:
    """Whether (arch x shape) is a runnable cell.

    long_500k needs sub-quadratic attention (SSM / hybrid with
    sequence-sharded KV); skipped otherwise per the assignment, noted in
    DESIGN.md.  All assigned archs are decoder-style so decode shapes
    always apply otherwise.
    """
    if shape.name == "long_500k":
        return arch_cfg.supports_long_context
    return True
