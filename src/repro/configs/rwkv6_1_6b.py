"""rwkv6-1.6b (Finch) [arXiv:2404.05892] — attention-free, data-dependent decay.

24L d_model=2048 d_ff=7168 vocab=65536.  head_dim=64 -> 32 wkv heads.
CLOVER Q-K/V-O is inapplicable (no attention); the paper's MLP.Up blockwise
decomposition applies to channel-mix (DESIGN.md §5).  Supports long_500k
(O(1) recurrent state).
"""
from repro.configs.base import ArchConfig, MIXER_RWKV, MLP_RWKV

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,          # wkv heads (d_model / rwkv_head_dim)
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    rope=False,
    pattern=((MIXER_RWKV, MLP_RWKV),),
    norm="layernorm",
    rwkv_head_dim=64,
    supports_long_context=True,
)
