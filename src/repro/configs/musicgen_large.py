"""musicgen-large [arXiv:2306.05284] — decoder-only over EnCodec tokens.

48L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=2048.  Sinusoidal positions
(no RoPE) -> the cleanest CLOVER case: full cross-layer Q-K and V-O
orthogonalization (like the paper's Whisper §4.4 training-free pruning).
The EnCodec frontend is a stub: input_specs() provides precomputed frame
embeddings per the assignment.
"""
from repro.configs.base import ArchConfig, MIXER_ATTN, MLP_DENSE

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    rope=False,
    learned_pos=False,   # sinusoidal, added in-model
    pattern=((MIXER_ATTN, MLP_DENSE),),
    mlp_act="gelu",
    norm="layernorm",
    frontend="audio",
    frontend_len=250,    # stub: 250 precomputed EnCodec frame embeddings
    frontend_dim=2048,
)
