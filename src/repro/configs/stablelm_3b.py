"""stablelm-3b [hf:stabilityai/stablelm-2 family].

32L d_model=2560 32H (MHA kv=32) d_ff=6912 vocab=50304.
StableLM-2 uses partial rotary (rotary_pct=0.25): only 25% of head dims are
rotated.  Beyond-paper: the remaining 75% NoPE dims admit full cross-layer
Q-K CLOVER blockwise (see DESIGN.md §5).
"""
from repro.configs.base import ArchConfig, MIXER_ATTN, MLP_DENSE

CONFIG = ArchConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab_size=50304,
    rope=True,
    rope_theta=10000.0,
    rotary_pct=0.25,
    pattern=((MIXER_ATTN, MLP_DENSE),),
    mlp_act="swiglu",
    norm="layernorm",
)
