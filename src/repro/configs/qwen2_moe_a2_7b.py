"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (kv=16, i.e. MHA) d_ff=1408 vocab=151936,
MoE: 4 shared + 60 routed, top-4.  RoPE -> Q-K CLOVER falls back to
intra-layer K decomposition; V-O CLOVER applies (MHA, group size 1).
"""
from repro.configs.base import ArchConfig, MoEConfig, MIXER_ATTN, MLP_MOE

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151936,
    rope=True,
    rope_theta=1e6,
    pattern=((MIXER_ATTN, MLP_MOE),),
    moe=MoEConfig(n_experts=60, top_k=4, n_shared=4, d_expert=1408),
    mlp_act="swiglu",
    norm="rmsnorm",
)
