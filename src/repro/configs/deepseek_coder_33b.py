"""deepseek-coder-33b [arXiv:2401.14196] — llama-arch.

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.
"""
from repro.configs.base import ArchConfig, MIXER_ATTN, MLP_DENSE

CONFIG = ArchConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32256,
    rope=True,
    rope_theta=100000.0,
    pattern=((MIXER_ATTN, MLP_DENSE),),
    mlp_act="swiglu",
    norm="rmsnorm",
)
