"""CLOVER core: cross-layer orthogonal decomposition, pruning, PEFT."""
from repro.core.decompose import (  # noqa: F401
    clover_decompose, merge_clover, svd_lowrank_product, svd_tall, qk_mode)
from repro.core.prune import (  # noqa: F401
    clover_prune, vanilla_prune, plan_ranks, draft_ranks, threshold_ratios,
    snap_rank, HeadPartition, head_rank_loads, rank_balanced_partition,
    permute_attention_heads, mask_head_ranks, RankBudget, plan_rank_budget,
    apply_rank_budget, budget_kept_energy)
from repro.core.peft import (  # noqa: F401
    PeftConfig, partition, combine, count_params, init_adapters,
    materialize, pissa_residual, merge_adapters, CLOVER_TRAIN_KEYS,
    sv_extract, sv_fold, AdapterRegistry)
