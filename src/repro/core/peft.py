"""Parameter-efficient fine-tuning: CLOVER-S plus the paper's baselines.

CLOVER (the paper's method): after ``clover_decompose(peft=True)`` the
trainable transitions live INSIDE the param tree under the keys
``s_qk / k_t / s_vo / up_t``.  ``partition`` splits the tree into
(trainable, frozen) halves for the optimizer; ``merge_clover`` folds the
transitions back afterwards (zero inference overhead).

Baselines for Table 2 (LoRA / DoRA / PiSSA) are implemented as adapter
trees over 2D-flattened target weights; ``materialize`` produces the
effective params for the forward pass.  At benchmark scale the W + AB
materialization per step is negligible; production CLOVER needs no
materialization at all — which is exactly the paper's point.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

# Keys that clover_decompose(peft=True) marks trainable.
CLOVER_TRAIN_KEYS = ("s_qk", "k_t", "s_vo", "up_t")

# LoRA-family default targets (paper Table 3: Q, K, V, Up, Down).
LORA_TARGETS = ("wq", "wk", "wv", "w_up", "w_down")


# ---------------------------------------------------------------------------
# partition / combine for CLOVER-S training
# ---------------------------------------------------------------------------

def _is_trainable_path(path) -> bool:
    for p in path:
        key = getattr(p, "key", None)
        if key in CLOVER_TRAIN_KEYS:
            return True
    return False


def partition(params: Params) -> Tuple[Params, Params]:
    """Split into (trainable, frozen) trees of identical structure, with
    ``None`` at the complementary positions."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    train_leaves, frozen_leaves = [], []
    for path, leaf in flat:
        if _is_trainable_path(path):
            train_leaves.append(leaf)
            frozen_leaves.append(None)
        else:
            train_leaves.append(None)
            frozen_leaves.append(leaf)
    return (jax.tree_util.tree_unflatten(treedef, train_leaves),
            jax.tree_util.tree_unflatten(treedef, frozen_leaves))


def combine(trainable: Params, frozen: Params) -> Params:
    """Inverse of partition."""
    return jax.tree.map(
        lambda a, b: a if b is None else b, frozen, trainable,
        is_leaf=lambda x: x is None)


def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree)
               if x is not None)


# ---------------------------------------------------------------------------
# LoRA / DoRA / PiSSA baselines
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PeftConfig:
    method: str = "lora"          # lora | dora | pissa
    rank: int = 32
    alpha: float = 32.0
    targets: Tuple[str, ...] = LORA_TARGETS

    @property
    def scale(self) -> float:
        return self.alpha / self.rank


def _flat2d(w: jnp.ndarray) -> jnp.ndarray:
    """Flatten a stacked target weight to (n_blocks, in, out).

    Block weights carry a leading ``n_blocks`` scan axis:
      (nb, D, F)        -> unchanged                      (w_up / w_down)
      (nb, D, H, d)     -> (nb, D, H*d)                   (wq / wk / wv)
      (nb, H, d, D)     -> (nb, H*d, D)                   (wo)
    """
    if w.ndim == 3:
        return w
    if w.ndim == 4:
        if w.shape[1] >= w.shape[3]:
            return w.reshape(w.shape[0], w.shape[1], -1)
        return w.reshape(w.shape[0], -1, w.shape[3])
    raise ValueError(w.shape)


def _targets(params: Params, pcfg: PeftConfig):
    """Yield (path, leaf) for every adapter target leaf (block weights)."""
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        key = getattr(path[-1], "key", None)
        if key in pcfg.targets and leaf.ndim >= 3:
            yield path, leaf


def init_adapters(params: Params, pcfg: PeftConfig, key) -> Params:
    """Adapter tree keyed by flattened path string; one adapter per block
    (leading ``nb`` axis throughout).

    lora:  {a (nb, r, in), b (nb, out, r)}           b zero-init
    dora:  lora + {m (nb, out)} column magnitudes
    pissa: {a, b} = principal SVD factors; params must then be replaced
           by ``pissa_residual`` so materialize == original at init.
    """
    adapters: Params = {}
    leaves = list(_targets(params, pcfg))
    keys = jax.random.split(key, max(1, len(leaves)))
    for (path, leaf), k in zip(leaves, keys):
        name = jax.tree_util.keystr(path)
        W = _flat2d(leaf).astype(jnp.float32)                 # (nb, in, out)
        nb, n_in, n_out = W.shape
        r = min(pcfg.rank, min(n_in, n_out))
        if pcfg.method in ("lora", "dora"):
            a = jax.random.normal(k, (nb, r, n_in)) * (1.0 / jnp.sqrt(n_in))
            b = jnp.zeros((nb, n_out, r), jnp.float32)
            ad = {"a": a, "b": b}
            if pcfg.method == "dora":
                ad["m"] = jnp.linalg.norm(W, axis=1)          # (nb, out)
        elif pcfg.method == "pissa":
            # W (nb, in, out) = U S Vt with U (nb, in, k), Vt (nb, k, out).
            U, S, Vt = jax.vmap(
                lambda w: jnp.linalg.svd(w, full_matrices=False))(W)
            sr = jnp.sqrt(S[:, :r])                           # (nb, r)
            a = jnp.swapaxes(U[:, :, :r], 1, 2) * sr[:, :, None]   # (nb, r, in)
            b = jnp.swapaxes(Vt[:, :r, :], 1, 2) * sr[:, None, :]  # (nb, out, r)
            ad = {"a": a, "b": b}
        else:
            raise ValueError(pcfg.method)
        adapters[name] = ad
    return adapters


def _delta(ad) -> jnp.ndarray:
    """(nb, in, out) low-rank update."""
    return jnp.einsum("nor,nri->nio", ad["b"], ad["a"])


def materialize(params: Params, adapters: Params, pcfg: PeftConfig) -> Params:
    """Effective params for the forward pass: W' = f(W, adapter)."""
    def visit(path, leaf):
        name = jax.tree_util.keystr(path)
        if name not in adapters:
            return leaf
        ad = adapters[name]
        W = _flat2d(leaf).astype(jnp.float32)                 # (nb, in, out)
        if pcfg.method == "pissa":
            # params here are the RESIDUAL (see pissa_residual); training
            # moves the principal component itself -> full-step updates.
            Wp = W + _delta(ad)
        elif pcfg.method == "dora":
            V = W + pcfg.scale * _delta(ad)
            norm = jnp.linalg.norm(V, axis=1, keepdims=True)
            Wp = ad["m"][:, None, :] * V / jnp.maximum(norm, 1e-6)
        else:
            Wp = W + pcfg.scale * _delta(ad)
        return Wp.reshape(leaf.shape).astype(leaf.dtype)
    return jax.tree_util.tree_map_with_path(visit, params)


def pissa_residual(params: Params, adapters: Params, pcfg: PeftConfig) -> Params:
    """Subtract the initial principal component so that
    materialize(residual, adapters) == original params at init."""
    def visit(path, leaf):
        name = jax.tree_util.keystr(path)
        if name not in adapters:
            return leaf
        W = _flat2d(leaf).astype(jnp.float32) - _delta(adapters[name])
        return W.reshape(leaf.shape).astype(leaf.dtype)
    return jax.tree_util.tree_map_with_path(visit, params)


def merge_adapters(params: Params, adapters: Params, pcfg: PeftConfig) -> Params:
    """Fold adapters into the weights (post-training)."""
    return materialize(params, adapters, pcfg)
