"""Parameter-efficient fine-tuning: CLOVER-S plus the paper's baselines.

CLOVER (the paper's method): after ``clover_decompose(peft=True)`` the
trainable transitions live INSIDE the param tree under the keys
``s_qk / k_t / s_vo / up_t``.  ``partition`` splits the tree into
(trainable, frozen) halves for the optimizer; ``merge_clover`` folds the
transitions back afterwards (zero inference overhead).

Baselines for Table 2 (LoRA / DoRA / PiSSA) are implemented as adapter
trees over 2D-flattened target weights; ``materialize`` produces the
effective params for the forward pass.  At benchmark scale the W + AB
materialization per step is negligible; production CLOVER needs no
materialization at all — which is exactly the paper's point.

Serving-side SV adapters (DESIGN.md §13): ``sv_extract`` / ``sv_fold``
round-trip the rank-space diagonals of the decomposed transitions, and
``AdapterRegistry`` keeps per-tenant diagonal scalings that the serving
engine applies as an elementwise multiply — zero extra matmuls, and the
identity adapter is bitwise the base model.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

# Keys that clover_decompose(peft=True) marks trainable.
CLOVER_TRAIN_KEYS = ("s_qk", "k_t", "s_vo", "up_t")

# LoRA-family default targets (paper Table 3: Q, K, V, Up, Down).
LORA_TARGETS = ("wq", "wk", "wv", "w_up", "w_down")


# ---------------------------------------------------------------------------
# partition / combine for CLOVER-S training
# ---------------------------------------------------------------------------

def _is_trainable_path(path) -> bool:
    for p in path:
        key = getattr(p, "key", None)
        if key in CLOVER_TRAIN_KEYS:
            return True
    return False


def partition(params: Params) -> Tuple[Params, Params]:
    """Split into (trainable, frozen) trees of identical structure, with
    ``None`` at the complementary positions."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    train_leaves, frozen_leaves = [], []
    for path, leaf in flat:
        if _is_trainable_path(path):
            train_leaves.append(leaf)
            frozen_leaves.append(None)
        else:
            train_leaves.append(None)
            frozen_leaves.append(leaf)
    return (jax.tree_util.tree_unflatten(treedef, train_leaves),
            jax.tree_util.tree_unflatten(treedef, frozen_leaves))


def combine(trainable: Params, frozen: Params) -> Params:
    """Inverse of partition."""
    return jax.tree.map(
        lambda a, b: a if b is None else b, frozen, trainable,
        is_leaf=lambda x: x is None)


def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree)
               if x is not None)


# ---------------------------------------------------------------------------
# LoRA / DoRA / PiSSA baselines
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PeftConfig:
    method: str = "lora"          # lora | dora | pissa
    rank: int = 32
    alpha: float = 32.0
    targets: Tuple[str, ...] = LORA_TARGETS

    @property
    def scale(self) -> float:
        """Nominal scale; per-adapter code must prefer ``alpha / r_eff``
        because ``init_adapters`` clamps the rank on narrow targets."""
        return self.alpha / self.rank


def _flat2d(w: jnp.ndarray) -> jnp.ndarray:
    """Flatten a stacked target weight to (n_blocks, in, out).

    Block weights carry a leading ``n_blocks`` scan axis:
      (nb, D, F)        -> unchanged                      (w_up / w_down)
      (nb, D, H, d)     -> (nb, D, H*d)                   (wq / wk / wv)
      (nb, H, d, D)     -> (nb, H*d, D)                   (wo)
    """
    if w.ndim == 3:
        return w
    if w.ndim == 4:
        if w.shape[1] >= w.shape[3]:
            return w.reshape(w.shape[0], w.shape[1], -1)
        return w.reshape(w.shape[0], -1, w.shape[3])
    raise ValueError(w.shape)


def _targets(params: Params, pcfg: PeftConfig):
    """Yield (path, leaf) for every adapter target leaf (block weights)."""
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        key = getattr(path[-1], "key", None)
        if key in pcfg.targets and leaf.ndim >= 3:
            yield path, leaf


def init_adapters(params: Params, pcfg: PeftConfig, key) -> Params:
    """Adapter tree keyed by flattened path string; one adapter per block
    (leading ``nb`` axis throughout).

    lora:  {a (nb, r, in), b (nb, out, r)}           b zero-init
    dora:  lora + {m (nb, out)} column magnitudes
    pissa: {a, b} = principal SVD factors; params must then be replaced
           by ``pissa_residual`` so materialize == original at init.
    """
    adapters: Params = {}
    leaves = list(_targets(params, pcfg))
    keys = jax.random.split(key, max(1, len(leaves)))
    for (path, leaf), k in zip(leaves, keys):
        name = jax.tree_util.keystr(path)
        W = _flat2d(leaf).astype(jnp.float32)                 # (nb, in, out)
        nb, n_in, n_out = W.shape
        r = min(pcfg.rank, min(n_in, n_out))
        if pcfg.method in ("lora", "dora"):
            a = jax.random.normal(k, (nb, r, n_in)) * (1.0 / jnp.sqrt(n_in))
            b = jnp.zeros((nb, n_out, r), jnp.float32)
            ad = {"a": a, "b": b}
            if pcfg.method == "dora":
                ad["m"] = jnp.linalg.norm(W, axis=1)          # (nb, out)
        elif pcfg.method == "pissa":
            # W (nb, in, out) = U S Vt with U (nb, in, k), Vt (nb, k, out).
            U, S, Vt = jax.vmap(
                lambda w: jnp.linalg.svd(w, full_matrices=False))(W)
            sr = jnp.sqrt(S[:, :r])                           # (nb, r)
            a = jnp.swapaxes(U[:, :, :r], 1, 2) * sr[:, :, None]   # (nb, r, in)
            b = jnp.swapaxes(Vt[:, :r, :], 1, 2) * sr[:, None, :]  # (nb, out, r)
            ad = {"a": a, "b": b}
        else:
            raise ValueError(pcfg.method)
        # the clamp above can shrink r below pcfg.rank on narrow targets;
        # materialize must scale by alpha / THIS rank, not the nominal one.
        # Stored as a 0-d float so the adapter dict stays a valid jax tree
        # for grad/optimizer transforms (stop_gradient'd at use).
        ad["r_eff"] = jnp.float32(r)
        adapters[name] = ad
    return adapters


def _delta(ad) -> jnp.ndarray:
    """(nb, in, out) low-rank update."""
    return jnp.einsum("nor,nri->nio", ad["b"], ad["a"])


def _ad_scale(ad, pcfg: PeftConfig):
    """alpha / effective rank for ONE adapter (falls back to the nominal
    ``pcfg.scale`` for adapter dicts predating the ``r_eff`` field)."""
    r_eff = ad.get("r_eff")
    if r_eff is None:
        return pcfg.scale
    return pcfg.alpha / jax.lax.stop_gradient(r_eff)


def materialize(params: Params, adapters: Params, pcfg: PeftConfig) -> Params:
    """Effective params for the forward pass: W' = f(W, adapter)."""
    def visit(path, leaf):
        name = jax.tree_util.keystr(path)
        if name not in adapters:
            return leaf
        ad = adapters[name]
        W = _flat2d(leaf).astype(jnp.float32)                 # (nb, in, out)
        if pcfg.method == "pissa":
            # params here are the RESIDUAL (see pissa_residual); training
            # moves the principal component itself -> full-step updates.
            Wp = W + _delta(ad)
        elif pcfg.method == "dora":
            V = W + _ad_scale(ad, pcfg) * _delta(ad)
            norm = jnp.linalg.norm(V, axis=1, keepdims=True)
            Wp = ad["m"][:, None, :] * V / jnp.maximum(norm, 1e-6)
        else:
            Wp = W + _ad_scale(ad, pcfg) * _delta(ad)
        return Wp.reshape(leaf.shape).astype(leaf.dtype)
    return jax.tree_util.tree_map_with_path(visit, params)


def pissa_residual(params: Params, adapters: Params, pcfg: PeftConfig) -> Params:
    """Subtract the initial principal component so that
    materialize(residual, adapters) == original params at init."""
    def visit(path, leaf):
        name = jax.tree_util.keystr(path)
        if name not in adapters:
            return leaf
        W = _flat2d(leaf).astype(jnp.float32) - _delta(adapters[name])
        return W.reshape(leaf.shape).astype(leaf.dtype)
    return jax.tree_util.tree_map_with_path(visit, params)


def merge_adapters(params: Params, adapters: Params, pcfg: PeftConfig) -> Params:
    """Fold adapters into the weights (post-training)."""
    return materialize(params, adapters, pcfg)


# ---------------------------------------------------------------------------
# SV adapters: per-tenant diagonal scalings of the CLOVER transitions
# (serving-side counterpart of CLOVER-S; DESIGN.md §13)
# ---------------------------------------------------------------------------

# (transition key in the layer tree, diagonal key in the adapter tree)
SV_ADAPTER_KEYS = (("s_qk", "s_qk_diag"), ("s_vo", "s_vo_diag"))


def sv_extract(params: Params) -> Tuple[Dict[str, jnp.ndarray], ...]:
    """Pull the rank-space diagonals of the CLOVER SV transitions.

    After ``clover_decompose(peft=True)`` every attention layer carries
    ``s_qk / s_vo`` transitions stacked ``(nb, H, d, d)`` whose diagonals
    are the paper's trainable singular values.  Returns one dict per
    pattern position with ``s_qk_diag (nb, H, dq)`` / ``s_vo_diag
    (nb, H, dv)``; a key is absent when the position has no matching
    transition (full-RoPE Q-K, non-attention mixers, undecomposed model).
    """
    out = []
    for stacked in params["blocks"]:
        entry = {}
        attn = stacked.get("attn", {})
        for src, dst in SV_ADAPTER_KEYS:
            if src in attn:
                entry[dst] = jnp.diagonal(attn[src], axis1=-2, axis2=-1)
        out.append(entry)
    return tuple(out)


def sv_fold(params: Params, adapter) -> Params:
    """Write an SV-adapter tree's diagonals back into the transitions.

    Exact inverse of :func:`sv_extract`:
    ``sv_fold(params, sv_extract(params))`` is bitwise-identical to
    ``params``.  Only the diagonal entries are touched — off-diagonal
    content (e.g. the partial-RoPE identity block) and every other key
    (``k_t`` / ``up_t`` included) pass through untouched.
    """
    new_blocks = []
    for stacked, entry in zip(params["blocks"], adapter):
        stacked = dict(stacked)
        if entry and "attn" in stacked:
            attn = dict(stacked["attn"])
            for src, dst in SV_ADAPTER_KEYS:
                if dst in entry:
                    mat = attn[src]
                    eye = jnp.eye(mat.shape[-1], dtype=bool)
                    attn[src] = jnp.where(
                        eye, entry[dst][..., :, None].astype(mat.dtype), mat)
            stacked["attn"] = attn
        new_blocks.append(stacked)
    out = dict(params)
    out["blocks"] = tuple(new_blocks)
    return out


class AdapterRegistry:
    """Host-side, versioned registry of per-tenant SV adapters.

    Stores MULTIPLICATIVE per-head rank-space scale trees shaped like
    :func:`sv_extract` output.  Adapter id 0 is always the identity
    (all-ones): the serving engine applies adapters as an elementwise
    ``x * scale`` after the ``s_qk`` / ``s_vo`` einsums, and IEEE
    ``x * 1.0 == x`` makes identity-adapter streams bitwise equal to
    the base model.  Ids are dense ``0..n-1`` so the engine can stack
    every adapter into one fixed-shape gather bank (DESIGN.md §13).
    """

    def __init__(self, params: Params):
        self._base = sv_extract(params)
        if not any(self._base):
            raise ValueError(
                "AdapterRegistry needs clover_decompose(peft=True) params "
                "(no s_qk/s_vo transitions found)")
        identity = tuple({k: jnp.ones_like(v) for k, v in entry.items()}
                         for entry in self._base)
        self._scales = [identity]
        self._versions = [0]
        self.generation = 0

    def __len__(self) -> int:
        return len(self._scales)

    @property
    def n_adapters(self) -> int:
        return len(self._scales)

    def _validated(self, scales):
        scales = tuple(dict(entry) for entry in scales)
        if len(scales) != len(self._base):
            raise ValueError(
                f"adapter has {len(scales)} pattern positions, "
                f"base has {len(self._base)}")
        for entry, base in zip(scales, self._base):
            if set(entry) != set(base):
                raise ValueError(
                    f"adapter keys {sorted(entry)} != base {sorted(base)}")
            for k, v in entry.items():
                if tuple(v.shape) != tuple(base[k].shape):
                    raise ValueError(
                        f"{k}: adapter shape {v.shape} != "
                        f"base {base[k].shape}")
        return scales

    def scales_from_finetuned(self, diags):
        """Convert a fine-tuned :func:`sv_extract` tree (absolute singular
        values) into the multiplicative scales the engine applies, i.e.
        ``finetuned / base`` with pruned (zero) base entries left at 1."""
        return tuple(
            {k: jnp.where(base[k] != 0, v / base[k],
                          jnp.ones_like(v)).astype(jnp.float32)
             for k, v in entry.items()}
            for entry, base in zip(diags, self._base))

    def register(self, scales) -> int:
        """Add an adapter (multiplicative scale tree); returns its id."""
        self._scales.append(self._validated(scales))
        self._versions.append(0)
        self.generation += 1
        return len(self._scales) - 1

    def update(self, adapter_id: int, scales) -> int:
        """Replace an adapter in place; returns its bumped version."""
        if adapter_id == 0:
            raise ValueError("adapter id 0 is the reserved identity")
        self._scales[adapter_id] = self._validated(scales)
        self._versions[adapter_id] += 1
        self.generation += 1
        return self._versions[adapter_id]

    def get(self, adapter_id: int):
        return self._scales[adapter_id]

    def version(self, adapter_id: int) -> int:
        return self._versions[adapter_id]

    def folded(self, params: Params, adapter_id: int) -> Params:
        """``params`` with adapter ``adapter_id`` merged into the
        ``s_qk``/``s_vo`` diagonals — the single-tenant model whose
        whole-prompt replay every multi-tenant stream is gated against
        (DESIGN.md §13).  Identity folds back bitwise."""
        scaled = tuple(
            {k: base[k] * entry[k] for k in base}
            for base, entry in zip(self._base, self._scales[adapter_id]))
        return sv_fold(params, scaled)

    def bank(self):
        """Stack every adapter into per-position gather buffers.

        Returns one dict per pattern position mapping ``a_qk`` / ``a_vo``
        to ``(nb, A, H, d)`` float32 arrays (A = number of adapters,
        adapter id = index on axis 1).  ``None`` for positions with no SV
        transitions.  The bank has a FIXED shape per engine lifetime, so
        per-slot adapter selection is a traced gather — no new compiled
        shapes (DESIGN.md §13).
        """
        bank_keys = {"s_qk_diag": "a_qk", "s_vo_diag": "a_vo"}
        out = []
        for j, base in enumerate(self._base):
            if not base:
                out.append(None)
                continue
            out.append({bank_keys[dst]: jnp.stack(
                [sc[j][dst].astype(jnp.float32) for sc in self._scales],
                axis=1) for dst in base})
        return tuple(out)
