"""CLOVER cross-layer orthogonal decomposition (the paper's core).

The Q-K and V-O pairs of each attention head are a low-rank factorization
of two ``D x D`` matrices:

    W_QK^h = W_Q^h (W_K^h)^T      rank <= d        (d = head_dim << D)
    W_VO^h = W_V^h  W_O^h         rank <= d

An SVD of each product re-expresses the pair in orthogonal bases whose
importance is exactly the singular values — attention only ever consumes
the *products*, so the re-expression is function-preserving.  We never
materialize the ``D x D`` product: the QR trick reduces the SVD to a
``d x d`` problem.

GQA extension (beyond-paper, DESIGN.md §2): for a KV group with G query
heads, the row-stack ``[W_QK^{h1}; ...; W_QK^{hG}]`` is still a rank-<=d
product ``A B^T`` with ``A in R^{GD x d}`` (stacked queries) and
``B = W_K^g in R^{D x d}``.  A joint SVD yields ONE shared set of
orthogonal K directions per group (so pruning shrinks the *shared* K
cache) plus per-query-head U blocks.  MHA is the G=1 special case and
reduces exactly to the paper.

RoPE fallback (paper §5): with a nonlinearity between Q and K the
cross-layer merge is illegal; we instead orthogonalize ``W_K^g`` itself
(intra-layer SVD) and expose the ``d x d`` transition ``diag(S) V^T`` as
the trainable matrix.  Partial-RoPE models (stablelm, rotary_pct<1)
get cross-layer treatment on the un-rotated (NoPE) block — beyond-paper.

MLP.Up: consecutive ``up_block`` output dims are treated as a head and
decomposed intra-layer, exactly the paper's U-D treatment.

All transforms run host-side at init/conversion time (one-off cost), are
vmapped over the stacked ``n_blocks`` axis of the scanned layer stack,
and work in float32 regardless of the param dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ArchConfig, MIXER_ATTN,
                                MLP_DENSE, MLP_RWKV)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# the QR-trick SVD of a low-rank product
# ---------------------------------------------------------------------------

def svd_lowrank_product(A: jnp.ndarray, B: jnp.ndarray):
    """SVD of ``A @ B.T`` without materializing it.

    A: (M, d), B: (N, d) with d << M, N.
    Returns (U, S, Vt): U (M, d) col-orthonormal, S (d,) descending,
    Vt (d, N) row-orthonormal, with  A @ B.T == (U * S) @ Vt.
    """
    A = A.astype(jnp.float32)
    B = B.astype(jnp.float32)
    Qa, Ra = jnp.linalg.qr(A)            # (M, d), (d, d)
    Qb, Rb = jnp.linalg.qr(B)            # (N, d), (d, d)
    Us, S, Vst = jnp.linalg.svd(Ra @ Rb.T)   # all (d, d) / (d,)
    return Qa @ Us, S, Vst @ Qb.T


def svd_tall(W: jnp.ndarray):
    """Economic SVD of a tall matrix W (M, d), M >= d.
    Returns (U (M, d), S (d,), Vt (d, d))."""
    W = W.astype(jnp.float32)
    Q, R = jnp.linalg.qr(W)
    Us, S, Vt = jnp.linalg.svd(R)
    return Q @ Us, S, Vt


# ---------------------------------------------------------------------------
# per-layer decompositions.  Weight layout (repro.models.layers):
#   wq (D, H, dq)   wk (D, KV, dq)   wv (D, KV, dv)   wo (H, dv, D)
# ---------------------------------------------------------------------------

def _group_qk(wq: jnp.ndarray, wk: jnp.ndarray, G: int):
    """Grouped cross-layer QK SVD.

    wq: (D, H, d), wk: (D, KV, d), H == KV * G.
    Returns (Uq (KV, G, D, d), S (KV, d), Vk (KV, D, d)) such that for
    every query head h = g*G+j:   wq[:,h] @ wk[:,g].T == (Uq[g,j]*S[g]) @ Vk[g].T
    """
    D, H, d = wq.shape
    KV = wk.shape[1]
    # (KV, G*D, d): stack the group's query heads along rows
    A = wq.transpose(1, 0, 2).reshape(KV, G, D, d).reshape(KV, G * D, d)
    B = wk.transpose(1, 0, 2)                                  # (KV, D, d)
    U, S, Vt = jax.vmap(svd_lowrank_product)(A, B)
    Uq = U.reshape(KV, G, D, d)
    Vk = jnp.swapaxes(Vt, -1, -2)                              # (KV, D, d)
    return Uq, S, Vk


def _group_vo(wv: jnp.ndarray, wo: jnp.ndarray, G: int):
    """Grouped cross-layer VO SVD.

    wv: (D, KV, d), wo: (H, d, D).
    Returns (Uv (KV, D, d), S (KV, d), Vo (KV, G, d, D)) such that for
    every query head h = g*G+j:   wv[:,g] @ wo[h] == (Uv[g]*S[g]) @ Vo[g,j]
    """
    D, KV, d = wv.shape
    H = wo.shape[0]
    A = wv.transpose(1, 0, 2)                                  # (KV, D, d)
    # (KV, G*D, d): stack the group's output heads' columns
    Bt = wo.reshape(KV, G, d, D).transpose(0, 1, 3, 2).reshape(KV, G * D, d)
    U, S, Vt = jax.vmap(svd_lowrank_product)(A, Bt)
    Vo = Vt.reshape(KV, d, G, D).transpose(0, 2, 1, 3)         # (KV, G, d, D)
    return U, S, Vo


def _intra_k(wk: jnp.ndarray):
    """Intra-layer K orthogonalization (RoPE fallback).

    wk: (D, KV, d).  Returns (Uk (KV, D, d), T (KV, d, d)) with
    wk[:,g] == Uk[g] @ T[g]; Uk col-orthonormal, T = diag(S) Vt the
    trainable transition.
    """
    U, S, Vt = jax.vmap(svd_tall)(wk.transpose(1, 0, 2))
    T = S[..., None] * Vt
    return U, T


def _block_up(w_up: jnp.ndarray, block: int):
    """Blockwise intra-layer decomposition of MLP.Up (paper's U-D pairs).

    w_up: (D, F), F % block == 0.  Returns (Uu (D, nb, block),
    T (nb, block, block)) with w_up[:, n*block:(n+1)*block] == Uu[:,n] @ T[n].
    """
    D, F = w_up.shape
    nb = F // block
    Wb = w_up.reshape(D, nb, block).transpose(1, 0, 2)         # (nb, D, block)
    U, S, Vt = jax.vmap(svd_tall)(Wb)
    T = S[..., None] * Vt                                      # (nb, block, block)
    return U.transpose(1, 0, 2), T


# ---------------------------------------------------------------------------
# attention decomposition (one layer; vmapped over the block axis)
# ---------------------------------------------------------------------------

def qk_mode(cfg: ArchConfig) -> str:
    """How the Q-K pair may be treated (DESIGN.md §5 applicability).

    "cross"   — no positional nonlinearity between Q and K: full cross-layer.
    "partial" — partial RoPE: cross-layer on the un-rotated (NoPE) block.
    "intra"   — full RoPE: intra-layer K orthogonalization only (PEFT only).
    """
    if cfg.rope_dims == 0:
        return "cross"
    if cfg.rope_dims < cfg.head_dim_:
        return "partial"
    return "intra"


def decompose_attention(attn: Params, cfg: ArchConfig, *,
                        peft: bool) -> Tuple[Params, Params, Dict[str, jnp.ndarray]]:
    """Orthogonalize one attention layer's Q-K and V-O pairs.

    Returns (new_weights, trainables, spectra):
      * ``peft=False`` (pruning mode): singular values are merged
        sqrt-balanced into both factors; ``trainables`` is empty.
      * ``peft=True``: factors are kept orthonormal and the singular
        values become the trainable transitions
        (s_qk (H,d,d) | k_t (KV,d,d), s_vo (H,d,d)).
    spectra: {"qk": (KV, d) or None, "vo": (KV, d)} singular values.
    """
    D, H, dq = attn["wq"].shape
    KV = attn["wk"].shape[1]
    dv = attn["wv"].shape[2]
    G = H // KV
    dtype = attn["wq"].dtype
    mode = qk_mode(cfg)
    rot = cfg.rope_dims
    new: Params = dict(attn)
    train: Params = {}
    spectra: Dict[str, Any] = {}

    # ---- Q-K pair ---------------------------------------------------------
    if mode == "cross":
        Uq, S, Vk = _group_qk(attn["wq"], attn["wk"], G)
        spectra["qk"] = S
        if peft:
            new["wq"] = Uq.transpose(2, 0, 1, 3).reshape(D, H, dq).astype(dtype)
            new["wk"] = Vk.transpose(1, 0, 2).astype(dtype)
            # per query head, init = diag(S of its group)
            s = jnp.repeat(jax.vmap(jnp.diag)(S), G, axis=0)    # (H, d, d)
            train["s_qk"] = s.astype(jnp.float32)
        else:
            r = jnp.sqrt(S)                                      # (KV, d)
            wq = Uq * r[:, None, None, :]
            new["wq"] = wq.transpose(2, 0, 1, 3).reshape(D, H, dq).astype(dtype)
            new["wk"] = (Vk * r[:, None, :]).transpose(1, 0, 2).astype(dtype)
    elif mode == "partial":
        # cross-layer on the un-rotated tail block [rot:], identity on the
        # rotated head block (beyond-paper, DESIGN.md §5 note †).
        d_pass = dq - rot
        Uq, S, Vk = _group_qk(attn["wq"][..., rot:], attn["wk"][..., rot:], G)
        spectra["qk"] = S
        if peft:
            wq_pass = Uq.transpose(2, 0, 1, 3).reshape(D, H, d_pass)
            new["wq"] = jnp.concatenate(
                [attn["wq"][..., :rot], wq_pass.astype(dtype)], axis=-1)
            new["wk"] = jnp.concatenate(
                [attn["wk"][..., :rot],
                 Vk.transpose(1, 0, 2).astype(dtype)], axis=-1)
            eye = jnp.eye(rot, dtype=jnp.float32)
            s_pass = jnp.repeat(jax.vmap(jnp.diag)(S), G, axis=0)
            s = jax.vmap(lambda sp: jax.scipy.linalg.block_diag(eye, sp))(s_pass)
            train["s_qk"] = s.astype(jnp.float32)
        else:
            r = jnp.sqrt(S)
            wq_pass = (Uq * r[:, None, None, :]).transpose(2, 0, 1, 3)
            new["wq"] = jnp.concatenate(
                [attn["wq"][..., :rot],
                 wq_pass.reshape(D, H, d_pass).astype(dtype)], axis=-1)
            new["wk"] = jnp.concatenate(
                [attn["wk"][..., :rot],
                 (Vk * r[:, None, :]).transpose(1, 0, 2).astype(dtype)],
                axis=-1)
    else:  # intra: PEFT-only K orthogonalization; pruning illegal (paper §5)
        spectra["qk"] = None
        if peft:
            Uk, T = _intra_k(attn["wk"])
            new["wk"] = Uk.transpose(1, 0, 2).astype(dtype)
            train["k_t"] = T.astype(jnp.float32)

    # ---- V-O pair (no nonlinearity in any assigned arch: always legal) ----
    Uv, Svo, Vo = _group_vo(attn["wv"], attn["wo"], G)
    spectra["vo"] = Svo
    if peft:
        new["wv"] = Uv.transpose(1, 0, 2).astype(dtype)
        new["wo"] = Vo.reshape(H, dv, D).astype(dtype)
        train["s_vo"] = jnp.repeat(
            jax.vmap(jnp.diag)(Svo), G, axis=0).astype(jnp.float32)
    else:
        r = jnp.sqrt(Svo)
        new["wv"] = (Uv * r[:, None, :]).transpose(1, 0, 2).astype(dtype)
        new["wo"] = (Vo * r[:, None, :, None]).reshape(H, dv, D).astype(dtype)
    return new, train, spectra


def decompose_up(mlp: Params, cfg: ArchConfig, *, key_name: str = "w_up",
                 peft: bool = True) -> Tuple[Params, Params]:
    """Blockwise Up decomposition (always intra-layer; PEFT-oriented).
    Applies to dense-MLP ``w_up`` and rwkv channel-mix ``wk``."""
    W = mlp[key_name]
    block = min(cfg.clover.up_block, W.shape[1])
    if W.shape[1] % block != 0:
        return mlp, {}
    Uu, T = _block_up(W, block)
    new = dict(mlp)
    del new[key_name]
    new["up_u"] = Uu.astype(W.dtype)
    train = {"up_t": T.astype(jnp.float32)}
    if not peft:  # merged orthogonal form (rarely useful; kept for symmetry)
        new["up_u"] = jnp.einsum("dnr,nrk->dnk", Uu, T).astype(W.dtype)
        train = {}
    return new, train


# ---------------------------------------------------------------------------
# whole-model driver
# ---------------------------------------------------------------------------

def _map_blocks(params: Params, cfg: ArchConfig, fn):
    """Apply ``fn(layer_params, mixer, mlp) -> (new_layer, extras)`` to every
    stacked pattern position (vmapped over the n_blocks axis)."""
    new_blocks = []
    extras = []
    for j, (mixer, mlp) in enumerate(cfg.pattern):
        stacked = params["blocks"][j]
        out, ex = jax.vmap(lambda lp: fn(lp, mixer, mlp))(stacked)
        new_blocks.append(out)
        extras.append(ex)
    out = dict(params)
    out["blocks"] = tuple(new_blocks)
    return out, extras


def clover_decompose(params: Params, cfg: ArchConfig, *, peft: bool,
                     include_up: bool = True,
                     ) -> Tuple[Params, ArchConfig, list]:
    """Orthogonalize every attention layer (and optionally MLP.Up blocks).

    Returns (params', cfg', per-pattern-position extras) where extras[j] =
    {"train": {...}, "spectra": {...}} stacked over the block axis.
    In PEFT mode the trainable transitions are *inserted into the layer
    param trees* (keys s_qk / k_t / s_vo / up_t) so the model hooks pick
    them up; ``repro.core.peft.trainable_mask`` selects them for the
    optimizer.
    """
    def fn(lp: Params, mixer: str, mlp: str):
        lp = dict(lp)
        extra: Dict[str, Any] = {"spectra": {}}
        if mixer == MIXER_ATTN:
            new_attn, train, spectra = decompose_attention(
                lp["attn"], cfg, peft=peft)
            new_attn.update(train)
            lp["attn"] = new_attn
            extra["spectra"] = {k: v for k, v in spectra.items()
                                if v is not None}
        if include_up and peft:
            if mlp == MLP_DENSE:
                new_mlp, train = decompose_up(lp["mlp"], cfg, key_name="w_up")
                new_mlp.update(train)
                lp["mlp"] = new_mlp
            elif mlp == MLP_RWKV:
                new_cm, train = decompose_up(lp["rwkv_chan"], cfg, key_name="wk")
                new_cm.update(train)
                lp["rwkv_chan"] = new_cm
        return lp, extra

    new_params, extras = _map_blocks(params, cfg, fn)
    new_cfg = dataclasses.replace(
        cfg, clover=dataclasses.replace(cfg.clover, enabled=True,
                                        finetune_s=peft))
    return new_params, new_cfg, extras


def merge_clover(params: Params, cfg: ArchConfig) -> Tuple[Params, ArchConfig]:
    """Fold the trainable transitions back into the weights (paper: 'these
    values are reintegrated into the model without increasing its parameter
    count').  Inverse of PEFT-mode decomposition; function-preserving."""
    def fn(lp: Params, mixer: str, mlp: str):
        lp = jax.tree.map(lambda a: a, lp)  # shallow-ish copy
        if mixer == MIXER_ATTN:
            attn = dict(lp["attn"])
            if "s_qk" in attn:
                attn["wq"] = jnp.einsum(
                    "dhq,hqr->dhr", attn["wq"],
                    attn.pop("s_qk").astype(attn["wq"].dtype))
            if "k_t" in attn:
                attn["wk"] = jnp.einsum(
                    "dkq,kqr->dkr", attn["wk"],
                    attn.pop("k_t").astype(attn["wk"].dtype))
            if "s_vo" in attn:
                attn["wo"] = jnp.einsum(
                    "hvw,hwd->hvd", attn.pop("s_vo").astype(attn["wo"].dtype),
                    attn["wo"])
            lp["attn"] = attn
        for name, wkey in (("mlp", "w_up"), ("rwkv_chan", "wk")):
            if name in lp and "up_t" in lp[name]:
                sub = dict(lp[name])
                W = jnp.einsum("dnr,nrk->dnk", sub.pop("up_u"),
                               sub.pop("up_t").astype(sub["w_down"].dtype
                                                      if "w_down" in sub
                                                      else jnp.float32))
                sub[wkey] = W.reshape(W.shape[0], -1)
                lp[name] = sub
        return lp, {}

    new_params, _ = _map_blocks(params, cfg, fn)
    new_cfg = dataclasses.replace(
        cfg, clover=dataclasses.replace(cfg.clover, finetune_s=False))
    return new_params, new_cfg
