"""Spectrum / projection analytics backing Figures 2, 4, 5, 6.

All functions are pure JAX on host-resident weights; they power the
benchmark scripts and the DESIGN.md claims:

  * Fig 2  — CLOVER singular spectra vs vanilla per-dim L2 products:
             the orthogonalized spectrum concentrates energy in few
             directions (``energy_topk``, ``importance_curves``).
  * Fig 4  — projection mass of data features onto LoRA-random /
             PiSSA-top-r / CLOVER-all directions (``projection_mass``).
  * Fig 5  — rank of the fine-tuning update ΔW (``delta_spectrum``).
  * Fig 6  — intruder dimensions: top singular vectors of the tuned
             weight with no counterpart in the base weight
             (``intruder_dims``).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.decompose import svd_lowrank_product

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Fig. 2: importance curves
# ---------------------------------------------------------------------------

def qk_curves(attn: Params, G: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(clover, vanilla) importance per K dim, each (KV, d) sorted desc.

    clover  = singular values of the grouped product (what CLOVER prunes on)
    vanilla = grouped L2-norm products ||wq_i|| * ||wk_i|| (what magnitude
              pruning prunes on), sorted for comparability.
    """
    wq, wk = attn["wq"], attn["wk"]
    D, H, d = wq.shape
    KV = wk.shape[1]
    A = wq.transpose(1, 0, 2).reshape(KV, G * D, d)
    B = wk.transpose(1, 0, 2)
    _, S, _ = jax.vmap(svd_lowrank_product)(A, B)
    nq = jnp.linalg.norm(wq, axis=0).reshape(KV, G, d).sum(1)
    nk = jnp.linalg.norm(wk, axis=0)
    vanilla = jnp.sort(nq * nk, axis=-1)[:, ::-1]
    return S, vanilla


def vo_curves(attn: Params, G: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    wv, wo = attn["wv"], attn["wo"]
    D, KV, d = wv.shape
    A = wv.transpose(1, 0, 2)
    Bt = wo.reshape(KV, G, d, -1).transpose(0, 1, 3, 2).reshape(KV, G * D, d)
    _, S, _ = jax.vmap(svd_lowrank_product)(A, Bt)
    nv = jnp.linalg.norm(wv, axis=0)
    no = jnp.linalg.norm(wo, axis=2).reshape(KV, G, d).sum(1)
    vanilla = jnp.sort(nv * no, axis=-1)[:, ::-1]
    return S, vanilla


def energy_topk(spectrum: jnp.ndarray, k: int) -> jnp.ndarray:
    """Fraction of squared mass in the top-k entries (already sorted)."""
    sq = jnp.square(spectrum)
    return jnp.sum(sq[..., :k], -1) / jnp.maximum(jnp.sum(sq, -1), 1e-30)


def energy_blocks(spectrum, multiple: int) -> np.ndarray:
    """Squared singular mass per ``multiple``-wide rank block.

    ``spectrum`` (..., d), sorted descending -> (..., ceil(d/multiple))
    float64 block sums (a short final block zero-pads).  This is the
    worth table the ``core.prune.plan_rank_budget`` water-filling greedy
    allocates over (DESIGN.md §14): block ``i`` of a head is the energy
    gained by growing that head's kept rank from ``i*multiple`` to
    ``(i+1)*multiple``, and the descending sort makes the per-head
    block energies monotone — greedy allocation always extends
    prefixes.  Host numpy, not jnp: the planner runs at plan time, not
    in a traced step."""
    sq = np.square(np.asarray(spectrum, np.float64))
    d = sq.shape[-1]
    multiple = max(1, int(multiple))
    n = -(-d // multiple)
    pad = n * multiple - d
    if pad:
        sq = np.concatenate(
            [sq, np.zeros(sq.shape[:-1] + (pad,), sq.dtype)], axis=-1)
    return sq.reshape(sq.shape[:-1] + (n, multiple)).sum(-1)


# ---------------------------------------------------------------------------
# Fig. 4: projection of data features onto adapter directions
# ---------------------------------------------------------------------------

def projection_mass(X: jnp.ndarray, dirs: jnp.ndarray,
                    weights: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Share of feature energy captured by each direction.

    X: (n, D) activations; dirs: (D, r) columns (need not be complete);
    weights: optional per-direction scaling (singular values — the
    paper's point 2: the model amplifies large-singular-value directions).
    Returns (r,) fractions of total projected energy.
    """
    proj = X.astype(jnp.float32) @ dirs.astype(jnp.float32)     # (n, r)
    e = jnp.sum(jnp.square(proj), axis=0)
    if weights is not None:
        e = e * jnp.square(weights.astype(jnp.float32))
    return e / jnp.maximum(jnp.sum(e), 1e-30)


def coverage(X: jnp.ndarray, dirs: jnp.ndarray) -> float:
    """Fraction of total feature energy lying INSIDE span(dirs) — the
    quantity whose complement drives LoRA/PiSSA's zero-gradient risk."""
    Q, _ = jnp.linalg.qr(dirs.astype(jnp.float32))
    Xf = X.astype(jnp.float32)
    inside = jnp.sum(jnp.square(Xf @ Q))
    total = jnp.sum(jnp.square(Xf))
    return float(inside / jnp.maximum(total, 1e-30))


# ---------------------------------------------------------------------------
# Fig. 5/6: update rank & intruder dimensions
# ---------------------------------------------------------------------------

def delta_spectrum(W0: jnp.ndarray, W1: jnp.ndarray) -> jnp.ndarray:
    """Singular values of the update ΔW = W1 - W0 (2D-flattened)."""
    d = (W1.astype(jnp.float32) - W0.astype(jnp.float32))
    if d.ndim == 3:
        d = d.reshape(d.shape[0], -1) if d.shape[0] > d.shape[2] \
            else d.reshape(-1, d.shape[2])
    return jnp.linalg.svd(d, compute_uv=False)


def effective_rank(s: jnp.ndarray, tol: float = 1e-3) -> int:
    """#singular values above tol * s_max."""
    return int(jnp.sum(s > tol * jnp.max(s)))


def intruder_dims(W0: jnp.ndarray, W1: jnp.ndarray, *, k: int = 16,
                  tau: float = 0.6) -> int:
    """Count of W1's top-k left singular vectors whose best cosine
    similarity to ANY of W0's left singular vectors is < tau
    (Shuttleworth et al., 2024).  LoRA injects such dimensions;
    full FT and CLOVER do not."""
    U0, _, _ = jnp.linalg.svd(W0.astype(jnp.float32), full_matrices=False)
    U1, _, _ = jnp.linalg.svd(W1.astype(jnp.float32), full_matrices=False)
    sims = jnp.abs(U1[:, :k].T @ U0)                       # (k, r0)
    best = jnp.max(sims, axis=1)
    return int(jnp.sum(best < tau))
