"""CLOVER pruning planner + vanilla baseline (paper Table 1, §4.4).

After ``clover_decompose`` the per-head factors are sorted by singular
value (descending), so structured pruning is a static slice ``[..., :r]``
— the same rate across all layers (paper: "to maintain inference
efficiency, we apply the same pruning rate across all layers").  The
KV cache then stores K at rank ``r_qk`` and V at rank ``r_vo``: the
decode memory win the paper targets.

TPU adaptation (DESIGN.md §4): kept ranks are snapped UP to the sublane
multiple (``cfg.clover.rank_multiple``) so MXU/VPU tiles stay aligned;
the pruned weights never carry HBM zero-padding.

Vanilla baseline: magnitude pruning of paired per-dim L2 norms
(``||wq_i||*||wk_i||`` / ``||wv_i||*||wo_i||``) WITHOUT
orthogonalization — per-head top-r gather.  For RoPE archs the rotated
block is never pruned (pairing would break); this mirrors CLOVER's own
applicability so comparisons are apples-to-apples.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MIXER_ATTN
from repro.core.decompose import qk_mode

Params = Dict[str, Any]


def snap_rank(r: int, multiple: int, d: int) -> int:
    """Snap a kept rank UP to the TPU sublane multiple, capped at d."""
    if multiple <= 1:
        return max(1, min(r, d))
    return max(multiple, min(d, ((r + multiple - 1) // multiple) * multiple))


def plan_ranks(cfg: ArchConfig, qk_ratio: float, vo_ratio: float
               ) -> Tuple[int, int]:
    """Kept per-head widths (qk_keep, vo_keep) for a pruning ratio.

    In partial-RoPE mode only the NoPE tail is prunable: the ratio is
    applied to the tail and the rotated block is always kept.
    """
    d = cfg.head_dim_
    m = cfg.clover.rank_multiple
    mode = qk_mode(cfg)
    if mode == "cross":
        qk_keep = snap_rank(round(d * (1.0 - qk_ratio)), m, d)
    elif mode == "partial":
        rot = cfg.rope_dims
        tail = d - rot
        qk_keep = rot + snap_rank(round(tail * (1.0 - qk_ratio)), m, tail)
    else:  # intra (full RoPE): Q-K pruning illegal (paper §5)
        qk_keep = d
    vo_keep = snap_rank(round(d * (1.0 - vo_ratio)), m, d)
    return qk_keep, vo_keep


def draft_ranks(cfg: ArchConfig, ratio: float) -> Tuple[int, int]:
    """Per-head (qk, vo) widths of the self-speculative DRAFT model.

    The draft is the same weights with the last orthogonal directions of
    every head sliced off — ``ratio`` is applied to the CURRENT widths
    (which may already be pruned), so a model served at prune 0.5 drafts
    from a further-halved rank.  Applicability mirrors ``plan_ranks``:
    in partial-RoPE mode only the NoPE tail shrinks (slicing inside the
    rotated block would break RoPE's dim pairing), and in intra mode
    (full RoPE) the Q-K pair is never sliced — only V-O.  Widths snap UP
    to the TPU sublane multiple like every other kept rank.
    """
    dq, dv = cfg.qk_dim, cfg.vo_dim
    m = cfg.clover.rank_multiple
    mode = qk_mode(cfg)
    if mode == "cross":
        r_q = snap_rank(round(dq * (1.0 - ratio)), m, dq)
    elif mode == "partial":
        rot = min(cfg.rope_dims, dq)
        tail = dq - rot
        r_q = rot + (snap_rank(round(tail * (1.0 - ratio)), m, tail)
                     if tail > 0 else 0)
    else:  # intra (full RoPE): Q-K slicing illegal (paper §5)
        r_q = dq
    r_v = snap_rank(round(dv * (1.0 - ratio)), m, dv)
    return r_q, r_v


def _set_ranks(cfg: ArchConfig, qk_keep: int, vo_keep: int) -> ArchConfig:
    d = cfg.head_dim_
    return dataclasses.replace(
        cfg, clover=dataclasses.replace(
            cfg.clover, enabled=True,
            qk_rank=0 if qk_keep == d else qk_keep,
            vo_rank=0 if vo_keep == d else vo_keep))


# ---------------------------------------------------------------------------
# CLOVER pruning: static slices of the sorted factors
# ---------------------------------------------------------------------------

def _prune_attn_clover(attn: Params, cfg: ArchConfig,
                       qk_keep: int, vo_keep: int) -> Params:
    """Slice the sorted factors.  Works on stacked params (leading
    ``n_blocks`` axis) via ellipsis indexing:
        wq (..., D, H, dq)  wk (..., D, KV, dq)
        wv (..., D, KV, dv) wo (..., H, dv, D)
        s_qk/s_vo (..., H, d, d)  k_t (..., KV, d, d)."""
    new = dict(attn)
    d = cfg.head_dim_
    if qk_keep < d and qk_mode(cfg) != "intra":
        new["wq"] = attn["wq"][..., :qk_keep]
        new["wk"] = attn["wk"][..., :qk_keep]
        if "s_qk" in attn:   # CLOVER-dagger: keep S trainable post-prune
            new["s_qk"] = attn["s_qk"][..., :qk_keep, :qk_keep]
        if "k_t" in attn:
            new["k_t"] = attn["k_t"][..., :qk_keep, :qk_keep]
    if vo_keep < d:
        new["wv"] = attn["wv"][..., :vo_keep]
        new["wo"] = attn["wo"][..., :vo_keep, :]
        if "s_vo" in attn:
            new["s_vo"] = attn["s_vo"][..., :vo_keep, :vo_keep]
    return new


def clover_prune(params: Params, cfg: ArchConfig, *,
                 qk_ratio: float = 0.0, vo_ratio: float = 0.0,
                 ) -> Tuple[Params, ArchConfig]:
    """Prune a CLOVER-decomposed model (either peft or merged mode).

    ``params`` must come from ``clover_decompose`` (factors sorted by
    singular value).  Returns (params', cfg') with cfg'.clover ranks set
    so the model/KV-cache shapes shrink accordingly.
    """
    assert cfg.clover.enabled, "clover_prune requires a decomposed model"
    qk_keep, vo_keep = plan_ranks(cfg, qk_ratio, vo_ratio)

    new_blocks = []
    for j, (mixer, mlp) in enumerate(cfg.pattern):
        stacked = dict(params["blocks"][j])
        if mixer == MIXER_ATTN:
            stacked["attn"] = _prune_attn_clover(
                stacked["attn"], cfg, qk_keep, vo_keep)
        new_blocks.append(stacked)
    out = dict(params)
    out["blocks"] = tuple(new_blocks)
    return out, _set_ranks(cfg, qk_keep, vo_keep)


# ---------------------------------------------------------------------------
# Vanilla magnitude pruning baseline (no orthogonalization)
# ---------------------------------------------------------------------------

def _prune_attn_vanilla(attn: Params, cfg: ArchConfig,
                        qk_keep: int, vo_keep: int) -> Params:
    """Per-head top-r magnitude pruning on the RAW weights.

    wq (D,H,dq), wk (D,KV,dq), wv (D,KV,dv), wo (H,dv,D); GQA importance
    for the shared K/V dims is summed over the group's query heads.
    RoPE block ([:rot]) is always kept (see module docstring).
    """
    D, H, d = attn["wq"].shape
    KV = attn["wk"].shape[1]
    G = H // KV
    rot = min(cfg.rope_dims, d)
    new = dict(attn)

    if qk_keep < d and qk_mode(cfg) != "intra":
        nq = jnp.linalg.norm(attn["wq"], axis=0)              # (H, d)
        nk = jnp.linalg.norm(attn["wk"], axis=0)              # (KV, d)
        imp = (nq.reshape(KV, G, d) * nk[:, None, :]).sum(1)  # (KV, d)
        tail_keep = qk_keep - rot
        imp_t = imp[:, rot:]
        _, idx = jax.lax.top_k(imp_t, tail_keep)
        idx = jnp.sort(idx, axis=-1) + rot                    # (KV, tail_keep)
        if rot:
            idx = jnp.concatenate(
                [jnp.broadcast_to(jnp.arange(rot)[None], (KV, rot)), idx], -1)
        # gather per KV group; query heads share the group's index set
        idx_h = jnp.repeat(idx, G, axis=0)                    # (H, keep)
        new["wq"] = jnp.take_along_axis(
            attn["wq"], idx_h[None, :, :], axis=2)
        new["wk"] = jnp.take_along_axis(
            attn["wk"], idx[None, :, :], axis=2)

    if vo_keep < d:
        nv = jnp.linalg.norm(attn["wv"], axis=0)              # (KV, d)
        no = jnp.linalg.norm(attn["wo"], axis=2)              # (H, d)
        imp = (no.reshape(KV, G, d) * nv[:, None, :]).sum(1)  # (KV, d)
        _, idx = jax.lax.top_k(imp, vo_keep)
        idx = jnp.sort(idx, axis=-1)                          # (KV, keep)
        idx_h = jnp.repeat(idx, G, axis=0)
        new["wv"] = jnp.take_along_axis(attn["wv"], idx[None, :, :], axis=2)
        new["wo"] = jnp.take_along_axis(
            attn["wo"], idx_h[:, :, None], axis=1)
    return new


def vanilla_prune(params: Params, cfg: ArchConfig, *,
                  qk_ratio: float = 0.0, vo_ratio: float = 0.0,
                  ) -> Tuple[Params, ArchConfig]:
    """Magnitude pruning WITHOUT CLOVER orthogonalization (the baseline)."""
    qk_keep, vo_keep = plan_ranks(cfg, qk_ratio, vo_ratio)

    new_blocks = []
    for j, (mixer, mlp) in enumerate(cfg.pattern):
        stacked = dict(params["blocks"][j])
        if mixer == MIXER_ATTN:
            stacked["attn"] = jax.vmap(
                lambda a: _prune_attn_vanilla(a, cfg, qk_keep, vo_keep)
            )(stacked["attn"])
        new_blocks.append(stacked)
    out = dict(params)
    out["blocks"] = tuple(new_blocks)
    return out, _set_ranks(cfg, qk_keep, vo_keep)


# ---------------------------------------------------------------------------
# Threshold planning (paper §4.4: training-free pruning by magnitude cutoff)
# ---------------------------------------------------------------------------

def threshold_ratios(extras, cfg: ArchConfig, *,
                     qk_thresh: float, vo_thresh: float) -> Dict[str, float]:
    """From decomposition spectra, the uniform kept rank implied by a
    singular-value threshold: r = max over heads/layers of #{S >= t}
    (max keeps every head lossless; uniformity keeps shapes static).

    Returns achieved ratios + planned keeps; feed into clover_prune.
    """
    d = cfg.head_dim_
    qk_keep, vo_keep = 0, 0
    qk_total = vo_total = 0.0
    for ex in extras:
        sp = ex["spectra"] if "spectra" in ex else {}
        if "qk" in sp:
            s = sp["qk"]                      # (n_blocks, KV, d_eff)
            qk_keep = max(qk_keep, int(jnp.max(jnp.sum(s >= qk_thresh, -1))))
            qk_total += float(jnp.mean(jnp.sum(s >= qk_thresh, -1)))
        if "vo" in sp:
            s = sp["vo"]
            vo_keep = max(vo_keep, int(jnp.max(jnp.sum(s >= vo_thresh, -1))))
            vo_total += float(jnp.mean(jnp.sum(s >= vo_thresh, -1)))
    m = cfg.clover.rank_multiple
    mode = qk_mode(cfg)
    d_qk = (d - cfg.rope_dims) if mode == "partial" else d
    qk_keep = snap_rank(max(qk_keep, 1), m, d_qk) if mode != "intra" else d
    vo_keep = snap_rank(max(vo_keep, 1), m, d)
    return {
        "qk_keep": qk_keep, "vo_keep": vo_keep,
        "qk_ratio": 1.0 - qk_keep / d_qk if mode != "intra" else 0.0,
        "vo_ratio": 1.0 - vo_keep / d,
    }
