"""CLOVER pruning planner + vanilla baseline (paper Table 1, §4.4).

After ``clover_decompose`` the per-head factors are sorted by singular
value (descending), so structured pruning is a static slice ``[..., :r]``
— the same rate across all layers (paper: "to maintain inference
efficiency, we apply the same pruning rate across all layers").  The
KV cache then stores K at rank ``r_qk`` and V at rank ``r_vo``: the
decode memory win the paper targets.

TPU adaptation (DESIGN.md §4): kept ranks are snapped UP to the sublane
multiple (``cfg.clover.rank_multiple``) so MXU/VPU tiles stay aligned;
the pruned weights never carry HBM zero-padding.

Vanilla baseline: magnitude pruning of paired per-dim L2 norms
(``||wq_i||*||wk_i||`` / ``||wv_i||*||wo_i||``) WITHOUT
orthogonalization — per-head top-r gather.  For RoPE archs the rotated
block is never pruned (pairing would break); this mirrors CLOVER's own
applicability so comparisons are apples-to-apples.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, MIXER_ATTN
from repro.core.decompose import qk_mode

Params = Dict[str, Any]

# nested per-plan rank table: [pattern position][stacked block][kv head]
RankTable = Tuple[Tuple[Tuple[int, ...], ...], ...]


def snap_rank(r: int, multiple: int, d: int) -> int:
    """Snap a kept rank UP to the TPU sublane multiple, capped at d."""
    if multiple <= 1:
        return max(1, min(r, d))
    return max(multiple, min(d, ((r + multiple - 1) // multiple) * multiple))


def plan_ranks(cfg: ArchConfig, qk_ratio: float, vo_ratio: float
               ) -> Tuple[int, int]:
    """Kept per-head widths (qk_keep, vo_keep) for a pruning ratio.

    In partial-RoPE mode only the NoPE tail is prunable: the ratio is
    applied to the tail and the rotated block is always kept.
    """
    d = cfg.head_dim_
    m = cfg.clover.rank_multiple
    mode = qk_mode(cfg)
    if mode == "cross":
        qk_keep = snap_rank(round(d * (1.0 - qk_ratio)), m, d)
    elif mode == "partial":
        rot = cfg.rope_dims
        tail = d - rot
        qk_keep = rot + snap_rank(round(tail * (1.0 - qk_ratio)), m, tail)
    else:  # intra (full RoPE): Q-K pruning illegal (paper §5)
        qk_keep = d
    vo_keep = snap_rank(round(d * (1.0 - vo_ratio)), m, d)
    return qk_keep, vo_keep


def draft_ranks(cfg: ArchConfig, ratio: float) -> Tuple[int, int]:
    """Per-head (qk, vo) widths of the self-speculative DRAFT model.

    The draft is the same weights with the last orthogonal directions of
    every head sliced off — ``ratio`` is applied to the CURRENT widths
    (which may already be pruned), so a model served at prune 0.5 drafts
    from a further-halved rank.  Applicability mirrors ``plan_ranks``:
    in partial-RoPE mode only the NoPE tail shrinks (slicing inside the
    rotated block would break RoPE's dim pairing), and in intra mode
    (full RoPE) the Q-K pair is never sliced — only V-O.  Widths snap UP
    to the TPU sublane multiple like every other kept rank.
    """
    dq, dv = cfg.qk_dim, cfg.vo_dim
    m = cfg.clover.rank_multiple
    mode = qk_mode(cfg)
    if mode == "cross":
        r_q = snap_rank(round(dq * (1.0 - ratio)), m, dq)
    elif mode == "partial":
        rot = min(cfg.rope_dims, dq)
        tail = dq - rot
        r_q = rot + (snap_rank(round(tail * (1.0 - ratio)), m, tail)
                     if tail > 0 else 0)
    else:  # intra (full RoPE): Q-K slicing illegal (paper §5)
        r_q = dq
    r_v = snap_rank(round(dv * (1.0 - ratio)), m, dv)
    return r_q, r_v


def _set_ranks(cfg: ArchConfig, qk_keep: int, vo_keep: int) -> ArchConfig:
    d = cfg.head_dim_
    return dataclasses.replace(
        cfg, clover=dataclasses.replace(
            cfg.clover, enabled=True,
            qk_rank=0 if qk_keep == d else qk_keep,
            vo_rank=0 if vo_keep == d else vo_keep))


# ---------------------------------------------------------------------------
# CLOVER pruning: static slices of the sorted factors
# ---------------------------------------------------------------------------

def _prune_attn_clover(attn: Params, cfg: ArchConfig,
                       qk_keep: int, vo_keep: int) -> Params:
    """Slice the sorted factors.  Works on stacked params (leading
    ``n_blocks`` axis) via ellipsis indexing:
        wq (..., D, H, dq)  wk (..., D, KV, dq)
        wv (..., D, KV, dv) wo (..., H, dv, D)
        s_qk/s_vo (..., H, d, d)  k_t (..., KV, d, d)."""
    new = dict(attn)
    d = cfg.head_dim_
    if qk_keep < d and qk_mode(cfg) != "intra":
        new["wq"] = attn["wq"][..., :qk_keep]
        new["wk"] = attn["wk"][..., :qk_keep]
        if "s_qk" in attn:   # CLOVER-dagger: keep S trainable post-prune
            new["s_qk"] = attn["s_qk"][..., :qk_keep, :qk_keep]
        if "k_t" in attn:
            new["k_t"] = attn["k_t"][..., :qk_keep, :qk_keep]
    if vo_keep < d:
        new["wv"] = attn["wv"][..., :vo_keep]
        new["wo"] = attn["wo"][..., :vo_keep, :]
        if "s_vo" in attn:
            new["s_vo"] = attn["s_vo"][..., :vo_keep, :vo_keep]
    return new


def clover_prune(params: Params, cfg: ArchConfig, *,
                 qk_ratio: float = 0.0, vo_ratio: float = 0.0,
                 ) -> Tuple[Params, ArchConfig]:
    """Prune a CLOVER-decomposed model (either peft or merged mode).

    ``params`` must come from ``clover_decompose`` (factors sorted by
    singular value).  Returns (params', cfg') with cfg'.clover ranks set
    so the model/KV-cache shapes shrink accordingly.
    """
    assert cfg.clover.enabled, "clover_prune requires a decomposed model"
    qk_keep, vo_keep = plan_ranks(cfg, qk_ratio, vo_ratio)

    new_blocks = []
    for j, (mixer, mlp) in enumerate(cfg.pattern):
        stacked = dict(params["blocks"][j])
        if mixer == MIXER_ATTN:
            stacked["attn"] = _prune_attn_clover(
                stacked["attn"], cfg, qk_keep, vo_keep)
        new_blocks.append(stacked)
    out = dict(params)
    out["blocks"] = tuple(new_blocks)
    return out, _set_ranks(cfg, qk_keep, vo_keep)


# ---------------------------------------------------------------------------
# Vanilla magnitude pruning baseline (no orthogonalization)
# ---------------------------------------------------------------------------

def _prune_attn_vanilla(attn: Params, cfg: ArchConfig,
                        qk_keep: int, vo_keep: int) -> Params:
    """Per-head top-r magnitude pruning on the RAW weights.

    wq (D,H,dq), wk (D,KV,dq), wv (D,KV,dv), wo (H,dv,D); GQA importance
    for the shared K/V dims is summed over the group's query heads.
    RoPE block ([:rot]) is always kept (see module docstring).
    """
    D, H, d = attn["wq"].shape
    KV = attn["wk"].shape[1]
    G = H // KV
    rot = min(cfg.rope_dims, d)
    new = dict(attn)

    if qk_keep < d and qk_mode(cfg) != "intra":
        nq = jnp.linalg.norm(attn["wq"], axis=0)              # (H, d)
        nk = jnp.linalg.norm(attn["wk"], axis=0)              # (KV, d)
        imp = (nq.reshape(KV, G, d) * nk[:, None, :]).sum(1)  # (KV, d)
        tail_keep = qk_keep - rot
        imp_t = imp[:, rot:]
        _, idx = jax.lax.top_k(imp_t, tail_keep)
        idx = jnp.sort(idx, axis=-1) + rot                    # (KV, tail_keep)
        if rot:
            idx = jnp.concatenate(
                [jnp.broadcast_to(jnp.arange(rot)[None], (KV, rot)), idx], -1)
        # gather per KV group; query heads share the group's index set
        idx_h = jnp.repeat(idx, G, axis=0)                    # (H, keep)
        new["wq"] = jnp.take_along_axis(
            attn["wq"], idx_h[None, :, :], axis=2)
        new["wk"] = jnp.take_along_axis(
            attn["wk"], idx[None, :, :], axis=2)

    if vo_keep < d:
        nv = jnp.linalg.norm(attn["wv"], axis=0)              # (KV, d)
        no = jnp.linalg.norm(attn["wo"], axis=2)              # (H, d)
        imp = (no.reshape(KV, G, d) * nv[:, None, :]).sum(1)  # (KV, d)
        _, idx = jax.lax.top_k(imp, vo_keep)
        idx = jnp.sort(idx, axis=-1)                          # (KV, keep)
        idx_h = jnp.repeat(idx, G, axis=0)
        new["wv"] = jnp.take_along_axis(attn["wv"], idx[None, :, :], axis=2)
        new["wo"] = jnp.take_along_axis(
            attn["wo"], idx_h[:, :, None], axis=1)
    return new


def vanilla_prune(params: Params, cfg: ArchConfig, *,
                  qk_ratio: float = 0.0, vo_ratio: float = 0.0,
                  ) -> Tuple[Params, ArchConfig]:
    """Magnitude pruning WITHOUT CLOVER orthogonalization (the baseline)."""
    qk_keep, vo_keep = plan_ranks(cfg, qk_ratio, vo_ratio)

    new_blocks = []
    for j, (mixer, mlp) in enumerate(cfg.pattern):
        stacked = dict(params["blocks"][j])
        if mixer == MIXER_ATTN:
            stacked["attn"] = jax.vmap(
                lambda a: _prune_attn_vanilla(a, cfg, qk_keep, vo_keep)
            )(stacked["attn"])
        new_blocks.append(stacked)
    out = dict(params)
    out["blocks"] = tuple(new_blocks)
    return out, _set_ranks(cfg, qk_keep, vo_keep)


# ---------------------------------------------------------------------------
# Threshold planning (paper §4.4: training-free pruning by magnitude cutoff)
# ---------------------------------------------------------------------------

def threshold_ratios(extras, cfg: ArchConfig, *,
                     qk_thresh: float, vo_thresh: float) -> Dict[str, Any]:
    """From decomposition spectra, the kept ranks implied by a
    singular-value threshold.

    The UNIFORM summary (``qk_keep``/``vo_keep``/``*_ratio``) takes the
    max over heads and layers, so feeding it into ``clover_prune``
    keeps every head lossless at one static shape.  Uniformity is a
    property of THAT consumer, not of this function: the per-layer /
    per-head keeps the threshold actually implies are returned
    alongside as ``qk_head_keeps`` / ``vo_head_keeps`` — nested tuples
    ``[pattern position][stacked block][kv head]`` of snapped ranks
    (empty tuples for non-attention positions), the raw material for a
    non-uniform ``RankBudget`` plan (DESIGN.md §14).

    Returns achieved ratios + planned keeps; the uniform summary feeds
    ``clover_prune``, the per-head tables feed ``mask_head_ranks`` /
    ``plan_rank_budget``.
    """
    d = cfg.head_dim_
    m = cfg.clover.rank_multiple
    mode = qk_mode(cfg)
    d_qk = (d - cfg.rope_dims) if mode == "partial" else d
    rot = cfg.rope_dims if mode == "partial" else 0
    qk_keep, vo_keep = 0, 0
    qk_total = vo_total = 0.0
    qk_heads, vo_heads = [], []
    for ex in extras:
        sp = ex["spectra"] if "spectra" in ex else {}
        if "qk" in sp:
            s = sp["qk"]                      # (n_blocks, KV, d_eff)
            counts = np.asarray(jnp.sum(s >= qk_thresh, -1))
            qk_keep = max(qk_keep, int(counts.max()))
            qk_total += float(counts.mean())
            qk_heads.append(tuple(
                tuple(rot + snap_rank(max(int(c), 1), m, d_qk)
                      for c in row) for row in counts))
        else:
            qk_heads.append(())
        if "vo" in sp:
            s = sp["vo"]
            counts = np.asarray(jnp.sum(s >= vo_thresh, -1))
            vo_keep = max(vo_keep, int(counts.max()))
            vo_total += float(counts.mean())
            vo_heads.append(tuple(
                tuple(snap_rank(max(int(c), 1), m, d)
                      for c in row) for row in counts))
        else:
            vo_heads.append(())
    qk_keep = snap_rank(max(qk_keep, 1), m, d_qk) if mode != "intra" else d
    vo_keep = snap_rank(max(vo_keep, 1), m, d)
    return {
        "qk_keep": qk_keep, "vo_keep": vo_keep,
        "qk_ratio": 1.0 - qk_keep / d_qk if mode != "intra" else 0.0,
        "vo_ratio": 1.0 - vo_keep / d,
        "qk_head_keeps": tuple(qk_heads),
        "vo_head_keeps": tuple(vo_heads),
    }


# ---------------------------------------------------------------------------
# Spectrum-driven rank budgets (non-uniform pruning, DESIGN.md §14)
# ---------------------------------------------------------------------------
#
# ``plan_ranks`` spends one global ratio uniformly; CLOVER's point is
# that the orthogonalized spectra are NOT uniform — some layers/heads
# concentrate their energy in far fewer directions than others.  The
# planner below water-fills a single global rank budget across every
# (pattern position, stacked block, kv head, family) by greedy
# allocation over singular-value energy: kept rank grows in
# ``rank_multiple``-wide blocks, each block's worth is the squared
# singular mass it covers, and blocks are taken globally in descending
# energy order until the budget is met.  Because each head's spectrum
# is sorted descending, block energies within a head are monotone, so
# the greedy order always extends prefixes — the allocation is a valid
# leading-directions keep for every head, and (with equal block widths)
# it maximizes total kept energy among all prefix allocations of the
# same total rank: the uniform plan is one such allocation, so the
# planned kept energy can only match or beat it.


@dataclasses.dataclass(frozen=True)
class RankBudget:
    """A serializable non-uniform rank plan (DESIGN.md §14).

    ``qk_ranks[j][b][h]`` / ``vo_ranks[j][b][h]`` are the kept ranks of
    kv head ``h`` in stacked block ``b`` of pattern position ``j``
    (empty tuples for non-attention positions).  All ranks are already
    snapped to ``rank_multiple`` and respect §5 applicability: in
    partial-RoPE mode every qk rank includes the always-kept rotated
    block, and in intra mode qk ranks are pinned at ``head_dim``.

    Realization is two-level (the compiled-shape contract): arrays are
    sliced to the plan's global max widths (``qk_width``/``vo_width``
    — ONE static shape per plan), and the per-head remainder is the
    ``mask_head_ranks`` zero-pad convention plus the kernels' per-head
    rank clamp, so a head's pruned tail costs neither DMA nor compute
    without fragmenting shapes.
    """
    head_dim: int                       # original per-head width d
    rank_multiple: int
    total_rank: int                     # sum of every kept qk+vo rank
    budget: int                         # the requested total (pre-clamp)
    qk_ranks: RankTable
    vo_ranks: RankTable

    @property
    def qk_width(self) -> int:
        """Global max kept qk rank — the static array/cache width."""
        return max((r for j in self.qk_ranks for b in j for r in b),
                   default=self.head_dim)

    @property
    def vo_width(self) -> int:
        return max((r for j in self.vo_ranks for b in j for r in b),
                   default=self.head_dim)

    def head_loads(self) -> np.ndarray:
        """(KV,) per-kv-head rank load summed over all layers — feeds
        ``rank_balanced_partition`` so tp shards carry ~equal pruned
        bytes/FLOPs under the non-uniform plan."""
        kv = max(len(b) for j in self.qk_ranks for b in j)
        loads = np.zeros(kv, np.float64)
        for table in (self.qk_ranks, self.vo_ranks):
            for j in table:
                for b in j:
                    loads += np.asarray(b, np.float64)
        return loads

    def layer_ranks(self, j: int) -> Tuple[np.ndarray, np.ndarray]:
        """Pattern position ``j``'s ((n_blocks, KV) qk, (n_blocks, KV)
        vo) kept-rank arrays (int32), for ``mask_head_ranks`` and the
        ``rank_qk``/``rank_vo`` param leaves."""
        return (np.asarray(self.qk_ranks[j], np.int32),
                np.asarray(self.vo_ranks[j], np.int32))

    def salt(self) -> Tuple:
        """Folds the plan into cache keys (the prefix trie's salt, like
        ``HeadPartition.salt``): pages written under a different rank
        plan live in a different basis and must never alias."""
        return (("budget", self.head_dim, self.rank_multiple,
                 self.total_rank)
                + tuple(r for j in self.qk_ranks for b in j for r in b)
                + tuple(r for j in self.vo_ranks for b in j for r in b))


def plan_rank_budget(extras, cfg: ArchConfig, *,
                     budget: Optional[float] = None,
                     total_rank: Optional[int] = None) -> RankBudget:
    """Water-fill a global rank budget across layers and heads by
    singular-value energy (DESIGN.md §14).

    ``extras`` comes from ``clover_decompose`` (``extras[j]["spectra"]``
    holds the descending per-head spectra).  Give the budget either as
    ``budget`` — the fraction of TOTAL rank capacity to keep (e.g. 0.4
    = "keep 40% of total rank") — or as ``total_rank``, the absolute
    kept-rank total (used to match a uniform plan exactly).

    §5 applicability is structural, not scored: partial-RoPE rotated
    blocks and intra-mode Q-K widths are mandatory allocations the
    greedy pass never touches; V-O is always prunable.  Every head
    additionally keeps at least one ``rank_multiple`` block (the
    ``snap_rank`` floor).  Conservation: the kept total lands within
    one block width above the budget unless the budget is below the
    mandatory floor or above capacity (then it clamps, exactly).
    """
    d = cfg.head_dim_
    m = max(1, cfg.clover.rank_multiple)
    mode = qk_mode(cfg)
    rot = cfg.rope_dims if mode == "partial" else 0

    def blocks_of(width: int):
        """[(offset, block width)] tiling of a prunable width."""
        out = []
        o = 0
        while o < width:
            out.append((o, min(m, width - o)))
            o += m
        return out

    qk_tab: list = []
    vo_tab: list = []
    capacity = 0
    floor_total = 0
    candidates = []          # (energy, family, j, b, h, block idx, width)
    for j, ex in enumerate(extras):
        sp = ex.get("spectra", {}) if isinstance(ex, dict) else {}
        if "vo" not in sp:                     # non-attention position
            qk_tab.append(())
            vo_tab.append(())
            continue
        from repro.core.analytics import energy_blocks
        vo_e = energy_blocks(sp["vo"], m)       # (nb, KV, n_blk)
        nb, kv = vo_e.shape[:2]
        capacity += nb * kv * 2 * d
        qk_j = np.zeros((nb, kv), np.int64)
        vo_j = np.zeros((nb, kv), np.int64)
        if mode == "intra" or "qk" not in sp:  # Q-K pruning illegal (§5)
            qk_j[:] = d
            floor_total += nb * kv * d
        else:
            d_eff = np.asarray(sp["qk"]).shape[-1]   # prunable NoPE width
            qk_e = energy_blocks(sp["qk"], m)
            qk_blocks = blocks_of(d_eff)
            for b in range(nb):
                for h in range(kv):
                    qk_j[b, h] = rot + qk_blocks[0][1]   # snap_rank floor
                    floor_total += rot + qk_blocks[0][1]
                    for i, (o, w) in enumerate(qk_blocks[1:], 1):
                        candidates.append(
                            (float(qk_e[b, h, i]), 0, j, b, h, i, w))
        vo_blocks = blocks_of(d)
        for b in range(nb):
            for h in range(kv):
                vo_j[b, h] = vo_blocks[0][1]
                floor_total += vo_blocks[0][1]
                for i, (o, w) in enumerate(vo_blocks[1:], 1):
                    candidates.append(
                        (float(vo_e[b, h, i]), 1, j, b, h, i, w))
        qk_tab.append(qk_j)
        vo_tab.append(vo_j)

    if (budget is None) == (total_rank is None):
        raise ValueError("plan_rank_budget: give exactly one of "
                         "budget (keep fraction) or total_rank")
    target = (int(total_rank) if total_rank is not None
              else int(round(float(budget) * capacity)))
    if not 0 < target or (budget is not None and not 0 < budget <= 1):
        raise ValueError(
            f"plan_rank_budget: budget={budget} total_rank={total_rank} "
            f"must select a positive kept total (capacity {capacity})")
    target = min(max(target, floor_total), capacity)

    # Greedy: descending energy; ties broken by position so the order —
    # hence monotonicity in the budget — is fully deterministic.
    # Within a head the descending spectrum makes block energies
    # monotone, so taking in this order always extends prefixes.
    candidates.sort(key=lambda c: (-c[0], c[1], c[2], c[3], c[4], c[5]))
    kept = floor_total
    for e, fam, j, b, h, i, w in candidates:
        if kept >= target:
            break
        tab = qk_tab if fam == 0 else vo_tab
        tab[j][b, h] += w
        kept += w

    freeze = lambda t: (() if isinstance(t, tuple) else tuple(  # noqa: E731
        tuple(int(r) for r in row) for row in t))
    return RankBudget(
        head_dim=d, rank_multiple=m, total_rank=int(kept),
        budget=target,
        qk_ranks=tuple(freeze(t) for t in qk_tab),
        vo_ranks=tuple(freeze(t) for t in vo_tab))


def budget_kept_energy(extras, plan: RankBudget) -> float:
    """Total squared singular mass the plan keeps — the spectral quality
    proxy serve_bench's budget scenario gates on: at matched total kept
    rank the greedy plan's kept energy is >= any uniform plan's
    (DESIGN.md §14).  Rotated/intra blocks carry no spectrum entries
    and contribute equally to every plan, so they cancel in
    comparisons."""
    total = 0.0
    for j, ex in enumerate(extras):
        sp = ex.get("spectra", {}) if isinstance(ex, dict) else {}
        if "qk" in sp and plan.qk_ranks[j]:
            sq = np.square(np.asarray(sp["qk"], np.float64))
            d_eff = sq.shape[-1]
            rot = plan.head_dim - d_eff
            for b, row in enumerate(plan.qk_ranks[j]):
                for h, r in enumerate(row):
                    total += float(sq[b, h, :max(r - rot, 0)].sum())
        if "vo" in sp and plan.vo_ranks[j]:
            sq = np.square(np.asarray(sp["vo"], np.float64))
            for b, row in enumerate(plan.vo_ranks[j]):
                for h, r in enumerate(row):
                    total += float(sq[b, h, :r].sum())
    return total


def apply_rank_budget(params: Params, cfg: ArchConfig,
                      plan: RankBudget) -> Tuple[Params, ArchConfig]:
    """Realize a ``RankBudget`` on a decomposed model (DESIGN.md §14).

    Three steps, composing the existing machinery: (1) slice every
    attention stack to the plan's global max widths (``clover_prune``'s
    static-slice convention — ONE compiled shape per plan), (2) zero-pad
    each head's tail past its own kept rank (``mask_head_ranks`` — the
    padded model is BITWISE the per-head-truncated model), and
    (3) embed the per-layer kept ranks as ``rank_qk``/``rank_vo``
    (n_blocks, KV) int32 leaves in each attention stack; the layer scan
    delivers them per layer to ``models.layers.attention``, which
    forwards them to the decode kernels' per-head rank clamp so the
    zero-padded tails also cost no DMA/FLOPs.

    Returns (params', cfg') with cfg'.clover ranks set to the plan's
    max widths (the KV-cache/page-pool width).
    """
    assert cfg.clover.enabled, "apply_rank_budget requires a decomposed model"
    dq_max, dv_max = plan.qk_width, plan.vo_width
    new_blocks = []
    for j, (mixer, mlp) in enumerate(cfg.pattern):
        stacked = dict(params["blocks"][j])
        if mixer == MIXER_ATTN:
            attn = _prune_attn_clover(stacked["attn"], cfg, dq_max, dv_max)
            qk_j, vo_j = plan.layer_ranks(j)
            attn["rank_qk"] = jnp.asarray(qk_j)
            attn["rank_vo"] = jnp.asarray(vo_j)
            stacked["attn"] = attn
        new_blocks.append(stacked)
    out = dict(params)
    out["blocks"] = tuple(new_blocks)
    cfg1 = _set_ranks(cfg, dq_max, dv_max)
    qk_per_j = {j: plan.layer_ranks(j)[0] for j, (mx, _) in
                enumerate(cfg.pattern) if mx == MIXER_ATTN}
    vo_per_j = {j: plan.layer_ranks(j)[1] for j, (mx, _) in
                enumerate(cfg.pattern) if mx == MIXER_ATTN}
    return mask_head_ranks(out, cfg1, qk_per_j, vo_per_j), cfg1


# ---------------------------------------------------------------------------
# Rank-balanced head partitioning (tensor-parallel serving, DESIGN.md §10)
# ---------------------------------------------------------------------------
#
# CLOVER's per-head Q-K / V-O pruning can leave heads with HETEROGENEOUS
# ranks (threshold planning keeps a different number of directions per
# head before the uniform snap), so a naive even head split hands some
# model shards more pruned FLOPs/bytes than others and the slowest shard
# sets the step time.  The partition below plans the head -> shard
# assignment explicitly: equal head COUNTS per shard (SPMD needs equal
# array slices) with the per-head rank LOADS bin-packed so every shard
# carries ~the same pruned work.  Heads are assigned at KV-head
# granularity — a GQA group's query heads must live with their KV head.


@dataclasses.dataclass(frozen=True)
class HeadPartition:
    """A head -> shard plan: ``kv_assign[s]`` is the tuple of kv-head
    ids shard ``s`` owns (each shard owns exactly ``KV / n_shards``).
    Realized by PERMUTING the head axes so shard ``s`` holds the
    contiguous slice ``[s*per : (s+1)*per]`` — attention is a sum over
    heads, so a consistent permutation of wq/wk/wv/wo (and the cache
    written through them) is exact."""
    n_shards: int
    group: int                                # query heads per kv head
    kv_assign: Tuple[Tuple[int, ...], ...]
    loads: Tuple[float, ...]                  # per-shard rank load

    @property
    def kv_perm(self) -> Tuple[int, ...]:
        """KV-head permutation: new position -> old kv-head id."""
        return tuple(h for shard in self.kv_assign for h in shard)

    @property
    def q_perm(self) -> Tuple[int, ...]:
        """Query-head permutation implied by ``kv_perm`` (GQA groups
        move with their kv head)."""
        return tuple(kv * self.group + g for kv in self.kv_perm
                     for g in range(self.group))

    @property
    def identity(self) -> bool:
        return self.kv_perm == tuple(range(len(self.kv_perm)))

    @property
    def balance(self) -> float:
        """max/min per-shard rank load (1.0 = perfectly balanced)."""
        lo = min(self.loads)
        return float(max(self.loads)) / float(lo) if lo > 0 else float("inf")

    def salt(self) -> Tuple:
        """Folds the plan into cache keys (the prefix trie's salt):
        pages written under a different head layout must never alias."""
        return ("tp", self.n_shards, self.group) + self.kv_perm


def head_rank_loads(cfg: ArchConfig,
                    qk_ranks: Optional[Sequence[int]] = None,
                    vo_ranks: Optional[Sequence[int]] = None) -> np.ndarray:
    """(KV,) per-kv-head rank load: cached bytes AND attention FLOPs per
    token both scale with ``r_qk + r_vo``.  Defaults to the config's
    uniform CLOVER plan; pass per-head rank vectors (e.g. from
    threshold spectra) for a heterogeneous plan."""
    kv = cfg.n_kv_heads
    if qk_ranks is None:
        qk_ranks = [cfg.qk_dim] * kv
    if vo_ranks is None:
        vo_ranks = [cfg.vo_dim] * kv
    qk = np.asarray(qk_ranks, np.float64)
    vo = np.asarray(vo_ranks, np.float64)
    assert qk.shape == (kv,) and vo.shape == (kv,), (qk.shape, vo.shape, kv)
    return qk + vo


def rank_balanced_partition(loads: Sequence[float], n_shards: int,
                            group: int = 1) -> HeadPartition:
    """Greedy LPT bin-packing of per-kv-head loads into ``n_shards``
    equal-cardinality bins.

    Heads sorted by descending load each go to the least-loaded bin
    that still has a free slot (ties: lowest bin index, then lowest
    head id — fully deterministic).  Equal cardinality is an SPMD
    constraint, not a heuristic: every shard's array slice must have
    the same extent.  All-equal loads short-circuit to the contiguous
    identity split so the uniform-rank serving path keeps the exact
    head order (and FP summation order) of the unsharded model.
    """
    loads = [float(x) for x in loads]
    H = len(loads)
    if n_shards < 1 or H % n_shards != 0:
        raise ValueError(
            f"{H} kv heads do not split over {n_shards} shards: the "
            "tensor-parallel degree must divide the kv-head count")
    per = H // n_shards
    if len(set(loads)) <= 1:          # uniform ranks: identity split
        assign = tuple(tuple(range(s * per, (s + 1) * per))
                       for s in range(n_shards))
        return HeadPartition(n_shards, group, assign,
                             tuple(sum(loads[s * per:(s + 1) * per])
                                   for s in range(n_shards)))
    bins: list = [[] for _ in range(n_shards)]
    totals = [0.0] * n_shards
    order = sorted(range(H), key=lambda h: (-loads[h], h))
    for h in order:
        s = min((s for s in range(n_shards) if len(bins[s]) < per),
                key=lambda s: (totals[s], s))
        bins[s].append(h)
        totals[s] += loads[h]
    return HeadPartition(n_shards, group,
                         tuple(tuple(sorted(b)) for b in bins),
                         tuple(totals))


def _permute_axis(leaf, perm: Tuple[int, ...], axis_from_end: int):
    idx = jnp.asarray(perm, jnp.int32)
    return jnp.take(leaf, idx, axis=leaf.ndim - axis_from_end)


def permute_attention_heads(params: Params, cfg: ArchConfig,
                            plan: HeadPartition) -> Params:
    """Reorder every attention block's head axes by ``plan`` so shard
    ``s`` owns the contiguous head slice the partition assigned it.
    Works on stacked params (leading ``n_blocks`` axis) via
    end-relative axis indexing.  Exact: attention sums over heads and
    each head's factors move together (wq/wo by ``q_perm``; wk/wv/k_t
    by ``kv_perm``; s_qk/s_vo by ``q_perm``).  The KV cache needs no
    permutation — it starts empty and is only ever written through the
    permuted projections."""
    if plan.identity:
        return params
    q_perm, kv_perm = plan.q_perm, plan.kv_perm
    # leaf name -> (perm, head axis counted from the END of the shape);
    # rank_qk/rank_vo (n_blocks, KV) ride with their kv heads so the
    # kernels' per-head rank clamp stays aligned after the permutation
    moves = {"wq": (q_perm, 2), "wk": (kv_perm, 2), "wv": (kv_perm, 2),
             "wo": (q_perm, 3), "s_qk": (q_perm, 3), "s_vo": (q_perm, 3),
             "k_t": (kv_perm, 3), "rank_qk": (kv_perm, 1),
             "rank_vo": (kv_perm, 1)}
    new_blocks = []
    for j, (mixer, mlp) in enumerate(cfg.pattern):
        stacked = dict(params["blocks"][j])
        if mixer == MIXER_ATTN:
            attn = dict(stacked["attn"])
            for name, (perm, ax) in moves.items():
                if name in attn:
                    attn[name] = _permute_axis(attn[name], perm, ax)
            stacked["attn"] = attn
        new_blocks.append(stacked)
    out = dict(params)
    out["blocks"] = tuple(new_blocks)
    return out


def mask_head_ranks(params: Params, cfg: ArchConfig,
                    qk_ranks, vo_ranks) -> Params:
    """RAGGED per-head ranks, realized as zero-padding: head ``h``
    keeps its leading ``qk_ranks[h]`` / ``vo_ranks[h]`` directions and
    the tail up to the (uniform) array width is zeroed in every factor
    that touches it.  Zeroed rank dims contribute exactly 0 to the
    Q·K logits and to the V·O context — the padded model is BITWISE
    the per-head-truncated model, while all shapes stay static (the
    rank analogue of the paged pool's garbage-row convention: padding
    exists physically but can never influence a result).  This is what
    lets shards carry heads of different ranks through ONE compiled
    step shape per parallelism degree.

    ``qk_ranks``/``vo_ranks`` are either flat (KV,) vectors (one rank
    per head, shared by every layer — the original contract) or
    mappings ``{pattern position j: (n_blocks, KV) array}`` for
    per-LAYER ragged ranks (a ``RankBudget`` plan, DESIGN.md §14); the
    per-block masks broadcast over the stacked layer axis exactly as
    the flat ones do."""
    kv = cfg.n_kv_heads
    G = cfg.q_per_kv

    def norm(ranks, j):
        """Rank array for pattern position ``j``: (KV,) or (nb, KV)."""
        if isinstance(ranks, dict):
            r = np.asarray(ranks[j], np.int64)
            assert r.ndim == 2 and r.shape[-1] == kv, (r.shape, kv)
        else:
            r = np.asarray(ranks, np.int64)
            assert r.shape == (kv,), (r.shape, kv)
        return r

    def rank_mask(r, width, per_q: bool):
        if per_q:
            r = np.repeat(r, G, axis=-1)
        # (..., heads, width): leading block axis (if any) broadcasts
        return jnp.asarray(np.arange(width)[None, :] < r[..., :, None])

    new_blocks = []
    for j, (mixer, mlp) in enumerate(cfg.pattern):
        stacked = dict(params["blocks"][j])
        if mixer == MIXER_ATTN:
            attn = dict(stacked["attn"])
            qk = norm(qk_ranks, j)
            vo = norm(vo_ranks, j)
            dq = attn["wq"].shape[-1]
            dv = attn["wv"].shape[-1]
            mq = rank_mask(qk, dq, True)          # (..., H, dq)
            mk = rank_mask(qk, dq, False)         # (..., KV, dq)
            mv = rank_mask(vo, dv, False)         # (..., KV, dv)
            mo = rank_mask(vo, dv, True)          # (..., H, dv)
            # wq/wk/wv (..., D, heads, r): the embed axis sits between
            # any block axis and the head axis, so per-block masks gain
            # a broadcast dim for it; flat masks broadcast as before.
            emb = (lambda msk: msk[:, None] if msk.ndim == 3 else msk)
            attn["wq"] = attn["wq"] * emb(mq)
            attn["wk"] = attn["wk"] * emb(mk)
            attn["wv"] = attn["wv"] * emb(mv)
            attn["wo"] = attn["wo"] * mo[..., :, :, None]
            if "s_qk" in attn:                    # rows AND cols masked
                attn["s_qk"] = (attn["s_qk"] * mq[..., :, :, None]
                                * mq[..., :, None, :])
            if "k_t" in attn:
                attn["k_t"] = (attn["k_t"] * mk[..., :, :, None]
                               * mk[..., :, None, :])
            if "s_vo" in attn:
                attn["s_vo"] = (attn["s_vo"] * mv[..., :, :, None]
                                * mv[..., :, None, :])
            stacked["attn"] = attn
        new_blocks.append(stacked)
    out = dict(params)
    out["blocks"] = tuple(new_blocks)
    return out
