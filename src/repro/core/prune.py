"""CLOVER pruning planner + vanilla baseline (paper Table 1, §4.4).

After ``clover_decompose`` the per-head factors are sorted by singular
value (descending), so structured pruning is a static slice ``[..., :r]``
— the same rate across all layers (paper: "to maintain inference
efficiency, we apply the same pruning rate across all layers").  The
KV cache then stores K at rank ``r_qk`` and V at rank ``r_vo``: the
decode memory win the paper targets.

TPU adaptation (DESIGN.md §4): kept ranks are snapped UP to the sublane
multiple (``cfg.clover.rank_multiple``) so MXU/VPU tiles stay aligned;
the pruned weights never carry HBM zero-padding.

Vanilla baseline: magnitude pruning of paired per-dim L2 norms
(``||wq_i||*||wk_i||`` / ``||wv_i||*||wo_i||``) WITHOUT
orthogonalization — per-head top-r gather.  For RoPE archs the rotated
block is never pruned (pairing would break); this mirrors CLOVER's own
applicability so comparisons are apples-to-apples.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, MIXER_ATTN
from repro.core.decompose import qk_mode

Params = Dict[str, Any]


def snap_rank(r: int, multiple: int, d: int) -> int:
    """Snap a kept rank UP to the TPU sublane multiple, capped at d."""
    if multiple <= 1:
        return max(1, min(r, d))
    return max(multiple, min(d, ((r + multiple - 1) // multiple) * multiple))


def plan_ranks(cfg: ArchConfig, qk_ratio: float, vo_ratio: float
               ) -> Tuple[int, int]:
    """Kept per-head widths (qk_keep, vo_keep) for a pruning ratio.

    In partial-RoPE mode only the NoPE tail is prunable: the ratio is
    applied to the tail and the rotated block is always kept.
    """
    d = cfg.head_dim_
    m = cfg.clover.rank_multiple
    mode = qk_mode(cfg)
    if mode == "cross":
        qk_keep = snap_rank(round(d * (1.0 - qk_ratio)), m, d)
    elif mode == "partial":
        rot = cfg.rope_dims
        tail = d - rot
        qk_keep = rot + snap_rank(round(tail * (1.0 - qk_ratio)), m, tail)
    else:  # intra (full RoPE): Q-K pruning illegal (paper §5)
        qk_keep = d
    vo_keep = snap_rank(round(d * (1.0 - vo_ratio)), m, d)
    return qk_keep, vo_keep


def draft_ranks(cfg: ArchConfig, ratio: float) -> Tuple[int, int]:
    """Per-head (qk, vo) widths of the self-speculative DRAFT model.

    The draft is the same weights with the last orthogonal directions of
    every head sliced off — ``ratio`` is applied to the CURRENT widths
    (which may already be pruned), so a model served at prune 0.5 drafts
    from a further-halved rank.  Applicability mirrors ``plan_ranks``:
    in partial-RoPE mode only the NoPE tail shrinks (slicing inside the
    rotated block would break RoPE's dim pairing), and in intra mode
    (full RoPE) the Q-K pair is never sliced — only V-O.  Widths snap UP
    to the TPU sublane multiple like every other kept rank.
    """
    dq, dv = cfg.qk_dim, cfg.vo_dim
    m = cfg.clover.rank_multiple
    mode = qk_mode(cfg)
    if mode == "cross":
        r_q = snap_rank(round(dq * (1.0 - ratio)), m, dq)
    elif mode == "partial":
        rot = min(cfg.rope_dims, dq)
        tail = dq - rot
        r_q = rot + (snap_rank(round(tail * (1.0 - ratio)), m, tail)
                     if tail > 0 else 0)
    else:  # intra (full RoPE): Q-K slicing illegal (paper §5)
        r_q = dq
    r_v = snap_rank(round(dv * (1.0 - ratio)), m, dv)
    return r_q, r_v


def _set_ranks(cfg: ArchConfig, qk_keep: int, vo_keep: int) -> ArchConfig:
    d = cfg.head_dim_
    return dataclasses.replace(
        cfg, clover=dataclasses.replace(
            cfg.clover, enabled=True,
            qk_rank=0 if qk_keep == d else qk_keep,
            vo_rank=0 if vo_keep == d else vo_keep))


# ---------------------------------------------------------------------------
# CLOVER pruning: static slices of the sorted factors
# ---------------------------------------------------------------------------

def _prune_attn_clover(attn: Params, cfg: ArchConfig,
                       qk_keep: int, vo_keep: int) -> Params:
    """Slice the sorted factors.  Works on stacked params (leading
    ``n_blocks`` axis) via ellipsis indexing:
        wq (..., D, H, dq)  wk (..., D, KV, dq)
        wv (..., D, KV, dv) wo (..., H, dv, D)
        s_qk/s_vo (..., H, d, d)  k_t (..., KV, d, d)."""
    new = dict(attn)
    d = cfg.head_dim_
    if qk_keep < d and qk_mode(cfg) != "intra":
        new["wq"] = attn["wq"][..., :qk_keep]
        new["wk"] = attn["wk"][..., :qk_keep]
        if "s_qk" in attn:   # CLOVER-dagger: keep S trainable post-prune
            new["s_qk"] = attn["s_qk"][..., :qk_keep, :qk_keep]
        if "k_t" in attn:
            new["k_t"] = attn["k_t"][..., :qk_keep, :qk_keep]
    if vo_keep < d:
        new["wv"] = attn["wv"][..., :vo_keep]
        new["wo"] = attn["wo"][..., :vo_keep, :]
        if "s_vo" in attn:
            new["s_vo"] = attn["s_vo"][..., :vo_keep, :vo_keep]
    return new


def clover_prune(params: Params, cfg: ArchConfig, *,
                 qk_ratio: float = 0.0, vo_ratio: float = 0.0,
                 ) -> Tuple[Params, ArchConfig]:
    """Prune a CLOVER-decomposed model (either peft or merged mode).

    ``params`` must come from ``clover_decompose`` (factors sorted by
    singular value).  Returns (params', cfg') with cfg'.clover ranks set
    so the model/KV-cache shapes shrink accordingly.
    """
    assert cfg.clover.enabled, "clover_prune requires a decomposed model"
    qk_keep, vo_keep = plan_ranks(cfg, qk_ratio, vo_ratio)

    new_blocks = []
    for j, (mixer, mlp) in enumerate(cfg.pattern):
        stacked = dict(params["blocks"][j])
        if mixer == MIXER_ATTN:
            stacked["attn"] = _prune_attn_clover(
                stacked["attn"], cfg, qk_keep, vo_keep)
        new_blocks.append(stacked)
    out = dict(params)
    out["blocks"] = tuple(new_blocks)
    return out, _set_ranks(cfg, qk_keep, vo_keep)


# ---------------------------------------------------------------------------
# Vanilla magnitude pruning baseline (no orthogonalization)
# ---------------------------------------------------------------------------

def _prune_attn_vanilla(attn: Params, cfg: ArchConfig,
                        qk_keep: int, vo_keep: int) -> Params:
    """Per-head top-r magnitude pruning on the RAW weights.

    wq (D,H,dq), wk (D,KV,dq), wv (D,KV,dv), wo (H,dv,D); GQA importance
    for the shared K/V dims is summed over the group's query heads.
    RoPE block ([:rot]) is always kept (see module docstring).
    """
    D, H, d = attn["wq"].shape
    KV = attn["wk"].shape[1]
    G = H // KV
    rot = min(cfg.rope_dims, d)
    new = dict(attn)

    if qk_keep < d and qk_mode(cfg) != "intra":
        nq = jnp.linalg.norm(attn["wq"], axis=0)              # (H, d)
        nk = jnp.linalg.norm(attn["wk"], axis=0)              # (KV, d)
        imp = (nq.reshape(KV, G, d) * nk[:, None, :]).sum(1)  # (KV, d)
        tail_keep = qk_keep - rot
        imp_t = imp[:, rot:]
        _, idx = jax.lax.top_k(imp_t, tail_keep)
        idx = jnp.sort(idx, axis=-1) + rot                    # (KV, tail_keep)
        if rot:
            idx = jnp.concatenate(
                [jnp.broadcast_to(jnp.arange(rot)[None], (KV, rot)), idx], -1)
        # gather per KV group; query heads share the group's index set
        idx_h = jnp.repeat(idx, G, axis=0)                    # (H, keep)
        new["wq"] = jnp.take_along_axis(
            attn["wq"], idx_h[None, :, :], axis=2)
        new["wk"] = jnp.take_along_axis(
            attn["wk"], idx[None, :, :], axis=2)

    if vo_keep < d:
        nv = jnp.linalg.norm(attn["wv"], axis=0)              # (KV, d)
        no = jnp.linalg.norm(attn["wo"], axis=2)              # (H, d)
        imp = (no.reshape(KV, G, d) * nv[:, None, :]).sum(1)  # (KV, d)
        _, idx = jax.lax.top_k(imp, vo_keep)
        idx = jnp.sort(idx, axis=-1)                          # (KV, keep)
        idx_h = jnp.repeat(idx, G, axis=0)
        new["wv"] = jnp.take_along_axis(attn["wv"], idx[None, :, :], axis=2)
        new["wo"] = jnp.take_along_axis(
            attn["wo"], idx_h[:, :, None], axis=1)
    return new


def vanilla_prune(params: Params, cfg: ArchConfig, *,
                  qk_ratio: float = 0.0, vo_ratio: float = 0.0,
                  ) -> Tuple[Params, ArchConfig]:
    """Magnitude pruning WITHOUT CLOVER orthogonalization (the baseline)."""
    qk_keep, vo_keep = plan_ranks(cfg, qk_ratio, vo_ratio)

    new_blocks = []
    for j, (mixer, mlp) in enumerate(cfg.pattern):
        stacked = dict(params["blocks"][j])
        if mixer == MIXER_ATTN:
            stacked["attn"] = jax.vmap(
                lambda a: _prune_attn_vanilla(a, cfg, qk_keep, vo_keep)
            )(stacked["attn"])
        new_blocks.append(stacked)
    out = dict(params)
    out["blocks"] = tuple(new_blocks)
    return out, _set_ranks(cfg, qk_keep, vo_keep)


# ---------------------------------------------------------------------------
# Threshold planning (paper §4.4: training-free pruning by magnitude cutoff)
# ---------------------------------------------------------------------------

def threshold_ratios(extras, cfg: ArchConfig, *,
                     qk_thresh: float, vo_thresh: float) -> Dict[str, float]:
    """From decomposition spectra, the uniform kept rank implied by a
    singular-value threshold: r = max over heads/layers of #{S >= t}
    (max keeps every head lossless; uniformity keeps shapes static).

    Returns achieved ratios + planned keeps; feed into clover_prune.
    """
    d = cfg.head_dim_
    qk_keep, vo_keep = 0, 0
    qk_total = vo_total = 0.0
    for ex in extras:
        sp = ex["spectra"] if "spectra" in ex else {}
        if "qk" in sp:
            s = sp["qk"]                      # (n_blocks, KV, d_eff)
            qk_keep = max(qk_keep, int(jnp.max(jnp.sum(s >= qk_thresh, -1))))
            qk_total += float(jnp.mean(jnp.sum(s >= qk_thresh, -1)))
        if "vo" in sp:
            s = sp["vo"]
            vo_keep = max(vo_keep, int(jnp.max(jnp.sum(s >= vo_thresh, -1))))
            vo_total += float(jnp.mean(jnp.sum(s >= vo_thresh, -1)))
    m = cfg.clover.rank_multiple
    mode = qk_mode(cfg)
    d_qk = (d - cfg.rope_dims) if mode == "partial" else d
    qk_keep = snap_rank(max(qk_keep, 1), m, d_qk) if mode != "intra" else d
    vo_keep = snap_rank(max(vo_keep, 1), m, d)
    return {
        "qk_keep": qk_keep, "vo_keep": vo_keep,
        "qk_ratio": 1.0 - qk_keep / d_qk if mode != "intra" else 0.0,
        "vo_ratio": 1.0 - vo_keep / d,
    }


# ---------------------------------------------------------------------------
# Rank-balanced head partitioning (tensor-parallel serving, DESIGN.md §10)
# ---------------------------------------------------------------------------
#
# CLOVER's per-head Q-K / V-O pruning can leave heads with HETEROGENEOUS
# ranks (threshold planning keeps a different number of directions per
# head before the uniform snap), so a naive even head split hands some
# model shards more pruned FLOPs/bytes than others and the slowest shard
# sets the step time.  The partition below plans the head -> shard
# assignment explicitly: equal head COUNTS per shard (SPMD needs equal
# array slices) with the per-head rank LOADS bin-packed so every shard
# carries ~the same pruned work.  Heads are assigned at KV-head
# granularity — a GQA group's query heads must live with their KV head.


@dataclasses.dataclass(frozen=True)
class HeadPartition:
    """A head -> shard plan: ``kv_assign[s]`` is the tuple of kv-head
    ids shard ``s`` owns (each shard owns exactly ``KV / n_shards``).
    Realized by PERMUTING the head axes so shard ``s`` holds the
    contiguous slice ``[s*per : (s+1)*per]`` — attention is a sum over
    heads, so a consistent permutation of wq/wk/wv/wo (and the cache
    written through them) is exact."""
    n_shards: int
    group: int                                # query heads per kv head
    kv_assign: Tuple[Tuple[int, ...], ...]
    loads: Tuple[float, ...]                  # per-shard rank load

    @property
    def kv_perm(self) -> Tuple[int, ...]:
        """KV-head permutation: new position -> old kv-head id."""
        return tuple(h for shard in self.kv_assign for h in shard)

    @property
    def q_perm(self) -> Tuple[int, ...]:
        """Query-head permutation implied by ``kv_perm`` (GQA groups
        move with their kv head)."""
        return tuple(kv * self.group + g for kv in self.kv_perm
                     for g in range(self.group))

    @property
    def identity(self) -> bool:
        return self.kv_perm == tuple(range(len(self.kv_perm)))

    @property
    def balance(self) -> float:
        """max/min per-shard rank load (1.0 = perfectly balanced)."""
        lo = min(self.loads)
        return float(max(self.loads)) / float(lo) if lo > 0 else float("inf")

    def salt(self) -> Tuple:
        """Folds the plan into cache keys (the prefix trie's salt):
        pages written under a different head layout must never alias."""
        return ("tp", self.n_shards, self.group) + self.kv_perm


def head_rank_loads(cfg: ArchConfig,
                    qk_ranks: Optional[Sequence[int]] = None,
                    vo_ranks: Optional[Sequence[int]] = None) -> np.ndarray:
    """(KV,) per-kv-head rank load: cached bytes AND attention FLOPs per
    token both scale with ``r_qk + r_vo``.  Defaults to the config's
    uniform CLOVER plan; pass per-head rank vectors (e.g. from
    threshold spectra) for a heterogeneous plan."""
    kv = cfg.n_kv_heads
    if qk_ranks is None:
        qk_ranks = [cfg.qk_dim] * kv
    if vo_ranks is None:
        vo_ranks = [cfg.vo_dim] * kv
    qk = np.asarray(qk_ranks, np.float64)
    vo = np.asarray(vo_ranks, np.float64)
    assert qk.shape == (kv,) and vo.shape == (kv,), (qk.shape, vo.shape, kv)
    return qk + vo


def rank_balanced_partition(loads: Sequence[float], n_shards: int,
                            group: int = 1) -> HeadPartition:
    """Greedy LPT bin-packing of per-kv-head loads into ``n_shards``
    equal-cardinality bins.

    Heads sorted by descending load each go to the least-loaded bin
    that still has a free slot (ties: lowest bin index, then lowest
    head id — fully deterministic).  Equal cardinality is an SPMD
    constraint, not a heuristic: every shard's array slice must have
    the same extent.  All-equal loads short-circuit to the contiguous
    identity split so the uniform-rank serving path keeps the exact
    head order (and FP summation order) of the unsharded model.
    """
    loads = [float(x) for x in loads]
    H = len(loads)
    if n_shards < 1 or H % n_shards != 0:
        raise ValueError(
            f"{H} kv heads do not split over {n_shards} shards: the "
            "tensor-parallel degree must divide the kv-head count")
    per = H // n_shards
    if len(set(loads)) <= 1:          # uniform ranks: identity split
        assign = tuple(tuple(range(s * per, (s + 1) * per))
                       for s in range(n_shards))
        return HeadPartition(n_shards, group, assign,
                             tuple(sum(loads[s * per:(s + 1) * per])
                                   for s in range(n_shards)))
    bins: list = [[] for _ in range(n_shards)]
    totals = [0.0] * n_shards
    order = sorted(range(H), key=lambda h: (-loads[h], h))
    for h in order:
        s = min((s for s in range(n_shards) if len(bins[s]) < per),
                key=lambda s: (totals[s], s))
        bins[s].append(h)
        totals[s] += loads[h]
    return HeadPartition(n_shards, group,
                         tuple(tuple(sorted(b)) for b in bins),
                         tuple(totals))


def _permute_axis(leaf, perm: Tuple[int, ...], axis_from_end: int):
    idx = jnp.asarray(perm, jnp.int32)
    return jnp.take(leaf, idx, axis=leaf.ndim - axis_from_end)


def permute_attention_heads(params: Params, cfg: ArchConfig,
                            plan: HeadPartition) -> Params:
    """Reorder every attention block's head axes by ``plan`` so shard
    ``s`` owns the contiguous head slice the partition assigned it.
    Works on stacked params (leading ``n_blocks`` axis) via
    end-relative axis indexing.  Exact: attention sums over heads and
    each head's factors move together (wq/wo by ``q_perm``; wk/wv/k_t
    by ``kv_perm``; s_qk/s_vo by ``q_perm``).  The KV cache needs no
    permutation — it starts empty and is only ever written through the
    permuted projections."""
    if plan.identity:
        return params
    q_perm, kv_perm = plan.q_perm, plan.kv_perm
    # leaf name -> (perm, head axis counted from the END of the shape)
    moves = {"wq": (q_perm, 2), "wk": (kv_perm, 2), "wv": (kv_perm, 2),
             "wo": (q_perm, 3), "s_qk": (q_perm, 3), "s_vo": (q_perm, 3),
             "k_t": (kv_perm, 3)}
    new_blocks = []
    for j, (mixer, mlp) in enumerate(cfg.pattern):
        stacked = dict(params["blocks"][j])
        if mixer == MIXER_ATTN:
            attn = dict(stacked["attn"])
            for name, (perm, ax) in moves.items():
                if name in attn:
                    attn[name] = _permute_axis(attn[name], perm, ax)
            stacked["attn"] = attn
        new_blocks.append(stacked)
    out = dict(params)
    out["blocks"] = tuple(new_blocks)
    return out


def mask_head_ranks(params: Params, cfg: ArchConfig,
                    qk_ranks: Sequence[int],
                    vo_ranks: Sequence[int]) -> Params:
    """RAGGED per-head ranks, realized as zero-padding: head ``h``
    keeps its leading ``qk_ranks[h]`` / ``vo_ranks[h]`` directions and
    the tail up to the (uniform) array width is zeroed in every factor
    that touches it.  Zeroed rank dims contribute exactly 0 to the
    Q·K logits and to the V·O context — the padded model is BITWISE
    the per-head-truncated model, while all shapes stay static (the
    rank analogue of the paged pool's garbage-row convention: padding
    exists physically but can never influence a result).  This is what
    lets shards carry heads of different ranks through ONE compiled
    step shape per parallelism degree."""
    kv = cfg.n_kv_heads
    G = cfg.q_per_kv
    qk = np.asarray(qk_ranks, np.int64)
    vo = np.asarray(vo_ranks, np.int64)
    assert qk.shape == (kv,) and vo.shape == (kv,), (qk.shape, vo.shape)

    def rank_mask(ranks_per_head, width, per_q: bool):
        r = np.repeat(ranks_per_head, G) if per_q else ranks_per_head
        return jnp.asarray(np.arange(width)[None, :] < r[:, None])

    new_blocks = []
    for j, (mixer, mlp) in enumerate(cfg.pattern):
        stacked = dict(params["blocks"][j])
        if mixer == MIXER_ATTN:
            attn = dict(stacked["attn"])
            dq = attn["wq"].shape[-1]
            dv = attn["wv"].shape[-1]
            mq = rank_mask(qk, dq, True)          # (H, dq)
            mk = rank_mask(qk, dq, False)         # (KV, dq)
            mv = rank_mask(vo, dv, False)         # (KV, dv)
            mo = rank_mask(vo, dv, True)          # (H, dv)
            attn["wq"] = attn["wq"] * mq
            attn["wk"] = attn["wk"] * mk
            attn["wv"] = attn["wv"] * mv
            attn["wo"] = attn["wo"] * mo[..., :, :, None]
            if "s_qk" in attn:                    # rows AND cols masked
                attn["s_qk"] = (attn["s_qk"] * mq[..., :, :, None]
                                * mq[..., :, None, :])
            if "k_t" in attn:
                attn["k_t"] = (attn["k_t"] * mk[..., :, :, None]
                               * mk[..., :, None, :])
            if "s_vo" in attn:
                attn["s_vo"] = (attn["s_vo"] * mv[..., :, :, None]
                                * mv[..., :, None, :])
            stacked["attn"] = attn
        new_blocks.append(stacked)
    out = dict(params)
    out["blocks"] = tuple(new_blocks)
    return out
