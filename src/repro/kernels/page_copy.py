"""Batched KV-page clone: the device half of copy-on-write prefix
caching (DESIGN.md §9, serve.engine).

When a sequence must write into a page that other sequences (or the
prefix trie) still read, the host repoints its page-table entry to a
fresh page and the CONTENT of the shared page has to move ``src ->
dst`` across every layer's pool before the step's scatter-write runs.
That copy is pure DMA — no compute — so the kernel is a grid of
row-to-row block moves driven by scalar-prefetched ``src``/``dst`` id
vectors, exactly the indirection idiom of
``paged_decode_attention.py``: the BlockSpec index maps dereference the
id vectors BEFORE the body runs, so the pipeline streams each (pt, KV,
r) slab from pool row ``src[i]`` straight into row ``dst[i]`` without a
device-wide gather/scatter.

The pool is aliased input->output (in-place on TPU): grid steps only
touch their (src, dst) rows, every other row keeps its bytes.  Pairs
execute in grid order, which the caller relies on when a page freed
after serving as a ``src`` is immediately reallocated as a later
``dst`` (the reverse — a fresh dst becoming a later src — cannot occur
in one batch; see ``Engine._copy_pages``).  Padding a short batch with
sentinel->sentinel self-copies is legal: a row copied onto itself is a
no-op.

Pool rows are (page_tokens, KV, r) slabs; on real TPUs keep
``page_tokens`` a multiple of the dtype sublane tile (8 for f32, 16
for bf16) — the same layout rule the paged decode kernel already
imposes.  Tests run interpret mode where any size is legal.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _page_copy_kernel(src_ref, dst_ref, in_ref, out_ref):
    del src_ref, dst_ref          # consumed by the BlockSpec index maps
    out_ref[...] = in_ref[...]


def page_copy(pool: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray, *,
              interpret: bool = False) -> jnp.ndarray:
    """pool: (n_blocks, N, page_tokens, KV, r) — one layer-stacked KV
    pool leaf;  src, dst: (m,) int32 pool-row ids (pairs disjoint
    except sentinel self-copy padding).  Returns the pool with row
    ``dst[i]`` holding a copy of row ``src[i]`` for every i, all other
    rows untouched.  -> same shape/dtype as ``pool``.
    """
    n_blocks, N, pt, KV, r = pool.shape
    m = src.shape[0]

    def _src_block(i, b, src, dst):
        return (b, src[i], 0, 0, 0)

    def _dst_block(i, b, src, dst):
        return (b, dst[i], 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        # pairs are the OUTER (sequential) axis so pair i+1 reads pair
        # i's writes if the host ever chains them; blocks inner
        grid=(m, n_blocks),
        in_specs=[pl.BlockSpec((1, 1, pt, KV, r), _src_block)],
        out_specs=pl.BlockSpec((1, 1, pt, KV, r), _dst_block),
    )
    return pl.pallas_call(
        _page_copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        # alias the pool through: untouched rows keep their bytes and
        # the copy is in-place on TPU (index 2 = pool, after the two
        # scalar-prefetch operands)
        input_output_aliases={2: 0},
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(src.astype(jnp.int32), dst.astype(jnp.int32), pool)
