"""Batched KV-page row movers: the device half of copy-on-write prefix
caching AND of the host-RAM spill tier's restore path (DESIGN.md §9,
§12; serve.memory / serve.engine).

Two entry points share one shape contract — a grid of row-to-row
(page_tokens, KV, r) slab moves over the layer-stacked pool, driven by
scalar-prefetched page-id vectors, exactly the indirection idiom of
``paged_decode_attention.py`` (the BlockSpec index maps dereference the
id vectors BEFORE the body runs, so each slab streams straight to its
destination row without a device-wide gather/scatter):

* ``page_copy`` — intra-pool clone ``src[i] -> dst[i]`` (PR 4's
  copy-on-write fault: a sequence about to write a shared page gets a
  private copy first).  Pure DMA, no compute.
* ``page_restore`` — scatter EXTERNAL row content into the pool:
  slab ``rows[:, i]`` (host-tier bytes copied back to device) lands in
  pool row ``dst[i]``.  Same grid, same block shapes, so restoring a
  spilled prefix adds exactly ONE fixed-width compiled shape on top of
  the page-copy one (DESIGN.md §12's shape-budget argument).

The pool is aliased input->output (in-place on TPU): grid steps only
touch their destination rows, every other row keeps its bytes.  Pairs
execute in grid order, which the copy caller relies on when a page
freed after serving as a ``src`` is immediately reallocated as a later
``dst`` (the reverse — a fresh dst becoming a later src — cannot occur
in one batch; see ``Engine._copy_pages``).  Padding a short batch is
legal in both directions: sentinel->sentinel self-copies are no-ops,
and restore padding scatters all-zero slabs into the garbage row.

Pool rows are (page_tokens, KV, r) slabs; on real TPUs keep
``page_tokens`` a multiple of the dtype sublane tile (8 for f32, 16
for bf16) — the same layout rule the paged decode kernel already
imposes.  Tests run interpret mode where any size is legal.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _page_copy_kernel(src_ref, dst_ref, in_ref, out_ref):
    del src_ref, dst_ref          # consumed by the BlockSpec index maps
    out_ref[...] = in_ref[...]


def page_copy(pool: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray, *,
              interpret: bool = False) -> jnp.ndarray:
    """pool: (n_blocks, N, page_tokens, KV, r) — one layer-stacked KV
    pool leaf;  src, dst: (m,) int32 pool-row ids (pairs disjoint
    except sentinel self-copy padding).  Returns the pool with row
    ``dst[i]`` holding a copy of row ``src[i]`` for every i, all other
    rows untouched.  -> same shape/dtype as ``pool``.
    """
    n_blocks, N, pt, KV, r = pool.shape
    m = src.shape[0]

    def _src_block(i, b, src, dst):
        return (b, src[i], 0, 0, 0)

    def _dst_block(i, b, src, dst):
        return (b, dst[i], 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        # pairs are the OUTER (sequential) axis so pair i+1 reads pair
        # i's writes if the host ever chains them; blocks inner
        grid=(m, n_blocks),
        in_specs=[pl.BlockSpec((1, 1, pt, KV, r), _src_block)],
        out_specs=pl.BlockSpec((1, 1, pt, KV, r), _dst_block),
    )
    return pl.pallas_call(
        _page_copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        # alias the pool through: untouched rows keep their bytes and
        # the copy is in-place on TPU (index 2 = pool, after the two
        # scalar-prefetch operands)
        input_output_aliases={2: 0},
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(src.astype(jnp.int32), dst.astype(jnp.int32), pool)


def _page_restore_kernel(dst_ref, rows_ref, pool_ref, out_ref):
    del dst_ref, pool_ref         # dst drives the output index map; the
    out_ref[...] = rows_ref[...]  # pool block is only read for aliasing


def page_restore(pool: jnp.ndarray, rows: jnp.ndarray, dst: jnp.ndarray,
                 *, interpret: bool = False) -> jnp.ndarray:
    """pool: (n_blocks, N, page_tokens, KV, r) — one layer-stacked KV
    pool leaf;  rows: (n_blocks, W, page_tokens, KV, r) — externally
    sourced slab content (host-tier restore);  dst: (W,) int32 pool-row
    ids (freshly-allocated pages; padding entries repeat the sentinel
    row with zero slabs).  Returns the pool with row ``dst[i]`` holding
    ``rows[:, i]`` for every i, all other rows untouched.  -> same
    shape/dtype as ``pool``.
    """
    n_blocks, N, pt, KV, r = pool.shape
    W = rows.shape[1]

    def _rows_block(i, b, dst):
        return (b, i, 0, 0, 0)

    def _dst_block(i, b, dst):
        return (b, dst[i], 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(W, n_blocks),
        in_specs=[pl.BlockSpec((1, 1, pt, KV, r), _rows_block),
                  pl.BlockSpec((1, 1, pt, KV, r), _dst_block)],
        out_specs=pl.BlockSpec((1, 1, pt, KV, r), _dst_block),
    )
    return pl.pallas_call(
        _page_restore_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        # alias the pool through: untouched rows keep their bytes and
        # the scatter is in-place on TPU (index 2 = pool, after the
        # scalar-prefetch operand and the rows input)
        input_output_aliases={2: 0},
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(dst.astype(jnp.int32), rows.astype(pool.dtype), pool)
