"""Public kernel entry points: padding, backend dispatch, jit.

On TPU the Pallas kernels compile natively; on CPU they run in interpret
mode (Python-level execution of the kernel body) when ``interpret=True``
is requested, otherwise the pure-jnp reference executes (XLA-fused, much
faster on CPU — the default for model code so smoke tests stay quick).
The dry-run never traces through these (model code calls them only under
``attn_impl="pallas"``).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.clover_attention import flash_attention as _flash
from repro.kernels.decode_attention import flash_decode as _decode
from repro.kernels.paged_decode_attention import (
    paged_flash_decode as _paged_decode)
from repro.kernels.wkv6 import wkv6 as _wkv6


def _pad_to(x: jnp.ndarray, axis: int, multiple: int,
            value: float = 0.0) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "impl"))
def clover_attention(q, k, v, *, causal: bool = True,
                     scale: Optional[float] = None,
                     block_q: int = 128, block_k: int = 128,
                     impl: str = "ref") -> jnp.ndarray:
    """Asymmetric-head-width GQA attention.  impl: ref | pallas | interpret.

    q (B,S,H,dq), k (B,T,KV,dq), v (B,T,KV,dv) -> (B,S,H,dv).
    """
    if impl == "ref":
        return _ref.attention_ref(q, k, v, causal=causal, scale=scale)
    B, S, H, dq = q.shape
    T = k.shape[1]
    bq = min(block_q, max(8, S))
    bk = min(block_k, max(8, T))
    qp = _pad_to(q, 1, bq)
    kp = _pad_to(k, 1, bk)
    vp = _pad_to(v, 1, bk)
    # padded K tail is masked only by causality -> require causal when padded
    assert causal or (S % bq == 0 and T % bk == 0), \
        "non-causal pallas path requires block-aligned shapes"
    out = _flash(qp, kp, vp, causal=causal, scale=scale, block_q=bq,
                 block_k=bk, interpret=(impl == "interpret"))
    return out[:, :S]


@functools.partial(
    jax.jit, static_argnames=("scale", "block_t", "impl"))
def decode_attention(q, k, v, lengths, *, scale: Optional[float] = None,
                     block_t: int = 256, impl: str = "ref") -> jnp.ndarray:
    """Flash-decoding vs a (possibly CLOVER-rank) KV cache.

    q (B,H,dq), k (B,T,KV,dq), v (B,T,KV,dv), lengths (B,) -> (B,H,dv).
    """
    if impl == "ref":
        return _ref.decode_attention_ref(q, k, v, lengths, scale=scale)
    T = k.shape[1]
    bt = min(block_t, max(8, T))
    kp = _pad_to(k, 1, bt)
    vp = _pad_to(v, 1, bt)
    return _decode(q, kp, vp, lengths, scale=scale, block_t=bt,
                   interpret=(impl == "interpret"))


@functools.partial(jax.jit, static_argnames=("scale", "impl"))
def paged_decode_attention(q, k_pool, v_pool, page_table, lengths, *,
                           scale: Optional[float] = None,
                           impl: str = "ref") -> jnp.ndarray:
    """Flash-decoding vs a PAGED (possibly CLOVER-rank) KV cache.

    q (B,H,dq), k_pool (N,page_tokens,KV,dq), v_pool (N,page_tokens,KV,dv),
    page_table (B,n_p) int32, lengths (B,) -> (B,H,dv).

    No padding is needed: the pool's ``page_tokens`` axis IS the block
    size, and page-table entries past each slot's in-use pages are never
    dereferenced (the kernel clamps its sequential axis per row).
    """
    if impl == "ref":
        return _ref.paged_decode_attention_ref(q, k_pool, v_pool,
                                               page_table, lengths,
                                               scale=scale)
    return _paged_decode(q, k_pool, v_pool, page_table, lengths,
                         scale=scale, interpret=(impl == "interpret"))


@functools.partial(jax.jit, static_argnames=("impl",))
def page_copy(pool, src, dst, *, impl: str = "ref") -> jnp.ndarray:
    """Batched KV-page clone — the device half of copy-on-write prefix
    caching (serve.engine, DESIGN.md §9).

    pool (n_blocks, N, page_tokens, KV, r), src/dst (m,) int32 pool-row
    ids -> pool with row ``dst[i]`` a copy of row ``src[i]``, all other
    rows untouched.  Pure DMA, no compute: the Pallas kernel is a
    scalar-prefetched row-to-row block move with the pool aliased
    through (in-place on TPU).
    """
    if impl == "ref":
        return _ref.page_copy_ref(pool, src, dst)
    from repro.kernels.page_copy import page_copy as _page_copy
    return _page_copy(pool, src, dst, interpret=(impl == "interpret"))


@functools.partial(jax.jit, static_argnames=("chunk", "tile", "impl"))
def mamba_scan(dt, A, Bmat, C, x, h0=None, *, chunk: int = 128,
               tile: int = 512,
               impl: str = "ref") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mamba-1 selective scan.  dt,x (B,S,dI); A (dI,dS); B,C (B,S,dS).

    Padding is state-neutral: dt=0 on the tail gives decay exp(0)=1 and
    zero input, so h_end is exact; padded outputs are sliced away."""
    if impl == "ref":
        return _ref.mamba_scan_ref(dt, A, Bmat, C, x, h0)
    from repro.kernels.mamba_scan import mamba_scan as _pallas_scan
    B, S, dI = x.shape
    c = min(chunk, max(8, S))
    dtp = _pad_to(dt, 1, c)
    xp = _pad_to(x, 1, c)
    Bp = _pad_to(Bmat, 1, c)
    Cp = _pad_to(C, 1, c)
    t = tile
    while dI % t:
        t //= 2
    y, h_end = _pallas_scan(dtp, A, Bp, Cp, xp, h0, chunk=c,
                            tile=max(1, t),
                            interpret=(impl == "interpret"))
    return y[:, :S], h_end


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def wkv6(r, k, v, logw, u, s0=None, *, chunk: int = 64,
         impl: str = "ref") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """RWKV-6 wkv.  r,k,v,logw (B,H,T,d), u (H,d), s0 (B,H,d,d)|None.

    Padding is state-neutral: logw=0 (decay 1) and k=0 (no update) on the
    padded tail leave S_end exact; padded outputs are sliced away.
    """
    if impl == "ref":
        return _ref.wkv6_ref(r, k, v, logw, u, s0)
    B, H, T, d = r.shape
    c = min(chunk, max(8, T))
    rp = _pad_to(r, 2, c)
    kp = _pad_to(k, 2, c)
    vp = _pad_to(v, 2, c)
    lwp = _pad_to(logw, 2, c)
    out, s_end = _wkv6(rp, kp, vp, lwp, u, s0, chunk=c,
                       interpret=(impl == "interpret"))
    return out[:, :, :T], s_end
