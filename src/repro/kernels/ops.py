"""Public kernel entry points: padding, backend dispatch, jit, meshes
(DESIGN.md §10's dispatch API over the §4/§6/§9/§12 kernels).

The dispatch surface is ``resolve(impl, mesh=None)`` -> a frozen
``KernelDispatch`` whose methods are the kernel entry points.  It is
resolved once per (impl alias, platform, mesh):

* ``impl`` aliases: ``"ref"`` (pure-jnp oracles), ``"xla"`` (model code
  takes its einsum paths; these entry points fall back to the oracles),
  ``"pallas"`` (native Pallas; resolves to ``"interpret"`` off
  TPU/GPU, where no native lowering exists), ``"interpret"`` (Pallas
  kernels in interpret mode — the CPU validation path).
* ``mesh``: when set, the serving hot-path kernels (flash-decode,
  paged-decode, page-copy, full-sequence attention) run PER SHARD
  under ``shard_map`` with serve-rules operand specs (slot batch over
  "data", KV heads over "model" — ``parallel.sharding.kernel_axes``).
  Per-(slot, kv-head) grid cells are independent, so the sharded
  outputs are bitwise identical to the single-device kernels.  Page
  ids stay HOST-GLOBAL: the pools' page-row axis is replicated
  (``serve_state_specs``), so scalar-prefetched page tables need no
  shard-local translation — each shard dereferences the same rows and
  reads its own head slice.

The module-level functions (``clover_attention`` et al.) are the thin
string-alias compatibility layer over ``resolve`` — existing call
sites and tests that pass ``impl="interpret"`` keep working unchanged.
The recurrent kernels (``mamba_scan``, ``wkv6``) never shard: they
carry cross-step state and have no shard_map partitioning (the
executors reject that combination loudly instead).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels import ref as _ref
from repro.kernels.clover_attention import flash_attention as _flash
from repro.kernels.decode_attention import (
    flash_decode as _decode, flash_decode_ranked as _decode_ranked)
from repro.kernels.paged_decode_attention import (
    paged_flash_decode as _paged_decode,
    paged_flash_decode_ranked as _paged_decode_ranked)
from repro.kernels.wkv6 import wkv6 as _wkv6

IMPLS = ("ref", "xla", "pallas", "interpret")


def _pad_to(x: jnp.ndarray, axis: int, multiple: int,
            value: float = 0.0) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# per-shard kernel bodies (shape-local: safe inside shard_map, where
# every padded/blocked axis — seq, pages, rank — is unsharded)
# ---------------------------------------------------------------------------

def _clover_body(q, k, v, *, causal, scale, block_q, block_k, interpret):
    S, T = q.shape[1], k.shape[1]
    bq = min(block_q, max(8, S))
    bk = min(block_k, max(8, T))
    qp = _pad_to(q, 1, bq)
    kp = _pad_to(k, 1, bk)
    vp = _pad_to(v, 1, bk)
    # padded K tail is masked only by causality -> require causal when padded
    assert causal or (S % bq == 0 and T % bk == 0), \
        "non-causal pallas path requires block-aligned shapes"
    out = _flash(qp, kp, vp, causal=causal, scale=scale, block_q=bq,
                 block_k=bk, interpret=interpret)
    return out[:, :S]


def _decode_body(q, k, v, lengths, *, scale, block_t, interpret):
    T = k.shape[1]
    bt = min(block_t, max(8, T))
    kp = _pad_to(k, 1, bt)
    vp = _pad_to(v, 1, bt)
    return _decode(q, kp, vp, lengths, scale=scale, block_t=bt,
                   interpret=interpret)


def _decode_ranked_body(q, k, v, lengths, qk_ranks, vo_ranks, *,
                        scale, block_t, rank_block, interpret):
    # Rank-dim zero-padding to block multiples is exact under the
    # mask_head_ranks convention (zeroed dims contribute exactly 0).
    T, dq, dv = k.shape[1], q.shape[-1], v.shape[-1]
    bt = min(block_t, max(8, T))
    rb = min(rank_block, max(8, max(dq, dv)))
    if scale is None:
        scale = float(1.0 / (dq ** 0.5))
    out = _decode_ranked(
        _pad_to(q, -1, rb), _pad_to(_pad_to(k, 1, bt), -1, rb),
        _pad_to(_pad_to(v, 1, bt), -1, rb), lengths, qk_ranks, vo_ranks,
        scale=scale, block_t=bt, rank_block=rb, interpret=interpret)
    return out[..., :dv]


def _paged_decode_ranked_body(q, k_pool, v_pool, page_table, lengths,
                              qk_ranks, vo_ranks, *, scale, rank_block,
                              interpret):
    dq, dv = q.shape[-1], v_pool.shape[-1]
    rb = min(rank_block, max(8, max(dq, dv)))
    if scale is None:
        scale = float(1.0 / (dq ** 0.5))
    out = _paged_decode_ranked(
        _pad_to(q, -1, rb), _pad_to(k_pool, -1, rb),
        _pad_to(v_pool, -1, rb), page_table, lengths, qk_ranks, vo_ranks,
        scale=scale, rank_block=rb, interpret=interpret)
    return out[..., :dv]


# ---------------------------------------------------------------------------
# the dispatch object
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelDispatch:
    """Frozen kernel dispatch: WHICH implementation runs, and WHERE.

    Built by ``resolve()`` and threaded through ``ArchConfig
    .kernel_impl`` / ``attn_impl`` in place of the old bare strings
    (both forms remain accepted — ``resolve`` is idempotent).  ``impl``
    is the canonical backend; ``requested`` records the alias resolve()
    was handed (e.g. "pallas" that canonicalized to "interpret" on
    CPU).  With ``mesh`` set, the hot-path methods run under
    ``shard_map`` per shard; hashable, so configs holding one stay
    hashable.
    """
    impl: str
    mesh: Optional[Mesh] = None
    requested: str = ""

    def __post_init__(self):
        if self.impl not in IMPLS:
            raise ValueError(f"unknown kernel impl {self.impl!r}: "
                             f"expected one of {IMPLS}")

    @property
    def kernel_path(self) -> bool:
        """True when the Pallas kernel bodies run (native or interpret)."""
        return self.impl in ("pallas", "interpret")

    @property
    def interpret(self) -> bool:
        return self.impl == "interpret"

    def describe(self) -> str:
        """Human-readable tag for reports: impl plus, when the mesh
        actually splits heads, the shard_map degree."""
        if (self.kernel_path and self.mesh is not None
                and self.mesh.shape.get("model", 1) > 1):
            return f"{self.impl}+shard_map(model=" \
                   f"{self.mesh.shape['model']})"
        return self.impl

    def _axes(self, *, batch: int, kv_heads: int):
        from repro.parallel.sharding import kernel_axes
        return kernel_axes(self.mesh, batch=batch, kv_heads=kv_heads)

    def _shard(self, body, in_specs, out_specs):
        from repro.parallel.sharding import shard_map_call
        return shard_map_call(body, self.mesh, in_specs, out_specs)

    # -- attention family ----------------------------------------------
    def clover_attention(self, q, k, v, *, causal: bool = True,
                         scale: Optional[float] = None,
                         block_q: int = 128,
                         block_k: int = 128) -> jnp.ndarray:
        """Asymmetric-head-width GQA attention.

        q (B,S,H,dq), k (B,T,KV,dq), v (B,T,KV,dv) -> (B,S,H,dv).
        """
        if not self.kernel_path:
            return _ref.attention_ref(q, k, v, causal=causal, scale=scale)
        body = functools.partial(_clover_body, causal=causal, scale=scale,
                                 block_q=block_q, block_k=block_k,
                                 interpret=self.interpret)
        b, m = self._axes(batch=q.shape[0], kv_heads=k.shape[2])
        if b is None and m is None:
            return body(q, k, v)
        fn = self._shard(body,
                         in_specs=(P(b, None, m, None), P(b, None, m, None),
                                   P(b, None, m, None)),
                         out_specs=P(b, None, m, None))
        return fn(q, k, v)

    def decode_attention(self, q, k, v, lengths, *,
                         scale: Optional[float] = None,
                         block_t: int = 256,
                         qk_ranks: Optional[jnp.ndarray] = None,
                         vo_ranks: Optional[jnp.ndarray] = None,
                         rank_block: int = 128) -> jnp.ndarray:
        """Flash-decoding vs a (possibly CLOVER-rank) KV cache.

        q (B,H,dq), k (B,T,KV,dq), v (B,T,KV,dv), lengths (B,)
        -> (B,H,dv).  With ``qk_ranks``/``vo_ranks`` ((KV,) int32,
        both or neither) the per-head rank-clamped kernel runs instead
        (non-uniform ``RankBudget`` plans, DESIGN.md §14); under a
        mesh the rank vectors shard along KV heads with the caches.
        """
        ranked = qk_ranks is not None or vo_ranks is not None
        if not self.kernel_path:
            return _ref.decode_attention_ref(q, k, v, lengths, scale=scale,
                                             qk_ranks=qk_ranks,
                                             vo_ranks=vo_ranks)
        b, m = self._axes(batch=q.shape[0], kv_heads=k.shape[2])
        if ranked:
            dq, dv = q.shape[-1], v.shape[-1]
            qk_ranks = (jnp.full((k.shape[2],), dq, jnp.int32)
                        if qk_ranks is None else qk_ranks.astype(jnp.int32))
            vo_ranks = (jnp.full((k.shape[2],), dv, jnp.int32)
                        if vo_ranks is None else vo_ranks.astype(jnp.int32))
            body = functools.partial(_decode_ranked_body, scale=scale,
                                     block_t=block_t, rank_block=rank_block,
                                     interpret=self.interpret)
            if b is None and m is None:
                return body(q, k, v, lengths, qk_ranks, vo_ranks)
            fn = self._shard(body,
                             in_specs=(P(b, m, None), P(b, None, m, None),
                                       P(b, None, m, None), P(b), P(m),
                                       P(m)),
                             out_specs=P(b, m, None))
            return fn(q, k, v, lengths, qk_ranks, vo_ranks)
        body = functools.partial(_decode_body, scale=scale, block_t=block_t,
                                 interpret=self.interpret)
        if b is None and m is None:
            return body(q, k, v, lengths)
        fn = self._shard(body,
                         in_specs=(P(b, m, None), P(b, None, m, None),
                                   P(b, None, m, None), P(b)),
                         out_specs=P(b, m, None))
        return fn(q, k, v, lengths)

    def paged_decode_attention(self, q, k_pool, v_pool, page_table,
                               lengths, *,
                               scale: Optional[float] = None,
                               qk_ranks: Optional[jnp.ndarray] = None,
                               vo_ranks: Optional[jnp.ndarray] = None,
                               rank_block: int = 128) -> jnp.ndarray:
        """Flash-decoding vs a PAGED (possibly CLOVER-rank) KV cache.

        q (B,H,dq), k_pool (N,page_tokens,KV,dq), v_pool (N,page_tokens,
        KV,dv), page_table (B,n_p) int32, lengths (B,) -> (B,H,dv).

        No padding is needed: the pool's ``page_tokens`` axis IS the
        block size, and page-table entries past each slot's in-use
        pages are never dereferenced (the kernel clamps its sequential
        axis per row).  Under a mesh the pools split along KV heads
        only; their page-row axis is REPLICATED, so the host-global
        page ids in ``page_table`` are valid row indices on every
        shard — the scalar-prefetched table crosses the shard_map
        boundary untranslated.  With ``qk_ranks``/``vo_ranks`` ((KV,)
        int32) the per-head rank-clamped kernel runs instead
        (non-uniform ``RankBudget`` plans, DESIGN.md §14).
        """
        ranked = qk_ranks is not None or vo_ranks is not None
        if not self.kernel_path:
            return _ref.paged_decode_attention_ref(q, k_pool, v_pool,
                                                   page_table, lengths,
                                                   scale=scale,
                                                   qk_ranks=qk_ranks,
                                                   vo_ranks=vo_ranks)
        b, m = self._axes(batch=q.shape[0], kv_heads=k_pool.shape[2])
        if ranked:
            dq, dv = q.shape[-1], v_pool.shape[-1]
            KV = k_pool.shape[2]
            qk_ranks = (jnp.full((KV,), dq, jnp.int32)
                        if qk_ranks is None else qk_ranks.astype(jnp.int32))
            vo_ranks = (jnp.full((KV,), dv, jnp.int32)
                        if vo_ranks is None else vo_ranks.astype(jnp.int32))
            body = functools.partial(_paged_decode_ranked_body, scale=scale,
                                     rank_block=rank_block,
                                     interpret=self.interpret)
            if b is None and m is None:
                return body(q, k_pool, v_pool, page_table, lengths,
                            qk_ranks, vo_ranks)
            fn = self._shard(body,
                             in_specs=(P(b, m, None), P(None, None, m, None),
                                       P(None, None, m, None), P(b, None),
                                       P(b), P(m), P(m)),
                             out_specs=P(b, m, None))
            return fn(q, k_pool, v_pool, page_table, lengths, qk_ranks,
                      vo_ranks)
        body = functools.partial(_paged_decode, scale=scale,
                                 interpret=self.interpret)
        if b is None and m is None:
            return body(q, k_pool, v_pool, page_table, lengths)
        fn = self._shard(body,
                         in_specs=(P(b, m, None), P(None, None, m, None),
                                   P(None, None, m, None), P(b, None),
                                   P(b)),
                         out_specs=P(b, m, None))
        return fn(q, k_pool, v_pool, page_table, lengths)

    def page_copy(self, pool, src, dst) -> jnp.ndarray:
        """Batched KV-page clone — the device half of copy-on-write
        prefix caching (serve.engine, DESIGN.md §9).

        pool (n_blocks, N, page_tokens, KV, r), src/dst (m,) int32
        pool-row ids -> pool with row ``dst[i]`` a copy of row
        ``src[i]``, all other rows untouched.  Pure DMA, no compute.
        On the non-kernel paths this is the jnp oracle ("xla" included
        — there is no einsum equivalent to fall back to).  Under a
        mesh each shard clones its own KV-head slice of the same
        host-global rows.
        """
        if not self.kernel_path:
            return _ref.page_copy_ref(pool, src, dst)
        from repro.kernels.page_copy import page_copy as _page_copy
        body = functools.partial(_page_copy, interpret=self.interpret)
        _, m = self._axes(batch=1, kv_heads=pool.shape[3])
        if m is None:
            return body(pool, src, dst)
        fn = self._shard(body,
                         in_specs=(P(None, None, None, m, None), P(), P()),
                         out_specs=P(None, None, None, m, None))
        return fn(pool, src, dst)

    def page_restore(self, pool, rows, dst) -> jnp.ndarray:
        """Batched host-tier page restore — scatter EXTERNAL slab
        content into pool rows (hierarchical KV, serve.memory
        ``HostTier``; DESIGN.md §12).

        pool (n_blocks, N, page_tokens, KV, r), rows (n_blocks, W,
        page_tokens, KV, r), dst (W,) int32 pool-row ids -> pool with
        row ``dst[i]`` holding ``rows[:, i]``, all other rows
        untouched.  Pure DMA, no compute.  On the non-kernel paths
        this is the jnp oracle ("xla" included — there is no einsum
        equivalent).  Under a mesh the restore rows arrive replicated
        and each shard scatters its own KV-head slice into the same
        host-global rows.
        """
        if not self.kernel_path:
            return _ref.page_restore_ref(pool, rows, dst)
        from repro.kernels.page_copy import page_restore as _page_restore
        body = functools.partial(_page_restore, interpret=self.interpret)
        _, m = self._axes(batch=1, kv_heads=pool.shape[3])
        if m is None:
            return body(pool, rows, dst)
        fn = self._shard(body,
                         in_specs=(P(None, None, None, m, None),
                                   P(None, None, None, m, None), P()),
                         out_specs=P(None, None, None, m, None))
        return fn(pool, rows, dst)

    # -- recurrent kernels (never shard_map'd: cross-step state) -------
    def mamba_scan(self, dt, A, Bmat, C, x, h0=None, *, chunk: int = 128,
                   tile: int = 512) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Mamba-1 selective scan.  dt,x (B,S,dI); A (dI,dS); B,C
        (B,S,dS).  Padding is state-neutral: dt=0 on the tail gives
        decay exp(0)=1 and zero input, so h_end is exact; padded
        outputs are sliced away."""
        if not self.kernel_path:
            return _ref.mamba_scan_ref(dt, A, Bmat, C, x, h0)
        from repro.kernels.mamba_scan import mamba_scan as _pallas_scan
        S, dI = x.shape[1], x.shape[2]
        c = min(chunk, max(8, S))
        dtp = _pad_to(dt, 1, c)
        xp = _pad_to(x, 1, c)
        Bp = _pad_to(Bmat, 1, c)
        Cp = _pad_to(C, 1, c)
        t = tile
        while dI % t:
            t //= 2
        y, h_end = _pallas_scan(dtp, A, Bp, Cp, xp, h0, chunk=c,
                                tile=max(1, t), interpret=self.interpret)
        return y[:, :S], h_end

    def wkv6(self, r, k, v, logw, u, s0=None, *,
             chunk: int = 64) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """RWKV-6 wkv.  r,k,v,logw (B,H,T,d), u (H,d), s0 (B,H,d,d)|None.
        Padding is state-neutral: logw=0 (decay 1) and k=0 (no update)
        on the padded tail leave S_end exact."""
        if not self.kernel_path:
            return _ref.wkv6_ref(r, k, v, logw, u, s0)
        T = r.shape[2]
        c = min(chunk, max(8, T))
        rp = _pad_to(r, 2, c)
        kp = _pad_to(k, 2, c)
        vp = _pad_to(v, 2, c)
        lwp = _pad_to(logw, 2, c)
        out, s_end = _wkv6(rp, kp, vp, lwp, u, s0, chunk=c,
                           interpret=self.interpret)
        return out[:, :, :T], s_end


@functools.lru_cache(maxsize=None)
def _resolve(impl: str, mesh: Optional[Mesh]) -> KernelDispatch:
    if impl not in IMPLS:
        raise ValueError(f"unknown kernel impl {impl!r}: expected one "
                         f"of {IMPLS} (or an already-resolved "
                         "KernelDispatch)")
    canon = impl
    if impl == "pallas" and jax.local_devices()[0].platform not in (
            "tpu", "gpu"):
        canon = "interpret"     # no native Pallas lowering here
    return KernelDispatch(impl=canon, mesh=mesh, requested=impl)


def resolve(impl: Union[str, KernelDispatch],
            mesh: Optional[Mesh] = None) -> KernelDispatch:
    """impl alias (or already-resolved dispatch) -> ``KernelDispatch``.

    Cached per (alias, mesh) and resolved against the local platform
    once.  Idempotent: a ``KernelDispatch`` passes straight through
    (gaining ``mesh`` only if it had none), so config fields may hold
    either form and every consumer just calls ``resolve`` again.
    Unknown aliases raise ``ValueError`` here — at config time, not at
    trace time.
    """
    if isinstance(impl, KernelDispatch):
        if mesh is None or impl.mesh is not None:
            return impl
        return dataclasses.replace(impl, mesh=mesh)
    return _resolve(str(impl), mesh)


# ---------------------------------------------------------------------------
# string-alias compatibility layer: the original jitted entry points,
# now thin delegates to resolve(impl) (single device — no mesh)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "impl"))
def clover_attention(q, k, v, *, causal: bool = True,
                     scale: Optional[float] = None,
                     block_q: int = 128, block_k: int = 128,
                     impl: str = "ref") -> jnp.ndarray:
    """Asymmetric-head-width GQA attention.  impl: ref | pallas | interpret.

    q (B,S,H,dq), k (B,T,KV,dq), v (B,T,KV,dv) -> (B,S,H,dv).
    """
    return resolve(impl).clover_attention(q, k, v, causal=causal,
                                          scale=scale, block_q=block_q,
                                          block_k=block_k)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_t", "rank_block", "impl"))
def decode_attention(q, k, v, lengths, *, scale: Optional[float] = None,
                     block_t: int = 256, qk_ranks=None, vo_ranks=None,
                     rank_block: int = 128,
                     impl: str = "ref") -> jnp.ndarray:
    """Flash-decoding vs a (possibly CLOVER-rank) KV cache.

    q (B,H,dq), k (B,T,KV,dq), v (B,T,KV,dv), lengths (B,) -> (B,H,dv).
    qk_ranks / vo_ranks: optional (KV,) int32 per-head kept ranks
    (non-uniform ``RankBudget`` plans, DESIGN.md §14).
    """
    return resolve(impl).decode_attention(q, k, v, lengths, scale=scale,
                                          block_t=block_t,
                                          qk_ranks=qk_ranks,
                                          vo_ranks=vo_ranks,
                                          rank_block=rank_block)


@functools.partial(jax.jit, static_argnames=("scale", "rank_block", "impl"))
def paged_decode_attention(q, k_pool, v_pool, page_table, lengths, *,
                           scale: Optional[float] = None,
                           qk_ranks=None, vo_ranks=None,
                           rank_block: int = 128,
                           impl: str = "ref") -> jnp.ndarray:
    """Flash-decoding vs a PAGED (possibly CLOVER-rank) KV cache.

    q (B,H,dq), k_pool (N,page_tokens,KV,dq), v_pool (N,page_tokens,KV,dv),
    page_table (B,n_p) int32, lengths (B,) -> (B,H,dv).
    qk_ranks / vo_ranks: optional (KV,) int32 per-head kept ranks
    (non-uniform ``RankBudget`` plans, DESIGN.md §14).
    """
    return resolve(impl).paged_decode_attention(q, k_pool, v_pool,
                                                page_table, lengths,
                                                scale=scale,
                                                qk_ranks=qk_ranks,
                                                vo_ranks=vo_ranks,
                                                rank_block=rank_block)


@functools.partial(jax.jit, static_argnames=("impl",))
def page_copy(pool, src, dst, *, impl: str = "ref") -> jnp.ndarray:
    """Batched KV-page clone (copy-on-write prefix caching).

    pool (n_blocks, N, page_tokens, KV, r), src/dst (m,) int32 pool-row
    ids -> pool with row ``dst[i]`` a copy of row ``src[i]``.
    """
    return resolve(impl).page_copy(pool, src, dst)


@functools.partial(jax.jit, static_argnames=("impl",))
def page_restore(pool, rows, dst, *, impl: str = "ref") -> jnp.ndarray:
    """Batched host-tier page restore (hierarchical KV spill/restore).

    pool (n_blocks, N, page_tokens, KV, r), rows (n_blocks, W,
    page_tokens, KV, r), dst (W,) int32 pool-row ids -> pool with row
    ``dst[i]`` holding ``rows[:, i]``.
    """
    return resolve(impl).page_restore(pool, rows, dst)


@functools.partial(jax.jit, static_argnames=("chunk", "tile", "impl"))
def mamba_scan(dt, A, Bmat, C, x, h0=None, *, chunk: int = 128,
               tile: int = 512,
               impl: str = "ref") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mamba-1 selective scan.  dt,x (B,S,dI); A (dI,dS); B,C (B,S,dS)."""
    return resolve(impl).mamba_scan(dt, A, Bmat, C, x, h0, chunk=chunk,
                                    tile=tile)


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def wkv6(r, k, v, logw, u, s0=None, *, chunk: int = 64,
         impl: str = "ref") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """RWKV-6 wkv.  r,k,v,logw (B,H,T,d), u (H,d), s0 (B,H,d,d)|None."""
    return resolve(impl).wkv6(r, k, v, logw, u, s0, chunk=chunk)
