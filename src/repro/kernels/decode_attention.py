"""Flash-decoding: one new token's query against a long (CLOVER-rank)
KV cache (DESIGN.md §4).

The decode roofline is HBM-bound on streaming the cache (the paper's
motivation).  Per (batch, kv-head) the kernel streams (block_t x r_qk)
K-slabs and (block_t x r_vo) V-slabs once through VMEM — r_qk + r_vo
bytes per cached position instead of 2*head_dim, so the HBM term shrinks
exactly with the pruning ratio.

All G query heads of a KV group ride in one tile: the (G, dq) query slab
is resident in VMEM across the whole stream, turning the GQA group into
an MXU-friendly (G x block_t) matmul instead of G vector dots.

Grid (B, KV, n_t): n_t sequential with (m, l, acc) scratch.  The grid is
sized by cache CAPACITY (shape-static), but per-batch ``lengths`` arrive
via scalar prefetch and bound the work by each row's ACTUAL length: the
K/V index maps clamp the block index to each row's last in-range block,
so every tail iteration re-references the block already resident in VMEM
— Pallas skips the DMA for a revisited block index — and ``pl.when``
skips its compute.  (Previously only the compute was skipped; the tail
blocks still streamed from HBM, so a short slot in a long-capacity cache
paid full-capacity bandwidth.  They were never free.)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = float(-1e30)


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, block_t: int, n_t: int):
    b = pl.program_id(0)
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    to = it * block_t

    @pl.when(to < length)
    def _body():
        q = q_ref[0]                                           # (G, dq)
        k = k_ref[0, :, 0, :]                                  # (bt, dq)
        v = v_ref[0, :, 0, :]                                  # (bt, dv)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale        # (G, bt)
        tj = to + jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, 1)
        logits = jnp.where(tj < length, logits, NEG_INF)
        m_prev = m_scr[...]                                    # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(logits, 1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, 1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(it == n_t - 1)
    def _fin():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def _decode_ranked_kernel(len_ref, rq_ref, rv_ref, q_ref, k_ref, v_ref,
                          o_ref, m_scr, l_scr, p_scr, acc_scr, *,
                          scale: float, block_t: int, n_t: int,
                          rb: int, n_rq: int, n_rv: int):
    """Per-head rank-clamped flash-decoding body (DESIGN.md §14).

    Grid (B, KV, n_t, n_rq + n_rv): the innermost axis walks this
    (batch, kv-head, time-block)'s RANK blocks — first the kept Q-K
    blocks accumulate the (G, block_t) logits tile in ``p_scr``, then
    at ``ir == n_rq`` the completed tile runs the online-softmax update
    (rescaling every V accumulator row), then the kept V-O blocks each
    accumulate their (G, rb) slice of the context.  The scalar-
    prefetched per-head ranks drive both the ``pl.when`` guards (no
    compute) and the BlockSpec index-map clamps (revisited block index
    -> no DMA), so a pruned head's rank tail is genuinely free — the
    rank analogue of the per-row length clamp.  Rank granularity is
    ``rb``: a partially-kept block is processed whole, exact under the
    ``mask_head_ranks`` zero-pad convention (zeroed dims contribute
    exactly 0 to every partial sum, so the clamped kernel is BITWISE
    the unclamped kernel on padded data).
    """
    b = pl.program_id(0)
    kv = pl.program_id(1)
    it = pl.program_id(2)
    ir = pl.program_id(3)

    @pl.when((it == 0) & (ir == 0))
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(ir == 0)
    def _zero_logits():
        p_scr[...] = jnp.zeros_like(p_scr)

    length = len_ref[b]
    to = it * block_t
    live = to < length

    @pl.when(live & (ir < n_rq) & (ir * rb < rq_ref[kv]))
    def _k_phase():                     # logits += q_blk . k_blk^T
        q = q_ref[0]                                       # (G, rb)
        k = k_ref[0, :, 0, :]                              # (bt, rb)
        p_scr[...] += jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(live & (ir == n_rq))
    def _softmax():                     # logits complete for this tile
        logits = p_scr[...] * scale
        tj = to + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        logits = jnp.where(tj < length, logits, NEG_INF)
        m_prev = m_scr[...]                                # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(logits, 1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, 1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha[None]          # all V rows
        m_scr[...] = m_new
        p_scr[...] = p                  # reuse the tile as probabilities

    @pl.when(live & (ir >= n_rq) & ((ir - n_rq) * rb < rv_ref[kv]))
    def _v_phase():                     # acc[iv] += p . v_blk
        v = v_ref[0, :, 0, :]                              # (bt, rb)
        p = p_scr[...]                                     # (G, bt)
        iv = ir - n_rq
        upd = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_scr[pl.ds(iv, 1)] = acc_scr[pl.ds(iv, 1)] + upd[None]

    @pl.when((it == n_t - 1) & (ir == n_rq + n_rv - 1))
    def _fin():
        denom = jnp.maximum(l_scr[...], 1e-30)             # (G, 1)
        acc = acc_scr[...]                                 # (n_rv, G, rb)
        out = acc.transpose(1, 0, 2).reshape(acc.shape[1], n_rv * rb)
        o_ref[0] = (out / denom).astype(o_ref.dtype)


def flash_decode_ranked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        lengths: jnp.ndarray, qk_ranks: jnp.ndarray,
                        vo_ranks: jnp.ndarray, *,
                        scale: Optional[float] = None,
                        block_t: int = 256,
                        rank_block: int = 128,
                        interpret: bool = False) -> jnp.ndarray:
    """``flash_decode`` with a scalar-prefetched PER-HEAD rank clamp
    (non-uniform ``RankBudget`` plans, DESIGN.md §14).

    q: (B, H, dq);  k: (B, T, KV, dq);  v: (B, T, KV, dv);  lengths:
    (B,) int32;  qk_ranks / vo_ranks: (KV,) int32 kept ranks per kv
    head (values are clamped to the array widths).  dq/dv must be
    multiples of ``rank_block`` (ops.py pads; zero-padding is exact).
    -> (B, H, dv)

    Rank blocks at or past a head's kept rank cost neither DMA (their
    index maps re-reference the last kept block, which Pallas leaves
    resident) nor compute (``pl.when``).  On real TPUs keep
    ``rank_block`` a multiple of the 128 lane width; tests pass small
    blocks in interpret mode to exercise multi-block clamping.
    """
    B, H, dq = q.shape
    T, KV = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    G = H // KV
    rb = rank_block
    assert T % block_t == 0, (T, block_t)
    assert dq % rb == 0 and dv % rb == 0, (dq, dv, rb)
    if scale is None:
        scale = float(1.0 / (dq ** 0.5))
    n_t = T // block_t
    n_rq, n_rv = dq // rb, dv // rb

    kernel = functools.partial(
        _decode_ranked_kernel, scale=scale, block_t=block_t, n_t=n_t,
        rb=rb, n_rq=n_rq, n_rv=n_rv)

    def _nblk(r):
        return jnp.maximum((r + rb - 1) // rb, 1)

    def _q_block(b, kv, it, ir, lens, rq, rv):
        return (b, kv, jnp.minimum(ir, _nblk(rq[kv]) - 1))

    def _k_block(b, kv, it, ir, lens, rq, rv):
        n_valid = jnp.maximum((lens[b] + block_t - 1) // block_t, 1)
        return (b, jnp.minimum(it, n_valid - 1), kv,
                jnp.minimum(ir, _nblk(rq[kv]) - 1))

    def _v_block(b, kv, it, ir, lens, rq, rv):
        n_valid = jnp.maximum((lens[b] + block_t - 1) // block_t, 1)
        return (b, jnp.minimum(it, n_valid - 1), kv,
                jnp.clip(ir - n_rq, 0, _nblk(rv[kv]) - 1))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, KV, n_t, n_rq + n_rv),
        in_specs=[
            pl.BlockSpec((1, G, rb), _q_block),
            pl.BlockSpec((1, block_t, 1, rb), _k_block),
            pl.BlockSpec((1, block_t, 1, rb), _v_block),
        ],
        out_specs=pl.BlockSpec(
            (1, G, dv), lambda b, kv, it, ir, lens, rq, rv: (b, kv, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, block_t), jnp.float32),
            pltpu.VMEM((n_rv, G, rb), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, dv), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32),
      jnp.minimum(qk_ranks, dq).astype(jnp.int32),
      jnp.minimum(vo_ranks, dv).astype(jnp.int32), q, k, v)


def flash_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 lengths: jnp.ndarray, *,
                 scale: Optional[float] = None,
                 block_t: int = 256,
                 interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, dq);  k: (B, T, KV, dq);  v: (B, T, KV, dv);
    lengths: (B,) int32.  T % block_t == 0 (ops.py pads; padded positions
    are masked by lengths).  -> (B, H, dv)

    ``lengths`` is the only validity signal: positions past it may hold
    anything — zero-init tail, a previous tenant's cache, or K/V of
    speculative draft tokens rejected and rolled back by serve.engine —
    and never influence the output (masked in-block, clamped out of the
    stream across blocks).
    """
    B, H, dq = q.shape
    T, KV = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    G = H // KV
    assert T % block_t == 0, (T, block_t)
    if scale is None:
        scale = float(1.0 / (dq ** 0.5))
    n_t = T // block_t

    kernel = functools.partial(
        _decode_kernel, scale=scale, block_t=block_t, n_t=n_t)

    def _kv_block(b, kv, it, lens):
        # Clamp to the row's last in-range block: tail iterations revisit
        # the resident block (no DMA) and `pl.when` skips their compute,
        # so streamed bytes are bounded by lengths[b], not capacity.
        n_valid = jnp.maximum((lens[b] + block_t - 1) // block_t, 1)
        return (b, jnp.minimum(it, n_valid - 1), kv, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, n_t),
        in_specs=[
            pl.BlockSpec((1, G, dq), lambda b, kv, it, lens: (b, kv, 0)),
            pl.BlockSpec((1, block_t, 1, dq), _kv_block),
            pl.BlockSpec((1, block_t, 1, dv), _kv_block),
        ],
        out_specs=pl.BlockSpec((1, G, dv), lambda b, kv, it, lens: (b, kv, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, dv), jnp.float32),
        ],
    )

    # H is laid out as KV groups of G consecutive query heads, so the
    # (1, G, dq) block at index kv is exactly group kv's query slab.
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, dv), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k, v)
