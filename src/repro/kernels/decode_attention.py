"""Flash-decoding: one new token's query against a long (CLOVER-rank)
KV cache (DESIGN.md §4).

The decode roofline is HBM-bound on streaming the cache (the paper's
motivation).  Per (batch, kv-head) the kernel streams (block_t x r_qk)
K-slabs and (block_t x r_vo) V-slabs once through VMEM — r_qk + r_vo
bytes per cached position instead of 2*head_dim, so the HBM term shrinks
exactly with the pruning ratio.

All G query heads of a KV group ride in one tile: the (G, dq) query slab
is resident in VMEM across the whole stream, turning the GQA group into
an MXU-friendly (G x block_t) matmul instead of G vector dots.

Grid (B, KV, n_t): n_t sequential with (m, l, acc) scratch.  The grid is
sized by cache CAPACITY (shape-static), but per-batch ``lengths`` arrive
via scalar prefetch and bound the work by each row's ACTUAL length: the
K/V index maps clamp the block index to each row's last in-range block,
so every tail iteration re-references the block already resident in VMEM
— Pallas skips the DMA for a revisited block index — and ``pl.when``
skips its compute.  (Previously only the compute was skipped; the tail
blocks still streamed from HBM, so a short slot in a long-capacity cache
paid full-capacity bandwidth.  They were never free.)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = float(-1e30)


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, block_t: int, n_t: int):
    b = pl.program_id(0)
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    to = it * block_t

    @pl.when(to < length)
    def _body():
        q = q_ref[0]                                           # (G, dq)
        k = k_ref[0, :, 0, :]                                  # (bt, dq)
        v = v_ref[0, :, 0, :]                                  # (bt, dv)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale        # (G, bt)
        tj = to + jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, 1)
        logits = jnp.where(tj < length, logits, NEG_INF)
        m_prev = m_scr[...]                                    # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(logits, 1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, 1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(it == n_t - 1)
    def _fin():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 lengths: jnp.ndarray, *,
                 scale: Optional[float] = None,
                 block_t: int = 256,
                 interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, dq);  k: (B, T, KV, dq);  v: (B, T, KV, dv);
    lengths: (B,) int32.  T % block_t == 0 (ops.py pads; padded positions
    are masked by lengths).  -> (B, H, dv)

    ``lengths`` is the only validity signal: positions past it may hold
    anything — zero-init tail, a previous tenant's cache, or K/V of
    speculative draft tokens rejected and rolled back by serve.engine —
    and never influence the output (masked in-block, clamped out of the
    stream across blocks).
    """
    B, H, dq = q.shape
    T, KV = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    G = H // KV
    assert T % block_t == 0, (T, block_t)
    if scale is None:
        scale = float(1.0 / (dq ** 0.5))
    n_t = T // block_t

    kernel = functools.partial(
        _decode_kernel, scale=scale, block_t=block_t, n_t=n_t)

    def _kv_block(b, kv, it, lens):
        # Clamp to the row's last in-range block: tail iterations revisit
        # the resident block (no DMA) and `pl.when` skips their compute,
        # so streamed bytes are bounded by lengths[b], not capacity.
        n_valid = jnp.maximum((lens[b] + block_t - 1) // block_t, 1)
        return (b, jnp.minimum(it, n_valid - 1), kv, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, n_t),
        in_specs=[
            pl.BlockSpec((1, G, dq), lambda b, kv, it, lens: (b, kv, 0)),
            pl.BlockSpec((1, block_t, 1, dq), _kv_block),
            pl.BlockSpec((1, block_t, 1, dv), _kv_block),
        ],
        out_specs=pl.BlockSpec((1, G, dv), lambda b, kv, it, lens: (b, kv, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, dv), jnp.float32),
        ],
    )

    # H is laid out as KV groups of G consecutive query heads, so the
    # (1, G, dq) block at index kv is exactly group kv's query slab.
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, dv), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k, v)
