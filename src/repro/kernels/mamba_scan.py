"""Mamba-1 selective scan as a Pallas TPU kernel (DESIGN.md §4's TPU
adaptation for the recurrent mixers; §5 scopes where it applies).

The XLA chunked path materializes the decay/input tensors
``a = exp(dt*A)`` and ``b = dt*x*B`` at (B, chunk, dI, dS) — with
dI = 8192, dS = 16 that is ~85 MB per chunk per batch row streamed to
HBM several times by the associative scan (up+down sweeps), the dominant
memory-roofline term of the jamba train cell (EXPERIMENTS.md §Perf).

Here the (dI_tile, dS) state lives in VMEM scratch across the
sequential chunk axis and a/b exist only tile-at-a-time in VMEM: HBM
traffic collapses to the streams of dt/B/C/x in and y out —
(2*dI + 2*dS + dI)/ (dI*dS)  ≈ 1/5th of one a-materialization, per pass.

Grid (B, dI_tiles, n_chunks); within a chunk a sequential fori_loop
carries h (the recurrence is inherently sequential; the VPU does the
(tile, dS) elementwise update and the dS-contraction per step).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _mamba_kernel(dt_ref, b_ref, c_ref, x_ref, a_ref, h0_ref,
                  y_ref, hend_ref, h_scr, *, chunk: int, n_c: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    dt = dt_ref[0].astype(jnp.float32)        # (c, tile)
    Bm = b_ref[0].astype(jnp.float32)         # (c, dS)
    Cm = c_ref[0].astype(jnp.float32)         # (c, dS)
    xv = x_ref[0].astype(jnp.float32)         # (c, tile)
    A = a_ref[...].astype(jnp.float32)        # (tile, dS)

    def step(t, h):
        dt_t = dt[t][:, None]                 # (tile, 1)
        a = jnp.exp(dt_t * (-A))              # (tile, dS)
        b = (dt_t * xv[t][:, None]) * Bm[t][None, :]
        h = a * h + b
        y_t = jnp.sum(h * Cm[t][None, :], axis=1)   # (tile,)
        # jax 0.4.x interpret-mode discharge chokes on bare int indices;
        # a size-1 Slice is equivalent and portable
        pl.store(y_ref, (pl.dslice(0, 1), pl.dslice(t, 1), slice(None)),
                 y_t[None, None, :].astype(y_ref.dtype))
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h

    @pl.when(ic == n_c - 1)
    def _fin():
        hend_ref[0] = h_scr[...]


def mamba_scan(dt: jnp.ndarray, A: jnp.ndarray, Bmat: jnp.ndarray,
               C: jnp.ndarray, x: jnp.ndarray,
               h0: Optional[jnp.ndarray] = None, *,
               chunk: int = 128, tile: int = 512,
               interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """dt, x: (B, S, dI);  A: (dI, dS);  Bmat, C: (B, S, dS);
    h0: (B, dI, dS) f32 or None.  S % chunk == 0, dI % tile == 0
    (ops.py pads/fits).  Returns (y (B, S, dI) f32, h_end (B, dI, dS))."""
    B, S, dI = x.shape
    dS = A.shape[-1]
    tile = min(tile, dI)
    assert S % chunk == 0 and dI % tile == 0, (S, chunk, dI, tile)
    n_c = S // chunk
    n_t = dI // tile
    if h0 is None:
        h0 = jnp.zeros((B, dI, dS), jnp.float32)

    kernel = functools.partial(_mamba_kernel, chunk=chunk, n_c=n_c)
    seq_tile = pl.BlockSpec((1, chunk, tile),
                            lambda b, it, ic: (b, ic, it))
    seq_state = pl.BlockSpec((1, chunk, dS),
                             lambda b, it, ic: (b, ic, 0))

    y, h_end = pl.pallas_call(
        kernel,
        grid=(B, n_t, n_c),
        in_specs=[
            seq_tile,                                   # dt
            seq_state,                                  # B
            seq_state,                                  # C
            seq_tile,                                   # x
            pl.BlockSpec((tile, dS), lambda b, it, ic: (it, 0)),   # A
            pl.BlockSpec((1, tile, dS), lambda b, it, ic: (b, it, 0)),
        ],
        out_specs=[
            seq_tile,
            pl.BlockSpec((1, tile, dS), lambda b, it, ic: (b, it, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, dI), jnp.float32),
            jax.ShapeDtypeStruct((B, dI, dS), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((tile, dS), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(dt, Bmat, C, x, A, h0)
    return y, h_end
