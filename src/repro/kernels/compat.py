"""Version compatibility for Pallas TPU symbols (shared by every
DESIGN.md §4 kernel module).

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; the
container pins jax 0.4.x which only has the old name.  Kernels import
the symbol from here so both spellings work.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")
