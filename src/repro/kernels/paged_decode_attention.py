"""Paged flash-decoding: one new token's query against a PAGED
CLOVER-rank KV cache (vLLM-style page pool + per-slot page tables).

The dense `flash_decode` streams a per-slot cache of shape
``(B, capacity, KV, r)`` — every slot reserves (and, before the
index-map clamp, streamed) full capacity regardless of actual length.
Here the cache is one global pool ``(n_pages + 1, page_tokens, KV, r)``
shared by all slots; each slot owns an ordered list of page ids (its
page table row) and positions map through the indirection
``pool[table[b, p // page_tokens], p % page_tokens]``.  Rank pruning
composes with paging: smaller r -> more tokens per HBM byte -> more
resident sequences per pool (DESIGN.md §6).

Kernel schedule — grid ``(B, KV, n_p)`` with the page axis sequential:

  * ``lengths`` (B,) and ``page_table`` (B, n_p) arrive via SCALAR
    PREFETCH, so the K/V BlockSpec index maps dereference the page
    table BEFORE the body runs: iteration ``ip`` of row ``b`` DMAs pool
    row ``page_table[b, ip]`` — the gather through the indirection is
    done by the pipeline, not by a device-wide gather op.
  * The grid is statically sized by the page-table width, but the
    index maps clamp ``ip`` to each ROW's last in-use page: every
    iteration past a row's page count still issues, yet re-references
    the block already resident in VMEM (Pallas skips the DMA for a
    revisited block index) and ``pl.when`` skips its compute — so per
    row, streamed bytes and MXU work are bounded by the actual page
    count, not the table width.
  * Entries past a slot's in-use pages may be a sentinel id (the pool's
    spare garbage row); the clamp means they are never dereferenced.

Per (batch, kv-head) the whole GQA group's (G, dq) query slab stays
resident in VMEM across the page stream, same as the dense kernel.

Page size: ``page_tokens`` is the sublane dim of the streamed slabs, so
keep it a multiple of the dtype tile (8 for f32, 16 for bf16) on real
TPUs; tests run interpret mode where any size is legal.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = float(-1e30)


def _paged_decode_kernel(len_ref, tab_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *,
                         scale: float, page_tokens: int, n_p: int):
    b = pl.program_id(0)
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    to = ip * page_tokens

    @pl.when(to < length)
    def _body():
        q = q_ref[0]                                           # (G, dq)
        k = k_ref[0, :, 0, :]                                  # (pt, dq)
        v = v_ref[0, :, 0, :]                                  # (pt, dv)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale        # (G, pt)
        tj = to + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        logits = jnp.where(tj < length, logits, NEG_INF)
        m_prev = m_scr[...]                                    # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(logits, 1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, 1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ip == n_p - 1)
    def _fin():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def paged_flash_decode(q: jnp.ndarray, k_pool: jnp.ndarray,
                       v_pool: jnp.ndarray, page_table: jnp.ndarray,
                       lengths: jnp.ndarray, *,
                       scale: Optional[float] = None,
                       interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, dq);  k_pool: (N, page_tokens, KV, dq);
    v_pool: (N, page_tokens, KV, dv);  page_table: (B, n_p) int32 page
    ids into the pool (entries past ceil(lengths[b]/page_tokens) are
    never dereferenced and may be any in-range id, e.g. a garbage-sink
    sentinel);  lengths: (B,) int32.  -> (B, H, dv)

    POST-ROLLBACK contract (speculative decoding, serve.engine): after
    a verify round rejects draft tokens, ``lengths`` decrements while
    the rejected K/V stays written — both inside the row's last in-use
    page and in still-allocated pages past it.  The per-row clamp and
    the ``tj < length`` mask key on ``lengths`` ALONE, so rolled-back
    positions cost no DMA past the clamp and never enter the softmax;
    a row's allocated page count may exceed ``ceil(lengths[b] /
    page_tokens)`` freely.
    """
    B, H, dq = q.shape
    pt, KV = k_pool.shape[1], k_pool.shape[2]
    dv = v_pool.shape[-1]
    G = H // KV
    n_p = page_table.shape[1]
    if scale is None:
        scale = float(1.0 / (dq ** 0.5))

    kernel = functools.partial(
        _paged_decode_kernel, scale=scale, page_tokens=pt, n_p=n_p)

    def _page_block(b, kv, ip, lens, tab):
        # Clamp to the row's last in-use page: tail iterations revisit
        # the resident block (no DMA), pl.when skips their compute.
        n_used = jnp.maximum((lens[b] + pt - 1) // pt, 1)
        return (tab[b, jnp.minimum(ip, n_used - 1)], 0, kv, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, n_p),
        in_specs=[
            pl.BlockSpec((1, G, dq), lambda b, kv, ip, lens, tab: (b, kv, 0)),
            pl.BlockSpec((1, pt, 1, dq), _page_block),
            pl.BlockSpec((1, pt, 1, dv), _page_block),
        ],
        out_specs=pl.BlockSpec((1, G, dv),
                               lambda b, kv, ip, lens, tab: (b, kv, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, dv), jnp.float32),
        ],
    )

    # H is laid out as KV groups of G consecutive query heads, so the
    # (1, G, dq) block at index kv is exactly group kv's query slab.
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, dv), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), page_table.astype(jnp.int32), q,
      k_pool, v_pool)
