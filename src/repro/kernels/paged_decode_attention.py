"""Paged flash-decoding: one new token's query against a PAGED
CLOVER-rank KV cache (vLLM-style page pool + per-slot page tables).

The dense `flash_decode` streams a per-slot cache of shape
``(B, capacity, KV, r)`` — every slot reserves (and, before the
index-map clamp, streamed) full capacity regardless of actual length.
Here the cache is one global pool ``(n_pages + 1, page_tokens, KV, r)``
shared by all slots; each slot owns an ordered list of page ids (its
page table row) and positions map through the indirection
``pool[table[b, p // page_tokens], p % page_tokens]``.  Rank pruning
composes with paging: smaller r -> more tokens per HBM byte -> more
resident sequences per pool (DESIGN.md §6).

Kernel schedule — grid ``(B, KV, n_p)`` with the page axis sequential:

  * ``lengths`` (B,) and ``page_table`` (B, n_p) arrive via SCALAR
    PREFETCH, so the K/V BlockSpec index maps dereference the page
    table BEFORE the body runs: iteration ``ip`` of row ``b`` DMAs pool
    row ``page_table[b, ip]`` — the gather through the indirection is
    done by the pipeline, not by a device-wide gather op.
  * The grid is statically sized by the page-table width, but the
    index maps clamp ``ip`` to each ROW's last in-use page: every
    iteration past a row's page count still issues, yet re-references
    the block already resident in VMEM (Pallas skips the DMA for a
    revisited block index) and ``pl.when`` skips its compute — so per
    row, streamed bytes and MXU work are bounded by the actual page
    count, not the table width.
  * Entries past a slot's in-use pages may be a sentinel id (the pool's
    spare garbage row); the clamp means they are never dereferenced.

Per (batch, kv-head) the whole GQA group's (G, dq) query slab stays
resident in VMEM across the page stream, same as the dense kernel.

Page size: ``page_tokens`` is the sublane dim of the streamed slabs, so
keep it a multiple of the dtype tile (8 for f32, 16 for bf16) on real
TPUs; tests run interpret mode where any size is legal.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = float(-1e30)


def _paged_decode_kernel(len_ref, tab_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *,
                         scale: float, page_tokens: int, n_p: int):
    b = pl.program_id(0)
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    to = ip * page_tokens

    @pl.when(to < length)
    def _body():
        q = q_ref[0]                                           # (G, dq)
        k = k_ref[0, :, 0, :]                                  # (pt, dq)
        v = v_ref[0, :, 0, :]                                  # (pt, dv)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale        # (G, pt)
        tj = to + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        logits = jnp.where(tj < length, logits, NEG_INF)
        m_prev = m_scr[...]                                    # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(logits, 1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, 1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ip == n_p - 1)
    def _fin():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def _paged_decode_ranked_kernel(len_ref, tab_ref, rq_ref, rv_ref, q_ref,
                                k_ref, v_ref, o_ref, m_scr, l_scr, p_scr,
                                acc_scr, *, scale: float, page_tokens: int,
                                n_p: int, rb: int, n_rq: int, n_rv: int):
    """Paged flash-decoding with a per-head rank clamp (DESIGN.md §14).

    Same phase schedule as the dense ``_decode_ranked_kernel``: the
    innermost grid axis walks rank blocks — kept Q-K blocks accumulate
    the logits tile in ``p_scr``, the ``ir == n_rq`` step runs the
    online-softmax update, kept V-O blocks accumulate their context
    slice — with the K/V index maps composing BOTH clamps: the page
    axis through ``tab[b, min(ip, n_used-1)]`` and the rank axis
    through the scalar-prefetched per-head kept ranks.
    """
    b = pl.program_id(0)
    kv = pl.program_id(1)
    ip = pl.program_id(2)
    ir = pl.program_id(3)

    @pl.when((ip == 0) & (ir == 0))
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(ir == 0)
    def _zero_logits():
        p_scr[...] = jnp.zeros_like(p_scr)

    length = len_ref[b]
    to = ip * page_tokens
    live = to < length

    @pl.when(live & (ir < n_rq) & (ir * rb < rq_ref[kv]))
    def _k_phase():
        q = q_ref[0]                                       # (G, rb)
        k = k_ref[0, :, 0, :]                              # (pt, rb)
        p_scr[...] += jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(live & (ir == n_rq))
    def _softmax():
        logits = p_scr[...] * scale
        tj = to + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        logits = jnp.where(tj < length, logits, NEG_INF)
        m_prev = m_scr[...]                                # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(logits, 1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, 1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha[None]
        m_scr[...] = m_new
        p_scr[...] = p

    @pl.when(live & (ir >= n_rq) & ((ir - n_rq) * rb < rv_ref[kv]))
    def _v_phase():
        v = v_ref[0, :, 0, :]                              # (pt, rb)
        p = p_scr[...]                                     # (G, pt)
        iv = ir - n_rq
        upd = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_scr[pl.ds(iv, 1)] = acc_scr[pl.ds(iv, 1)] + upd[None]

    @pl.when((ip == n_p - 1) & (ir == n_rq + n_rv - 1))
    def _fin():
        denom = jnp.maximum(l_scr[...], 1e-30)             # (G, 1)
        acc = acc_scr[...]                                 # (n_rv, G, rb)
        out = acc.transpose(1, 0, 2).reshape(acc.shape[1], n_rv * rb)
        o_ref[0] = (out / denom).astype(o_ref.dtype)


def paged_flash_decode_ranked(q: jnp.ndarray, k_pool: jnp.ndarray,
                              v_pool: jnp.ndarray, page_table: jnp.ndarray,
                              lengths: jnp.ndarray, qk_ranks: jnp.ndarray,
                              vo_ranks: jnp.ndarray, *,
                              scale: Optional[float] = None,
                              rank_block: int = 128,
                              interpret: bool = False) -> jnp.ndarray:
    """``paged_flash_decode`` with a scalar-prefetched PER-HEAD rank
    clamp for non-uniform ``RankBudget`` plans (DESIGN.md §14).

    qk_ranks / vo_ranks: (KV,) int32 kept ranks per kv head (values
    clamped to the pool widths).  dq/dv must be multiples of
    ``rank_block`` (ops.py pads; the ``mask_head_ranks`` zero-pad
    convention makes padding exact).  Rank blocks at or past a head's
    kept rank revisit the resident block (no DMA) and ``pl.when``
    skips their compute, so a pruned head's rank tail is free — the
    rank analogue of the post-rollback length clamp below.
    """
    B, H, dq = q.shape
    pt, KV = k_pool.shape[1], k_pool.shape[2]
    dv = v_pool.shape[-1]
    G = H // KV
    rb = rank_block
    n_p = page_table.shape[1]
    assert dq % rb == 0 and dv % rb == 0, (dq, dv, rb)
    if scale is None:
        scale = float(1.0 / (dq ** 0.5))
    n_rq, n_rv = dq // rb, dv // rb

    kernel = functools.partial(
        _paged_decode_ranked_kernel, scale=scale, page_tokens=pt,
        n_p=n_p, rb=rb, n_rq=n_rq, n_rv=n_rv)

    def _nblk(r):
        return jnp.maximum((r + rb - 1) // rb, 1)

    def _q_block(b, kv, ip, ir, lens, tab, rq, rv):
        return (b, kv, jnp.minimum(ir, _nblk(rq[kv]) - 1))

    def _k_block(b, kv, ip, ir, lens, tab, rq, rv):
        n_used = jnp.maximum((lens[b] + pt - 1) // pt, 1)
        return (tab[b, jnp.minimum(ip, n_used - 1)], 0, kv,
                jnp.minimum(ir, _nblk(rq[kv]) - 1))

    def _v_block(b, kv, ip, ir, lens, tab, rq, rv):
        n_used = jnp.maximum((lens[b] + pt - 1) // pt, 1)
        return (tab[b, jnp.minimum(ip, n_used - 1)], 0, kv,
                jnp.clip(ir - n_rq, 0, _nblk(rv[kv]) - 1))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, KV, n_p, n_rq + n_rv),
        in_specs=[
            pl.BlockSpec((1, G, rb), _q_block),
            pl.BlockSpec((1, pt, 1, rb), _k_block),
            pl.BlockSpec((1, pt, 1, rb), _v_block),
        ],
        out_specs=pl.BlockSpec(
            (1, G, dv), lambda b, kv, ip, ir, lens, tab, rq, rv: (b, kv, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, pt), jnp.float32),
            pltpu.VMEM((n_rv, G, rb), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, dv), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), page_table.astype(jnp.int32),
      jnp.minimum(qk_ranks, dq).astype(jnp.int32),
      jnp.minimum(vo_ranks, dv).astype(jnp.int32), q, k_pool, v_pool)


def paged_flash_decode(q: jnp.ndarray, k_pool: jnp.ndarray,
                       v_pool: jnp.ndarray, page_table: jnp.ndarray,
                       lengths: jnp.ndarray, *,
                       scale: Optional[float] = None,
                       interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, dq);  k_pool: (N, page_tokens, KV, dq);
    v_pool: (N, page_tokens, KV, dv);  page_table: (B, n_p) int32 page
    ids into the pool (entries past ceil(lengths[b]/page_tokens) are
    never dereferenced and may be any in-range id, e.g. a garbage-sink
    sentinel);  lengths: (B,) int32.  -> (B, H, dv)

    POST-ROLLBACK contract (speculative decoding, serve.engine): after
    a verify round rejects draft tokens, ``lengths`` decrements while
    the rejected K/V stays written — both inside the row's last in-use
    page and in still-allocated pages past it.  The per-row clamp and
    the ``tj < length`` mask key on ``lengths`` ALONE, so rolled-back
    positions cost no DMA past the clamp and never enter the softmax;
    a row's allocated page count may exceed ``ceil(lengths[b] /
    page_tokens)`` freely.
    """
    B, H, dq = q.shape
    pt, KV = k_pool.shape[1], k_pool.shape[2]
    dv = v_pool.shape[-1]
    G = H // KV
    n_p = page_table.shape[1]
    if scale is None:
        scale = float(1.0 / (dq ** 0.5))

    kernel = functools.partial(
        _paged_decode_kernel, scale=scale, page_tokens=pt, n_p=n_p)

    def _page_block(b, kv, ip, lens, tab):
        # Clamp to the row's last in-use page: tail iterations revisit
        # the resident block (no DMA), pl.when skips their compute.
        n_used = jnp.maximum((lens[b] + pt - 1) // pt, 1)
        return (tab[b, jnp.minimum(ip, n_used - 1)], 0, kv, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, n_p),
        in_specs=[
            pl.BlockSpec((1, G, dq), lambda b, kv, ip, lens, tab: (b, kv, 0)),
            pl.BlockSpec((1, pt, 1, dq), _page_block),
            pl.BlockSpec((1, pt, 1, dv), _page_block),
        ],
        out_specs=pl.BlockSpec((1, G, dv),
                               lambda b, kv, ip, lens, tab: (b, kv, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, dv), jnp.float32),
        ],
    )

    # H is laid out as KV groups of G consecutive query heads, so the
    # (1, G, dq) block at index kv is exactly group kv's query slab.
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, dv), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), page_table.astype(jnp.int32), q,
      k_pool, v_pool)
