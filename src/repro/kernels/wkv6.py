"""Chunked RWKV-6 (Finch) wkv recurrence as a Pallas TPU kernel
(DESIGN.md §4's TPU adaptation for the recurrent mixers; §5 scopes
where it applies).

The recurrence S_t = diag(exp(logw_t)) S_{t-1} + k_t v_t^T is sequential
in t, but within a chunk of C tokens the outputs decompose into

  intra-chunk:  pairwise log-space decays  exp(cum_t - cum_s), s < t
  cross-chunk:  (r_t * exp(cum_t)) @ S_carry

so the kernel runs grid (B, H, n_chunks) with the (d x d) state carried
in VMEM scratch across the sequential chunk axis — the TPU analogue of
the CUDA linear-attention scan: the state never round-trips to HBM, and
the intra-chunk part is three MXU matmuls instead of C rank-1 updates.

All decays stay in log space; cum_t - cum_s <= 0 for s < t so exp() never
overflows (bf16-safe).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
                 o_ref, send_ref, s_scr, *, chunk: int, n_c: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    rb = r_ref[0, 0].astype(jnp.float32)                       # (C, d)
    kb = k_ref[0, 0].astype(jnp.float32)
    vb = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)                           # (d,)
    S = s_scr[...]                                             # (d, d)

    cum = jnp.cumsum(lw, axis=0)                               # (C, d) <= 0
    # intra-chunk pairwise scores: strictly-lower-triangular t > s
    ldiff = cum[:, None, :] - cum[None, :, :]                  # (C, C, d)
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(tri[..., None], jnp.exp(ldiff), 0.0)
    scores = jnp.einsum("td,tsd,sd->ts", rb, decay, kb,
                        preferred_element_type=jnp.float32)
    bonus = jnp.sum(rb * (u[None, :] * kb), axis=1)            # (C,)
    out = jax.lax.dot_general(scores, vb, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    out = out + bonus[:, None] * vb
    # cross-chunk: r_t decayed to the chunk start, applied to the carry
    ri = rb * jnp.exp(cum)
    out = out + jax.lax.dot_general(ri, S, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    o_ref[0, 0] = out.astype(o_ref.dtype)

    # state update: S' = diag(exp(cum_end)) S + sum_s exp(cum_end-cum_s) k_s v_s^T
    cend = cum[-1:, :]                                         # (1, d)
    kd = kb * jnp.exp(cend - cum)                              # (C, d)
    s_scr[...] = jnp.exp(cend[0])[:, None] * S + jax.lax.dot_general(
        kd, vb, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ic == n_c - 1)
    def _fin():
        send_ref[0, 0] = s_scr[...]


def wkv6(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
         logw: jnp.ndarray, u: jnp.ndarray,
         s0: Optional[jnp.ndarray] = None, *,
         chunk: int = 64,
         interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """r,k,v,logw: (B, H, T, d);  u: (H, d);  s0: (B, H, d, d) or None.
    T % chunk == 0 (ops.py pads with logw=0/k=0 which is state-neutral).
    Returns (out (B,H,T,d) in r.dtype, S_end (B,H,d,d) f32)."""
    B, H, T, d = r.shape
    assert T % chunk == 0, (T, chunk)
    n_c = T // chunk
    if s0 is None:
        s0 = jnp.zeros((B, H, d, d), jnp.float32)

    kernel = functools.partial(_wkv6_kernel, chunk=chunk, n_c=n_c)
    seq_spec = pl.BlockSpec((1, 1, chunk, d), lambda b, h, ic: (b, h, ic, 0))

    out, s_end = pl.pallas_call(
        kernel,
        grid=(B, H, n_c),
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, d), lambda b, h, ic: (h, 0)),
            pl.BlockSpec((1, 1, d, d), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, d, d), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, d), r.dtype),
            jax.ShapeDtypeStruct((B, H, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, logw, u, s0)
    return out, s_end
