"""Pallas TPU kernels for the shape class CLOVER pruning creates
(DESIGN.md §4), plus the serving-side page movers (§6, §9, §12).

One module per kernel, each with a pure-jnp oracle in ``ref.py`` and a
public dispatch surface in ``ops.py`` (``resolve(impl, mesh=None)`` —
§10's per-shard execution).  Kernels exist ONLY for compute hot-spots
the paper's inference story actually optimizes: asymmetric flash
attention and (paged) flash decoding over rank-pruned caches, the
recurrent mixers' scans, and the page-copy/page-restore row movers
behind prefix caching and the host spill tier.
"""
