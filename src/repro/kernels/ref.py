"""Pure-jnp oracles for every Pallas kernel (the correctness contract
behind DESIGN.md §4's kernels and the §9/§12 page movers).

These are the semantics the kernels must reproduce bit-approximately;
tests sweep shapes/dtypes and assert_allclose kernel-vs-ref.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """GQA attention with asymmetric head widths (dq != dv allowed —
    the shape class CLOVER pruning creates).

    q: (B, S, H, dq);  k: (B, T, KV, dq);  v: (B, T, KV, dv)
    -> (B, S, H, dv).  H % KV == 0.
    """
    B, S, H, dq = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    if scale is None:
        scale = 1.0 / jnp.sqrt(dq).astype(jnp.float32)
    qg = q.reshape(B, S, KV, G, dq)
    logits = jnp.einsum("bskgq,btkq->bkgst", qg, k).astype(jnp.float32) * scale
    if causal:
        qi = jnp.arange(S)[:, None] + (T - S)   # align ends (prefill windows)
        mask = qi >= jnp.arange(T)[None, :]
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkv->bskgv", p, v)
    return out.reshape(B, S, H, v.shape[-1])


def _rank_mask(x: jnp.ndarray, ranks: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Zero dims >= ranks[kv] of a (..., KV, d) array — the exact-
    truncation oracle semantics of a non-uniform per-head rank plan
    (DESIGN.md §14).  The rank-clamped kernels skip whole
    ``rank_block``-wide blocks instead; both agree whenever every rank
    is a block multiple OR the data already obeys the
    ``mask_head_ranks`` zero-pad convention (zeroed dims contribute
    exactly 0 either way)."""
    if ranks is None:
        return x
    d = x.shape[-1]
    keep = jnp.arange(d)[None, :] < jnp.minimum(ranks, d)[:, None]  # (KV, d)
    return jnp.where(keep, x, jnp.zeros((), x.dtype))


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         lengths: jnp.ndarray, *,
                         scale: Optional[float] = None,
                         qk_ranks: Optional[jnp.ndarray] = None,
                         vo_ranks: Optional[jnp.ndarray] = None,
                         ) -> jnp.ndarray:
    """Single-token flash-decoding oracle.

    q: (B, H, dq);  k: (B, T, KV, dq);  v: (B, T, KV, dv);
    lengths: (B,) int32 — positions >= length are masked.
    qk_ranks / vo_ranks: optional (KV,) int32 per-head kept ranks
    (non-uniform ``RankBudget`` plans, DESIGN.md §14): K dims >=
    qk_ranks[kv] and V dims >= vo_ranks[kv] are zeroed, which is
    exactly rank truncation (a zeroed K dim kills its logit term; a
    zeroed V dim zeros that output dim).
    -> (B, H, dv)
    """
    B, H, dq = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    if scale is None:
        scale = 1.0 / jnp.sqrt(dq).astype(jnp.float32)
    k = _rank_mask(k, qk_ranks)
    v = _rank_mask(v, vo_ranks)
    qg = q.reshape(B, KV, G, dq)
    logits = jnp.einsum("bkgq,btkq->bkgt", qg, k).astype(jnp.float32) * scale
    mask = jnp.arange(T)[None, :] < lengths[:, None]          # (B, T)
    logits = jnp.where(mask[:, None, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgt,btkv->bkgv", p, v)
    return out.reshape(B, H, v.shape[-1])


def verify_decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray,
                                v: jnp.ndarray, lengths: jnp.ndarray, *,
                                scale: Optional[float] = None) -> jnp.ndarray:
    """Speculative-VERIFY oracle: a W-token window of queries against a
    per-slot cache (the multi-token generalization of
    ``decode_attention_ref`` — W == 1 reduces to it exactly).

    q: (B, W, H, dq);  k: (B, T, KV, dq);  v: (B, T, KV, dv);
    lengths: (B,) int32 — the TOTAL valid cache length per row, window
    included: query j of row b sits at position ``lengths[b] - W + j``
    and attends to cache positions <= its own.  Cache contents past
    ``lengths[b]`` (e.g. K/V of draft tokens rejected by an earlier
    verify round and rolled back — see serve.engine) never influence
    the output.  -> (B, W, H, dv)
    """
    B, W, H, dq = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    if scale is None:
        scale = 1.0 / jnp.sqrt(dq).astype(jnp.float32)
    qg = q.reshape(B, W, KV, G, dq)
    logits = jnp.einsum("bskgq,btkq->bkgst", qg, k).astype(jnp.float32) * scale
    qpos = (lengths[:, None] - W) + jnp.arange(W)[None, :]        # (B, W)
    mask = jnp.arange(T)[None, None, :] <= qpos[:, :, None]       # (B, W, T)
    logits = jnp.where(mask[:, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkv->bskgv", p, v)
    return out.reshape(B, W, H, v.shape[-1])


def paged_decode_attention_ref(q: jnp.ndarray, k_pool: jnp.ndarray,
                               v_pool: jnp.ndarray,
                               page_table: jnp.ndarray,
                               lengths: jnp.ndarray, *,
                               scale: Optional[float] = None,
                               qk_ranks: Optional[jnp.ndarray] = None,
                               vo_ranks: Optional[jnp.ndarray] = None,
                               ) -> jnp.ndarray:
    """Paged flash-decoding oracle: gather each slot's pages into a
    dense per-slot cache through the page-table indirection, then defer
    to the dense oracle (lengths mask everything past each slot's valid
    tokens, so sentinel/garbage pages never influence the output).

    q: (B, H, dq);  k_pool: (N, page_tokens, KV, dq);
    v_pool: (N, page_tokens, KV, dv);  page_table: (B, n_p) int32;
    lengths: (B,) int32;  qk_ranks / vo_ranks: optional (KV,) int32
    per-head kept ranks (see ``decode_attention_ref``).  -> (B, H, dv)
    """
    B, n_p = page_table.shape
    pt = k_pool.shape[1]
    k = k_pool[page_table].reshape(B, n_p * pt, *k_pool.shape[2:])
    v = v_pool[page_table].reshape(B, n_p * pt, *v_pool.shape[2:])
    return decode_attention_ref(q, k, v, lengths, scale=scale,
                                qk_ranks=qk_ranks, vo_ranks=vo_ranks)


def page_copy_ref(pool: jnp.ndarray, src: jnp.ndarray,
                  dst: jnp.ndarray) -> jnp.ndarray:
    """Batched KV-page clone oracle (copy-on-write prefix caching —
    serve.engine).  Rows ``dst[i]`` become copies of rows ``src[i]``;
    every other row is untouched.

    pool: (n_blocks, N, page_tokens, KV, r);  src, dst: (m,) int32.
    Pairs are disjoint except sentinel self-copies (dst may repeat the
    sentinel row as padding), and all reads see the INPUT pool — a page
    can be a src of one pair and the dst of a LATER pair only after the
    src content was already cloned (see ``Engine._copy_pages``), so
    gather-then-scatter semantics agree with the kernel's in-order
    row-to-row moves.  -> pool shape.
    """
    return pool.at[:, dst].set(pool[:, src])


def page_restore_ref(pool: jnp.ndarray, rows: jnp.ndarray,
                     dst: jnp.ndarray) -> jnp.ndarray:
    """Host-tier page restore oracle (hierarchical KV, serve.memory
    ``HostTier``): scatter externally-held page CONTENT into pool rows.
    Where ``page_copy_ref`` moves rows within the pool (COW), this
    writes rows whose bytes came from outside it — host RAM spill
    slabs copied back before a prefix-cache restore resumes prefill.

    pool: (n_blocks, N, page_tokens, KV, r);
    rows: (n_blocks, W, page_tokens, KV, r) — slab ``rows[:, i]``
    lands in pool row ``dst[i]``;  dst: (W,) int32.  Real dst entries
    are distinct freshly-allocated pages; padding repeats the sentinel
    row with all-zero slabs (duplicate scatter targets therefore all
    carry identical content, so gather-vs-in-order semantics agree).
    -> pool shape.
    """
    return pool.at[:, dst].set(rows)


def mamba_scan_ref(dt: jnp.ndarray, A: jnp.ndarray, Bmat: jnp.ndarray,
                   C: jnp.ndarray, x: jnp.ndarray,
                   h0: Optional[jnp.ndarray] = None,
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential Mamba-1 selective-scan oracle.

    dt, x: (B, S, dI);  A: (dI, dS);  Bmat, C: (B, S, dS).
    h_t = exp(dt_t * -A) * h_{t-1} + (dt_t * x_t) B_t;   y_t = h_t . C_t.
    Returns (y (B,S,dI) f32, h_end (B,dI,dS) f32)."""
    B, S, dI = x.shape
    dS = A.shape[-1]
    dtf = dt.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    Bf = Bmat.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    h = (jnp.zeros((B, dI, dS), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))

    def step(h, xs):
        dt_t, x_t, b_t, c_t = xs                    # (B,dI),(B,dI),(B,dS)x2
        a = jnp.exp(dt_t[..., None] * (-Af)[None])  # (B,dI,dS)
        b = (dt_t * x_t)[..., None] * b_t[:, None, :]
        h = a * h + b
        y = jnp.einsum("bns,bs->bn", h, c_t)
        return h, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (dtf, xf, Bf, Cf))
    h_end, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1), h_end


def wkv6_ref(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
             logw: jnp.ndarray, u: jnp.ndarray,
             s0: Optional[jnp.ndarray] = None,
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential RWKV-6 wkv oracle.

    r,k,v,logw: (B, H, T, d);  u: (H, d);  s0: (B, H, d, d) or None.
    Per step: Sd = diag(exp(logw_t)) S;  o_t = r_t Sd + (r_t . (u*k_t)) v_t;
              S' = Sd + k_t v_t^T.
    Returns (out (B,H,T,d) f32, S_end (B,H,d,d) f32).
    """
    B, H, T, d = r.shape
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    wf = logw.astype(jnp.float32)
    uf = u.astype(jnp.float32)
    S = (jnp.zeros((B, H, d, d), jnp.float32) if s0 is None
         else s0.astype(jnp.float32))

    def step(S, xs):
        rt, kt, vt, wt = xs                                   # (B, H, d)
        Sd = jnp.exp(wt)[..., None] * S                       # decay k-side
        o = jnp.einsum("bhd,bhde->bhe", rt, Sd)
        bonus = jnp.einsum("bhd,bhd->bh", rt, uf[None] * kt)
        o = o + bonus[..., None] * vt
        S = Sd + kt[..., None] * vt[..., None, :]
        return S, o

    xs = tuple(jnp.moveaxis(t, 2, 0) for t in (rf, kf, vf, wf))
    S_end, outs = jax.lax.scan(step, S, xs)
    return jnp.moveaxis(outs, 0, 2), S_end
