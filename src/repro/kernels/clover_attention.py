"""Flash attention for CLOVER-pruned heads (asymmetric dq != dv), GQA, causal.

TPU adaptation of the paper's inference story: after CLOVER pruning, Q/K
live at rank ``r_qk`` and V/O at rank ``r_vo`` — a shape class stock
flash kernels don't serve (they assume one head_dim).  This kernel tiles
(block_q x dq) and (block_k x dq/dv) slabs through VMEM with a running
softmax (m, l, acc) in scratch, the canonical TPU flash schedule:

  grid = (B, H, n_q, n_k), n_k innermost/sequential ("arbitrary");
  the output block index is constant in ik so the accumulator revisits
  legally.  Causal blocks strictly above the diagonal are skipped with
  ``pl.when`` (zero MXU work), which for long sequences halves compute.

MXU alignment: dq/dv are minor dims; CLOVER's pruning planner snaps kept
ranks to the sublane multiple so these slabs stay tile-aligned
(DESIGN.md §4).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = float(-1e30)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int,
                  causal: bool, n_k: int, q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # global element offsets of this tile
    qo = iq * block_q + q_offset      # query positions offset (prefill window)
    ko = ik * block_k

    run = True if not causal else (ko <= qo + block_q - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0, :, 0, :]                                  # (bq, dq)
        k = k_ref[0, :, 0, :]                                  # (bk, dq)
        v = v_ref[0, :, 0, :]                                  # (bk, dv)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale        # (bq, bk)
        if causal:
            qi = qo + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kj = ko + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            logits = jnp.where(qi >= kj, logits, NEG_INF)
        m_prev = m_scr[...]                                    # (bq, 1)
        m_cur = jnp.max(logits, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_prev - m_new)                        # (bq, 1)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, 1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _fin():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True,
                    scale: Optional[float] = None,
                    block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, S, H, dq);  k: (B, T, KV, dq);  v: (B, T, KV, dv).

    S and T must be multiples of block_q / block_k (ops.py pads).
    When S < T (windowed prefill against a longer cache) queries are
    aligned to the END of the key range, matching attention_ref.
    """
    B, S, H, dq = q.shape
    T, KV = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    G = H // KV
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    if scale is None:
        scale = float(1.0 / (dq ** 0.5))
    n_q, n_k = S // block_q, T // block_k

    grid = (B, H, n_q, n_k)
    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, n_k=n_k, q_offset=T - S)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, dq),
                         lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, block_k, 1, dq),
                         lambda b, h, iq, ik: (b, ik, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, dv),
                         lambda b, h, iq, ik: (b, ik, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, dv),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dv), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
