"""Data pipeline: synthetic sharded token streams with savable state."""
from repro.data.synthetic import (  # noqa: F401
    SyntheticConfig, SyntheticLM, make_global_batch)
