"""Deterministic synthetic LM data with exactly-resumable iterator state.

The stream is a counter-addressed PRNG: batch ``i`` is a pure function of
(seed, i), so the iterator state is a single integer — checkpoints save
it and restarts resume mid-epoch with zero drift, and ANY data-parallel
rank can regenerate ANY shard (elastic resharding needs no data
redistribution).

The token distribution is a small induction-head-friendly Markov chain
(repeating bigrams) rather than uniform noise so that recovery
fine-tuning and PEFT benchmarks have actual signal to learn.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Iterator

import jax
import numpy as np


@dataclass(frozen=True)
class SyntheticConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_patterns: int = 64      # distinct bigram patterns
    pattern_len: int = 8      # repeat period


class SyntheticLM:
    """Iterator over {tokens, labels} global batches.

    State = {"step": int}.  ``batch_at(step)`` is pure; ``__next__``
    advances the counter.
    """

    def __init__(self, cfg: SyntheticConfig):
        self.cfg = cfg
        self.step = 0
        rng = np.random.default_rng(cfg.seed)
        # fixed library of repeating patterns (the learnable structure)
        self.patterns = rng.integers(
            0, cfg.vocab_size, (cfg.n_patterns, cfg.pattern_len),
            dtype=np.int32)

    # -- state ----------------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {"step": self.step, "seed": self.cfg.seed}

    def load_state_dict(self, st: Dict[str, Any]):
        assert st["seed"] == self.cfg.seed, "stream identity mismatch"
        self.step = int(st["step"])

    # -- generation ------------------------------------------------------
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 20) ^ step)
        B, S = cfg.global_batch, cfg.seq_len
        pat = rng.integers(0, cfg.n_patterns, (B,))
        base = self.patterns[pat]                       # (B, P)
        reps = (S + cfg.pattern_len) // cfg.pattern_len + 1
        seq = np.tile(base, (1, reps))[:, :S + 1]
        # noise: corrupt 10% of positions so the task isn't trivial
        noise = rng.random((B, S + 1)) < 0.10
        rand = rng.integers(0, cfg.vocab_size, (B, S + 1), dtype=np.int32)
        seq = np.where(noise, rand, seq).astype(np.int32)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self.step)
        self.step += 1
        return b


def make_global_batch(batch_np: Dict[str, np.ndarray], mesh,
                      spec) -> Dict[str, jax.Array]:
    """Host numpy batch -> globally-sharded jax arrays.

    Single-process: device_put with NamedSharding.  (Multi-host would use
    make_array_from_process_local_data; the call-site contract is the
    same.)"""
    from jax.sharding import NamedSharding
    sh = NamedSharding(mesh, spec)
    return {k: jax.device_put(v, sh) for k, v in batch_np.items()}
