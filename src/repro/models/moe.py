"""GShard-style top-k MoE with capacity-bounded scatter dispatch.

Design notes (TPU / SPMD):
  * Experts are sharded along the ``model`` mesh axis (expert parallelism).
    Expert counts that don't divide the EP degree are padded with dead
    experts (router logits forced to -inf), e.g. qwen2-moe 60 -> 64.
  * Dispatch avoids the GShard (T, E, C) one-hot einsum (O(T*E*C) memory);
    instead we compute position-in-expert with a cumsum over a (T*k, E)
    one-hot and scatter into an (E, C, D) buffer — O(T*k*E) + O(E*C*D).
  * Aux losses: load-balance (Switch) + router z-loss.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

Params = Dict[str, Any]

# Expert-parallel degree the padded expert count must divide.  The
# production mesh has model=16; reduced smoke configs use tiny expert
# counts that are already multiples of 1.
EP_PAD_TO = 16


def n_padded_experts(cfg) -> int:
    e = cfg.moe.n_experts
    if e >= EP_PAD_TO and e % EP_PAD_TO != 0:
        return ((e + EP_PAD_TO - 1) // EP_PAD_TO) * EP_PAD_TO
    return e


def init_moe(key, cfg, dtype) -> Params:
    D = cfg.d_model
    de = cfg.moe.d_expert or cfg.d_ff
    E = n_padded_experts(cfg)
    ks = jax.random.split(key, 7)
    gated = cfg.mlp_act in ("swiglu", "geglu")
    p: Params = {
        "router": dense_init(ks[0], (D, E), D, jnp.float32),
        "w_up": dense_init(ks[1], (E, D, de), D, dtype),
        "w_down": dense_init(ks[2], (E, de, D), de, dtype),
    }
    if gated:
        p["w_gate"] = dense_init(ks[3], (E, D, de), D, dtype)
    if cfg.moe.n_shared:
        ds = cfg.moe.n_shared * de
        p["shared_up"] = dense_init(ks[4], (D, ds), D, dtype)
        p["shared_down"] = dense_init(ks[5], (ds, D), ds, dtype)
        if gated:
            p["shared_gate"] = dense_init(ks[6], (D, ds), D, dtype)
    return p


def _act(cfg, gate, up):
    if cfg.mlp_act == "swiglu":
        return jax.nn.silu(gate) * up
    if cfg.mlp_act == "geglu":
        return jax.nn.gelu(gate) * up
    return jax.nn.gelu(up)


def apply_moe(params: Params, cfg, x: jnp.ndarray) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B, S, D) -> (out, aux_losses).

    When a mesh with batch axes is ambient (pjit training/serving), the
    batch is reshaped to an explicit (n_shards, T_local) leading dim,
    sharding-constrained to the batch axes, and the dispatch vmapped
    over shards — so routing, position-in-expert, and CAPACITY are all
    per-data-shard: the GShard contract.  (A global cumsum-based
    dispatch would make the capacity buffers scale with the GLOBAL token
    count on every device: at 1M tokens that is tens of GiB per layer.)
    The expert dimension stays in GSPMD auto mode: expert weights are
    model-axis sharded (EP) and XLA inserts the dispatch/combine
    collectives.

    Per-shard dispatch is dropless (capacity = T, nothing dropped) when
    S == 1 (decode: exactness matters, buffers are tiny) or when
    ``capacity_factor == 0`` (test / eval configs); otherwise
    capacity-bounded.
    """
    from repro.parallel.sharding import (ambient_mesh, batch_mesh_axes,
                                         constrain, BATCH)

    B, S, D = x.shape
    mesh = ambient_mesh()
    ba = batch_mesh_axes(mesh) if mesh is not None else ()
    n_sh = math.prod(mesh.shape[a] for a in ba) if ba else 1
    dropless = (S == 1) or (cfg.moe.capacity_factor == 0)
    if n_sh == 1 or B % n_sh != 0:
        out, aux = _moe_tokens(params, cfg, x.reshape(B * S, D), dropless)
        return out.reshape(B, S, D), aux

    xg = x.reshape(n_sh, (B // n_sh) * S, D)
    xg = constrain(xg, (BATCH, None, None))
    out, aux = jax.vmap(
        lambda xl: _moe_tokens(params, cfg, xl, dropless))(xg)
    out = constrain(out, (BATCH, None, None))
    return (out.reshape(B, S, D),
            {k: jnp.mean(v) for k, v in aux.items()})


def _moe_tokens(params: Params, cfg, xf: jnp.ndarray, dropless: bool,
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Single-shard dispatch + expert FFN over a flat (T, D) token slab."""
    T, D = xf.shape
    moe = cfg.moe
    E_real, E = moe.n_experts, n_padded_experts(cfg)
    k = moe.top_k
    dtype = xf.dtype

    # --- routing (f32 for stability) ---------------------------------------
    logits = (xf.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    if E != E_real:  # dead padding experts
        pad_mask = jnp.arange(E) >= E_real
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)     # renormalize

    # aux losses
    me = jnp.mean(probs, axis=0)                              # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, E, dtype=jnp.float32), axis=1),
        axis=0)
    aux_lb = E_real * jnp.sum(me * ce) * moe.aux_loss_coef
    z = jax.nn.logsumexp(logits, axis=-1)
    aux_z = jnp.mean(jnp.square(z)) * moe.router_z_coef
    aux = {"moe_load_balance": aux_lb, "moe_router_z": aux_z}

    # --- capacity + position-in-expert --------------------------------------
    capacity = T if dropless else max(1, int(k * T * moe.capacity_factor / E))
    e_flat = expert_idx.reshape(T * k)                        # (Tk,)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)       # (Tk, E)
    pos_all = jnp.cumsum(onehot, axis=0) - 1                  # (Tk, E)
    pos = jnp.take_along_axis(pos_all, e_flat[:, None], axis=1)[:, 0]
    keep = pos < capacity
    pos_c = jnp.where(keep, pos, 0)
    e_c = jnp.where(keep, e_flat, 0)

    # --- dispatch: GATHER tokens into (E, C, D) ------------------------------
    # Slot->token map first, then one expert-major gather.  Cheaper than
    # scattering k replicated (Tk, D) slabs: the cross-shard tensor (and
    # its backward scatter) is (T, D)-sized, not (Tk, D)-sized.
    tok_ids = jnp.repeat(jnp.arange(T, dtype=jnp.int32)[:, None], k,
                         axis=1).reshape(T * k)
    dest = jnp.full((E, capacity), T, jnp.int32).at[e_c, pos_c].set(
        jnp.where(keep, tok_ids, T), mode="drop")             # T -> empty
    xf_pad = jnp.concatenate([xf.astype(dtype),
                              jnp.zeros((1, D), dtype)], axis=0)
    buf = xf_pad[dest]                                        # (E, C, D)

    # --- expert FFN ----------------------------------------------------------
    up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(dtype))
    if "w_gate" in params:
        gate = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(dtype))
    else:
        gate = None
    h = _act(cfg, gate, up)
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(dtype))

    # --- combine: expert-major scatter-accumulate ----------------------------
    # Weight each (e, c) slot and scatter straight to its token: the
    # cross-shard tensor is ONE (T, D) partial per expert shard (summed
    # by an all-reduce), not k gathered (Tk, D) slabs — 4x fewer
    # collective bytes at top-4 than gather-then-sum (measured on the
    # qwen2-moe train cell, EXPERIMENTS.md §Perf).
    w = (gate_vals.reshape(T * k) * keep).astype(dtype)       # (Tk,)
    w_ec = jnp.zeros((E, capacity), dtype).at[e_c, pos_c].set(
        w, mode="drop")
    contrib = out_buf * w_ec[..., None]                       # (E, C, D)
    out = jnp.zeros((T, D), dtype).at[dest.reshape(-1)].add(
        contrib.reshape(-1, D), mode="drop")

    # --- shared experts (always-on) ------------------------------------------
    if "shared_up" in params:
        sup = xf @ params["shared_up"].astype(dtype)
        sgate = (xf @ params["shared_gate"].astype(dtype)
                 if "shared_gate" in params else None)
        sh = _act(cfg, sgate, sup)
        out = out + sh @ params["shared_down"].astype(dtype)

    return out, aux
