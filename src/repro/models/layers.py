"""Core layers: norms, rotary embeddings, GQA attention (optionally
CLOVER-factored), dense MLPs.

Attention weight layout (CLOVER-ready):
    wq : (D, H,  dq)     dq = head_dim, or the CLOVER-pruned Q-K rank
    wk : (D, KV, dq)
    wv : (D, KV, dv)     dv = head_dim, or the CLOVER-pruned V-O rank
    wo : (H, dv, D)
Optional CLOVER fine-tuning matrices (present only while unmerged):
    s_qk : (H,  dq, dq)  transition between Q and K (applied on the Q side)
    k_t  : (KV, dq, dq)  intra-layer K transition (RoPE fallback, pre-RoPE)
    s_vo : (H,  dv, dv)  transition between attention-context and O
Attention only ever consumes the cross-layer *products*, which is exactly
the invariance CLOVER exploits.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def kernel_dispatch(impl: Any):
    """``impl`` (alias string or resolved ``kernels.ops.KernelDispatch``)
    -> dispatch object, or None for the plain einsum paths.  The bare
    "xla"/"ref" strings short-circuit WITHOUT importing the kernels
    package, so default model code never pays the Pallas import."""
    if isinstance(impl, str) and impl in ("xla", "ref"):
        return None
    from repro.kernels import ops as kops
    d = kops.resolve(impl)
    return d if d.kernel_path else None


# ---------------------------------------------------------------------------
# initializers / norms
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis_size: int, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(max(1, in_axis_size))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def init_norm(cfg, dtype) -> Params:
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def apply_norm(p: Params, cfg, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings (partial-RoPE aware)
# ---------------------------------------------------------------------------

def rope_tables(positions: jnp.ndarray, rot_dims: int, theta: float):
    """positions: (..., S) int32 -> cos/sin of shape (..., S, rot_dims//2)."""
    half = rot_dims // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray,
               rot_dims: int) -> jnp.ndarray:
    """x: (B, S, N, dq). Rotates the first `rot_dims` dims (half-split
    convention), passes the rest through (partial RoPE / NoPE block)."""
    if rot_dims == 0:
        return x
    half = rot_dims // 2
    x_rot, x_pass = x[..., :rot_dims], x[..., rot_dims:]
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    r1 = x1 * c - x2 * s
    r2 = x2 * c + x1 * s
    return jnp.concatenate([r1, r2, x_pass], axis=-1)


def sinusoidal_positions(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    half = d_model // 2
    freqs = 10000.0 ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

# q-block size for the chunked XLA attention path (peak logits slab is
# (B, H, ATTN_CHUNK, S) instead of (B, H, S, S)).
ATTN_CHUNK = 512


def _pick_block(S: int, target: int) -> int:
    """Largest divisor of S that is <= target (>= 1)."""
    b = min(S, target)
    while S % b:
        b -= 1
    return b


def _heads_shardable(H: int) -> bool:
    """Do the query heads divide the ambient model axis?"""
    from repro.parallel.sharding import ambient_mesh
    mesh = ambient_mesh()
    if mesh is None or "model" not in mesh.shape:
        return True
    return H % mesh.shape["model"] == 0


def _causal_attention_chunked(q, k, v, scale, *, softcap: float = 0.0,
                              q_offset=0, heads_shardable: bool = True,
                              unroll: bool = False):
    """Memory-bounded causal attention: lax.scan over query blocks, each
    block rematerialized (recompute probs in backward — XLA flash).

    q (B,S,H,dq), k (B,T,KV,dq), v (B,T,KV,dv) -> (B,S,H,dv).
    Query i sits at global position ``q_offset + i`` (traced OK); key t at
    position t.  T >= S; zero-filled cache tail is masked by causality.

    Sharding: when the head count divides the model axis the logits slab
    shards over heads; otherwise (phi3 40H, deepseek 56H, minitron 24H on
    a 16-way axis) the Q-SEQUENCE dim shards over "model" instead —
    Megatron-style context parallelism.  K/V are per-kv-head small (GQA)
    and replicate across the model axis in that mode.
    """
    from repro.parallel.sharding import constrain, BATCH, KV_SEQ
    B, S, H, dq = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    dv = v.shape[-1]
    bq = _pick_block(S, ATTN_CHUNK)
    n = S // bq
    qc = q.reshape(B, n, bq, KV, G, dq)
    kpos = jnp.arange(T, dtype=jnp.int32)
    seq_par = not heads_shardable
    if seq_par:
        qc = constrain(qc, (BATCH, None, KV_SEQ, None, None, None))

    def block(carry, xs):
        qb, i = xs                                  # (B,bq,KV,G,dq), scalar
        if seq_par:
            qb = constrain(qb, (BATCH, KV_SEQ, None, None, None))
        logits = jnp.einsum("bskgq,btkq->bkgst", qb, k).astype(jnp.float32)
        logits = logits * scale
        if softcap > 0:
            logits = jnp.tanh(logits / softcap) * softcap
        qpos = q_offset + i * bq + jnp.arange(bq, dtype=jnp.int32)
        mask = qpos[:, None] >= kpos[None, :]       # (bq, T)
        logits = jnp.where(mask[None, None, None], logits,
                           jnp.finfo(jnp.float32).min)
        p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        ob = jnp.einsum("bkgst,btkv->bskgv", p, v)  # (B,bq,KV,G,dv)
        if seq_par:
            ob = constrain(ob, (BATCH, KV_SEQ, None, None, None))
        return carry, ob

    if unroll:  # exact-cost mode: python loop, every chunk in the HLO
        outs = [block(None, (qc[:, i], jnp.int32(i)))[1] for i in range(n)]
        out = jnp.stack(outs, axis=1)
    else:
        _, out = jax.lax.scan(jax.checkpoint(block), None,
                              (jnp.moveaxis(qc, 1, 0),
                               jnp.arange(n, dtype=jnp.int32)))
        out = jnp.moveaxis(out, 0, 1)
    return out.reshape(B, S, H, dv)


def init_attention(key, cfg, dtype) -> Params:
    D, H, KV = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    dq, dv = cfg.qk_dim, cfg.vo_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H, dq), D, dtype),
        "wk": dense_init(ks[1], (D, KV, dq), D, dtype),
        "wv": dense_init(ks[2], (D, KV, dv), D, dtype),
        "wo": dense_init(ks[3], (H, dv, D), H * dv, dtype),
    }
    return p


def _pad_rank(t: jnp.ndarray, width: int) -> jnp.ndarray:
    """Zero-pad the last (rank) dim up to ``width`` (no-op if already
    there).  Used by the self-speculative DRAFT pass: its K/V live at a
    sliced rank but must land in the full-rank shared cache — the padded
    tail is overwritten by the verify pass before the full model ever
    reads those positions."""
    d = t.shape[-1]
    if d == width:
        return t
    return jnp.pad(t, [(0, 0)] * (t.ndim - 1) + [(0, width - d)])


def attention(params: Params, cfg, x: jnp.ndarray, *,
              positions: jnp.ndarray,
              kv_cache: Optional[Params] = None,
              cache_index: Optional[jnp.ndarray] = None,
              page_table: Optional[jnp.ndarray] = None,
              write_floor: Optional[jnp.ndarray] = None,
              attn_impl: Any = "xla",
              draft_rank: Optional[Tuple[int, int]] = None,
              adapter: Optional[Params] = None,
              ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """GQA attention.

    Full-sequence (train/prefill): ``kv_cache is None`` -> causal mask.
    Decode: ``kv_cache`` holds {"k": (B, Smax, KV, dq), "v": (B, Smax, KV, dv)}
    and ``cache_index`` is the write position (scalar int32); x has S==1.

    Paged decode: ``page_table`` (B, n_p) int32 is given and ``kv_cache``
    holds the global pools {"k": (N, page_tokens, KV, dq), "v": (N,
    page_tokens, KV, dv)} shared by all slots; ``cache_index`` must be
    the (B,) per-slot vector.  Position p of slot b lives at
    ``pool[page_table[b, p // page_tokens], p % page_tokens]``.  The
    table must cover positions [0, cache_index + S) per slot — entries
    may be a sentinel id addressing the pool's spare garbage row, where
    padding/idle-slot writes land harmlessly (DESIGN.md §6).  With
    prefix caching a slot's table may map pages SHARED with other
    sequences read-only (DESIGN.md §9); ``write_floor`` (B,) marks each
    slot's first writable position, and scatter-writes below it are
    rerouted to the garbage row — defense in depth under the engine's
    copy-on-write contract (reads go through the table unchanged).

    Self-speculative draft: ``draft_rank = (r_q, r_v)`` runs the SAME
    weights with every head's rank sliced to the leading draft widths
    (DESIGN.md §8).  Because CLOVER factors are sorted by singular
    value, ``x @ wq[..., :r]`` equals the leading dims of the full
    projection — so the draft's view of the SHARED cache is literally
    ``cache[..., :r]``; no second cache exists.  Draft K/V writes are
    zero-padded to the cache width and always overwritten by the verify
    pass before the full model reads those positions.

    Multi-tenant SV adapters: ``adapter`` holds per-slot rank-space
    scales {"a_qk": (B, H, dq_c), "a_vo": (B, H, dv_c)} (full cache
    widths — draft entries slice the leading ``:dq``/``:dv``, matching
    the weight slicing).  They multiply elementwise into the outputs of
    the ``s_qk`` / ``s_vo`` transitions — per-tenant singular values at
    zero extra matmuls; ``None`` (or the all-ones identity adapter)
    leaves every path bitwise unchanged (DESIGN.md §13).
    """
    B, S, D = x.shape
    H, KV = cfg.n_heads, cfg.n_kv_heads
    G = cfg.q_per_kv
    dq_c, dv_c = cfg.qk_dim, cfg.vo_dim     # cache (full-model) widths
    dq, dv = draft_rank if draft_rank is not None else (dq_c, dv_c)
    assert dq <= dq_c and dv <= dv_c, (draft_rank, dq_c, dv_c)
    # CLOVER-pruned heads approximate the ORIGINAL product Q K^T, so the
    # softmax scale stays 1/sqrt(original head_dim) regardless of rank.
    scale = 1.0 / math.sqrt(cfg.head_dim_)

    q = jnp.einsum("bsd,dhq->bshq", x,
                   params["wq"][..., :dq].astype(x.dtype))
    k = jnp.einsum("bsd,dkq->bskq", x,
                   params["wk"][..., :dq].astype(x.dtype))
    v = jnp.einsum("bsd,dkv->bskv", x,
                   params["wv"][..., :dv].astype(x.dtype))

    if "k_t" in params:  # intra-layer K transition (RoPE-safe CLOVER PEFT)
        k = jnp.einsum("bskq,kqr->bskr", k,
                       params["k_t"][..., :dq, :dq].astype(k.dtype))
    if "s_qk" in params:
        q = jnp.einsum("bshq,hqr->bshr", q,
                       params["s_qk"][..., :dq, :dq].astype(q.dtype))
    if adapter is not None and "a_qk" in adapter:
        # per-slot singular-value scaling of the Q-K transition output
        q = q * adapter["a_qk"][:, None, :, :dq].astype(q.dtype)

    def _vo_out(ctx):
        """Shared V-O tail: transition, per-slot adapter scale, output
        projection — the ONE place the s_vo math lives for every path."""
        if "s_vo" in params:
            ctx = jnp.einsum("bshv,hvw->bshw", ctx,
                             params["s_vo"][..., :dv, :dv].astype(ctx.dtype))
        if adapter is not None and "a_vo" in adapter:
            ctx = ctx * adapter["a_vo"][:, None, :, :dv].astype(ctx.dtype)
        return jnp.einsum("bshv,hvd->bsd", ctx,
                          params["wo"][..., :dv, :].astype(x.dtype))

    # Partial-RoPE pruning keeps the rotated block intact at the front, so
    # RoPE always applies to the first rope_dims (<= dq) dims.
    rot = min(cfg.rope_dims, dq)
    if rot:
        cos, sin = rope_tables(positions, rot, cfg.rope_theta)
        q = apply_rope(q, cos, sin, rot)
        k = apply_rope(k, cos, sin, rot)

    # ``attn_impl`` is an alias string or a resolved KernelDispatch (the
    # executors thread a mesh-aware one through cfg.kernel_impl, so the
    # flash kernels run per shard under shard_map when params are
    # sharded).  Softcapped logits have no kernel: einsum path.
    dispatch = kernel_dispatch(attn_impl)
    use_pallas = dispatch is not None and cfg.attn_logit_softcap == 0

    # Non-uniform RankBudget plans (DESIGN.md §14): apply_rank_budget
    # leaves (n_blocks, KV) int32 kept-rank tables in the stacked attn
    # params; the transformer's lax.scan delivers this layer's (KV,)
    # rows here.  The weights are already zero-padded past each head's
    # kept rank (mask_head_ranks), so the einsum paths need nothing —
    # the vectors only feed the decode kernels' per-head rank clamp,
    # which turns the semantic zeros into skipped DMA + compute.
    rank_qk = params.get("rank_qk")
    rank_vo = params.get("rank_vo")
    rank_kw = {}
    if use_pallas and (rank_qk is not None or rank_vo is not None):
        rank_kw = {
            "qk_ranks": (None if rank_qk is None
                         else jnp.minimum(rank_qk, dq).astype(jnp.int32)),
            "vo_ranks": (None if rank_vo is None
                         else jnp.minimum(rank_vo, dv).astype(jnp.int32)),
            "rank_block": max(8, cfg.clover.rank_multiple),
        }

    new_cache = None
    if kv_cache is not None and page_table is not None:
        # Paged cache: scatter the window through the page table into
        # the global pool.  Positions past a slot's allocated pages map
        # through sentinel entries to the pool's garbage row, so padded
        # windows and idle slots never corrupt other slots' pages;
        # garbage inside a slot's own last page sits beyond its causal
        # horizon until the slot itself overwrites it — the same
        # masked-or-overwritten invariant as the dense cache.
        N, PT = kv_cache["k"].shape[0], kv_cache["k"].shape[1]
        P = page_table.shape[1]
        pos = cache_index[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
        # Positions past the table (an idle slot whose index ran beyond
        # its pages) route to the garbage row EXPLICITLY: an out-of-
        # bounds take_along_axis index has mode-dependent lowering, and
        # after the ``page * PT + pos % PT`` arithmetic the scatter dest
        # can alias another slot's live page.
        pslot = pos // PT                                           # (B, S)
        page = jnp.take_along_axis(page_table,
                                   jnp.clip(pslot, 0, P - 1), axis=1)
        page = jnp.where(pslot >= P, N - 1, page)
        if write_floor is not None:
            # read-only prefix (prefix-cached shared pages): reroute
            # any sub-floor write to the garbage row N-1.  The engine's
            # COW path means this never fires for valid traffic.
            page = jnp.where(pos >= write_floor[:, None], page, N - 1)
        dest = (page * PT + pos % PT).reshape(-1)                   # (B*S,)
        ck = (kv_cache["k"].reshape(N * PT, KV, dq_c)
              .at[dest].set(_pad_rank(k, dq_c).reshape(B * S, KV, dq_c)
                            .astype(kv_cache["k"].dtype))
              .reshape(kv_cache["k"].shape))
        cv = (kv_cache["v"].reshape(N * PT, KV, dv_c)
              .at[dest].set(_pad_rank(v, dv_c).reshape(B * S, KV, dv_c)
                            .astype(kv_cache["v"].dtype))
              .reshape(kv_cache["v"].shape))
        new_cache = {"k": ck, "v": cv}
        if use_pallas and S == 1:  # paged flash-decoding: the hot path
            lengths = (cache_index + 1).astype(jnp.int32)
            ctx = dispatch.paged_decode_attention(
                q[:, 0], ck[..., :dq].astype(x.dtype),
                cv[..., :dv].astype(x.dtype),
                page_table, lengths, scale=scale,
                **rank_kw)[:, None]                         # (B,1,H,dv)
            return _vo_out(ctx), new_cache
        # Chunked-prefill reads gather each slot's pages into a dense
        # (B, P*PT, KV, r) view and reuse the masked path below; writes
        # stay pool-resident (noted in DESIGN.md §6 as the cold path).
        k = (ck[page_table].reshape(B, P * PT, KV, dq_c)[..., :dq]
             .astype(x.dtype))
        v = (cv[page_table].reshape(B, P * PT, KV, dv_c)[..., :dv]
             .astype(x.dtype))
        T = k.shape[1]
        kv_pos = jnp.arange(T, dtype=jnp.int32)
        qpos = cache_index[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
        mask = kv_pos[None, None, :] <= qpos[:, :, None]      # (B, S, T)
    elif kv_cache is not None:
        # cache_index: scalar (whole batch at one position — prefill and
        # lockstep decode) or (B,) vector (per-slot positions — the
        # serving engine's continuous batching; S may be > 1 for chunked
        # prefill, writing an S-token window at each slot's own offset).
        per_slot = jnp.ndim(cache_index) == 1
        kw = _pad_rank(k, dq_c).astype(kv_cache["k"].dtype)
        vw = _pad_rank(v, dv_c).astype(kv_cache["v"].dtype)
        if per_slot:
            upd = jax.vmap(
                lambda c, kn, i: jax.lax.dynamic_update_slice_in_dim(
                    c, kn, i, axis=0))
            ck = upd(kv_cache["k"], kw, cache_index)
            cv = upd(kv_cache["v"], vw, cache_index)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["k"], kw, cache_index, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["v"], vw, cache_index, axis=1)
        new_cache = {"k": ck, "v": cv}
        if use_pallas and S == 1:  # flash-decoding against the cache
            lengths = jnp.broadcast_to(cache_index + 1, (B,)).astype(jnp.int32)
            ctx = dispatch.decode_attention(
                q[:, 0], ck[..., :dq].astype(x.dtype),
                cv[..., :dv].astype(x.dtype), lengths,
                scale=scale, **rank_kw)[:, None]               # (B,1,H,dv)
            return _vo_out(ctx), new_cache
        k, v = ck[..., :dq].astype(x.dtype), cv[..., :dv].astype(x.dtype)
        if not per_slot and S > ATTN_CHUNK:
            # long cached prefill: chunked flash path
            ctx = _causal_attention_chunked(
                q, k, v, scale, softcap=cfg.attn_logit_softcap,
                q_offset=cache_index,
                heads_shardable=_heads_shardable(H),
                unroll=cfg.unroll_layers)
            return _vo_out(ctx), new_cache
        T = k.shape[1]
        kv_pos = jnp.arange(T, dtype=jnp.int32)
        ci = jnp.broadcast_to(jnp.atleast_1d(cache_index), (B,))
        # query j of slot b sits at global position ci[b] + j; causal
        # against every cached position (covers scalar AND per-slot
        # offsets, S == 1 and chunked windows uniformly).
        qpos = ci[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
        mask = kv_pos[None, None, :] <= qpos[:, :, None]      # (B, S, T)
    else:
        if use_pallas:  # full-sequence causal flash kernel
            ctx = dispatch.clover_attention(q, k, v, causal=True,
                                            scale=scale)       # (B,S,H,dv)
            return _vo_out(ctx), None
        if S > ATTN_CHUNK:
            # XLA flash: scan over q blocks so the (bq, S) logits slab is
            # the peak — full (S, S) logits at 4k-32k would not fit HBM.
            # unroll_layers (exact-cost mode) python-unrolls the chunk
            # loop: identical math, trip-count-free HLO.
            ctx = _causal_attention_chunked(q, k, v, scale,
                                            softcap=cfg.attn_logit_softcap,
                                            heads_shardable=_heads_shardable(H),
                                            unroll=cfg.unroll_layers)
            return _vo_out(ctx), None
        T = S
        qpos = jnp.arange(S, dtype=jnp.int32)
        mask = (qpos[None, :, None] >= qpos[None, None, :])
        mask = jnp.broadcast_to(mask, (B, S, T))

    qg = q.reshape(B, S, KV, G, dq)
    logits = jnp.einsum("bskgq,btkq->bkgst", qg, k) * scale
    if cfg.attn_logit_softcap > 0:
        c = cfg.attn_logit_softcap
        logits = jnp.tanh(logits / c) * c
    logits = logits.astype(jnp.float32)
    neg = jnp.finfo(jnp.float32).min
    logits = jnp.where(mask[:, None, None, :, :], logits, neg)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bkgst,btkv->bskgv", probs, v).reshape(B, S, H, dv)
    return _vo_out(ctx), new_cache


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, dtype) -> Params:
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_act in ("swiglu", "geglu"):
        p = {
            "w_gate": dense_init(ks[0], (D, F), D, dtype),
            "w_up": dense_init(ks[1], (D, F), D, dtype),
            "w_down": dense_init(ks[2], (F, D), F, dtype),
        }
    else:
        p = {
            "w_up": dense_init(ks[0], (D, F), D, dtype),
            "w_down": dense_init(ks[1], (F, D), F, dtype),
        }
    return p


def apply_mlp(params: Params, cfg, x: jnp.ndarray) -> jnp.ndarray:
    if "up_u" in params:  # CLOVER blockwise-decomposed Up (+ transition)
        h = jnp.einsum("bsd,dnr->bsnr", x, params["up_u"].astype(x.dtype))
        h = jnp.einsum("bsnr,nrk->bsnk", h, params["up_t"].astype(x.dtype))
        up = h.reshape(*x.shape[:-1], -1)
    else:
        up = x @ params["w_up"].astype(x.dtype)
    if cfg.mlp_act == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"].astype(x.dtype)) * up
    elif cfg.mlp_act == "geglu":
        h = jax.nn.gelu(x @ params["w_gate"].astype(x.dtype)) * up
    else:
        h = jax.nn.gelu(up)
    return h @ params["w_down"].astype(x.dtype)
