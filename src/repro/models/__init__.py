"""Config-driven model zoo covering all assigned architectures."""
from repro.models.transformer import (  # noqa: F401
    init_lm_params, forward, prefill, prefill_chunk, decode_step,
    init_decode_state, init_decode_state_paged)
