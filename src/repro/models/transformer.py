"""Config-driven decoder: one builder covers all 10 assigned architectures.

Layer stacking uses ``lax.scan`` over repeated blocks (stacked params with a
leading ``n_blocks`` axis), so HLO size and compile time are O(period), not
O(n_layers) — essential for the 62-layer deepseek-coder dry-run.  Hybrid
patterns (jamba) scan over one full period (7 mamba + 1 attn) per step.

Three entry points:
  * ``forward``      — full-sequence causal (train / scoring)
  * ``prefill``      — full-sequence, writes decode state, returns last logits
  * ``decode_step``  — one token against the decode state
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ArchConfig, MIXER_ATTN, MIXER_MAMBA,
                                MIXER_RWKV, MLP_DENSE, MLP_MOE, MLP_RWKV)
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models import rwkv as R

Params = Dict[str, Any]

AUX_KEYS = ("moe_load_balance", "moe_router_z")


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16,
            "float8_e4m3fn": jnp.float8_e4m3fn,
            "float8_e5m2": jnp.float8_e5m2}[name]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ArchConfig, mixer: str, mlp: str, dtype) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {"norm1": L.init_norm(cfg, dtype), "norm2": L.init_norm(cfg, dtype)}
    if mixer == MIXER_ATTN:
        p["attn"] = L.init_attention(k1, cfg, dtype)
    elif mixer == MIXER_MAMBA:
        p["mamba"] = M.init_mamba(k1, cfg, dtype)
    elif mixer == MIXER_RWKV:
        p["rwkv_time"] = R.init_rwkv_timemix(k1, cfg, dtype)
    else:
        raise ValueError(mixer)
    if mlp == MLP_DENSE:
        p["mlp"] = L.init_mlp(k2, cfg, dtype)
    elif mlp == MLP_MOE:
        p["moe"] = MOE.init_moe(k2, cfg, dtype)
    elif mlp == MLP_RWKV:
        p["rwkv_chan"] = R.init_rwkv_chanmix(k2, cfg, dtype)
    else:
        raise ValueError(mlp)
    return p


def init_lm_params(cfg: ArchConfig, key) -> Params:
    dtype = _dtype(cfg.param_dtype)
    keys = jax.random.split(key, cfg.period + 3)
    params: Params = {
        "embed": (jax.random.normal(keys[0], (cfg.padded_vocab, cfg.d_model))
                  * 0.02).astype(dtype),
        "final_norm": L.init_norm(cfg, dtype),
    }
    if cfg.learned_pos:
        params["pos_embed"] = (jax.random.normal(
            keys[1], (cfg.max_position, cfg.d_model)) * 0.01).astype(dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(
            keys[2], (cfg.d_model, cfg.padded_vocab), cfg.d_model, dtype)

    blocks = []
    for j, (mixer, mlp) in enumerate(cfg.pattern):
        layer_keys = jax.random.split(keys[3 + j], cfg.n_blocks)
        stacked = jax.vmap(
            lambda k: _init_layer(k, cfg, mixer, mlp, dtype))(layer_keys)
        blocks.append(stacked)
    params["blocks"] = tuple(blocks)
    return params


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------

def _zero_aux():
    return {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}


def _apply_layer(lp: Params, cfg: ArchConfig, mixer: str, mlp: str,
                 x: jnp.ndarray, *, positions, state: Optional[Params],
                 cache_index, pages=None, write_floor=None,
                 draft_rank=None, adapter=None,
                 ) -> Tuple[jnp.ndarray, Optional[Params], Dict]:
    from repro.parallel.sharding import constrain, BATCH
    aux = _zero_aux()
    # anchor: activations stay batch-sharded through every block.  The
    # FSDP axis ("data") shards both the batch AND weight d_model dims;
    # without this anchor GSPMD may choose weight-stationary layouts and
    # replicate the whole global batch per device (observed: 30x temp
    # memory on non-16-divisible-head archs).  No-op without a mesh.
    x = constrain(x, (BATCH, None, None))
    h = L.apply_norm(lp["norm1"], cfg, x)
    new_state: Params = {}
    if mixer == MIXER_ATTN:
        kv = state["kv"] if state is not None else None
        y, new_kv = L.attention(lp["attn"], cfg, h, positions=positions,
                                kv_cache=kv, cache_index=cache_index,
                                page_table=pages, write_floor=write_floor,
                                attn_impl=cfg.kernel_impl,
                                draft_rank=draft_rank, adapter=adapter)
        if state is not None:
            new_state["kv"] = new_kv
    elif mixer == MIXER_MAMBA:
        y, ns = M.mamba_forward(lp["mamba"], cfg, h,
                                state=state["mamba"] if state is not None else None)
        if state is not None:
            new_state["mamba"] = ns
    else:  # rwkv
        y, ns = R.rwkv_timemix_forward(
            lp["rwkv_time"], cfg, h,
            state=state["time"] if state is not None else None)
        if state is not None:
            new_state["time"] = ns
    x = x + y

    h = L.apply_norm(lp["norm2"], cfg, x)
    if mlp == MLP_DENSE:
        y = L.apply_mlp(lp["mlp"], cfg, h)
    elif mlp == MLP_MOE:
        y, moe_aux = MOE.apply_moe(lp["moe"], cfg, h)
        for k in moe_aux:
            aux[k] = aux[k] + moe_aux[k]
    else:  # rwkv channel mix
        y, ns = R.rwkv_chanmix_forward(
            lp["rwkv_chan"], cfg, h,
            state=state["chan"] if state is not None else None)
        if state is not None:
            new_state["chan"] = ns
    x = x + y
    return x, (new_state if state is not None else None), aux


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------

def _embed(params, cfg, tokens, positions, frontend_embeds):
    from repro.parallel.sharding import constrain, BATCH
    dtype = _dtype(cfg.compute_dtype)
    x = params["embed"][tokens].astype(dtype)
    if frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(dtype), x], axis=1)
    if cfg.learned_pos:
        x = x + params["pos_embed"][positions].astype(dtype)
    elif not cfg.rope and cfg.family == "audio":
        x = x + L.sinusoidal_positions(positions, cfg.d_model).astype(dtype)
    return constrain(x, (BATCH, None, None))


def _logits(params, cfg, x):
    from repro.parallel.sharding import constrain, BATCH, VOCAB
    if cfg.tie_embeddings:
        w = params["embed"].astype(x.dtype)
        out = (x @ w.T).astype(jnp.float32)
    else:
        out = (x @ params["lm_head"].astype(x.dtype)).astype(jnp.float32)
    out = constrain(out, (BATCH, None, VOCAB))
    if cfg.padded_vocab != cfg.vocab_size:  # mask padding ids
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        out = jnp.where(pad, -1e30, out)
    return out


# ---------------------------------------------------------------------------
# full-sequence forward (train / eval)
# ---------------------------------------------------------------------------

def forward(params: Params, cfg: ArchConfig, tokens: jnp.ndarray, *,
            frontend_embeds: Optional[jnp.ndarray] = None,
            remat: bool = False,
            last_only: bool = False,
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """tokens: (B, S_tok) int32; frontend_embeds: (B, F, D) or None.
    Returns (logits (B, S, V) f32, aux losses)."""
    B = tokens.shape[0]
    F = frontend_embeds.shape[1] if frontend_embeds is not None else 0
    S = tokens.shape[1] + F
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = _embed(params, cfg, tokens, positions, frontend_embeds)

    def block_fn(carry, block_params):
        x, aux = carry
        for j, (mixer, mlp) in enumerate(cfg.pattern):
            x, _, a = _apply_layer(block_params[j], cfg, mixer, mlp, x,
                                   positions=positions, state=None,
                                   cache_index=None)
            aux = {k: aux[k] + a[k] for k in aux}
        return (x, aux), None

    g = max(1, cfg.remat_group)
    if g > 1 and cfg.n_blocks % g == 0 and not cfg.unroll_layers:
        grouped = jax.tree.map(
            lambda a: a.reshape((cfg.n_blocks // g, g) + a.shape[1:]),
            params["blocks"])

        def group_fn(carry, group_params):
            for i in range(g):
                bp = jax.tree.map(lambda a: a[i], group_params)
                carry, _ = block_fn(carry, bp)
            return carry, None

        body = jax.checkpoint(group_fn) if remat else group_fn
        (x, aux), _ = jax.lax.scan(body, (x, _zero_aux()), grouped)
    else:
        body = jax.checkpoint(block_fn) if remat else block_fn
        if cfg.unroll_layers:
            carry = (x, _zero_aux())
            for i in range(cfg.n_blocks):
                bp = jax.tree.map(lambda a: a[i], params["blocks"])
                carry, _ = body(carry, bp)
            x, aux = carry
        else:
            (x, aux), _ = jax.lax.scan(body, (x, _zero_aux()),
                                       params["blocks"])
    x = L.apply_norm(params["final_norm"], cfg, x)
    if last_only:
        x = x[:, -1:]
    return _logits(params, cfg, x), aux


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    """Per-pattern-position stacked state trees (leading n_blocks)."""
    dtype = _dtype(cfg.compute_dtype)
    kv_dtype = _dtype(cfg.kv_cache_dtype or cfg.compute_dtype)

    def one(mixer, mlp):
        st: Params = {}
        if mixer == MIXER_ATTN:
            st["kv"] = {
                "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.qk_dim), kv_dtype),
                "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.vo_dim), kv_dtype),
            }
        elif mixer == MIXER_MAMBA:
            st["mamba"] = M.init_mamba_state(cfg, batch, dtype)
        else:
            st["time"] = R.init_rwkv_state(cfg, batch, dtype)["time"]
        if mlp == MLP_RWKV:
            st["chan"] = {"last_x": jnp.zeros((batch, cfg.d_model), dtype)}
        return st

    states = []
    for mixer, mlp in cfg.pattern:
        base = one(mixer, mlp)
        stacked = jax.tree.map(
            lambda a: jnp.zeros((cfg.n_blocks,) + a.shape, a.dtype), base)
        states.append(stacked)
    return {"blocks": tuple(states), "index": jnp.zeros((), jnp.int32)}


def init_decode_state_paged(cfg: ArchConfig, batch: int, n_pages: int,
                            page_tokens: int) -> Params:
    """Decode state whose KV caches are PAGED: one global pool
    ``(n_pages + 1, page_tokens, KV, r)`` per attention layer (stacked
    over ``n_blocks``) instead of a dense per-slot ``(batch, max_len,
    KV, r)``.  Row ``n_pages`` is the spare garbage row that sentinel
    page-table entries address (padding / idle-slot writes land there).
    Recurrent (mamba/rwkv) leaves stay per-slot — they are O(1) in
    sequence length, so paging buys them nothing.  ``index`` is the
    (batch,) per-slot position vector; the (batch, n_p) page table is
    host-owned (serve.engine's ``PageAllocator``) and passed into each
    step alongside the state.
    """
    dense = init_decode_state(cfg, batch, 1)   # non-KV leaves + layout
    kv_dtype = _dtype(cfg.kv_cache_dtype or cfg.compute_dtype)

    def repage(path, leaf):
        names = [getattr(p, "key", None) for p in path]
        if "kv" not in names:
            return leaf
        r = leaf.shape[-1]          # qk or vo rank
        KV = leaf.shape[-2]
        return jnp.zeros((cfg.n_blocks, n_pages + 1, page_tokens, KV, r),
                         kv_dtype)

    blocks = jax.tree_util.tree_map_with_path(repage, dense["blocks"])
    return {"blocks": blocks, "index": jnp.zeros((batch,), jnp.int32)}


def _run_with_state(params, cfg, x, state, positions, pages=None,
                    write_floor=None, draft_rank=None, adapters=None):
    # ``adapters``: per-slot SV-adapter scales, one tree per pattern
    # position (leading n_blocks axis like params["blocks"]) or None —
    # they ride the layer scan as a third xs element (DESIGN.md §13).
    cache_index = state["index"]

    def block_fn(x, xs):
        block_params, block_state, block_ad = xs
        new_states = []
        for j, (mixer, mlp) in enumerate(cfg.pattern):
            x, ns, _ = _apply_layer(block_params[j], cfg, mixer, mlp, x,
                                    positions=positions, state=block_state[j],
                                    cache_index=cache_index, pages=pages,
                                    write_floor=write_floor,
                                    draft_rank=draft_rank,
                                    adapter=None if adapters is None
                                    else block_ad[j])
            new_states.append(ns)
        return x, tuple(new_states)

    if cfg.unroll_layers:
        new_stacked = []
        for i in range(cfg.n_blocks):
            bp = jax.tree.map(lambda a: a[i], params["blocks"])
            bs = jax.tree.map(lambda a: a[i], state["blocks"])
            ba = jax.tree.map(lambda a: a[i], adapters)
            x, ns = block_fn(x, (bp, bs, ba))
            new_stacked.append(ns)
        new_block_states = jax.tree.map(
            lambda *xs: jnp.stack(xs), *new_stacked)
    else:
        x, new_block_states = jax.lax.scan(
            block_fn, x, (params["blocks"], state["blocks"], adapters))
    # index is advanced by the caller (prefill / decode_step)
    return x, {"blocks": new_block_states, "index": cache_index}


def prefill(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
            state: Params, *,
            frontend_embeds: Optional[jnp.ndarray] = None,
            ) -> Tuple[jnp.ndarray, Params]:
    """Run the prompt through the model, filling caches/states.
    Returns (last-position logits (B, V), new_state)."""
    B = tokens.shape[0]
    F = frontend_embeds.shape[1] if frontend_embeds is not None else 0
    S = tokens.shape[1] + F
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = _embed(params, cfg, tokens, positions, frontend_embeds)
    x, new_state = _run_with_state(params, cfg, x, state, positions)
    new_state["index"] = state["index"] + S
    x = L.apply_norm(params["final_norm"], cfg, x[:, -1:])
    return _logits(params, cfg, x)[:, 0], new_state


def prefill_chunk(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
                  state: Params, lengths: jnp.ndarray,
                  pages: Optional[jnp.ndarray] = None,
                  write_floor: Optional[jnp.ndarray] = None,
                  adapters=None,
                  ) -> Tuple[jnp.ndarray, Params]:
    """Write one fixed-size prompt chunk per slot into the decode state.

    tokens:  (B, C) int32 — each slot's next window of prompt tokens,
             right-padded; positions past ``lengths[b]`` are padding.
    state:   decode state with a per-slot ``index`` vector (B,) — the
             number of tokens already written per slot; the window lands
             at positions [index, index + C) of each slot's caches.
    lengths: (B,) int32 in [0, C] — valid tokens per slot.  A slot with
             length 0 is idle this step; length 1 is exactly a decode
             step (the slot's last sampled token rides in column 0).

    Returns (logits (B, V) at each slot's LAST VALID position, new state
    with ``index`` advanced by ``lengths``).  Padding columns write
    garbage KV past each slot's valid region — always masked (causality
    against the per-slot index) or overwritten before becoming readable.
    Recurrent (mamba/rwkv) states advance over the FULL window including
    padding; callers with such states must only pass fully-valid windows
    (see serve.engine's scheduler) and merge inactive slots' states back.
    ``pages``: optional (B, n_p) page table for paged KV caches — the
    window then writes through the page indirection (see
    ``init_decode_state_paged``).  ``write_floor``: optional (B,) first
    WRITABLE position per slot — scatter-writes below it (a
    prefix-cached read-only region, serve.engine) are rerouted to the
    pool's garbage row; reads are unaffected.  ``adapters``: optional
    per-slot SV-adapter scale trees (see ``_run_with_state``).
    """
    B, C = tokens.shape
    idx = state["index"]                                   # (B,)
    positions = idx[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    x = _embed(params, cfg, tokens, positions, None)
    x, new_state = _run_with_state(params, cfg, x, state, positions,
                                   pages=pages, write_floor=write_floor,
                                   adapters=adapters)
    new_state["index"] = idx + lengths
    last = jnp.clip(lengths - 1, 0, C - 1)
    x = jnp.take_along_axis(x, last[:, None, None], axis=1)
    x = L.apply_norm(params["final_norm"], cfg, x)
    return _logits(params, cfg, x)[:, 0], new_state


def verify_chunk(params: Params, cfg: ArchConfig, tokens: jnp.ndarray,
                 state: Params, lengths: jnp.ndarray,
                 pages: Optional[jnp.ndarray] = None,
                 write_floor: Optional[jnp.ndarray] = None,
                 adapters=None,
                 ) -> Tuple[jnp.ndarray, Params]:
    """Multi-token VERIFY step for self-speculative decoding
    (DESIGN.md §8): run a (B, W) window of already-proposed tokens
    against the decode state — the same chunked-window attention path as
    ``prefill_chunk`` — but return logits at EVERY window position
    ``(B, W, V)``, so the caller can check each draft token against the
    full model's next-token argmax and roll back the rejected tail.

    tokens[b, 0] is slot b's pending (last sampled, not yet cached)
    token and tokens[b, 1:] its draft proposals; lengths in {0, W} (0 =
    idle slot riding along).  K/V for all W positions are written at
    full rank at [index, index + W) — overwriting whatever the draft
    pass left there — and ``index`` advances by ``lengths``; the caller
    rolls ``index`` back to the accepted prefix (dense and paged: a pure
    length decrement — stale K/V past the new index sits beyond every
    causal horizon until overwritten, the cache invariant every padded
    chunk write already relies on).  ``write_floor`` and ``adapters`` as
    in ``prefill_chunk``."""
    B, C = tokens.shape
    idx = state["index"]                                   # (B,)
    positions = idx[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    x = _embed(params, cfg, tokens, positions, None)
    x, new_state = _run_with_state(params, cfg, x, state, positions,
                                   pages=pages, write_floor=write_floor,
                                   adapters=adapters)
    new_state["index"] = idx + lengths
    x = L.apply_norm(params["final_norm"], cfg, x)
    return _logits(params, cfg, x), new_state


def decode_step(params: Params, cfg: ArchConfig, token: jnp.ndarray,
                state: Params,
                pages: Optional[jnp.ndarray] = None,
                write_floor: Optional[jnp.ndarray] = None,
                draft_rank: Optional[Tuple[int, int]] = None,
                adapters=None,
                ) -> Tuple[jnp.ndarray, Params]:
    """token: (B,) int32.  Returns (logits (B, V), new_state).

    state["index"] may be a scalar (lockstep decode) or a (B,) vector
    (per-slot positions, continuous batching).  ``pages``: optional
    (B, n_p) page table for paged KV caches.  ``write_floor`` and
    ``adapters`` as in ``prefill_chunk``.  ``draft_rank``: run the
    attention layers at the sliced (r_q, r_v) widths — the
    self-speculative DRAFT pass over the shared full-rank cache
    (DESIGN.md §8)."""
    B = token.shape[0]
    idx = state["index"]
    if jnp.ndim(idx) == 1:
        positions = idx[:, None].astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(idx[None, None], (B, 1)).astype(jnp.int32)
    x = _embed(params, cfg, token[:, None], positions, None)
    x, new_state = _run_with_state(params, cfg, x, state, positions,
                                   pages=pages, write_floor=write_floor,
                                   draft_rank=draft_rank, adapters=adapters)
    new_state["index"] = state["index"] + 1
    x = L.apply_norm(params["final_norm"], cfg, x)
    return _logits(params, cfg, x)[:, 0], new_state
