"""Mamba-1 block (selective SSM) for the jamba hybrid architecture.

Training path uses a *chunked* selective scan: ``lax.scan`` over chunks of
the sequence with the SSM state as carry; within a chunk the recurrence is
evaluated with an associative scan, and the chunk body is rematerialized
(``jax.checkpoint``) so backward memory stays chunk-local — the TPU
adaptation of the CUDA selective-scan recomputation trick.

Decode path carries (conv_state, ssm_state) and does an O(1) update.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

Params = Dict[str, Any]

CHUNK = 128


def init_mamba(key, cfg, dtype) -> Params:
    D = cfg.d_model
    dI, dS = cfg.mamba_d_inner, cfg.mamba_d_state
    dt_rank = cfg.mamba_dt_rank_
    dconv = cfg.mamba_d_conv
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A.
    A = jnp.tile(jnp.arange(1, dS + 1, dtype=jnp.float32)[None, :], (dI, 1))
    return {
        "in_proj": dense_init(ks[0], (D, 2 * dI), D, dtype),
        "conv_w": dense_init(ks[1], (dconv, dI), dconv, dtype),
        "conv_b": jnp.zeros((dI,), dtype),
        "x_proj": dense_init(ks[2], (dI, dt_rank + 2 * dS), dI, dtype),
        "dt_proj": dense_init(ks[3], (dt_rank, dI), dt_rank, dtype),
        "dt_bias": jnp.full((dI,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(A),
        "D": jnp.ones((dI,), jnp.float32),
        "out_proj": dense_init(ks[4], (dI, D), dI, dtype),
    }


def _ssm_chunk(h0, a, b, C):
    """One chunk of the selective scan.

    h0: (B, dI, dS) carry;  a: (B, c, dI, dS) decay = exp(dt*A);
    b: (B, c, dI, dS) input = dt*B_t*x_t;  C: (B, c, dS).
    Returns (h_end, y) with y: (B, c, dI).
    """
    def comb(lhs, r):
        al, bl = lhs
        ar, br = r
        return al * ar, bl * ar + br

    acc_a, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    # fold in the carry: h_t += (prod a up to t) * h0
    h = h + acc_a * h0[:, None]
    y = jnp.einsum("bcns,bcs->bcn", h, C)
    h_end = h[:, -1]
    return h_end, y


def _selective_scan(dt, A, Bmat, C, xin, h0, unroll=False):
    """dt, xin: (B, S, dI); A: (dI, dS); Bmat, C: (B, S, dS); h0: (B,dI,dS).

    The (chunk, dI, dS) decay/input tensors are built INSIDE the
    rematerialized chunk body — materializing them for the full sequence
    would be S/chunk times the memory (fatal at 32k x dI=8k x dS=16).
    ``unroll`` (exact-cost mode) uses one whole-sequence chunk instead so
    cost_analysis counts every flop (compile-only; never executed).
    """
    B, S, dI = xin.shape
    dS = A.shape[-1]
    if unroll:
        # exact-cost mode: python-unrolled, capped at 64 chunks (cost is
        # linear in chunk size so totals stay exact).
        chunk = S
        for cand in range(max(CHUNK, (S + 63) // 64), S + 1):
            if S % cand == 0:
                chunk = cand
                break
    else:
        chunk = CHUNK if S % CHUNK == 0 else S
    n_chunks = S // chunk

    def split(t):  # (B, S, ...) -> (n_chunks, B, chunk, ...)
        return jnp.moveaxis(
            t.reshape(B, n_chunks, chunk, *t.shape[2:]), 1, 0)

    def chunk_body(h, xs):
        dtc, bc_in, cc, xc = xs                   # (B,c,dI),(B,c,dS),...
        a = jnp.exp(dtc[..., None] * (-A)[None, None])      # (B,c,dI,dS)
        b = (dtc * xc)[..., None] * bc_in[:, :, None, :]    # (B,c,dI,dS)
        return _ssm_chunk(h, a, b, cc)

    def body(h, xs):
        h_end, y = jax.checkpoint(chunk_body)(h, xs)
        return h_end, y

    if unroll:
        h = h0
        ys = []
        xs_all = (split(dt), split(Bmat), split(C), split(xin))
        for i in range(n_chunks):
            h, y = chunk_body(h, tuple(t[i] for t in xs_all))
            ys.append(y)
        y = (jnp.concatenate(ys, axis=1) if n_chunks > 1
             else ys[0]).reshape(B, S, dI)
        return y, h

    h_end, ys = jax.lax.scan(
        body, h0, (split(dt), split(Bmat), split(C), split(xin)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, dI)
    return y, h_end


def mamba_forward(params: Params, cfg, x: jnp.ndarray, *,
                  state: Optional[Params] = None,
                  ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """x: (B, S, D).  state (decode): {"conv": (B, dconv-1, dI),
    "ssm": (B, dI, dS)}.  Full-sequence when state is None."""
    B, S, D = x.shape
    dI, dS = cfg.mamba_d_inner, cfg.mamba_d_state
    dt_rank = cfg.mamba_dt_rank_
    dconv = cfg.mamba_d_conv

    xz = x @ params["in_proj"].astype(x.dtype)               # (B,S,2dI)
    xin, z = jnp.split(xz, 2, axis=-1)

    # causal depthwise conv over seq
    conv_w = params["conv_w"].astype(x.dtype)                # (dconv, dI)
    if state is None:
        pad = jnp.zeros((B, dconv - 1, dI), x.dtype)
        new_conv = xin[:, S - (dconv - 1):, :] if S >= dconv - 1 else None
    else:
        pad = state["conv"].astype(x.dtype)
        window = jnp.concatenate([pad, xin], axis=1)
        new_conv = window[:, -(dconv - 1):, :]
    xp = jnp.concatenate([pad, xin], axis=1)                 # (B,S+dc-1,dI)
    idx = jnp.arange(S)[:, None] + jnp.arange(dconv)[None, :]
    xw = xp[:, idx, :]                                       # (B,S,dconv,dI)
    xc = jnp.einsum("bscn,cn->bsn", xw, conv_w) + params["conv_b"].astype(x.dtype)
    xc = jax.nn.silu(xc)

    proj = xc @ params["x_proj"].astype(x.dtype)             # (B,S,dtr+2dS)
    dt_lr, Bmat, C = jnp.split(proj, [dt_rank, dt_rank + dS], axis=-1)
    dt = jax.nn.softplus(
        dt_lr @ params["dt_proj"].astype(x.dtype)
        + params["dt_bias"].astype(x.dtype)).astype(jnp.float32)
    A = jnp.exp(params["A_log"].astype(jnp.float32))         # (dI,dS), positive

    xcf = xc.astype(jnp.float32)
    Bf, Cf = Bmat.astype(jnp.float32), C.astype(jnp.float32)

    if state is None:
        h0 = jnp.zeros((B, dI, dS), jnp.float32)
        from repro.models.layers import kernel_dispatch
        dispatch = kernel_dispatch(getattr(cfg, "kernel_impl", "xla"))
        if dispatch is not None:
            y, h_end = dispatch.mamba_scan(dt, A, Bf, Cf, xcf, h0)
        else:
            y, h_end = _selective_scan(dt, A, Bf, Cf, xcf, h0,
                                       unroll=getattr(cfg, "unroll_layers",
                                                      False))
        new_state = None
    else:
        # single-step (S small, typically 1): plain recurrence
        h = state["ssm"].astype(jnp.float32)
        a = jnp.exp(dt[..., None] * (-A)[None, None])
        b = (dt * xcf)[..., None] * Bf[:, :, None, :]

        def step(hc, xs):
            at, bt, ct = xs
            hc = at * hc + bt
            return hc, jnp.einsum("bns,bs->bn", hc, ct)

        h_end, ys = jax.lax.scan(
            step, h,
            (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0), jnp.moveaxis(Cf, 1, 0)))
        y = jnp.moveaxis(ys, 0, 1)
        new_state = {"conv": new_conv.astype(x.dtype), "ssm": h_end}

    y = y.astype(x.dtype) + xc * params["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ params["out_proj"].astype(x.dtype), new_state


def init_mamba_state(cfg, batch: int, dtype) -> Params:
    return {
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, cfg.mamba_d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.mamba_d_inner, cfg.mamba_d_state), jnp.float32),
    }
