"""RWKV-6 (Finch) blocks: time-mix with data-dependent per-channel decay and
channel-mix FFN.

wkv recurrence per head (d = rwkv_head_dim):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T          S in R^{d x d}
    o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
Training uses the chunked parallel form (linear-attention style) with
log-space cumulative decays; decode carries (last_x, last_x_ffn, S).

Simplifications vs the reference implementation (documented in DESIGN.md):
token-shift mixing coefficients for r/k/v/g are static per-channel (RWKV-6
makes them data-dependent via a small LoRA); the decay w keeps its full
data-dependent LoRA form, which is the part that matters for the recurrence.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

Params = Dict[str, Any]

W_LORA = 64
CHUNK = 32


def init_rwkv_timemix(key, cfg, dtype) -> Params:
    D = cfg.d_model
    H, dh = cfg.rwkv_n_heads, cfg.rwkv_head_dim
    ks = jax.random.split(key, 8)
    return {
        "mix_r": jnp.full((D,), 0.5, dtype),
        "mix_k": jnp.full((D,), 0.5, dtype),
        "mix_v": jnp.full((D,), 0.5, dtype),
        "mix_g": jnp.full((D,), 0.5, dtype),
        "mix_w": jnp.full((D,), 0.5, dtype),
        "wr": dense_init(ks[0], (D, D), D, dtype),
        "wk": dense_init(ks[1], (D, D), D, dtype),
        "wv": dense_init(ks[2], (D, D), D, dtype),
        "wg": dense_init(ks[3], (D, D), D, dtype),
        "w0": jnp.full((D,), -2.0, jnp.float32),       # decay bias
        "w_lora_a": dense_init(ks[4], (D, W_LORA), D, dtype),
        "w_lora_b": (jax.random.normal(ks[5], (W_LORA, D)) * 0.01).astype(dtype),
        "u": (jax.random.normal(ks[6], (H, dh)) * 0.1).astype(jnp.float32),
        "out": dense_init(ks[7], (D, D), D, dtype),
        "ln_x_scale": jnp.ones((D,), dtype),
        "ln_x_bias": jnp.zeros((D,), dtype),
    }


def init_rwkv_chanmix(key, cfg, dtype) -> Params:
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mix_k": jnp.full((D,), 0.5, dtype),
        "mix_r": jnp.full((D,), 0.5, dtype),
        "wk": dense_init(ks[0], (D, F), D, dtype),
        "wv": dense_init(ks[1], (F, D), F, dtype),
        "wr": dense_init(ks[2], (D, D), D, dtype),
    }


def _token_shift(x: jnp.ndarray, last: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Shift sequence right by one; position 0 sees `last` (or zeros)."""
    if last is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = last[:, None, :].astype(x.dtype)
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _mix(x, xs, coef):
    c = coef.astype(x.dtype)
    return x * c + xs * (1.0 - c)


def _group_norm(x, scale, bias, n_groups, eps=1e-5):
    """x: (B, S, D) grouped into n_groups along D (RWKV head-wise LN)."""
    B, S, D = x.shape
    xg = x.reshape(B, S, n_groups, D // n_groups).astype(jnp.float32)
    mu = jnp.mean(xg, axis=-1, keepdims=True)
    var = jnp.var(xg, axis=-1, keepdims=True)
    xn = (xg - mu) * jax.lax.rsqrt(var + eps)
    xn = xn.reshape(B, S, D)
    return (xn * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def wkv6_chunked(r, k, v, logw, u, S0, unroll=False):
    """Chunked parallel wkv6.

    r,k,v: (B, H, T, d);  logw: (B, H, T, d) = log decay in (-inf, 0);
    u: (H, d) bonus;  S0: (B, H, d, d) initial state (k-dim x v-dim).
    Returns (out (B,H,T,d), S_end).  ``unroll`` = exact-cost mode: one
    whole-sequence chunk (compile-only; see transformer.unroll_layers).
    """
    B, H, T, d = r.shape
    if unroll:
        # exact-cost mode: python-unrolled, capped at 64 chunks (chunk
        # grows for long T; the c^2 intra-chunk term then overstates
        # deployed flops — noted in EXPERIMENTS.md §Roofline).
        c = T
        for cand in range(max(CHUNK, (T + 63) // 64), T + 1):
            if T % cand == 0:
                c = cand
                break
    else:
        c = CHUNK if T % CHUNK == 0 else T
    n = T // c
    rc = r.reshape(B, H, n, c, d)
    kc = k.reshape(B, H, n, c, d)
    vc = v.reshape(B, H, n, c, d)
    lwc = logw.reshape(B, H, n, c, d)

    def chunk_body(S, xs):
        rb, kb, vb, lw = xs                      # (B,H,c,d)
        cum = jnp.cumsum(lw, axis=2)             # inclusive logdecay (<=0, dec.)
        # within-chunk scores via pairwise log-space differences:
        # cum_t - cum_s <= 0 for t > s, so exp() never overflows.
        ri = rb * jnp.exp(cum)                   # exp(cum) <= 1, safe
        tri = jnp.tril(jnp.ones((c, c), bool), k=-1)
        ldiff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,H,t,s,d)
        ldiff = jnp.where(tri[None, None, :, :, None], ldiff, -jnp.inf)
        scores = jnp.einsum("bhtd,bhtsd,bhsd->bhts",
                            rb, jnp.exp(ldiff), kb)
        scores = jnp.where(tri[None, None], scores, 0.0)
        # diagonal bonus term: r_t . (u * k_t)
        diag = jnp.einsum("bhtd,bhtd->bht", rb, u[None, :, None, :] * kb)
        out = jnp.einsum("bhts,bhsd->bhtd", scores, vb) + diag[..., None] * vb
        # cross-chunk: r_t . (exp(cum_t) * S)
        out = out + jnp.einsum("bhtd,bhde->bhte", ri, S)
        # state update: S' = diag(exp(cum_end)) S + sum_s exp(cum_end-cum_s) k_s v_s^T
        cend = cum[:, :, -1:, :]
        kd = kb * jnp.exp(cend - cum)
        S = jnp.exp(cend[:, :, 0, :])[..., None] * S + jnp.einsum(
            "bhsd,bhse->bhde", kd, vb)
        return S, out

    def body(S, xs):
        return jax.checkpoint(chunk_body)(S, xs)

    if unroll:
        S = S0
        outs = []
        for i in range(n):
            S, o = chunk_body(
                S, (rc[:, :, i], kc[:, :, i], vc[:, :, i], lwc[:, :, i]))
            outs.append(o)
        out = jnp.concatenate(outs, axis=2) if n > 1 else outs[0]
        return out.reshape(B, H, T, d), S

    S_end, outs = jax.lax.scan(
        body, S0,
        (jnp.moveaxis(rc, 2, 0), jnp.moveaxis(kc, 2, 0),
         jnp.moveaxis(vc, 2, 0), jnp.moveaxis(lwc, 2, 0)))
    out = jnp.moveaxis(outs, 0, 2).reshape(B, H, T, d)
    return out, S_end


def rwkv_timemix_forward(params: Params, cfg, x: jnp.ndarray, *,
                         state: Optional[Params] = None,
                         ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """x: (B, S, D).  state (decode): {"last_x": (B,D), "wkv": (B,H,d,d)}."""
    B, T, D = x.shape
    H, dh = cfg.rwkv_n_heads, cfg.rwkv_head_dim

    last_x = state["last_x"] if state is not None else None
    xs = _token_shift(x, last_x)
    xr = _mix(x, xs, params["mix_r"])
    xk = _mix(x, xs, params["mix_k"])
    xv = _mix(x, xs, params["mix_v"])
    xg = _mix(x, xs, params["mix_g"])
    xw = _mix(x, xs, params["mix_w"])

    r = xr @ params["wr"].astype(x.dtype)
    k = xk @ params["wk"].astype(x.dtype)
    v = xv @ params["wv"].astype(x.dtype)
    g = jax.nn.silu(xg @ params["wg"].astype(x.dtype))

    # data-dependent decay (f32): logw = -exp(w0 + lora(xw)) in (-inf, 0)
    lora = jnp.tanh(xw @ params["w_lora_a"].astype(x.dtype)) @ \
        params["w_lora_b"].astype(x.dtype)
    logw = -jnp.exp(params["w0"].astype(jnp.float32)
                    + lora.astype(jnp.float32))              # (B,T,D)
    logw = jnp.clip(logw, -10.0, -1e-4)

    def heads(t):  # (B,T,D) -> (B,H,T,dh)
        return jnp.moveaxis(t.reshape(B, T, H, dh), 2, 1)

    rf, kf, vf = (heads(t.astype(jnp.float32)) for t in (r, k, v))
    lwf = heads(logw)
    u = params["u"].astype(jnp.float32)

    S0 = (state["wkv"].astype(jnp.float32) if state is not None
          else jnp.zeros((B, H, dh, dh), jnp.float32))
    from repro.models.layers import kernel_dispatch
    dispatch = kernel_dispatch(getattr(cfg, "kernel_impl", "xla"))
    if dispatch is not None:
        out, S_end = dispatch.wkv6(rf, kf, vf, lwf, u, S0)
    else:
        out, S_end = wkv6_chunked(rf, kf, vf, lwf, u, S0,
                                  unroll=getattr(cfg, "unroll_layers",
                                                 False))
    out = jnp.moveaxis(out, 1, 2).reshape(B, T, D).astype(x.dtype)

    out = _group_norm(out, params["ln_x_scale"], params["ln_x_bias"], H)
    out = out * g
    y = out @ params["out"].astype(x.dtype)

    new_state = None
    if state is not None:
        new_state = {"last_x": x[:, -1, :], "wkv": S_end}
    return y, new_state


def rwkv_chanmix_forward(params: Params, cfg, x: jnp.ndarray, *,
                         state: Optional[Params] = None,
                         ) -> Tuple[jnp.ndarray, Optional[Params]]:
    last_x = state["last_x"] if state is not None else None
    xs = _token_shift(x, last_x)
    xk = _mix(x, xs, params["mix_k"])
    xr = _mix(x, xs, params["mix_r"])
    if "up_u" in params:  # CLOVER blockwise-decomposed key projection
        h = jnp.einsum("bsd,dnr->bsnr", xk, params["up_u"].astype(x.dtype))
        h = jnp.einsum("bsnr,nrk->bsnk", h, params["up_t"].astype(x.dtype))
        kk = h.reshape(*xk.shape[:-1], -1)
    else:
        kk = xk @ params["wk"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(kk))
    out = jax.nn.sigmoid(xr @ params["wr"].astype(x.dtype)) * (
        k @ params["wv"].astype(x.dtype))
    new_state = {"last_x": x[:, -1, :]} if state is not None else None
    return out, new_state


def init_rwkv_state(cfg, batch: int, dtype) -> Params:
    H, dh = cfg.rwkv_n_heads, cfg.rwkv_head_dim
    return {
        "time": {"last_x": jnp.zeros((batch, cfg.d_model), dtype),
                 "wkv": jnp.zeros((batch, H, dh, dh), jnp.float32)},
        "chan": {"last_x": jnp.zeros((batch, cfg.d_model), dtype)},
    }
