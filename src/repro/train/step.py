"""jit-compiled train / serve steps with explicit shardings.

``make_train_step`` returns a jitted function
    (params, opt_state, batch) -> (params, opt_state, metrics)
with in/out shardings derived from ``repro.parallel.sharding`` and
params+opt donated.  Remat wraps each scanned block (memory ~ one block's
activations instead of n_layers).

``make_serve_step`` returns jitted prefill / decode entry points over a
sharded decode state (KV cache at CLOVER ranks, sequence-sharded on the
"model" axis for long caches).

CLOVER-S PEFT training reuses the same step: ``peft_mode=True`` splits
params via ``peft.partition`` and differentiates the trainable half only.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine
from repro.core import peft as peft_lib
from repro.parallel import sharding as sh

Params = Dict[str, Any]


@dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    warmup_steps: int = 100
    total_steps: int = 10000
    remat: bool = True
    peft_mode: bool = False         # differentiate CLOVER-S keys only
    grad_compress: bool = False     # int8 error-feedback on the pod axis
    # Gradient accumulation: the global batch is split into this many
    # microbatches (python-unrolled so cost_analysis sees every copy);
    # peak activation memory scales 1/microbatches while the f32 grad
    # accumulator is param-sized (sharded).  The production answer to
    # fitting 14B-52B train steps in 16GB/chip.
    microbatches: int = 1


def loss_fn(params: Params, cfg: ArchConfig, tokens, labels, *,
            frontend_embeds=None, remat: bool = True):
    """Causal-LM cross entropy (+ MoE aux losses), mean over tokens.

    labels < 0 are masked (padding)."""
    logits, aux = T.forward(params, cfg, tokens,
                            frontend_embeds=frontend_embeds, remat=remat)
    # frontend positions carry no labels
    S_tok = tokens.shape[1]
    logits = logits[:, -S_tok:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    mask = (labels >= 0)
    safe = jnp.maximum(labels, 0)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1)
    ce = jnp.sum(nll * mask) / denom
    total = ce + sum(aux.values())
    return total, {"loss": ce, **aux}


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig, mesh: Mesh,
                    rules: Optional[sh.ShardingRules] = None,
                    donate: bool = True) -> Callable:
    """Build the jitted, sharded train step for ``cfg`` on ``mesh``."""
    rules = rules or sh.ShardingRules()

    def grad_of(params_like, batch_slice, grad_fn):
        tokens, labels = batch_slice["tokens"], batch_slice["labels"]
        fe = batch_slice.get("frontend_embeds")
        return grad_fn(params_like, tokens, labels, fe)

    def step(params, opt_state, batch):
        if tcfg.peft_mode:
            trainable, frozen = peft_lib.partition(params)

            def grad_fn(tr, tokens, labels, fe):
                def peft_loss(tr):
                    full = peft_lib.combine(tr, frozen)
                    return loss_fn(full, cfg, tokens, labels,
                                   frontend_embeds=fe, remat=tcfg.remat)
                return jax.value_and_grad(peft_loss, has_aux=True)(tr)
            opt_params = trainable
        else:
            def grad_fn(p, tokens, labels, fe):
                return jax.value_and_grad(loss_fn, has_aux=True)(
                    p, cfg, tokens, labels, frontend_embeds=fe,
                    remat=tcfg.remat)
            opt_params = params

        m = max(1, tcfg.microbatches)
        if m == 1:
            (_, metrics), grads = grad_of(opt_params, batch, grad_fn)
        else:
            # lax.scan accumulation in f32 (sharded, param-sized carry):
            # scan forces microbatches to SEQUENCE, so peak activation
            # memory is one microbatch's, not the sum.
            B = batch["tokens"].shape[0]
            assert B % m == 0, (B, m)
            mb = B // m
            stacked = {k: v.reshape((m, mb) + v.shape[1:])
                       for k, v in batch.items()}
            is_none = lambda x: x is None  # noqa: E731
            g0 = jax.tree.map(
                lambda p: None if p is None
                else jnp.zeros(p.shape, jnp.float32), opt_params,
                is_leaf=is_none)

            def micro(carry, sl):
                acc, met_acc = carry
                (_, met), g = grad_of(opt_params, sl, grad_fn)
                acc = jax.tree.map(
                    lambda a, t: None if a is None
                    else a + t.astype(jnp.float32) / m, acc, g,
                    is_leaf=is_none)
                met_acc = {k: met_acc[k] + met[k] / m for k in met_acc}
                return (acc, met_acc), None

            met0 = {"loss": jnp.zeros((), jnp.float32),
                    "moe_load_balance": jnp.zeros((), jnp.float32),
                    "moe_router_z": jnp.zeros((), jnp.float32)}
            (grads, metrics), _ = jax.lax.scan(micro, (g0, met0), stacked)

        if tcfg.grad_compress and "pod" in mesh.shape:
            from repro.parallel.compress import compress_cross_pod
            grads = compress_cross_pod(grads, mesh)

        lr_scale = warmup_cosine(opt_state["step"],
                                 warmup=tcfg.warmup_steps,
                                 total=tcfg.total_steps)
        new_opt_params, new_opt, gnorm = adamw_update(
            grads, opt_state, opt_params, tcfg.optimizer, lr_scale)

        if tcfg.peft_mode:
            new_params = peft_lib.combine(new_opt_params, frozen)
        else:
            new_params = new_opt_params
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr_scale"] = jnp.asarray(lr_scale, jnp.float32)
        return new_params, new_opt, metrics

    def specs_for(params, opt_state):
        pspec = sh.param_specs(params, mesh, rules)
        if tcfg.peft_mode:
            # moments exist only for the trainable half; same layout
            mspec = jax.tree.map(lambda s: s, pspec)
        else:
            mspec = pspec
        ospec = {"m": mspec, "v": mspec, "step": P()}
        return pspec, ospec

    def compile_step(params_shape, opt_shape, batch_shape):
        pspec, ospec = specs_for(params_shape, opt_shape)
        dspec = sh.data_specs(mesh, rules)
        bspec = {k: dspec if k in ("tokens", "labels")
                 else P(rules.mesh_axes(sh.BATCH, mesh), None, None)
                 for k in batch_shape}
        jitted = jax.jit(
            step,
            in_shardings=(sh.shardings(pspec, mesh),
                          sh.shardings(ospec, mesh),
                          sh.shardings(bspec, mesh)),
            out_shardings=(sh.shardings(pspec, mesh),
                           sh.shardings(ospec, mesh), None),
            donate_argnums=(0, 1) if donate else ())
        return jitted

    return step, compile_step


def make_opt_state(params: Params, peft_mode: bool = False) -> Params:
    if peft_mode:
        trainable, _ = peft_lib.partition(params)
        return adamw_init(trainable)
    return adamw_init(params)


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------

def make_serve_step(cfg: ArchConfig, mesh: Mesh,
                    rules: Optional[sh.ShardingRules] = None):
    """(prefill_fn, decode_fn) jitted with sharded decode state."""
    rules = rules or sh.ShardingRules()

    def prefill_step(params, tokens, state, frontend_embeds=None):
        return T.prefill(params, cfg, tokens, state,
                         frontend_embeds=frontend_embeds)

    def decode_fn(params, token, state):
        return T.decode_step(params, cfg, token, state)

    def compile_serve(params_shape, state_shape, batch: int, prompt: int):
        pspec = sh.param_specs(params_shape, mesh, rules)
        sspec = sh.decode_state_specs(state_shape, mesh, rules)
        b = rules.mesh_axes(sh.BATCH, mesh)
        p_sh = sh.shardings(pspec, mesh)
        s_sh = sh.shardings(sspec, mesh)
        tok2 = NamedSharding(mesh, P(b, None))
        tok1 = NamedSharding(mesh, P(b))
        logits = NamedSharding(mesh, P(b, None))
        prefill_j = jax.jit(
            prefill_step,
            in_shardings=(p_sh, tok2, s_sh),
            out_shardings=(logits, s_sh),
            donate_argnums=(2,))
        decode_j = jax.jit(
            decode_fn,
            in_shardings=(p_sh, tok1, s_sh),
            out_shardings=(logits, s_sh),
            donate_argnums=(2,))
        return prefill_j, decode_j

    return prefill_step, decode_fn, compile_serve
