"""Checkpointing: atomic, async, keep-k, elastic-reshard on restore.

Layout (one directory per step):
    <root>/step_000420.tmp/...   while writing
    <root>/step_000420/          after atomic rename (commit point)
        manifest.json            tree structure + shapes + dtypes + meta
        arr_00000.npy ...        leaves in tree-flatten order

Writes happen on a daemon thread (training continues); ``wait()`` joins
before the next save or at shutdown.  Restore maps any saved layout onto
any mesh: leaves are loaded as full host arrays and device_put with the
TARGET mesh's shardings — this is the elastic path (checkpoint from a
(16,16) run restores onto (2,16,16), (4,4), or 1 device unchanged).

At 1000+ nodes the same protocol holds with per-host shard files +
a commit marker written by host 0 after a barrier; the single-process
writer here keeps the identical manifest/commit contract (DESIGN.md §7).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

Params = Dict[str, Any]

_STEP_RE = re.compile(r"^step_(\d{9})$")


class _RawView:
    """A numpy-unsupported dtype (bfloat16) stored as a raw uint16 view
    with the jax dtype name recorded for lossless restore."""
    def __init__(self, raw: np.ndarray, dtype_name: str):
        self.raw = raw
        self.dtype_name = dtype_name

    @property
    def dtype(self):
        return self.dtype_name

    @property
    def shape(self):
        return self.raw.shape


def _tree_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: x is None)[0]
    return [(jax.tree_util.keystr(p), leaf) for p, leaf in flat]


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3, async_write: bool = True):
        self.root = root
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    # -- save -------------------------------------------------------------
    def save(self, step: int, tree: Params, extra: Optional[Dict] = None):
        """Snapshot to host memory NOW, write (possibly async), rename."""
        self.wait()

        def to_host(x):
            if x is None:
                return None
            a = np.asarray(x)
            if a.dtype.kind == "V":  # bfloat16 etc: store raw 16-bit view
                return _RawView(a.view(np.uint16), str(x.dtype))
            return a
        host = jax.tree.map(to_host, tree, is_leaf=lambda x: x is None)
        extra = dict(extra or {})

        def write():
            name = f"step_{step:09d}"
            tmp = os.path.join(self.root, name + ".tmp")
            final = os.path.join(self.root, name)
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            leaves = _tree_paths(host)
            manifest = {
                "step": step,
                "extra": extra,
                "leaves": [
                    {"path": p,
                     "dtype": (None if a is None else str(a.dtype)),
                     "shape": (None if a is None else list(a.shape))}
                    for p, a in leaves],
                "treedef": jax.tree_util.tree_structure(
                    host, is_leaf=lambda x: x is None).__repr__(),
            }
            for i, (p, a) in enumerate(leaves):
                if a is None:
                    continue
                raw = a.raw if isinstance(a, _RawView) else a
                np.save(os.path.join(tmp, f"arr_{i:05d}.npy"), raw)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, final)       # atomic commit
            self._gc()

        if self.async_write:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:09d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for n in os.listdir(self.root):
            m = _STEP_RE.match(n)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.all_steps()
        return s[-1] if s else None

    def restore(self, step: int, like: Params,
                shardings: Optional[Params] = None,
                ) -> Tuple[Params, Dict]:
        """Load step into the structure of ``like``; if ``shardings`` is
        given (tree of NamedSharding on the TARGET mesh), leaves are
        device_put sharded — the elastic-reshard path."""
        d = os.path.join(self.root, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat_like = _tree_paths(like)
        saved = {e["path"]: i for i, e in enumerate(manifest["leaves"])}
        leaves = []
        sh_flat = (None if shardings is None else
                   [leaf for _, leaf in _tree_paths(shardings)])
        for j, (p, leaf) in enumerate(flat_like):
            if leaf is None:
                leaves.append(None)
                continue
            assert p in saved, f"checkpoint missing leaf {p}"
            arr = np.load(os.path.join(d, f"arr_{saved[p]:05d}.npy"))
            dt = manifest["leaves"][saved[p]]["dtype"]
            if arr.dtype == np.uint16 and dt == "bfloat16":
                arr = arr.view(jax.numpy.bfloat16.dtype)
            assert tuple(arr.shape) == tuple(leaf.shape), \
                f"{p}: saved {arr.shape} != expected {leaf.shape}"
            if sh_flat is not None and sh_flat[j] is not None:
                leaves.append(jax.device_put(arr, sh_flat[j]))
            else:
                leaves.append(jax.device_put(arr))
        treedef = jax.tree_util.tree_structure(
            like, is_leaf=lambda x: x is None)
        return (jax.tree_util.tree_unflatten(treedef, leaves),
                manifest["extra"])
