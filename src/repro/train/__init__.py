"""Training substrate: step functions, checkpointing, fault tolerance."""
from repro.train.step import (  # noqa: F401
    TrainConfig, make_train_step, make_serve_step, loss_fn)
