"""Fault-tolerance supervisor: restart-on-failure, straggler watchdog.

``Supervisor.run`` drives the training loop with:

  * periodic checkpoints (async, atomic — see checkpoint.py);
  * restart-on-failure: a step raising ``WorkerFailure`` (tests inject
    it; on real clusters a missing-heartbeat callback raises it) rolls
    back to the last committed checkpoint and replays — the data stream
    is counter-addressed so replay is bit-exact;
  * straggler watchdog: per-step wall time tracked with a running
    mean/variance (Welford); steps slower than mu + k*sigma are recorded
    and surfaced to the caller (on a real cluster this feeds the
    reshard/evict decision);
  * elastic restarts: ``restore`` maps the checkpoint onto whatever mesh
    the new incarnation runs with (checkpoint.py's reshard path).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.train.checkpoint import CheckpointManager


class WorkerFailure(RuntimeError):
    """A (simulated) worker failure: node loss, preemption, hang."""


@dataclass
class StragglerStats:
    n: int = 0
    mean: float = 0.0
    m2: float = 0.0
    flagged: List[Dict[str, float]] = field(default_factory=list)

    def update(self, step: int, dt: float, k: float = 3.0) -> bool:
        # Welford running moments; flag AFTER a warmup of 8 steps
        self.n += 1
        d = dt - self.mean
        self.mean += d / self.n
        self.m2 += d * (dt - self.mean)
        if self.n >= 8:
            sigma = math.sqrt(self.m2 / max(1, self.n - 1))
            if dt > self.mean + k * sigma and sigma > 0:
                self.flagged.append({"step": step, "dt": dt,
                                     "mean": self.mean, "sigma": sigma})
                return True
        return False


@dataclass
class SupervisorReport:
    steps_run: int = 0
    restarts: int = 0
    stragglers: List[Dict[str, float]] = field(default_factory=list)
    final_step: int = 0
    metrics_history: List[Dict[str, float]] = field(default_factory=list)


class Supervisor:
    def __init__(self, ckpt: CheckpointManager, *,
                 ckpt_every: int = 50,
                 max_restarts: int = 8,
                 straggler_k: float = 3.0):
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.straggler_k = straggler_k

    def run(self, *,
            state: Dict[str, Any],
            step_fn: Callable[[Dict[str, Any], int], Dict[str, Any]],
            save_tree: Callable[[Dict[str, Any]], Any],
            restore_tree: Callable[[Any, Dict], Dict[str, Any]],
            start_step: int,
            total_steps: int,
            metrics_cb: Optional[Callable[[int, Dict], None]] = None,
            ) -> SupervisorReport:
        """Run to ``total_steps`` with checkpoint/restart.

        state: opaque mutable training state (params/opt/data-iter...).
        step_fn(state, step) -> (state, metrics); may raise WorkerFailure.
        save_tree(state) -> (tree, extra) for the checkpointer.
        restore_tree(tree, extra) -> state after a rollback.
        """
        rep = SupervisorReport()
        stats = StragglerStats()
        step = start_step
        restarts = 0
        while step < total_steps:
            try:
                t0 = time.perf_counter()
                state, metrics = step_fn(state, step)
                dt = time.perf_counter() - t0
                if stats.update(step, dt, self.straggler_k):
                    rep.stragglers.append(stats.flagged[-1])
                if metrics_cb:
                    metrics_cb(step, metrics)
                rep.metrics_history.append(
                    {k: float(v) for k, v in metrics.items()})
                rep.steps_run += 1
                step += 1
                if step % self.ckpt_every == 0 or step == total_steps:
                    tree, extra = save_tree(state)
                    extra = dict(extra, step=step)
                    self.ckpt.save(step, tree, extra)
            except WorkerFailure:
                restarts += 1
                rep.restarts += 1
                if restarts > self.max_restarts:
                    raise
                self.ckpt.wait()
                last = self.ckpt.latest_step()
                if last is None:       # no checkpoint yet: replay from 0
                    step = start_step
                    continue
                tree, extra = save_tree(state)  # structure template
                restored, rextra = self.ckpt.restore(last, tree)
                state = restore_tree(restored, rextra)
                step = int(rextra.get("step", last))
        self.ckpt.wait()
        rep.final_step = step
        return rep
