"""Quickstart: CLOVER in five acts on a laptop-sized model.

  1. build a model (any assigned arch at reduced size)
  2. CLOVER-decompose  -> function preserved bit-near-exactly
  3. prune 50% of Q-K / V-O directions -> KV cache halves
  4. fine-tune ONLY the singular-value matrices (CLOVER-S PEFT)
  5. merge back -> same architecture, zero inference overhead

Run:  PYTHONPATH=src python examples/quickstart.py [--arch musicgen-large]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import clover_decompose, clover_prune, merge_clover
from repro.core.peft import count_params, partition
from repro.data import SyntheticConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import forward, init_decode_state, init_lm_params
from repro.optim import AdamWConfig
from repro.train.step import TrainConfig, make_opt_state, make_train_step


def train(params, cfg, data, *, steps, lr, peft_mode=False):
    mesh = make_host_mesh()
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=lr, weight_decay=0.0),
                       warmup_steps=2, total_steps=steps, remat=False,
                       peft_mode=peft_mode)
    step, _ = make_train_step(cfg, tcfg, mesh)
    opt = make_opt_state(params, peft_mode=peft_mode)
    jstep = jax.jit(step)
    losses = []
    for i in range(steps):
        b = data.batch_at(i)
        params, opt, m = jstep(
            params, opt, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="musicgen-large")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_lm_params(cfg, key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    fe = (jax.random.normal(key, (2, cfg.frontend_len, cfg.d_model)) * 0.02
          if cfg.frontend != "none" else None)
    base, _ = forward(params, cfg, toks, frontend_embeds=fe)

    # -- act 2: decompose ---------------------------------------------------
    dparams, dcfg, extras = clover_decompose(params, cfg, peft=False)
    out, _ = forward(dparams, dcfg, toks, frontend_embeds=fe)
    err = float(jnp.max(jnp.abs(out - base)))
    print(f"[2] decomposed: max |Δlogits| = {err:.2e}  (function preserved)")

    # -- act 3: prune ---------------------------------------------------------
    pparams, pcfg = clover_prune(dparams, dcfg, qk_ratio=0.5, vo_ratio=0.5)
    st_full = init_decode_state(cfg, 1, 128)
    st_pruned = init_decode_state(pcfg, 1, 128)
    nbytes = lambda st: sum(a.nbytes for a in jax.tree.leaves(st))  # noqa
    print(f"[3] pruned 50%: KV-cache bytes {nbytes(st_full):,} -> "
          f"{nbytes(st_pruned):,}")

    # -- act 4: CLOVER-S fine-tune -------------------------------------------
    ft_params, ft_cfg, _ = clover_decompose(params, cfg, peft=True)
    trainable, _ = partition(ft_params)
    print(f"[4] CLOVER-S trainables: {count_params(trainable):,} of "
          f"{count_params(ft_params):,} params "
          f"({100 * count_params(trainable) / count_params(ft_params):.2f}%)")
    data = SyntheticLM(SyntheticConfig(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=4))
    ft_params, losses = train(ft_params, ft_cfg, data, steps=20, lr=5e-3,
                              peft_mode=True)
    print(f"    loss {losses[0]:.3f} -> {losses[-1]:.3f} in 20 steps")

    # -- act 5: merge back ------------------------------------------------------
    merged, mcfg = merge_clover(ft_params, ft_cfg)
    tuned, _ = forward(ft_params, ft_cfg, toks, frontend_embeds=fe)
    after, _ = forward(merged, mcfg, toks, frontend_embeds=fe)
    err = float(jnp.max(jnp.abs(after - tuned)))
    n_leaves_before = len(jax.tree.leaves(params))
    n_leaves_after = len(jax.tree.leaves(merged))
    print(f"[5] merged: max |Δlogits| = {err:.2e}; param tree "
          f"{n_leaves_before} leaves -> {n_leaves_after} (no adapters left)")


if __name__ == "__main__":
    main()
