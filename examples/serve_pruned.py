"""Serving example: chunked-prefill continuous batching over a
CLOVER-pruned model.

Builds a reduced model, CLOVER-prunes 50% of every head (KV cache
halves), then serves a mixed batch of requests with different prompt
lengths and arrival times.  Prompts are consumed in fixed-size chunks
interleaved with decoding, so the whole mixed-length batch compiles
exactly two step shapes; each stream is verified against its isolated
greedy reference.  The same trace is then replayed on the PAGED engine
(global page pool + page tables, admission gated on free pages,
preemption on exhaustion) and must produce identical streams — and,
with ``--spec-k`` > 0, replayed once more with SELF-SPECULATIVE
decoding (a rank-sliced draft of the same weights proposes tokens, one
multi-token verify step commits a greedy prefix; DESIGN.md §8), again
token-identical.  Finally a shared-system-prompt batch runs twice on a
PREFIX-CACHED paged engine (DESIGN.md §9): the warm replay maps the
cached prompt pages read-only, skips their prefill chunks and still
matches the cold streams exactly.  With ``--host-pages N`` the shared
batch also runs against a HIERARCHICAL KV engine (DESIGN.md §12): a
host-RAM spill tier under the trie catches the pages cache pressure
evicts, and the replay restores the prefix from host RAM through one
fixed-width scatter instead of re-prefilling — the demo prints the
spill/restore counters and host hit rate from ``Engine.stats()``.

With ``--tp N`` the paged trace is replayed once more through the
rank-balanced ShardedExecutor (DESIGN.md §10): params and KV page
pools shard along heads over a ("data", "model") host mesh, the
head -> shard assignment planned so every shard carries ~equal pruned
FLOPs/bytes, and the streams must again be token-identical.  The
replay prints ``Engine.exe.kernel_report()`` — which kernel impl each
compiled entry (decode step, prefill chunk, draft/verify, page copy)
ACTUALLY used, e.g. ``interpret+shard_map(model=2)`` when the Pallas
hot path compiled per shard; ``--kernel-impl`` overrides the dispatch
(``ref | xla | pallas | interpret``).

With ``--rank-budget F`` the demo plans a NON-UNIFORM prune
(DESIGN.md §14): ``plan_rank_budget`` water-fills ``F`` of the model's
total rank capacity across layers/heads by singular-value energy,
prints every layer's kept per-head ranks and the analytic pool bytes
(``rank_pool_bytes``: kept vs max-width-allocated), then serves the
plan — ragged ranks as zero-padding plus the decode kernels' per-head
rank clamp — and verifies each stream against its greedy reference.

With ``--adapters N`` the demo also serves a MULTI-TENANT batch
(DESIGN.md §13): one base model plus ``N`` registered SV adapters —
per-tenant multiplicative scalings of the CLOVER singular values that
the attention einsums apply elementwise, so tenants share every weight
and compiled shape.  Requests carry ``adapter_id``; each stream is
verified against a single-tenant replay on the model with that
adapter folded into the diagonals, and the demo prints the per-tenant
token/completion counters from ``Engine.stats()``.

The final section demonstrates GRACEFUL DEGRADATION under overload
(DESIGN.md §11): a two-priority burst against a deliberately small
engine, low-priority requests carrying ``--deadline-steps``, one
request cancelled mid-flight, and — with ``--chaos-seed`` — a
deterministic fault schedule injected at the host boundaries (allocator
exhaustion, step failures, NaN logits, page-copy faults) that the
engine must absorb via bounded retry / quarantine / shedding while
every surviving stream stays token-exact.  It ends by printing the
``engine.stats()`` counter + per-priority-class latency table.

Run:  PYTHONPATH=src python examples/serve_pruned.py
      PYTHONPATH=src python examples/serve_pruned.py --spec-k 4
      PYTHONPATH=src python examples/serve_pruned.py --adapters 2
      PYTHONPATH=src python examples/serve_pruned.py \
          --chaos-seed 7 --deadline-steps 20
      XLA_FLAGS=--xla_force_host_platform_device_count=4 \
          PYTHONPATH=src python examples/serve_pruned.py \
          --tp 2 --kernel-impl interpret
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import (AdapterRegistry, apply_rank_budget,
                        clover_decompose, clover_prune, plan_rank_budget)
from repro.models import init_lm_params
from repro.serve import (Engine, EngineConfig, FaultPlan, Request,
                         greedy_reference, rank_pool_bytes)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec-k", type=int, default=2,
                    help="draft tokens per speculative round (0 = off)")
    ap.add_argument("--draft-rank-ratio", type=float, default=0.5,
                    help="fraction of every head's current rank the "
                         "draft slices off (0.0 = draft is the exact "
                         "model)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree for the sharded "
                         "replay (must divide jax.device_count(); on "
                         "CPU export XLA_FLAGS=--xla_force_host_"
                         "platform_device_count=N first)")
    ap.add_argument("--kernel-impl", default="",
                    choices=("", "ref", "xla", "pallas", "interpret"),
                    help="kernel dispatch override for the sharded "
                         "replay (default: inherit the arch config; "
                         "'interpret' compiles the Pallas hot path "
                         "per shard)")
    ap.add_argument("--host-pages", type=int, default=8,
                    help="host-RAM spill-tier capacity (pages) for the "
                         "hierarchical-KV demo (0 = skip it)")
    ap.add_argument("--adapters", type=int, default=2,
                    help="number of per-tenant SV adapters for the "
                         "multi-tenant demo (0 = skip it; id 0 is "
                         "always the identity/base tenant)")
    ap.add_argument("--rank-budget", type=float, default=0.5,
                    help="fraction of TOTAL rank capacity for the "
                         "spectrum-planned non-uniform serving demo "
                         "(DESIGN.md §14; 0 = skip it)")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="inject a deterministic FaultPlan with this "
                         "seed into the overload demo (omit = "
                         "fault-free; same seed = same faults)")
    ap.add_argument("--deadline-steps", type=int, default=24,
                    help="deadline (engine steps) on the low-priority "
                         "half of the overload demo: queued requests "
                         "that provably cannot meet it are shed when "
                         "higher-priority work is pending; running "
                         "ones time out with a partial stream")
    args = ap.parse_args()
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    dparams, dcfg, extras = clover_decompose(params, cfg, peft=False)
    pparams, pcfg = clover_prune(dparams, dcfg, qk_ratio=0.5, vo_ratio=0.5)
    print(f"serving {pcfg.name}: head_dim {cfg.head_dim_} -> "
          f"qk_rank {pcfg.clover.qk_rank}, vo_rank {pcfg.clover.vo_rank}")

    eng = Engine(pparams, pcfg, EngineConfig(slots=4, max_len=96,
                                             prefill_chunk=8))
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(3, 12))).astype(
                                            np.int32),
                    max_new_tokens=8)
            for i in range(10)]
    t0 = time.time()
    eng.run(reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.generated) for r in reqs)
    print(f"served {len(reqs)} requests / {n_tok} tokens in {dt:.1f}s "
          f"({eng.compiled_shapes()} compiled step shapes)")

    # verify stream 0 against its isolated reference
    r = reqs[0]
    ref = greedy_reference(pparams, pcfg, r.prompt, r.max_new_tokens)
    print(f"request 0: engine={r.generated}")
    print(f"           ref   ={ref}  match={r.generated == ref}")

    # replay on the paged engine: undersized pool -> page-gated
    # admission + preemption, identical streams
    ep = Engine(pparams, pcfg, EngineConfig(slots=4, max_len=96,
                                            prefill_chunk=8, paged=True,
                                            page_tokens=8, n_pages=8))
    reqs_p = [Request(uid=r.uid, prompt=r.prompt,
                      max_new_tokens=r.max_new_tokens) for r in reqs]
    ep.run(reqs_p)
    match = all(a.generated == b.generated for a, b in zip(reqs, reqs_p))
    print(f"paged replay: match={match} "
          f"({ep.compiled_shapes()} compiled step shapes, "
          f"{ep.sched.preemptions} preemptions, "
          f"peak page util {ep.peak_page_util:.0%})")

    # spectrum-planned rank budget (DESIGN.md §14): water-fill ONE
    # global rank budget across layers/heads by singular-value energy,
    # then serve the non-uniform plan — per-head ragged ranks ride as
    # zero-padding plus the decode kernels' per-head rank clamp, so
    # every stream still matches its greedy reference at ONE compiled
    # shape per plan
    if args.rank_budget > 0:
        plan = plan_rank_budget(extras, dcfg, budget=args.rank_budget)
        bparams, bcfg = apply_rank_budget(dparams, dcfg, plan)
        print(f"rank budget {args.rank_budget:.0%}: kept "
              f"{plan.total_rank} of {plan.budget} requested ranks, "
              f"widths qk={plan.qk_width} vo={plan.vo_width}")
        for j in range(len(bcfg.pattern)):
            if not plan.qk_ranks[j]:
                continue
            qk_j, vo_j = plan.layer_ranks(j)
            for b in range(qk_j.shape[0]):
                print(f"  layer {j}.{b}: qk {qk_j[b].tolist()} "
                      f"vo {vo_j[b].tolist()}")
        pb = rank_pool_bytes(plan, page_tokens=8, n_pages=8)
        print(f"  pool bytes: kept {pb['kept']} / allocated "
              f"{pb['allocated']} "
              f"({pb['kept'] / pb['allocated']:.0%} of max-width pool)")
        eb = Engine(bparams, bcfg,
                    EngineConfig(slots=4, max_len=96, prefill_chunk=8,
                                 paged=True, page_tokens=8,
                                 kernel_impl="interpret",
                                 rank_budget=plan))
        reqs_b = [Request(uid=r.uid, prompt=r.prompt,
                          max_new_tokens=r.max_new_tokens)
                  for r in reqs[:4]]
        eb.run(reqs_b)
        match = all(
            r.generated == greedy_reference(bparams, bcfg, r.prompt,
                                            r.max_new_tokens)
            for r in reqs_b)
        print(f"  budget-planned replay: match={match} "
              f"({eb.compiled_shapes()} compiled step shapes)")

    # replay once more with self-speculative decoding: the rank-sliced
    # draft of the SAME weights proposes spec_k tokens per decode step,
    # one (slots, k+1) verify step commits a greedy prefix — identical
    # streams, more tokens per full-model step (DESIGN.md §8)
    if args.spec_k > 0:
        es = Engine(pparams, pcfg,
                    EngineConfig(slots=4, max_len=96, prefill_chunk=8,
                                 spec_k=args.spec_k,
                                 draft_rank_ratio=args.draft_rank_ratio))
        reqs_s = [Request(uid=r.uid, prompt=r.prompt,
                          max_new_tokens=r.max_new_tokens) for r in reqs]
        es.run(reqs_s)
        match = all(a.generated == b.generated
                    for a, b in zip(reqs, reqs_s))
        print(f"speculative replay (k={args.spec_k}, draft ratio "
              f"{args.draft_rank_ratio}): match={match}, "
              f"{es.accepted_per_round:.2f} accepted tokens/step "
              f"(hist {dict(sorted(es.accept_hist.items()))}, "
              f"{es.compiled_shapes()} compiled step shapes)")

    # rank-balanced tensor-parallel replay (DESIGN.md §10): the SAME
    # paged trace through the ShardedExecutor — params and page pools
    # sharded along heads, streams still token-identical, and the page
    # pool's bytes split ~evenly across shards by the rank-balanced
    # head partition
    if args.tp > 1:
        if jax.device_count() % args.tp != 0:
            print(f"--tp {args.tp}: skipped — needs a device count "
                  f"divisible by {args.tp} (have {jax.device_count()}; "
                  "export XLA_FLAGS=--xla_force_host_platform_device_"
                  "count=4)")
        else:
            et = Engine(pparams, pcfg,
                        dataclasses.replace(
                            EngineConfig(slots=4, max_len=96,
                                         prefill_chunk=8, paged=True,
                                         page_tokens=8, n_pages=8),
                            tp=args.tp,
                            kernel_impl=args.kernel_impl))
            reqs_t = [Request(uid=r.uid, prompt=r.prompt,
                              max_new_tokens=r.max_new_tokens)
                      for r in reqs]
            et.run(reqs_t)
            match = all(a.generated == b.generated
                        for a, b in zip(reqs, reqs_t))
            plan = et.exe.plan
            print(f"tensor-parallel replay (tp={args.tp}): match={match} "
                  f"({et.compiled_shapes()} compiled step shapes, "
                  f"{et.sched.preemptions} preemptions)")
            print("  kernel dispatch per compiled entry:")
            for entry, impl in et.exe.kernel_report().items():
                print(f"    {entry:>13}: {impl}")
            used = et.alloc.used_pages()
            for s, frac in enumerate(et.exe.shard_load_fractions()):
                heads = plan.kv_assign[s] if plan is not None else "all"
                print(f"  shard {s}: kv heads {heads} — "
                      f"{et.peak_page_util:.0%} of pool pages at peak, "
                      f"{frac:.0%} of pooled KV bytes "
                      f"({used} pages mapped now)")

    # prefix caching: a batch sharing one system prompt, served twice
    # on the same engine — the warm pass hits the trie, skips the
    # shared prefill chunks, and must match the cold pass exactly
    epc = Engine(pparams, pcfg,
                 EngineConfig(slots=4, max_len=96, prefill_chunk=8,
                              paged=True, page_tokens=8,
                              prefix_cache=True))
    sys_prompt = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
    shared = [np.concatenate(
        [sys_prompt, rng.integers(0, cfg.vocab_size, 4).astype(np.int32)])
        for _ in range(6)]
    cold = [Request(uid=i, prompt=p, max_new_tokens=8)
            for i, p in enumerate(shared)]
    epc.run(cold)
    warm = [Request(uid=10 + i, prompt=p, max_new_tokens=8)
            for i, p in enumerate(shared)]
    epc.run(warm)
    match = all(a.generated == b.generated for a, b in zip(cold, warm))
    hit = sum(r.cached_tokens for r in warm)
    print(f"prefix-cache warm replay: match={match}, "
          f"{hit} prompt tokens served from shared pages "
          f"({epc.sched.prefix_hits} hits, "
          f"{len(epc.prefix)} trie nodes, "
          f"{epc.compiled_shapes()} compiled step shapes)")

    # hierarchical KV (DESIGN.md §12): the same shared batch with a
    # host-RAM spill tier under the trie.  Evicting the pool (standing
    # in for cache pressure) spills the published prefix host-side;
    # the replay restores it through one host->device scatter instead
    # of re-prefilling — streams identical, stats() shows the tier
    if args.host_pages > 0:
        eh = Engine(pparams, pcfg,
                    EngineConfig(slots=4, max_len=96, prefill_chunk=8,
                                 paged=True, page_tokens=8,
                                 prefix_cache=True,
                                 host_pages=args.host_pages))
        cold_h = [Request(uid=i, prompt=p, max_new_tokens=8)
                  for i, p in enumerate(shared)]
        eh.run(cold_h)
        evicted = eh.prefix.evict(eh.alloc.n_pages)
        warm_h = [Request(uid=10 + i, prompt=p, max_new_tokens=8)
                  for i, p in enumerate(shared)]
        eh.run(warm_h)
        match = all(a.generated == b.generated
                    for a, b in zip(cold_h, warm_h))
        st = eh.stats()
        print(f"hierarchical KV (--host-pages {args.host_pages}): "
              f"match={match}, {evicted} pages spilled on eviction, "
              f"{st['host_restores']} restored from host RAM "
              f"(spills={st['host_spills']}, "
              f"hit rate {st['host_hit_rate']:.0%}, "
              f"{st['host_pages_used']} host slots held)")

    # multi-tenant SV adapters (DESIGN.md §13): one base model, N
    # tenants as diagonal scalings of the CLOVER singular values.  The
    # mixed batch runs on a prefix-cached engine (per-tenant trie
    # partition); every stream must equal the single-tenant replay on
    # the model with that tenant's adapter folded into the weights.
    if args.adapters > 0:
        dp2, dcfg2, _ = clover_decompose(params, cfg, peft=True)
        reg = AdapterRegistry(dp2)
        import jax.numpy as jnp
        for a in range(1, args.adapters):
            reg.register(tuple(
                {k: jnp.asarray(rng.uniform(0.8, 1.25, np.shape(v)),
                                jnp.float32) for k, v in entry.items()}
                for entry in reg.get(0)))
        ea = Engine(dp2, dcfg2,
                    EngineConfig(slots=4, max_len=96, prefill_chunk=8,
                                 paged=True, page_tokens=8,
                                 prefix_cache=True),
                    adapters=reg)
        sys_a = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
        reqs_a = [Request(uid=300 + i,
                          prompt=np.concatenate(
                              [sys_a, rng.integers(0, cfg.vocab_size, 3)
                               .astype(np.int32)]),
                          max_new_tokens=6, adapter_id=i % len(reg))
                  for i in range(4)]
        ea.run(reqs_a)
        match = all(
            r.generated == greedy_reference(
                reg.folded(dp2, r.adapter_id) if r.adapter_id else dp2,
                dcfg2, r.prompt, r.max_new_tokens)
            for r in reqs_a)
        st = ea.stats()
        print(f"multi-tenant replay ({len(reg)} adapters, shared "
              f"weights): match={match} "
              f"({ea.compiled_shapes()} compiled step shapes)")
        print(f"  per-tenant tokens {st['adapter_tokens']}, "
              f"completions {st['adapter_done']}")

    # overload + graceful degradation (DESIGN.md §11): a two-priority
    # burst against a deliberately small engine.  Lows carry
    # --deadline-steps; one low is cancelled mid-decode; --chaos-seed
    # adds a deterministic fault schedule at the host boundaries.
    # Whatever gets shed / times out / is cancelled must leave the
    # allocator exactly as if it never ran — the surviving streams
    # stay token-exact (the chaos soak and serve_bench scenario 6
    # gate this; here we just watch it degrade gracefully).
    faults = (FaultPlan.chaos(seed=args.chaos_seed, intensity=0.05)
              if args.chaos_seed is not None else None)
    eo = Engine(pparams, pcfg,
                EngineConfig(slots=2, max_len=96, prefill_chunk=8,
                             paged=True, page_tokens=8, n_pages=8,
                             step_retries=1, quarantine_steps=2,
                             watchdog_steps=32),
                faults=faults)
    mk = rng.integers  # overload trace: 6 lows burst, 3 highs overtake
    lows = [Request(uid=100 + i,
                    prompt=mk(0, cfg.vocab_size,
                              int(mk(4, 12))).astype(np.int32),
                    max_new_tokens=8, priority=0,
                    deadline_steps=args.deadline_steps)
            for i in range(6)]
    highs = [Request(uid=200 + i,
                     prompt=mk(0, cfg.vocab_size,
                               int(mk(4, 12))).astype(np.int32),
                     max_new_tokens=8, priority=1)
             for i in range(3)]
    for r in lows:
        eo.submit(r)
    step = 0
    while eo.sched.busy and step < 500:
        if step == 2:                 # high wave jumps the low queue
            for r in highs:
                eo.submit(r)
        if step == 4:                 # client walks away mid-decode
            eo.cancel(lows[1].uid)
        eo.step()
        step += 1
    chaos = (f"chaos seed {args.chaos_seed}" if faults is not None
             else "fault-free")
    print(f"overload demo ({chaos}, deadline {args.deadline_steps} "
          f"steps): drained in {step} steps")
    for r in lows + highs:
        print(f"  uid {r.uid} prio {r.priority}: {r.status:>9} "
              f"({len(r.generated)}/{r.max_new_tokens} tokens)")
    st = eo.stats()
    print("  counters: " + ", ".join(
        f"{k}={v}" for k, v in sorted(st["counters"].items())))
    if faults is not None:
        print(f"  faults injected: {faults.total_injected} "
              f"(sites {dict(faults.injected)})")
    hdr = (f"  {'class':>5} {'n':>3} {'ttft_p50':>9} {'ttft_p95':>9} "
           f"{'itl_p50':>8} {'itl_p95':>8}   (engine steps)")
    print(hdr)
    for prio, row in sorted(st["classes"].items()):
        print(f"  {prio:>5} {row.get('n_ttft_steps', 0):>3} "
              f"{row.get('ttft_steps_p50', float('nan')):>9.1f} "
              f"{row.get('ttft_steps_p95', float('nan')):>9.1f} "
              f"{row.get('itl_steps_p50', float('nan')):>8.1f} "
              f"{row.get('itl_steps_p95', float('nan')):>8.1f}")
    print(f"  pool after drain: {eo.alloc.free_pages}/"
          f"{eo.alloc.n_pages} pages free")


if __name__ == "__main__":
    main()
