"""Serving example: chunked-prefill continuous batching over a
CLOVER-pruned model.

Builds a reduced model, CLOVER-prunes 50% of every head (KV cache
halves), then serves a mixed batch of requests with different prompt
lengths and arrival times.  Prompts are consumed in fixed-size chunks
interleaved with decoding, so the whole mixed-length batch compiles
exactly two step shapes; each stream is verified against its isolated
greedy reference.  The same trace is then replayed on the PAGED engine
(global page pool + page tables, admission gated on free pages,
preemption on exhaustion) and must produce identical streams.

Run:  PYTHONPATH=src python examples/serve_pruned.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import clover_decompose, clover_prune
from repro.models import init_lm_params
from repro.serve import Engine, EngineConfig, Request, greedy_reference


def main():
    cfg = get_config("musicgen-large").reduced()
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    dparams, dcfg, _ = clover_decompose(params, cfg, peft=False)
    pparams, pcfg = clover_prune(dparams, dcfg, qk_ratio=0.5, vo_ratio=0.5)
    print(f"serving {pcfg.name}: head_dim {cfg.head_dim_} -> "
          f"qk_rank {pcfg.clover.qk_rank}, vo_rank {pcfg.clover.vo_rank}")

    eng = Engine(pparams, pcfg, EngineConfig(slots=4, max_len=96,
                                             prefill_chunk=8))
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(3, 12))).astype(
                                            np.int32),
                    max_new_tokens=8)
            for i in range(10)]
    t0 = time.time()
    eng.run(reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.generated) for r in reqs)
    print(f"served {len(reqs)} requests / {n_tok} tokens in {dt:.1f}s "
          f"({eng.compiled_shapes()} compiled step shapes)")

    # verify stream 0 against its isolated reference
    r = reqs[0]
    ref = greedy_reference(pparams, pcfg, r.prompt, r.max_new_tokens)
    print(f"request 0: engine={r.generated}")
    print(f"           ref   ={ref}  match={r.generated == ref}")

    # replay on the paged engine: undersized pool -> page-gated
    # admission + preemption, identical streams
    ep = Engine(pparams, pcfg, EngineConfig(slots=4, max_len=96,
                                            prefill_chunk=8, paged=True,
                                            page_tokens=8, n_pages=8))
    reqs_p = [Request(uid=r.uid, prompt=r.prompt,
                      max_new_tokens=r.max_new_tokens) for r in reqs]
    ep.run(reqs_p)
    match = all(a.generated == b.generated for a, b in zip(reqs, reqs_p))
    print(f"paged replay: match={match} "
          f"({ep.compiled_shapes()} compiled step shapes, "
          f"{ep.sched.preemptions} preemptions, "
          f"peak page util {ep.peak_page_util:.0%})")


if __name__ == "__main__":
    main()
