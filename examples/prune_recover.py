"""End-to-end driver example: prune 50% then recovery-train ~a few
hundred steps with checkpointing + fault-tolerance supervisor.

This is the paper's Table-1 workflow through the PRODUCTION path
(repro.launch.train): config -> prune -> sharded train step -> synthetic
data -> checkpoints -> supervisor (with an injected worker failure to
demonstrate restart).

Run:  PYTHONPATH=src python examples/prune_recover.py
"""
from repro.launch.train import main

if __name__ == "__main__":
    raise SystemExit(main([
        "--arch", "musicgen-large",
        "--reduced",
        "--clover-prune", "0.5",
        "--peft", "clover",          # recovery via CLOVER-S only
        "--steps", "60",
        "--batch", "8",
        "--seq", "64",
        "--lr", "5e-3",
        "--ckpt-every", "20",
        "--fail-at", "30",           # inject a failure; supervisor restarts
        "--ckpt-dir", "/tmp/repro_prune_recover",
    ]))
