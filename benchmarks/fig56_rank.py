"""Paper Figs. 5-6: ΔW rank and intruder dimensions.

Fine-tune the same base with LoRA, CLOVER-S, and full FT; then:
  Fig 5 — SVD of ΔW: LoRA's update has rank <= r; CLOVER's and full
          FT's updates are (near-)full-rank.
  Fig 6 — intruder dimensions: top singular vectors of the tuned weight
          with no counterpart in the base.  LoRA injects them; CLOVER
          and full FT do not.
"""
from __future__ import annotations

import jax

from benchmarks.common import data_for, pretrain_base, train
from benchmarks.table2_peft import _train_adapters
from repro.core import PeftConfig, clover_decompose, merge_clover
from repro.core.analytics import delta_spectrum, effective_rank, intruder_dims

RANK = 2


def _wq0(tree):
    return jax.tree.map(lambda a: a[0], tree["blocks"][0])["attn"]["wq"]


def _flat(w):  # (D, H, dq) -> (D, H*dq)
    return w.reshape(w.shape[0], -1)


def run(verbose: bool = True):
    params, cfg, _ = pretrain_base()
    new_data = data_for(cfg, seed=99)
    W0 = _flat(_wq0(params))

    # LoRA (tiny rank to make the contrast sharp)
    pcfg = PeftConfig(method="lora", rank=RANK, alpha=16.0,
                      targets=("wq",))
    eff, _ = _train_adapters(params, cfg, pcfg, new_data, steps=60,
                             lr=5e-3)
    W_lora = _flat(_wq0(eff))

    # CLOVER-S
    p2, cfg2, _ = clover_decompose(params, cfg, peft=True)
    p2, _ = train(p2, cfg2, new_data, steps=60, lr=5e-3, peft_mode=True)
    merged, _ = merge_clover(p2, cfg2)
    W_clover = _flat(_wq0(merged))

    # full FT
    pf, _ = train(params, cfg, new_data, steps=60, lr=1e-3)
    W_full = _flat(_wq0(pf))

    res = {}
    for name, W1 in (("lora", W_lora), ("clover", W_clover),
                     ("full_ft", W_full)):
        s = delta_spectrum(W0, W1)
        res[name] = {
            "delta_rank": effective_rank(s, tol=1e-2),
            "intruders": intruder_dims(W0, W1, k=8, tau=0.6),
        }
    if verbose:
        for k, v in res.items():
            print(f"{k:8s} delta_rank={v['delta_rank']:4d} "
                  f"intruders={v['intruders']}")
    checks = {
        "lora_low_rank": res["lora"]["delta_rank"] <= RANK + 1,
        "clover_high_rank": res["clover"]["delta_rank"]
        > 4 * res["lora"]["delta_rank"],
        "full_high_rank": res["full_ft"]["delta_rank"]
        > 4 * res["lora"]["delta_rank"],
        "clover_no_extra_intruders": res["clover"]["intruders"]
        <= res["full_ft"]["intruders"] + 1,
    }
    return {"res": res, "checks": checks}


if __name__ == "__main__":
    print(run()["checks"])
