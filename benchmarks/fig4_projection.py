"""Paper Fig. 4 / §4.5: why learning ALL orthogonal directions matters.

Project data features onto (a) random rank-r directions (LoRA),
(b) top-r principal directions (PiSSA), (c) all d directions (CLOVER).
The paper's numbers: with singular-value scaling the principal direction
carries ~18% of the energy — but 82% lies OUTSIDE the top direction, and
~94% outside a rank-r random adapter: the zero-gradient risk CLOVER's
full-rank update removes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import pretrain_base
from repro.core.analytics import coverage, projection_mass
from repro.core.decompose import svd_lowrank_product
from repro.models import transformer as T
from repro.models import layers as L


def run(verbose: bool = True, rank: int = 4):
    params, cfg, data = pretrain_base()
    # activations entering the first attention layer (16 samples, paper's
    # protocol)
    b = data.batch_at(5000)
    toks = jnp.asarray(b["tokens"][:16])
    x = T._embed(params, cfg, toks,
                 jnp.broadcast_to(jnp.arange(toks.shape[1])[None],
                                  toks.shape), None)
    lp = jax.tree.map(lambda a: a[0], params["blocks"][0])
    h = L.apply_norm(lp["norm1"], cfg, x)
    X = h.reshape(-1, cfg.d_model)

    attn = lp["attn"]
    D, H, d = attn["wq"].shape
    A = attn["wq"].transpose(1, 0, 2).reshape(H, D, d)[0]
    B = attn["wk"].transpose(1, 0, 2)[0]
    U, S, Vt = svd_lowrank_product(A, B)      # head-0 orthogonal basis

    key = jax.random.PRNGKey(0)
    rand_dirs = jnp.linalg.qr(
        jax.random.normal(key, (cfg.d_model, rank)))[0]
    res = {
        "lora_coverage": coverage(X, rand_dirs),
        "pissa_coverage": coverage(X, U[:, :rank]),
        "clover_coverage": coverage(X, U),
        "principal_share_unscaled": float(
            projection_mass(X, U)[0]),
        "principal_share_scaled": float(
            projection_mass(X, U, weights=S)[0]),
    }
    if verbose:
        for k, v in res.items():
            print(f"{k:28s} {v:.3f}")
    checks = {
        # scaled principal direction dominates its unscaled share (Fig 4c)
        "scaling_amplifies_principal": res["principal_share_scaled"]
        > res["principal_share_unscaled"],
        # most energy is OUTSIDE rank-r subspaces (the zero-grad risk)
        "lora_misses_most": res["lora_coverage"] < 0.5,
        "pissa_partial": res["pissa_coverage"] < 0.9,
        # CLOVER's basis spans the head's whole reachable subspace
        "clover_covers_most": res["clover_coverage"]
        >= res["pissa_coverage"],
    }
    return {"res": res, "checks": checks}


if __name__ == "__main__":
    print(run()["checks"])
