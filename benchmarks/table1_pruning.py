"""Paper Table 1: CLOVER vs vanilla pruning on GPT-2-family, with
recovery fine-tuning at two token budgets.

Reproduced claims (orderings, at reduced scale):
  1. w/o training: CLOVER PPL << vanilla PPL at every ratio;
  2. recovery fine-tuning of the pruned attention closes most of the gap,
     faster for CLOVER (less functional damage);
  3. CLOVER-dagger (fine-tune ONLY the singular values S) approaches
     full-attention-FT quality at a fraction of trainable params.
"""
from __future__ import annotations

from benchmarks.common import perplexity, pretrain_base, train
from repro.core import clover_decompose, clover_prune, vanilla_prune
from repro.core.peft import count_params, partition

RATIOS = (0.25, 0.5, 0.75)
FT_SHORT, FT_LONG = 60, 120     # "66M/131M tokens" at our scale


def run(verbose: bool = True):
    params, cfg, data = pretrain_base()
    base_ppl = perplexity(params, cfg, data)
    rows = []
    dp, dcfg, _ = clover_decompose(params, cfg, peft=False)
    dp_ft, dcfg_ft, _ = clover_decompose(params, cfg, peft=True)

    for ratio in RATIOS:
        # -- no-training PPL ------------------------------------------------
        cp, ccfg = clover_prune(dp, dcfg, qk_ratio=ratio, vo_ratio=ratio)
        vp, vcfg = vanilla_prune(params, cfg, qk_ratio=ratio,
                                 vo_ratio=ratio)
        row = {"ratio": ratio,
               "vanilla_ppl": perplexity(vp, vcfg, data),
               "clover_ppl": perplexity(cp, ccfg, data)}

        # -- recovery fine-tune (attention only would need masking; we
        # fine-tune all params at benchmark scale, same for both arms) --
        for name, budget in (("short", FT_SHORT), ("long", FT_LONG)):
            vp_ft, _ = train(vp, vcfg, data, steps=budget, lr=1e-3,
                             start_step=1000)
            cp_ft, _ = train(cp, ccfg, data, steps=budget, lr=1e-3,
                             start_step=1000)
            row[f"vanilla_ft_{name}"] = perplexity(vp_ft, vcfg, data)
            row[f"clover_ft_{name}"] = perplexity(cp_ft, ccfg, data)

        # -- CLOVER-dagger: prune, then fine-tune only S --------------------
        cpd, ccfgd = clover_prune(dp_ft, dcfg_ft, qk_ratio=ratio,
                                  vo_ratio=ratio)
        cpd, _ = train(cpd, ccfgd, data, steps=FT_SHORT, lr=1e-2,
                       peft_mode=True, start_step=1000)
        row["clover_dagger_ft_short"] = perplexity(cpd, ccfgd, data)
        tr, _ = partition(cpd)
        row["dagger_trainable_params"] = count_params(tr)
        rows.append(row)
        if verbose:
            print(f"ratio={ratio:.2f} base={base_ppl:.2f} "
                  f"vanilla={row['vanilla_ppl']:.2f} "
                  f"clover={row['clover_ppl']:.2f} | ft(short) "
                  f"v={row['vanilla_ft_short']:.2f} "
                  f"c={row['clover_ft_short']:.2f} "
                  f"dagger={row['clover_dagger_ft_short']:.2f}")

    checks = {
        "clover_beats_vanilla_all_ratios": all(
            r["clover_ppl"] < r["vanilla_ppl"] for r in rows),
        "ft_recovers": all(
            r["clover_ft_long"] < r["clover_ppl"] for r in rows),
        "dagger_close_to_full_ft": rows[0]["clover_dagger_ft_short"]
        < 1.5 * rows[0]["clover_ft_short"],
    }
    return {"base_ppl": base_ppl, "rows": rows, "checks": checks}


if __name__ == "__main__":
    out = run()
    print(out["checks"])
