"""Benchmark driver: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``          # all
``PYTHONPATH=src python -m benchmarks.run table1``   # one
Each module returns {..., "checks": {name: bool}}; the driver reports
every check and exits non-zero if any reproduced claim fails.
"""
from __future__ import annotations

import sys
import time

MODULES = ("table1_pruning", "table2_peft", "fig2_spectrum",
           "fig3_trainfree", "fig4_projection", "fig56_rank",
           "kernel_bench", "serve_bench")


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    selected = [m for m in MODULES
                if not argv or any(a in m for a in argv)]
    if not selected:
        # a typo'd selector must not report ALL CHECKS PASS (CI runs
        # this driver with explicit module names)
        print(f"no benchmark modules match {argv}; known: {MODULES}")
        return 2
    failures = []
    for name in selected:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        print(f"\n=== {name} " + "=" * (60 - len(name)))
        t0 = time.time()
        out = mod.run(verbose=True)
        dt = time.time() - t0
        for check, ok in out["checks"].items():
            status = "PASS" if ok else "FAIL"
            print(f"  [{status}] {check}")
            if not ok:
                failures.append(f"{name}:{check}")
        print(f"  ({dt:.1f}s)")
    print("\n" + ("ALL CHECKS PASS" if not failures
                  else f"FAILURES: {failures}"))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
