"""Benchmark driver: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``                  # all
``PYTHONPATH=src python -m benchmarks.run table1``           # substring
``PYTHONPATH=src python -m benchmarks.run --only serve_bench``  # exact
Each module returns {..., "checks": {name: bool}}; the driver reports
every check and exits non-zero if any reproduced claim fails OR any
module crashes (a raise is recorded as that module's failure, the
remaining modules still run, and the exit code is non-zero).

Perf modules (``*_bench``) additionally get a machine-readable dump
``BENCH_<stem>.json`` (e.g. BENCH_serve.json, BENCH_kernel.json) written
to the REPO ROOT — rows, checks and the module's ``metrics`` dict
(tokens/sec, p50/p95 ITL, TTFT, page-pool utilization, ...).  The root
files are COMMITTED (and also uploaded as CI workflow artifacts), so
the perf trajectory is tracked in-repo across PRs instead of
evaporating with the build log; ``compare.py`` verifies they stay
key-synchronized with ``benchmarks/baselines/``.
"""
from __future__ import annotations

import json
import os
import sys
import time
import traceback
from pathlib import Path

# Anchor BENCH_*.json at the repo root regardless of the invoking CWD:
# "written wherever the runner happened to cd" is how the committed
# perf trajectory ended up empty.  BENCH_OUTPUT_DIR redirects the
# output for runs that must NOT touch the committed trajectory files
# (subprocess tests, scenario-filtered smokes).
REPO_ROOT = Path(__file__).resolve().parent.parent

# serve_bench's tp cells need >= 2 devices, and XLA only honors the
# host-device-count flag before jax first initializes.  Set it HERE,
# before any benchmark module import: when serve_bench runs after a
# module that already imported jax (e.g. kernel_bench in the same
# process), its own import-time guard is too late, the tp > 1 cells
# cannot form a mesh, and (before they raised) the run silently
# dropped their gated baseline keys.
if ("jax" not in sys.modules
        and "xla_force_host_platform_device_count"
        not in os.environ.get("XLA_FLAGS", "")):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4"
                               ).strip()

MODULES = ("table1_pruning", "table2_peft", "fig2_spectrum",
           "fig3_trainfree", "fig4_projection", "fig56_rank",
           "kernel_bench", "serve_bench")


def _write_bench_json(name: str, out: dict, elapsed_s: float) -> str:
    out_dir = Path(os.environ.get("BENCH_OUTPUT_DIR") or REPO_ROOT)
    path = out_dir / f"BENCH_{name[:-len('_bench')]}.json"
    payload = {
        "module": name,
        "elapsed_s": round(elapsed_s, 2),
        "rows": [list(r) for r in out.get("rows", [])],
        "checks": out.get("checks", {}),
        "metrics": out.get("metrics", {}),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    try:
        return str(path.relative_to(Path.cwd()))
    except ValueError:
        return str(path)


def main(argv=None) -> int:
    argv = list(argv if argv is not None else sys.argv[1:])
    # --only <module>: exact-name filter (repeatable) for local
    # iteration; bare args remain substring filters
    only, subs = [], []
    i = 0
    while i < len(argv):
        if argv[i] == "--only":
            if i + 1 >= len(argv):
                print("--only requires a module name")
                return 2
            only.append(argv[i + 1])
            i += 2
        elif argv[i].startswith("--only="):
            only.append(argv[i].split("=", 1)[1])
            i += 1
        else:
            subs.append(argv[i])
            i += 1
    unknown = [m for m in only if m not in MODULES]
    if unknown:
        print(f"--only: unknown modules {unknown}; known: {MODULES}")
        return 2
    selected = [m for m in MODULES
                if (m in only if only else
                    (not subs or any(a in m for a in subs)))]
    if not selected:
        # a typo'd selector must not report ALL CHECKS PASS (CI runs
        # this driver with explicit module names)
        print(f"no benchmark modules match {subs}; known: {MODULES}")
        return 2
    failures = []
    for name in selected:
        print(f"\n=== {name} " + "=" * (60 - len(name)))
        t0 = time.time()
        # a crashing benchmark is a FAILURE of that module, never a
        # silent pass NOR an abort that hides the remaining modules'
        # results — record it, keep going, exit non-zero at the end
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            out = mod.run(verbose=True)
            checks = out["checks"]
        except Exception as e:  # noqa: BLE001 - the driver must survive
            traceback.print_exc()
            failures.append(f"{name}:raised:{type(e).__name__}")
            print(f"  [FAIL] {name} raised {type(e).__name__}: {e}")
            continue
        dt = time.time() - t0
        for check, ok in checks.items():
            status = "PASS" if ok else "FAIL"
            print(f"  [{status}] {check}")
            if not ok:
                failures.append(f"{name}:{check}")
        if name.endswith("_bench"):
            print(f"  wrote {_write_bench_json(name, out, dt)}")
        print(f"  ({dt:.1f}s)")
    print("\n" + ("ALL CHECKS PASS" if not failures
                  else f"FAILURES: {failures}"))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
