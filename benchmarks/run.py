"""Benchmark driver: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``          # all
``PYTHONPATH=src python -m benchmarks.run table1``   # one
Each module returns {..., "checks": {name: bool}}; the driver reports
every check and exits non-zero if any reproduced claim fails.

Perf modules (``*_bench``) additionally get a machine-readable dump
``BENCH_<stem>.json`` (e.g. BENCH_serve.json, BENCH_kernel.json) written
next to the stdout report — rows, checks and the module's ``metrics``
dict (tokens/sec, p50/p95 ITL, TTFT, page-pool utilization, ...) — so
the perf trajectory is tracked across PRs (CI uploads these as workflow
artifacts) instead of evaporating with the build log.
"""
from __future__ import annotations

import json
import sys
import time
import traceback

MODULES = ("table1_pruning", "table2_peft", "fig2_spectrum",
           "fig3_trainfree", "fig4_projection", "fig56_rank",
           "kernel_bench", "serve_bench")


def _write_bench_json(name: str, out: dict, elapsed_s: float) -> str:
    path = f"BENCH_{name[:-len('_bench')]}.json"
    payload = {
        "module": name,
        "elapsed_s": round(elapsed_s, 2),
        "rows": [list(r) for r in out.get("rows", [])],
        "checks": out.get("checks", {}),
        "metrics": out.get("metrics", {}),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    selected = [m for m in MODULES
                if not argv or any(a in m for a in argv)]
    if not selected:
        # a typo'd selector must not report ALL CHECKS PASS (CI runs
        # this driver with explicit module names)
        print(f"no benchmark modules match {argv}; known: {MODULES}")
        return 2
    failures = []
    for name in selected:
        print(f"\n=== {name} " + "=" * (60 - len(name)))
        t0 = time.time()
        # a crashing benchmark is a FAILURE of that module, never a
        # silent pass NOR an abort that hides the remaining modules'
        # results — record it, keep going, exit non-zero at the end
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            out = mod.run(verbose=True)
            checks = out["checks"]
        except Exception as e:  # noqa: BLE001 - the driver must survive
            traceback.print_exc()
            failures.append(f"{name}:raised:{type(e).__name__}")
            print(f"  [FAIL] {name} raised {type(e).__name__}: {e}")
            continue
        dt = time.time() - t0
        for check, ok in checks.items():
            status = "PASS" if ok else "FAIL"
            print(f"  [{status}] {check}")
            if not ok:
                failures.append(f"{name}:{check}")
        if name.endswith("_bench"):
            print(f"  wrote {_write_bench_json(name, out, dt)}")
        print(f"  ({dt:.1f}s)")
    print("\n" + ("ALL CHECKS PASS" if not failures
                  else f"FAILURES: {failures}"))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
