"""Paper §4.4: training-free threshold pruning (the Whisper demo).

On the trained tiny model: CLOVER-orthogonalize, drop every direction
whose singular value is below a magnitude threshold, and verify the
model's output is nearly unchanged — while vanilla pruning at the SAME
ratio degrades it badly.  (musicgen-large stands in for Whisper: both
are sinusoidal-position encoder/decoder audio stacks = the paper's
cleanest cross-layer case.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import perplexity, pretrain_base
from repro.core import (clover_decompose, clover_prune, threshold_ratios,
                        vanilla_prune)


def run(verbose: bool = True):
    params, cfg, data = pretrain_base()
    base_ppl = perplexity(params, cfg, data)
    dp, dcfg, extras = clover_decompose(params, cfg, peft=False)

    # pick thresholds from the spectra (drop the near-zero tail)
    s = extras[0]["spectra"]["qk"]
    qk_t = float(jnp.quantile(s, 0.45))
    s_vo = extras[0]["spectra"]["vo"]
    vo_t = float(jnp.quantile(s_vo, 0.30))
    plan = threshold_ratios(extras, dcfg, qk_thresh=qk_t, vo_thresh=vo_t)

    cp, ccfg = clover_prune(dp, dcfg, qk_ratio=plan["qk_ratio"],
                            vo_ratio=plan["vo_ratio"])
    vp, vcfg = vanilla_prune(params, cfg, qk_ratio=plan["qk_ratio"],
                             vo_ratio=plan["vo_ratio"])
    ppl_c = perplexity(cp, ccfg, data)
    ppl_v = perplexity(vp, vcfg, data)
    if verbose:
        print(f"threshold plan: qk_ratio={plan['qk_ratio']:.2f} "
              f"vo_ratio={plan['vo_ratio']:.2f}")
        print(f"base={base_ppl:.2f} clover(train-free)={ppl_c:.2f} "
              f"vanilla={ppl_v:.2f}")
    checks = {
        "some_pruning_happened": plan["qk_ratio"] > 0.1,
        "clover_nearly_unchanged": ppl_c < 1.6 * base_ppl,
        "vanilla_degrades_more": ppl_v > ppl_c,
    }
    return {"base_ppl": base_ppl, "plan": plan, "clover_ppl": ppl_c,
            "vanilla_ppl": ppl_v, "checks": checks}


if __name__ == "__main__":
    print(run()["checks"])
