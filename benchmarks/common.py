"""Shared benchmark utilities: tiny-GPT2 testbed, PPL eval, recovery FT.

The paper's quantitative claims are reproduced at CPU-feasible scale: a
GPT-2-family model (MHA + learned positions => full cross-layer CLOVER,
exactly the paper's setting) trained on the synthetic bigram-pattern LM
task until it has real structure, then pruned/fine-tuned.  What must
transfer from the paper is the ORDERINGS (CLOVER < vanilla PPL at every
ratio; recovery FT closes the gap; CLOVER-dagger ~ full-attention FT),
not absolute numbers.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ArchConfig
from repro.data import SyntheticConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import init_lm_params, forward
from repro.optim import AdamWConfig
from repro.train.step import TrainConfig, make_train_step, make_opt_state

Params = Dict[str, Any]


def tiny_gpt2(n_layers=4, d_model=128, n_heads=4, head_dim=32,
              d_ff=256, vocab=512) -> ArchConfig:
    return get_config("gpt2-xl").reduced(
        n_layers=n_layers, d_model=d_model, n_heads=n_heads,
        n_kv_heads=n_heads, head_dim=head_dim, d_ff=d_ff,
        vocab_size=vocab)


def data_for(cfg: ArchConfig, *, seq=64, batch=16, seed=0) -> SyntheticLM:
    return SyntheticLM(SyntheticConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch,
        seed=seed))


def perplexity(params: Params, cfg: ArchConfig, data: SyntheticLM,
               *, n_batches=8, start=10_000) -> float:
    """Eval PPL on held-out stream positions (disjoint from training)."""
    tot, cnt = 0.0, 0
    for i in range(n_batches):
        b = data.batch_at(start + i)
        logits, _ = forward(params, cfg, jnp.asarray(b["tokens"]))
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(
            logp, jnp.asarray(b["labels"])[..., None], -1)[..., 0]
        tot += float(jnp.sum(nll))
        cnt += nll.size
    return float(np.exp(tot / cnt))


def train(params: Params, cfg: ArchConfig, data: SyntheticLM, *,
          steps: int, lr: float = 1e-3, peft_mode: bool = False,
          weight_decay: float = 0.0,
          start_step: int = 0) -> Tuple[Params, list]:
    mesh = make_host_mesh()
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=lr, weight_decay=weight_decay),
        warmup_steps=max(2, steps // 10), total_steps=steps,
        remat=False, peft_mode=peft_mode)
    step, _ = make_train_step(cfg, tcfg, mesh)
    opt = make_opt_state(params, peft_mode=peft_mode)
    # no donation: benchmark callers reuse the same input tree for
    # multiple fine-tuning arms
    jstep = jax.jit(step)
    losses = []
    for i in range(steps):
        b = data.batch_at(start_step + i)
        params, opt, m = jstep(
            params, opt, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    return params, losses


def pretrain_base(seed=0, steps=300) -> Tuple[Params, ArchConfig, SyntheticLM]:
    """A tiny GPT-2 with real learned structure (the pruning testbed)."""
    cfg = tiny_gpt2()
    data = data_for(cfg)
    params = init_lm_params(cfg, jax.random.PRNGKey(seed))
    params, _ = train(params, cfg, data, steps=steps, lr=2e-3)
    return params, cfg, data
