"""Kernel micro-benchmarks: wall time of the XLA paths on CPU (what this
container can measure) + the decode-cache byte model CLOVER targets.

The Pallas kernels are TPU-targeted (validated in interpret mode by the
test suite; interpret timings are meaningless).  What IS meaningful on
CPU: (a) the XLA chunked fallbacks' relative scaling, (b) the decode
bytes-per-token model at different CLOVER ranks — the paper's actual
claim ("inference becomes memory-bound; pruning shrinks the cache").
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.parallel.hlo import HBM_BW


def _sync(out):
    return (out[0] if isinstance(out, tuple) else out).block_until_ready()


def _time(fn, *args, iters=10):
    _sync(fn(*args))                     # warm-up / compile
    best = float("inf")
    # min over repeats: robust to scheduler noise on shared CPUs (the
    # checks below gate CI, so one preempted sample must not fail it)
    for _ in range(iters):
        t0 = time.perf_counter()
        _sync(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6  # us


def run(verbose: bool = True):
    rows = []
    key = jax.random.PRNGKey(0)

    # attention scaling in asymmetric width (the CLOVER shape class)
    B, S, H, KV = 2, 256, 8, 4
    attn_cases = {}
    for dq, dv in ((64, 64), (32, 64), (32, 32), (16, 16)):
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, H, dq))
        k = jax.random.normal(ks[1], (B, S, KV, dq))
        v = jax.random.normal(ks[2], (B, S, KV, dv))
        f = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v))
        us = _time(f, q, k, v)
        attn_cases[(dq, dv)] = (f, (q, k, v))
        rows.append(("attention", f"dq{dq}_dv{dv}", us))

    # the full-vs-pruned ratio check needs INTERLEAVED timing: the two
    # endpoints measured back-to-back within each iteration, so a
    # co-tenant CPU-steal burst (observed inflating one row's separate
    # min-over-iters 1.7x while sparing the other) hits both sides
    # alike and cancels in the ratio
    f_full, a_full = attn_cases[(64, 64)]
    f_prun, a_prun = attn_cases[(16, 16)]
    best_full = best_prun = float("inf")
    for _ in range(20):
        t0 = time.perf_counter()
        _sync(f_full(*a_full))
        best_full = min(best_full, time.perf_counter() - t0)
        t0 = time.perf_counter()
        _sync(f_prun(*a_prun))
        best_prun = min(best_prun, time.perf_counter() - t0)

    # decode bytes/token at CLOVER ranks (the paper's KV-cache win)
    T, KVh, d = 32768, 8, 128
    for keep in (1.0, 0.75, 0.5, 0.25):
        r = int(d * keep)
        cache_bytes = T * KVh * (r + r) * 2          # bf16 K+V per seq
        t_stream_us = cache_bytes / HBM_BW * 1e6     # one-token roofline
        rows.append(("decode_cache", f"keep{keep:.2f}",
                     round(t_stream_us, 2)))

    # wkv6 chunked scaling in T
    Hh, d = 4, 32
    for T2 in (128, 512, 2048):
        ks = jax.random.split(key, 5)
        r = jax.random.normal(ks[0], (1, Hh, T2, d))
        kk = jax.random.normal(ks[1], (1, Hh, T2, d)) * 0.5
        vv = jax.random.normal(ks[2], (1, Hh, T2, d))
        lw = -jnp.exp(jax.random.normal(ks[3], (1, Hh, T2, d)) * 0.5)
        u = jax.random.normal(ks[4], (Hh, d)) * 0.1
        from repro.models.rwkv import wkv6_chunked
        s0 = jnp.zeros((1, Hh, d, d))
        f = jax.jit(lambda *a: wkv6_chunked(*a))
        us = _time(f, r, kk, vv, lw, u, s0)
        rows.append(("wkv6", f"T{T2}", us))

    # Pallas interpret-mode validation (CPU executes the TPU kernel
    # bodies; timings are meaningless but CORRECTNESS is the smoke CI
    # runs on every push — a kernel regression fails these checks)
    ks = jax.random.split(key, 4)
    Bi, Si, Hi, KVi, dqi, dvi = 1, 64, 4, 2, 32, 16
    qi = jax.random.normal(ks[0], (Bi, Si, Hi, dqi))
    ki = jax.random.normal(ks[1], (Bi, Si, KVi, dqi))
    vi = jax.random.normal(ks[2], (Bi, Si, KVi, dvi))
    flash = ops.clover_attention(qi, ki, vi, causal=True, impl="interpret")
    flash_ok = bool(np.allclose(
        np.asarray(flash),
        np.asarray(ref.attention_ref(qi, ki, vi, causal=True)),
        atol=2e-4))
    lens = jnp.array([Si // 2], jnp.int32)
    dec = ops.decode_attention(qi[:, 0], ki, vi, lens, impl="interpret")
    dec_ok = bool(np.allclose(
        np.asarray(dec),
        np.asarray(ref.decode_attention_ref(qi[:, 0], ki, vi, lens)),
        atol=2e-4))
    # paged decode: same query against the same cache re-laid-out as a
    # shuffled page pool + page table must agree with the dense oracle
    pt = 8
    n_p = Si // pt
    perm = np.random.default_rng(0).permutation(n_p)
    kp = jnp.concatenate([ki.reshape(n_p, pt, KVi, dqi)[perm],
                          jnp.full((1, pt, KVi, dqi), 1e4)])   # + sink row
    vp = jnp.concatenate([vi.reshape(n_p, pt, KVi, dvi)[perm],
                          jnp.full((1, pt, KVi, dvi), -1e4)])
    tab = jnp.asarray(np.argsort(perm)[None, :], jnp.int32)
    pag = ops.paged_decode_attention(qi[:, 0], kp, vp, tab, lens,
                                     impl="interpret")
    paged_ok = bool(np.allclose(
        np.asarray(pag),
        np.asarray(ref.decode_attention_ref(qi[:, 0], ki, vi, lens)),
        atol=2e-4))
    # page_copy (COW prefix caching): row-to-row clone incl. sentinel
    # self-copy padding must match the oracle bit-for-bit
    pool = jax.random.normal(ks[3], (2, n_p + 1, pt, KVi, dqi))
    csrc = jnp.array([0, 2, n_p], jnp.int32)
    cdst = jnp.array([3, 1, n_p], jnp.int32)
    copy_ok = bool(np.array_equal(
        np.asarray(ops.page_copy(pool, csrc, cdst, impl="interpret")),
        np.asarray(ref.page_copy_ref(pool, csrc, cdst))))
    if verbose:
        print("name,case,us_per_call")
        for n, c, us in rows:
            print(f"{n},{c},{us:.1f}")
    checks = {
        # pruned-width attention is never slower than full width —
        # interleaved best-of-N measurement (see above); the margin
        # absorbs the residual jitter of an overhead-dominated toy call
        "asym_attention_scales": best_prun <= best_full * 1.3,
        # decode roofline scales linearly with kept rank
        "cache_bytes_linear": abs(rows[5][2] / rows[4][2] - 0.75) < 0.05,
        # Pallas kernels in interpret mode reproduce the jnp oracles
        "interpret_flash_matches_ref": flash_ok,
        "interpret_decode_matches_ref": dec_ok,
        "interpret_paged_decode_matches_ref": paged_ok,
        "interpret_page_copy_matches_ref": copy_ok,
    }
    metrics = {f"{n}/{c}": v for n, c, v in rows}
    return {"rows": rows, "checks": checks, "metrics": metrics}


if __name__ == "__main__":
    print(run()["checks"])
