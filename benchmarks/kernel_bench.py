"""Kernel micro-benchmarks: wall time of the XLA paths on CPU (what this
container can measure) + the decode-cache byte model CLOVER targets.

The Pallas kernels are TPU-targeted (validated in interpret mode by the
test suite; interpret timings are meaningless).  What IS meaningful on
CPU: (a) the XLA chunked fallbacks' relative scaling, (b) the decode
bytes-per-token model at different CLOVER ranks — the paper's actual
claim ("inference becomes memory-bound; pruning shrinks the cache").
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.parallel.hlo import HBM_BW


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) \
        else fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(verbose: bool = True):
    rows = []
    key = jax.random.PRNGKey(0)

    # attention scaling in asymmetric width (the CLOVER shape class)
    B, S, H, KV = 2, 256, 8, 4
    for dq, dv in ((64, 64), (32, 64), (32, 32), (16, 16)):
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, H, dq))
        k = jax.random.normal(ks[1], (B, S, KV, dq))
        v = jax.random.normal(ks[2], (B, S, KV, dv))
        f = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v))
        us = _time(f, q, k, v)
        rows.append(("attention", f"dq{dq}_dv{dv}", us))

    # decode bytes/token at CLOVER ranks (the paper's KV-cache win)
    T, KVh, d = 32768, 8, 128
    for keep in (1.0, 0.75, 0.5, 0.25):
        r = int(d * keep)
        cache_bytes = T * KVh * (r + r) * 2          # bf16 K+V per seq
        t_stream_us = cache_bytes / HBM_BW * 1e6     # one-token roofline
        rows.append(("decode_cache", f"keep{keep:.2f}",
                     round(t_stream_us, 2)))

    # wkv6 chunked scaling in T
    Hh, d = 4, 32
    for T2 in (128, 512, 2048):
        ks = jax.random.split(key, 5)
        r = jax.random.normal(ks[0], (1, Hh, T2, d))
        kk = jax.random.normal(ks[1], (1, Hh, T2, d)) * 0.5
        vv = jax.random.normal(ks[2], (1, Hh, T2, d))
        lw = -jnp.exp(jax.random.normal(ks[3], (1, Hh, T2, d)) * 0.5)
        u = jax.random.normal(ks[4], (Hh, d)) * 0.1
        from repro.models.rwkv import wkv6_chunked
        s0 = jnp.zeros((1, Hh, d, d))
        f = jax.jit(lambda *a: wkv6_chunked(*a))
        us = _time(f, r, kk, vv, lw, u, s0)
        rows.append(("wkv6", f"T{T2}", us))

    if verbose:
        print("name,case,us_per_call")
        for n, c, us in rows:
            print(f"{n},{c},{us:.1f}")
    checks = {
        # pruned-width attention is never slower than full width
        "asym_attention_scales": rows[3][2] <= rows[0][2] * 1.1,
        # decode roofline scales linearly with kept rank
        "cache_bytes_linear": abs(rows[5][2] / rows[4][2] - 0.75) < 0.05,
    }
    return {"rows": rows, "checks": checks}


if __name__ == "__main__":
    print(run()["checks"])
