"""Paper Table 2: CLOVER-S vs LoRA / DoRA / PiSSA at matched budgets.

The paper fine-tunes LLaMA on 8 commonsense tasks; at CPU scale we
fine-tune the pretrained tiny-GPT2 onto a SHIFTED synthetic task (new
pattern library = new "domain") and compare adaptation quality (PPL on
the new domain) at comparable trainable-parameter budgets.

Reproduced claims:
  1. CLOVER-S (full-rank update in every head) adapts better than
     rank-r LoRA at the same (or fewer) trainable params;
  2. PiSSA > LoRA (principal init), CLOVER >= PiSSA;
  3. after merge-back, CLOVER's inference graph is unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import data_for, perplexity, pretrain_base, train
from repro.core import (clover_decompose, merge_clover, PeftConfig,
                        init_adapters, materialize, pissa_residual,
                        count_params, partition)
from repro.models import forward
from repro.optim import AdamWConfig

FT_STEPS = 80


def _train_adapters(params, cfg, pcfg, data, *, steps, lr):
    """Generic adapter-training loop (differentiates the adapter tree)."""
    key = jax.random.PRNGKey(42)
    adapters = init_adapters(params, pcfg, key)
    frozen = (pissa_residual(params, adapters, pcfg)
              if pcfg.method == "pissa" else params)

    from repro.optim import adamw_init, adamw_update
    opt = adamw_init(adapters)
    ocfg = AdamWConfig(lr=lr, weight_decay=0.0)

    def loss_fn(ad, tokens, labels):
        eff = materialize(frozen, ad, pcfg)
        logits, aux = forward(eff, cfg, tokens)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
        return jnp.mean(nll) + sum(aux.values())

    @jax.jit
    def step(ad, opt, tokens, labels):
        loss_val, g = jax.value_and_grad(loss_fn)(ad, tokens, labels)
        ad, opt, _ = adamw_update(g, opt, ad, ocfg)
        return ad, opt, loss_val

    for i in range(steps):
        b = data.batch_at(i)
        adapters, opt, loss_val = step(adapters, opt, jnp.asarray(b["tokens"]),
                                jnp.asarray(b["labels"]))
    return materialize(frozen, adapters, pcfg), count_params(adapters)


def run(verbose: bool = True):
    params, cfg, _ = pretrain_base()
    # the NEW domain: same family, different pattern library
    new_data = data_for(cfg, seed=99)
    before = perplexity(params, cfg, new_data)

    results = {}
    # --- LoRA / DoRA / PiSSA at the CLOVER-matched budget (paper A.2:
    # equal trainable params; rank 16 here == H*d^2*2 + up blocks) ------
    for method in ("lora", "dora", "pissa"):
        pcfg = PeftConfig(method=method, rank=16,
                          alpha=16.0 if method != "pissa" else 1.0)
        lr = 2e-3 if method != "pissa" else 1e-4   # paper: PiSSA ~15x lower
        eff, n_train = _train_adapters(params, cfg, pcfg, new_data,
                                       steps=FT_STEPS, lr=lr)
        results[method] = {"ppl": perplexity(eff, cfg, new_data),
                           "trainable": n_train}

    # --- CLOVER-S -------------------------------------------------------
    p2, cfg2, _ = clover_decompose(params, cfg, peft=True)
    tr, _ = partition(p2)
    p2, _ = train(p2, cfg2, new_data, steps=FT_STEPS, lr=5e-3,
                  peft_mode=True)
    merged, cfg3 = merge_clover(p2, cfg2)
    results["clover"] = {"ppl": perplexity(merged, cfg3, new_data),
                         "trainable": count_params(tr)}

    # --- full fine-tuning reference --------------------------------------
    pf, _ = train(params, cfg, new_data, steps=FT_STEPS, lr=1e-3)
    results["full_ft"] = {"ppl": perplexity(pf, cfg, new_data),
                          "trainable": count_params(params)}

    if verbose:
        print(f"before adaptation: ppl={before:.2f}")
        for k, v in results.items():
            print(f"{k:8s} ppl={v['ppl']:8.2f} trainable={v['trainable']}")
    checks = {
        "all_adapt": all(v["ppl"] < before for v in results.values()),
        "clover_beats_lora": results["clover"]["ppl"]
        < results["lora"]["ppl"],
        "budget_matched": results["clover"]["trainable"]
        <= results["lora"]["trainable"],
    }
    return {"before": before, "results": results, "checks": checks}


if __name__ == "__main__":
    out = run()
    print(out["checks"])
