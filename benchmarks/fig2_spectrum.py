"""Paper Fig. 2: CLOVER spectra concentrate energy; vanilla norms don't.

For every assigned arch family: per-head singular spectra of the Q-K and
V-O products vs sorted per-dim L2-norm products, summarized by the
energy-in-top-25% metric.  The paper's claim: after orthogonalization a
small set of directions carries nearly all the energy (the crossing
point in their plots), enabling aggressive pruning.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from benchmarks.common import pretrain_base
from repro.configs import get_config
from repro.core.analytics import energy_topk, qk_curves, vo_curves
from repro.models import init_lm_params

ARCHS = ("musicgen-large", "stablelm-3b", "jamba-v0.1-52b",
         "internvl2-2b", "qwen2-moe-a2.7b")


def _first_attn(cfg, params):
    j = next(i for i, (m, _) in enumerate(cfg.pattern) if m == "attn")
    return jax.tree.map(lambda a: a[0], params["blocks"][j]["attn"])


def run(verbose: bool = True):
    rows = []
    # trained testbed (real structure, like the paper's checkpoints)
    params, cfg, _ = pretrain_base()
    attn = _first_attn(cfg, params)
    d = cfg.head_dim_
    k = max(1, d // 4)
    S, van = qk_curves(attn, cfg.q_per_kv)
    Sv, vanv = vo_curves(attn, cfg.q_per_kv)
    rows.append({
        "arch": "tiny-gpt2(trained)",
        "qk_clover_top25": float(jnp.mean(energy_topk(S, k))),
        "qk_vanilla_top25": float(jnp.mean(energy_topk(van, k))),
        "vo_clover_top25": float(jnp.mean(energy_topk(Sv, k))),
        "vo_vanilla_top25": float(jnp.mean(energy_topk(vanv, k))),
    })
    # random-init spectra across families (structure of the math itself)
    for name in ARCHS:
        acfg = get_config(name).reduced()
        ap = init_lm_params(acfg, jax.random.PRNGKey(0))
        attn = _first_attn(acfg, ap)
        d = acfg.head_dim_
        k = max(1, d // 4)
        S, van = qk_curves(attn, acfg.q_per_kv)
        rows.append({
            "arch": name,
            "qk_clover_top25": float(jnp.mean(energy_topk(S, k))),
            "qk_vanilla_top25": float(jnp.mean(energy_topk(van, k))),
        })
    if verbose:
        for r in rows:
            print(f"{r['arch']:24s} qk: clover={r['qk_clover_top25']:.3f} "
                  f"vanilla={r['qk_vanilla_top25']:.3f}")
    checks = {
        # orthogonalized spectra always concentrate at least as much
        "clover_concentrates": all(
            r["qk_clover_top25"] >= r["qk_vanilla_top25"] - 1e-6
            for r in rows),
        # on a TRAINED model the gap is material (the paper's key plot)
        "trained_gap": rows[0]["qk_clover_top25"]
        > rows[0]["qk_vanilla_top25"],
    }
    return {"rows": rows, "checks": checks}


if __name__ == "__main__":
    print(run()["checks"])
